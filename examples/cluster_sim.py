"""Pod-fleet scheduling at scale: 200 jobs on a 960-lane cluster.

Uses the discrete-event core (same predictor + policies as everywhere else)
to schedule a Poisson stream of heterogeneous jobs over a large machine —
the 1000-node deployment story.  Reports STP/ANTT/fairness and p50/p99
turnaround under FIFO / MPMax / SRTF / SRTF-Adaptive.

Run:  PYTHONPATH=src python examples/cluster_sim.py [--jobs 200]
"""

import argparse

import numpy as np

from repro.core import Arrival, KernelSpec, evaluate, make_policy, simulate
from repro.core.workload import MAX_BLOCK_SLOTS

#: job archetypes (blocks ~ steps, mean_t ~ step seconds in "cycles")
ARCHETYPES = [
    ("finetune-small", dict(num_blocks=240, max_residency=8,
                            threads_per_block=64, mean_t=2e4, rsd=0.08)),
    ("pretrain-chunk", dict(num_blocks=2400, max_residency=8,
                            threads_per_block=64, mean_t=6e4, rsd=0.05)),
    ("batch-inference", dict(num_blocks=96, max_residency=8,
                             threads_per_block=64, mean_t=8e3, rsd=0.25)),
    ("eval-sweep", dict(num_blocks=480, max_residency=8,
                        threads_per_block=64, mean_t=1.5e4, rsd=0.1)),
]


def build_workload(n_jobs: int, seed: int):
    rng = np.random.default_rng(seed)
    arrivals = []
    t = 0.0
    for i in range(n_jobs):
        t += rng.exponential(3e4)                 # Poisson arrivals
        name, kw = ARCHETYPES[rng.integers(len(ARCHETYPES))]
        spec = KernelSpec(name=f"{name}", **kw)
        arrivals.append(Arrival(spec, t, uid=f"{name}#{i}"))
    return arrivals


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=200)
    ap.add_argument("--lanes", type=int, default=960,
                    help="total lanes = n_sm * slots (120 SMs x 8)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    n_sm = max(1, args.lanes // MAX_BLOCK_SLOTS)

    workload = build_workload(args.jobs, args.seed)
    # solo runtimes (oracle + normalization)
    solo = {}
    for arr in workload:
        if arr.spec.name not in solo:
            res = simulate([Arrival(arr.spec, 0.0, uid="solo#0")],
                           lambda: make_policy("fifo"), n_sm=n_sm,
                           seed=args.seed)
            solo[arr.spec.name] = res.turnaround["solo#0"]

    print(f"cluster: {n_sm} execution units x {MAX_BLOCK_SLOTS} slots "
          f"= {n_sm * MAX_BLOCK_SLOTS} lanes; {args.jobs} jobs")
    for policy in ("fifo", "mpmax", "srtf", "srtf-adaptive"):
        res = simulate(workload, lambda p=policy: make_policy(p),
                       n_sm=n_sm, seed=args.seed, oracle_runtimes=solo)
        ta = res.turnaround
        solo_map = {k: solo[res.name[k]] for k in ta}
        m = evaluate(ta, solo_map)
        sd = sorted(ta[k] / solo_map[k] for k in ta)
        p50 = sd[len(sd) // 2]
        p99 = sd[int(len(sd) * 0.99)]
        print(f"{policy:14s} STP={m.stp:7.2f} ANTT={m.antt:6.2f} "
              f"fair={m.fairness:.3f}  slowdown p50={p50:5.2f} p99={p99:7.2f}")
    print("\nSRTF keeps p99 slowdown bounded as load rises; FIFO's p99 "
          "explodes when short jobs queue behind pretrain chunks.")


if __name__ == "__main__":
    main()
