"""End-to-end training with preemption and restart.

Trains a ~100M-parameter llama-style model for a few hundred steps with the
full substrate stack (deterministic seekable data pipeline, AdamW, async
checkpointing).  Mid-run, the job is preempted (as the SRTF scheduler or a
node failure would); training resumes from the latest checkpoint and the
structural predictor re-estimates the remaining runtime from one
post-restart step (a new "slice", Section 4 of the paper).

Run:  PYTHONPATH=src python examples/preemptive_training.py \
          [--steps 200] [--preempt-at 0.4]
"""

import argparse
import dataclasses
import tempfile
import time

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_arch
from repro.configs.shapes import InputShape
from repro.core.predictor import staircase_runtime
from repro.data import pipeline as data
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.optim import adamw


def model_100m():
    # yi-family block at ~100M params: 2*V*D + L*(4*D*hd*H-ish + 3*D*F)
    return dataclasses.replace(
        get_arch("yi-6b"), arch_id="yi-100m",
        d_model=640, n_layers=10, n_heads=10, n_kv_heads=2, d_ff=1712,
        vocab_size=49152)


def run_segment(cfg, shape, bundle, ck, start, stop, seed, label):
    params = lm.init(cfg, jax.random.PRNGKey(seed))
    opt = adamw.init(params)
    step = 0
    if ck.latest_step() is not None:
        step, state, _ = ck.restore({"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"[{label}] restored checkpoint at step {step}")
    t_sample = None
    for s in range(max(step, start), stop):
        batch = data.batch_for_step(cfg, shape, s)
        t0 = time.perf_counter()
        params, opt, metrics = bundle.fn(params, opt, batch)
        jax.block_until_ready(metrics["nll"])
        dt = time.perf_counter() - t0
        if t_sample is None and s > max(step, start):
            t_sample = dt
            pred = staircase_runtime(stop - s, 1, dt)
            print(f"[{label}] predictor: t={dt:.3f}s/step -> "
                  f"~{pred:.1f}s to finish this segment")
        if s % 20 == 0:
            print(f"[{label}] step={s} nll={float(metrics['nll']):.4f} "
                  f"({dt:.3f}s)")
        if (s + 1) % 25 == 0:
            ck.save(s + 1, {"params": params, "opt": opt}, {"seg": label})
    ck.save(stop, {"params": params, "opt": opt}, {"seg": label})
    ck.wait()
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preempt-at", type=float, default=0.4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = model_100m()
    n = cfg.n_params()
    print(f"model: {n / 1e6:.0f}M params, {cfg.n_layers}L d={cfg.d_model}")
    shape = InputShape("train100m", args.seq, args.batch, "train")
    bundle = build_train_step(
        cfg, shape, mesh=None, remat=False,
        opt_cfg=adamw.OptConfig(lr=6e-4, warmup_steps=20,
                                total_steps=args.steps))

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        cut = int(args.steps * args.preempt_at)
        print(f"== segment 1: steps 0..{cut}, then PREEMPT ==")
        run_segment(cfg, shape, bundle, ck, 0, cut, 0, "seg1")
        print("== preempted (scheduler hand-off / node loss) ==")
        print("== segment 2: resume from checkpoint and finish ==")
        run_segment(cfg, shape, bundle, ck, 0, args.steps, 0, "seg2")
        print("done: training survived preemption with step-granular state.")


if __name__ == "__main__":
    main()
