"""Quickstart: the paper's idea in 60 seconds.

1. Build a reduced model from the zoo and train it for a few steps.
2. Profile ONE step and predict the whole job's runtime with the Staircase
   model (Eq. 1) — structural runtime prediction.
3. Compare the prediction against the actual runtime.

Run:  PYTHONPATH=src python examples/quickstart.py [--arch yi-6b]
"""

import argparse
import time

import jax

from repro.configs import ARCHS, get_arch
from repro.core.predictor import staircase_runtime
from repro.data import pipeline as data
from repro.configs.shapes import InputShape
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    shape = InputShape("quickstart", seq_len=64, global_batch=4, kind="train")
    bundle = build_train_step(cfg, shape, mesh=None, remat=False,
                              opt_cfg=adamw.OptConfig(lr=1e-3,
                                                      warmup_steps=2,
                                                      total_steps=args.steps))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)

    print(f"arch={args.arch} (reduced: {sum(x.size for x in jax.tree.leaves(params)) / 1e6:.1f}M params)")
    t_job0 = time.perf_counter()
    predicted = None
    for step in range(args.steps):
        batch = data.batch_for_step(cfg, shape, step)
        t0 = time.perf_counter()
        params, opt, metrics = bundle.fn(params, opt, batch)
        jax.block_until_ready(metrics["nll"])
        dt = time.perf_counter() - t0
        if step == 1:   # steady-state sample: one "thread block"
            predicted = staircase_runtime(args.steps - 1, 1, dt)
            print(f"[staircase] t={dt * 1e3:.1f} ms/step -> predicted "
                  f"{predicted:.2f}s for the remaining {args.steps - 1} steps")
        print(f"step {step}: nll={float(metrics['nll']):.4f} ({dt * 1e3:.0f} ms)")
    actual = time.perf_counter() - t_job0
    if predicted:
        # compare against the steady-state portion (exclude step 0 = compile)
        print(f"[staircase] total wall {actual:.2f}s (step 0 is JIT "
              f"compile); prediction for the sampled portion was "
              f"{predicted:.2f}s — see benchmarks/fig04 for the calibrated "
              "accuracy study")


if __name__ == "__main__":
    main()
