"""Concurrent serving under preemptive SRTF vs FIFO — the paper's headline
scenario on real JAX computation.

A long decode job (many chunks) is already running when a short job
arrives.  FIFO serializes the short job behind the long one; SRTF samples
the newcomer's first chunk on one lane (structural runtime prediction),
learns it is shorter, and hands the machine over — preempting only at
chunk boundaries, exactly like the paper's thread-block-granular
preemption.

Run:  PYTHONPATH=src python examples/concurrent_serving.py
"""

from repro.configs import get_arch
from repro.core.executor import LaneExecutor
from repro.core.jobs import make_serve_job
from repro.core.metrics import evaluate
from repro.core.policies import make_policy

LANES = 4


def build():
    return [
        make_serve_job(get_arch("minicpm3-4b").reduced(), "long-job",
                       blocks=40, tokens_per_block=16, batch=2,
                       prompt_len=16, max_residency=LANES, seed=0),
        make_serve_job(get_arch("yi-6b").reduced(), "short-job",
                       blocks=5, tokens_per_block=16, batch=2,
                       prompt_len=16, max_residency=LANES,
                       arrival=0.01, seed=1),
    ]


def solo_runtimes():
    out = {}
    for job in build():
        res = LaneExecutor([job], make_policy("fifo"), n_lanes=LANES).run()
        out[job.name] = next(iter(res.values())).turnaround
    return out


def main():
    solo = solo_runtimes()
    print(f"solo runtimes: " +
          ", ".join(f"{k}={v:.2f}s" for k, v in solo.items()))
    for policy in ("fifo", "srtf", "srtf-adaptive"):
        ex = LaneExecutor(build(), make_policy(policy), n_lanes=LANES)
        ex.oracle_runtimes.update(solo)
        results = ex.run()
        ta = {k: r.turnaround for k, r in results.items()}
        m = evaluate(ta, {k: solo[k.rsplit("#", 1)[0]] for k in ta})
        detail = ", ".join(f"{k}={v:.2f}s" for k, v in sorted(ta.items()))
        print(f"{policy:14s} STP={m.stp:.2f} ANTT={m.antt:.2f} "
              f"fairness={m.fairness:.2f}   [{detail}]")
    print("\nExpected: SRTF rescues the short job's turnaround at a tiny "
          "cost to the long job (paper Fig. 12 / Table 5).")


if __name__ == "__main__":
    main()
