"""Sweep worker — one node of the distributed sweep farm.

Connects to a :class:`repro.core.distrib.QueueDispatcher`, handshakes
(protocol version + code fingerprints + the run's queued-key manifest),
then pulls chunks of DES cells and runs them through this process's
long-lived compiled engine until the dispatcher says shutdown.  Each
chunk runs as one `run_des_chunk` call — adjacent same-body policy
siblings share a staging prototype and results take the lean terminal
scatter (DESIGN.md Section 13) — so per-cell Python boundary cost is
paid once per chunk, not once per cell.  The
dispatcher spawns local workers itself; this entry point exists for
*remote* fan-out — run it on any machine that shares the code tree::

    PYTHONPATH=src python -m repro.launch.worker --connect host:5055 \
        --cache-dir /scratch/sweep_cache

With ``--cache-dir`` the worker keeps a local record cache: queued keys it
already holds are *prefilled* to the dispatcher before any cell runs, and
every computed chunk is persisted locally as a packfile — so a farm warms
across runs and a re-run ships bytes, not simulations.  Safe by
construction: cache keys are content-addressed and host-independent
(DESIGN.md Section 5), and the fingerprint handshake refuses a dispatcher
running different result-determining code.

``--die-after N`` hard-exits the process after N computed cells — failure
injection so the re-dispatch path stays testable end to end.

Exit codes: 0 clean shutdown, 1 dispatcher vanished, 3 fingerprint
mismatch.
"""

from __future__ import annotations

import argparse
import sys

from repro.core.distrib import worker_serve
from repro.core.sweep import code_fingerprints


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--connect", required=True, metavar="HOST:PORT",
                    help="dispatcher address (from the parent sweep run)")
    ap.add_argument("--cache-dir", default=None,
                    help="local record cache: prefill queued keys from it "
                         "and persist computed chunks into it")
    ap.add_argument("--heartbeat", type=float, default=1.0,
                    help="seconds between liveness frames (the dispatcher "
                         "may override via the welcome frame)")
    ap.add_argument("--connect-timeout", type=float, default=10.0,
                    help="keep retrying the connect this long")
    ap.add_argument("--die-after", type=int, default=None, metavar="N",
                    help="failure injection: hard-exit after computing N "
                         "cells (never send their result frame)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-chunk progress lines")
    args = ap.parse_args(argv)

    host, _, port = args.connect.rpartition(":")
    if not host or not port.isdigit():
        ap.error(f"--connect wants HOST:PORT, got {args.connect!r}")

    def log(msg: str) -> None:
        if not args.quiet:
            print(f"[worker] {msg}", flush=True)

    return worker_serve(
        host, int(port),
        cache_dir=args.cache_dir,
        fingerprints=code_fingerprints(),
        heartbeat_s=args.heartbeat,
        connect_timeout_s=args.connect_timeout,
        die_after=args.die_after,
        log=log,
    )


if __name__ == "__main__":
    sys.exit(main())
