"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch JAX device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import; real deployments get the same meshes from the TPU topology.

Mesh axes:
* ``pod``   — data parallelism across pods (slow inter-pod links carry only
  the gradient all-reduce / cross-pod job migration traffic),
* ``data``  — intra-pod data parallelism / FSDP shard axis,
* ``model`` — tensor/expert/context parallel axis (16-way).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (dry-run) or run on a real pod")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for CPU integration tests (requires forced device count)."""
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def batch_axes_of(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
