"""Concurrent serving driver — the paper's scenario on the serving side.

Multiple decode jobs (request batches with different generation lengths)
share the machine under a thread-block-style scheduling policy.  Jobs are
submitted **asynchronously** through the multi-tenant
:class:`repro.core.scheduler_service.SchedulerService`: each arrives
``--stagger`` seconds after the previous one while the machine is already
running — the dynamic-arrival path the production story needs, not a fixed
up-front job list.  The structural predictor profiles each job's first
decode chunk and SRTF runs the predicted-shortest job first, preempting at
chunk boundaries; STP/ANTT are reported per tenant (one tenant per arch).

Key convention: job keys are ``{arch}#{order}`` — the text before the last
``#`` is the arch/tenant name (recover it with ``key.rsplit("#", 1)[0]``),
the number after is the machine-wide submission order.  Solo baselines are
measured once per distinct (arch, blocks) item and mapped to job keys at
submission time.

Submission pacing comes from the scenario registry
(:mod:`repro.core.scenarios`) when ``--scenario`` is given: the named
arrival process (``poisson-open`` open-loop streams, ``bursty`` ON/OFF
traffic, ...) is sampled at ``--seed`` and its arrival times, scaled by
``--time-scale`` seconds/cycle, pace the async submissions.  Without it,
jobs arrive every ``--stagger`` seconds (the paper's staggered launches).

With ``--scenario-kernels`` the scenario supplies the *jobs* too, not just
the pacing: its first workload's arrivals are bridged to jobs of real
jitted synthetic blocks (:func:`repro.core.scenarios.executor_workload` —
the same bridge executor sweeps use), and solo baselines go through the
content-addressed sweep cache
(:func:`repro.core.sweep.solo_runtime_executor_cached`), so repeated
serving runs reuse them.  Baselines are keyed by spec content plus the
pool width they were measured under, and ``--max-blocks`` rewrites the
specs before bridging — so they are shared with executor *sweeps* only
when the grids match (e.g. ``--max-blocks 0``, or a scenario whose
declared grids are already small) AND the sweep ran serially
(``--jobs 1``): a ``--jobs > 1`` sweep caches pool-contention-measured
baselines under a different key, which this serial frontend deliberately
does not reuse.

**Closed-loop driver** (``--closed-loop N``): instead of pacing
submissions open-loop, ``N`` client coroutines each hold one job in
flight — submit, await completion, optionally think ``Exp(--think)``
seconds, resubmit — until ``--requests`` total jobs complete.  This
exercises the async service at a *target concurrency* (the serving mirror
of the ``think-time``/``mgk-closed`` sweep scenarios): offered load
tracks service capacity, which is where preemptive SRTF earns or loses
its win.  Reported metrics are the steady-state queueing view
(:func:`repro.core.metrics.evaluate_queueing` over machine-time
arrivals/finishes) plus the usual STP/ANTT.

Example::

    PYTHONPATH=src python -m repro.launch.serve \
        --jobs yi-6b:24,minicpm3-4b:6 --policy srtf --compare-fifo
    PYTHONPATH=src python -m repro.launch.serve \
        --jobs yi-6b:8,minicpm3-4b:4,yi-6b:8 --scenario poisson-open \
        --time-scale 2e-7 --policy srtf
    PYTHONPATH=src python -m repro.launch.serve \
        --scenario poisson-open --scenario-kernels --policy srtf \
        --time-scale 1e-6
    PYTHONPATH=src python -m repro.launch.serve \
        --jobs yi-6b:6,minicpm3-4b:4 --closed-loop 3 --requests 12 \
        --policy srtf --compare-fifo
"""

from __future__ import annotations

import argparse
import asyncio
import itertools
from typing import Callable, Dict, List, Tuple

from repro.configs import get_arch
from repro.core.executor import LaneExecutor
from repro.core.jobs import make_serve_job
from repro.core.metrics import evaluate, evaluate_queueing
from repro.core.policies import make_policy
from repro.core.scenarios import (
    executor_job,
    make_scenario,
    open_loop_names,
    submission_offsets,
)
from repro.core.scheduler_service import SchedulerService
from repro.core.sweep import solo_runtime_executor_cached
from repro.core.workload import Arrival, scaled_spec


def parse_jobs(args) -> List[Tuple[str, int]]:
    out = []
    for item in args.jobs.split(","):
        arch_id, _, blocks = item.partition(":")
        out.append((arch_id, int(blocks or 8)))
    return out


def build_job(args, arch_id: str, blocks: int, seed: int):
    return make_serve_job(
        get_arch(arch_id).reduced(), arch_id, blocks=blocks,
        tokens_per_block=args.tokens_per_block, batch=args.batch,
        prompt_len=args.prompt_len, max_residency=args.lanes,
        seed=seed, tenant=arch_id)


def scenario_arrivals(args):
    """First-workload arrivals of the ``--scenario`` arrival process.

    Grids are capped at ``--max-blocks`` before bridging: scenario specs
    declare simulator-scale grids (thousands of blocks), and every bridged
    block is a real measured execution — a serving demo wants seconds, not
    hours.  The cap rescales ``num_blocks`` only; the per-block cost and
    kernel mix stay scenario-declared.
    """
    scn = make_scenario(args.scenario, seed=args.seed)
    workloads = scn.workloads()
    if not workloads:
        raise ValueError(f"scenario {scn.name!r} produced no workloads")
    arrivals = workloads[0][1]
    cap = args.max_blocks
    if cap:
        arrivals = [
            Arrival(scaled_spec(a.spec,
                                num_blocks=min(a.spec.num_blocks, cap)),
                    a.time, uid=a.uid)
            for a in arrivals
        ]
    return arrivals


def measure_solo(args) -> Dict[object, float]:
    """Measured isolated runtime per distinct job — the STP/ANTT baseline.

    One warmed job object per distinct (arch, blocks) item, measured once
    and reused by every policy run: rebuilding a job per policy would
    re-trace and re-JIT its step functions and re-pay prefill, so the
    baseline would drift between the ``--policy`` and ``--compare-fifo``
    runs of the same invocation.  Keyed by (arch, blocks), not arch alone:
    the same arch listed with a different decode length is a different
    job and needs its own baseline.

    With ``--scenario-kernels`` the baselines are keyed by the scenario's
    kernel specs and go through the content-addressed sweep cache, shared
    with executor sweeps of the same scenario.
    """
    if args.scenario_kernels:
        return {a.spec: solo_runtime_executor_cached(
                    a.spec, n_lanes=args.lanes, cache_dir=args.cache_dir)
                for a in scenario_arrivals(args)}
    solo: Dict[object, float] = {}
    for arch_id, blocks in parse_jobs(args):
        if (arch_id, blocks) in solo:
            continue                  # one baseline per distinct item
        job = build_job(args, arch_id, blocks, args.seed)
        res = LaneExecutor([job], make_policy("fifo"),
                           n_lanes=args.lanes).run()
        solo[(arch_id, blocks)] = next(iter(res.values())).turnaround
    return solo


def submission_schedule(args) -> List[float]:
    """Per-job submission offsets (seconds since the first submission).

    Default: a fixed ``--stagger`` gap, the paper's staggered launches.
    With ``--scenario`` the offsets come from the named arrival process in
    the scenario registry (e.g. ``poisson-open`` for shared-cloud open-loop
    streams), scaled by ``--time-scale`` seconds per cycle.
    """
    n = len(parse_jobs(args))
    if not args.scenario:
        return [i * args.stagger for i in range(n)]
    return submission_offsets(args.scenario, n, time_scale=args.time_scale,
                              seed=args.seed)


def submission_plan(args, solo: Dict[object, float]
                    ) -> List[Tuple[float, Callable, str, float]]:
    """Per-submission ``(offset_s, job_factory, tenant, solo_runtime)``.

    The default path builds arch-model jobs from ``--jobs``; with
    ``--scenario-kernels`` the scenario's own arrivals are bridged to
    synthetic real-jitted jobs, keeping its kernel mix and arrival times.
    """
    if args.scenario_kernels:
        return [
            (a.time * args.time_scale,
             lambda a=a: executor_job(a, n_lanes=args.lanes,
                                      time_scale=args.time_scale),
             a.spec.name, solo[a.spec])
            for a in scenario_arrivals(args)
        ]
    offsets = submission_schedule(args)
    return [
        (offsets[i],
         lambda arch_id=arch_id, blocks=blocks, i=i: build_job(
             args, arch_id, blocks, args.seed + i),
         arch_id, solo[(arch_id, blocks)])
        for i, (arch_id, blocks) in enumerate(parse_jobs(args))
    ]


async def run_service(args, policy: str, solo: Dict[object, float]):
    """One policy run: staggered async submissions against a live service."""
    service = SchedulerService(n_lanes=args.lanes, policy=policy,
                               predictor=args.predictor)
    plan = submission_plan(args, solo)
    try:
        handles = []
        solo_by_key: Dict[str, float] = {}
        t0 = asyncio.get_event_loop().time()
        for offset, job_factory, tenant, solo_rt in plan:
            delay = t0 + offset - asyncio.get_event_loop().time()
            if delay > 0:
                await asyncio.sleep(delay)  # late arrival, busy machine
            handle = service.submit(job_factory(), tenant=tenant,
                                    solo_runtime=solo_rt)
            solo_by_key[handle.key] = solo_rt
            handles.append(handle)
        results = [await h.result() for h in handles]
    finally:
        service.close()

    turnaround = {r.key: r.turnaround for r in results}
    m = evaluate(turnaround, solo_by_key)
    print(f"[serve] policy={policy:14s} STP={m.stp:.3f} ANTT={m.antt:.3f} "
          f"fairness={m.fairness:.3f}")
    print_tenant_report(service)
    for r in sorted(results, key=lambda r: r.key):
        print(f"    {r.key}: turnaround={r.turnaround:.2f}s")
    return m


def print_tenant_report(service: SchedulerService) -> None:
    for tenant, info in sorted(service.tenant_report().items()):
        tm = info["metrics"]
        if tm is not None:
            print(f"    tenant={tenant}: jobs={info['jobs']} "
                  f"STP={tm['stp']:.3f} ANTT={tm['antt']:.3f}")


def closed_loop_items(args, solo: Dict[object, float]):
    """The job menu closed-loop clients cycle through: per-item
    ``(make(i) -> job, tenant, solo_runtime)``.

    Arrival *times* are deliberately absent — in closed-loop mode pacing
    comes from completions (and ``--think``), not from a scenario clock —
    so scenario-kernel jobs are bridged at arrival time 0 and submitted
    whenever a client's previous job finishes.
    """
    if args.scenario_kernels:
        return [
            (lambda i, a=a: executor_job(
                Arrival(a.spec, 0.0), n_lanes=args.lanes,
                time_scale=args.time_scale),
             a.spec.name, solo[a.spec])
            for a in scenario_arrivals(args)
        ]
    return [
        (lambda i, arch_id=arch_id, blocks=blocks: build_job(
            args, arch_id, blocks, args.seed + i),
         arch_id, solo[(arch_id, blocks)])
        for arch_id, blocks in parse_jobs(args)
    ]


async def run_service_closed_loop(args, policy: str,
                                  solo: Dict[object, float]):
    """One closed-loop policy run: ``--closed-loop`` concurrent clients,
    each looping submit -> await -> think, against a live service."""
    import numpy as np

    service = SchedulerService(n_lanes=args.lanes, policy=policy,
                               predictor=args.predictor)
    items = closed_loop_items(args, solo)
    counter = itertools.count()
    results = []
    solo_by_key: Dict[str, float] = {}

    async def client(cid: int) -> None:
        rng = np.random.default_rng((args.seed, cid))
        while True:
            i = next(counter)
            if i >= args.requests:
                return
            if args.think > 0.0:
                await asyncio.sleep(float(rng.exponential(args.think)))
            make, tenant, solo_rt = items[i % len(items)]
            handle = service.submit(make(i), tenant=tenant,
                                    solo_runtime=solo_rt)
            solo_by_key[handle.key] = solo_rt
            results.append(await handle.result())

    try:
        await asyncio.gather(
            *(client(c) for c in range(args.closed_loop)))
    finally:
        service.close()

    # Machine-time (virtual-clock) arrivals/finishes: the queueing view is
    # of the machine under load, not of wall-clock client latency.
    q = evaluate_queueing({r.key: r.arrival for r in results},
                          {r.key: r.finish for r in results},
                          end_time=service.machine_time,
                          warmup_frac=args.warmup_frac)
    m = evaluate({r.key: r.turnaround for r in results}, solo_by_key)
    print(f"[serve] policy={policy:14s} closed-loop={args.closed_loop} "
          f"requests={q.n_completed} mean_rt={q.mean_response:.3f}s "
          f"p95_rt={q.p95_response:.3f}s in_system={q.mean_in_system:.2f} "
          f"xput={q.throughput:.2f}/s")
    print(f"    STP={m.stp:.3f} ANTT={m.antt:.3f} "
          f"fairness={m.fairness:.3f}")
    print_tenant_report(service)
    return q


def run_policy(args, policy: str, solo: Dict[Tuple[str, int], float]):
    return asyncio.run(run_service(args, policy, solo))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", default="yi-6b:24,minicpm3-4b:6",
                    help="arch:decode_blocks,...")
    ap.add_argument("--policy", default="srtf")
    ap.add_argument("--predictor", default="simple-slicing",
                    help="registered predictor name (simple-slicing, ewma)")
    ap.add_argument("--compare-fifo", action="store_true")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens-per-block", type=int, default=8)
    ap.add_argument("--stagger", type=float, default=0.02,
                    help="seconds between async job submissions")
    ap.add_argument("--closed-loop", type=int, default=0,
                    help="drive the service closed-loop at this target "
                         "concurrency (N clients, each resubmitting when "
                         "its job finishes; 0 = open-loop pacing)")
    ap.add_argument("--requests", type=int, default=12,
                    help="total jobs a closed-loop run completes")
    ap.add_argument("--think", type=float, default=0.0,
                    help="mean Exp think seconds between a closed-loop "
                         "client's completion and its next submission")
    ap.add_argument("--warmup-frac", type=float, default=0.0,
                    help="fraction of the closed-loop window trimmed "
                         "before computing queueing metrics")
    # trace-replay is excluded (it needs a path/trace the CLI doesn't
    # take); closed-loop scenarios are excluded because this flag paces a
    # fixed submission stream — closed-loop serving is --closed-loop.
    ap.add_argument("--scenario", default=None,
                    choices=sorted(set(open_loop_names()) - {"trace-replay"}),
                    help="draw submission offsets from this registered "
                         "arrival process instead of a fixed stagger "
                         "(e.g. poisson-open, bursty)")
    ap.add_argument("--time-scale", type=float, default=1e-6,
                    help="seconds of wall time per scenario cycle "
                         "(with --scenario)")
    ap.add_argument("--scenario-kernels", action="store_true",
                    help="with --scenario: take the jobs themselves from "
                         "the scenario via the executor bridge (synthetic "
                         "real-jitted blocks) instead of --jobs archs")
    ap.add_argument("--cache-dir", default="artifacts/sweep_cache",
                    help="sweep cache for --scenario-kernels solo "
                         "baselines (shared with jobs=1 executor sweeps)")
    ap.add_argument("--max-blocks", type=int, default=16,
                    help="cap scenario grids at this many real blocks per "
                         "job (with --scenario-kernels; 0 = uncapped)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.scenario_kernels and not args.scenario:
        ap.error("--scenario-kernels requires --scenario")
    solo = measure_solo(args)
    if args.closed_loop > 0:
        q = asyncio.run(run_service_closed_loop(args, args.policy, solo))
        if args.compare_fifo and args.policy != "fifo":
            qf = asyncio.run(run_service_closed_loop(args, "fifo", solo))
            print(f"[serve] {args.policy} vs fifo at concurrency "
                  f"{args.closed_loop}: mean_rt "
                  f"{qf.mean_response / q.mean_response:.2f}x, p95_rt "
                  f"{qf.p95_response / q.p95_response:.2f}x")
        return
    m = run_policy(args, args.policy, solo)
    if args.compare_fifo and args.policy != "fifo":
        mf = run_policy(args, "fifo", solo)
        print(f"[serve] {args.policy} vs fifo: STP {m.stp / mf.stp:.2f}x, "
              f"ANTT {mf.antt / m.antt:.2f}x")


if __name__ == "__main__":
    main()
