"""Concurrent serving driver — the paper's scenario on the serving side.

Multiple decode jobs (request batches with different generation lengths)
share the machine under a thread-block-style scheduling policy.  The Simple
Slicing predictor profiles each job's first decode chunk and SRTF runs the
predicted-shortest job first, preempting at chunk boundaries.

Example::

    PYTHONPATH=src python -m repro.launch.serve \
        --jobs yi-6b:24,minicpm3-4b:6 --policy srtf --compare-fifo
"""

from __future__ import annotations

import argparse

from repro.configs import ARCHS, get_arch
from repro.core.executor import LaneExecutor
from repro.core.jobs import make_serve_job
from repro.core.metrics import evaluate
from repro.core.policies import make_policy


def build_jobs(args):
    jobs = []
    for i, item in enumerate(args.jobs.split(",")):
        arch_id, _, blocks = item.partition(":")
        cfg = get_arch(arch_id).reduced()
        jobs.append(make_serve_job(
            cfg, arch_id, blocks=int(blocks or 8),
            tokens_per_block=args.tokens_per_block, batch=args.batch,
            prompt_len=args.prompt_len, max_residency=args.lanes,
            seed=args.seed + i, arrival=0.02 * i))
    return jobs


def run_policy(args, policy: str):
    solo = {}
    for item in args.jobs.split(","):
        arch_id, _, blocks = item.partition(":")
        job = make_serve_job(
            get_arch(arch_id).reduced(), arch_id, blocks=int(blocks or 8),
            tokens_per_block=args.tokens_per_block, batch=args.batch,
            prompt_len=args.prompt_len, max_residency=args.lanes,
            seed=args.seed)
        res = LaneExecutor([job], make_policy("fifo"),
                           n_lanes=args.lanes).run()
        solo[arch_id] = next(iter(res.values())).turnaround
    ex = LaneExecutor(build_jobs(args), make_policy(policy),
                      n_lanes=args.lanes)
    ex.oracle_runtimes.update(solo)
    results = ex.run()
    turnaround = {k: r.turnaround for k, r in results.items()}
    solo_map = {k: solo[k.rsplit("#", 1)[0]] for k in turnaround}
    m = evaluate(turnaround, solo_map)
    print(f"[serve] policy={policy:14s} STP={m.stp:.3f} ANTT={m.antt:.3f} "
          f"fairness={m.fairness:.3f}")
    for k, r in sorted(results.items()):
        print(f"    {k}: turnaround={r.turnaround:.2f}s")
    return m


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", default="yi-6b:24,minicpm3-4b:6",
                    help="arch:decode_blocks,...")
    ap.add_argument("--policy", default="srtf")
    ap.add_argument("--compare-fifo", action="store_true")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens-per-block", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    m = run_policy(args, args.policy)
    if args.compare_fifo and args.policy != "fifo":
        mf = run_policy(args, "fifo")
        print(f"[serve] {args.policy} vs fifo: STP {m.stp / mf.stp:.2f}x, "
              f"ANTT {mf.antt / m.antt:.2f}x")


if __name__ == "__main__":
    main()
