import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record the compiled artifact's roofline terms.

The two lines above MUST stay the first statements in this module — JAX
locks the device count on first init, and the dry-run (and only the
dry-run) needs 512 placeholder host devices.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
        [--out artifacts/dryrun]

Each cell writes ``<out>/<mesh>/<arch>__<shape>.json`` with:
  flops / bytes from ``compiled.cost_analysis()`` (per-device, post-SPMD),
  per-device memory from ``compiled.memory_analysis()``,
  per-collective-op byte totals parsed from the optimized HLO,
  lowering and compile wall times.
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax  # noqa: F401  -- deliberately first: see the XLA_FLAGS note above

from repro.configs import ARCHS, SHAPES, get_arch
from repro.configs.shapes import SHAPE_ORDER, shape_applicable
from repro.core.predictor import staircase_runtime
from repro.core.scenarios import make_scenario, open_loop_names
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step, build_unit_probes

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in optimized HLO
    (per-device, since post-SPMD shapes are per-device)."""
    out = {c: {"bytes": 0, "count": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+(.*?)\s+(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(", line)
        if not m:
            continue
        kind = m.group(2)
        if m.group(3) == "-done":
            continue  # avoid double-counting async pairs
        out[kind]["bytes"] += _shape_bytes(m.group(1))
        out[kind]["count"] += 1
    return out


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: Path, verbose: bool = True) -> dict:
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
    }
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        record["status"] = "skipped"
        record["why"] = why
        _write(out_dir, mesh_name, arch_id, shape_name, record)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    bundle = build_step(cfg, shape, mesh=mesh)
    with mesh:
        lowered = bundle.fn.lower(*bundle.arg_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    record["status"] = "ok"
    record["lower_s"] = round(t_lower, 2)
    record["compile_s"] = round(t_compile, 2)

    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        record["cost_analysis"] = {
            k: v for k, v in cost.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed", "optimal_seconds")
                or k.startswith("bytes accessed"))
        }
    except Exception as e:  # pragma: no cover
        record["cost_analysis_error"] = str(e)

    try:
        mem = compiled.memory_analysis()
        record["memory_analysis"] = {
            attr: getattr(mem, attr)
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes")
            if hasattr(mem, attr)
        }
    except Exception as e:  # pragma: no cover
        record["memory_analysis_error"] = str(e)

    try:
        hlo = compiled.as_text()
        record["collectives"] = collective_bytes(hlo)
        record["hlo_size_chars"] = len(hlo)
    except Exception as e:  # pragma: no cover
        record["collectives_error"] = str(e)

    # Per-layer probes: XLA cost analysis counts scan bodies once, so the
    # roofline reconstructs totals as main + (repeats-1) * probe per stage.
    record["probes"] = {}
    try:
        probes = build_unit_probes(cfg, shape, mesh=mesh)
        for key, (bundle, repeats) in probes.items():
            with mesh:
                pc = bundle.fn.lower(*bundle.arg_specs).compile()
            cost = pc.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            try:
                pmem = pc.memory_analysis()
                probe_mem = int(getattr(pmem, "temp_size_in_bytes", 0))
            except Exception:
                probe_mem = -1
            record["probes"][key] = {
                "repeats": repeats,
                "flops": cost.get("flops", 0.0),
                "bytes_accessed": cost.get("bytes accessed", 0.0),
                "collectives": collective_bytes(pc.as_text()),
                "temp_bytes": probe_mem,
            }
    except Exception as e:  # pragma: no cover
        record["probe_error"] = f"{type(e).__name__}: {e}"

    _write(out_dir, mesh_name, arch_id, shape_name, record)
    if verbose:
        ma = record.get("memory_analysis", {})
        # donated outputs alias argument space: count live bytes once
        mem_gb = (ma.get("argument_size_in_bytes", 0)
                  + ma.get("temp_size_in_bytes", 0)
                  + ma.get("output_size_in_bytes", 0)
                  - ma.get("alias_size_in_bytes", 0)) / 2 ** 30
        coll = sum(v["bytes"] for v in record.get("collectives", {}).values())
        print(f"[dryrun] {mesh_name} {arch_id} {shape_name}: "
              f"compile={t_compile:.1f}s "
              f"flops/dev={record.get('cost_analysis', {}).get('flops', 0):.3g} "
              f"mem/dev={mem_gb:.2f}GiB coll/dev={coll/2**30:.3f}GiB",
              flush=True)
    return record


def _write(out_dir: Path, mesh_name: str, arch: str, shape: str,
           record: dict) -> None:
    d = out_dir / mesh_name
    d.mkdir(parents=True, exist_ok=True)
    with open(d / f"{arch}__{shape}.json", "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)


def _scenario_order(cells: list, scenario: str, seed: int) -> list:
    """Order compile cells as a submission stream from the scenario registry.

    The dry-run sweep is this driver's workload: each (arch, shape) cell
    is one submitted job, and the named scenario's seeded RNG stream
    (:meth:`repro.core.scenarios.Scenario.rng` — process-stable) draws the
    submission order.  Unlike the default nested arch x shape loop this
    interleaves architectures, so early cells give diverse signal and the
    same ``--scenario --seed`` pair replays the same stream anywhere.
    """
    scn = make_scenario(scenario, seed=seed)
    order = scn.rng(len(cells)).permutation(len(cells))
    return [cells[i] for i in order]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), help="single arch")
    ap.add_argument("--shape", choices=list(SHAPE_ORDER), help="single shape")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh (default 16x16)")
    ap.add_argument("--out", default="artifacts/dryrun", type=Path)
    ap.add_argument("--skip-existing", action="store_true")
    # trace-replay is excluded (it needs a path/trace the CLI doesn't
    # take); closed-loop scenarios are excluded because compile cells are
    # ordered by a fixed, materialized submission stream.
    ap.add_argument("--scenario", default=None,
                    choices=sorted(set(open_loop_names()) - {"trace-replay"}),
                    help="order the compile cells as a submission stream "
                         "drawn from this registered arrival process "
                         "(deterministic per --seed)")
    ap.add_argument("--seed", type=int, default=0,
                    help="scenario seed (with --scenario)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in sorted(ARCHS):
            for shape in SHAPE_ORDER:
                cells.append((arch, shape))
    elif args.arch and args.shape:
        cells.append((args.arch, args.shape))
    elif args.arch:
        cells = [(args.arch, s) for s in SHAPE_ORDER]
    else:
        ap.error("pass --all or --arch [--shape]")

    if args.scenario:
        cells = _scenario_order(cells, args.scenario, args.seed)

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    failures = 0
    done = 0
    for i, (arch, shape) in enumerate(cells):
        path = args.out / mesh_name / f"{arch}__{shape}.json"
        if args.skip_existing and path.exists():
            st = json.loads(path.read_text()).get("status")
            if st in ("ok", "skipped"):
                print(f"[dryrun] skip existing {arch} {shape} ({st})",
                      flush=True)
                continue
        t_cell0 = time.time()
        try:
            run_cell(arch, shape, args.multi_pod, args.out)
            done += 1
            remaining = len(cells) - i - 1
            if done == 1 and remaining:
                # Structural runtime prediction for the sweep itself: the
                # cells are this driver's homogeneous "blocks" (Eq. 1 with
                # R=1 compile lane) — profile one, extrapolate the rest
                # (an upper bound: later cells may be skipped).
                t_cell = time.time() - t_cell0
                pred = staircase_runtime(remaining, 1, t_cell)
                print(f"[dryrun] predictor: t={t_cell:.1f}s/cell -> "
                      f"<={pred:.0f}s for the up to {remaining} remaining "
                      f"cells", flush=True)
        except Exception:
            failures += 1
            print(f"[dryrun] FAILED {arch} {shape}", flush=True)
            traceback.print_exc()
            _write(args.out, mesh_name, arch, shape, {
                "arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "failed", "error": traceback.format_exc(),
            })
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
