"""Step-function builders: jitted, sharded train / prefill / decode steps.

These are the units the thread-block-style scheduler (repro.core) dispatches:
a job is N repetitions of one of these steps, so profiling the first
invocation (the paper's structural runtime prediction) predicts the job.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape
from repro.data import pipeline as data_pipeline
from repro.models import lm
from repro.optim import adamw
from repro.sharding.annotate import NULL_SHARDER, Sharder, profile_for
from repro.sharding.specs import (
    batch_shardings,
    cache_shardings,
    param_shardings,
)

from .mesh import batch_axes_of


@dataclass
class StepBundle:
    """A lowered-or-lowerable step function plus its arg specs/shardings."""

    fn: object                    # jitted callable
    arg_specs: Tuple              # ShapeDtypeStructs for .lower()
    kind: str


def param_struct(cfg: ArchConfig):
    return jax.eval_shape(lambda: lm.init(cfg, jax.random.PRNGKey(0)))


def _sharder(mesh, cfg) -> object:
    if mesh is None:
        return NULL_SHARDER
    return Sharder(mesh, profile_for(cfg), batch_axes_of(mesh),
                   full_dp=cfg.moe is None)


def _replicated(mesh):
    return NamedSharding(mesh, P()) if mesh is not None else None


#: Gradient-accumulation factor per arch for the train_4k cell: splits the
#: global batch into M sequential microbatches, dividing activation-linked
#: temp memory by ~M at identical tokens/step (EXPERIMENTS.md §Perf).
TRAIN_MICROBATCHES = {
    "dbrx-132b": 4,     # MoE keeps the CP plan; memory needs grad accumulation
    "mamba2-2.7b": 4,   # only when the full-mesh DP plan cannot engage
}


def train_microbatches(cfg: ArchConfig, shape: InputShape, mesh) -> int:
    """Gradient-accumulation factor: 1 when the full-mesh DP plan engages
    (it already minimizes activation memory), else the per-arch table."""
    if mesh is None:
        return 1
    total = 1
    for n in mesh.shape.values():
        total *= n
    if cfg.moe is None and shape.global_batch % total == 0:
        return 1
    M = TRAIN_MICROBATCHES.get(cfg.arch_id, 1)
    return M if shape.global_batch % max(M, 1) == 0 else 1


# ------------------------------------------------------------------- train
def build_train_step(cfg: ArchConfig, shape: InputShape, mesh=None,
                     opt_cfg: adamw.OptConfig = adamw.OptConfig(),
                     backend: str = "xla", remat: bool = True,
                     microbatches: Optional[int] = None) -> StepBundle:
    shard = _sharder(mesh, cfg)
    M = microbatches if microbatches is not None \
        else train_microbatches(cfg, shape, mesh)
    if shape.global_batch % max(M, 1):
        M = 1

    def mb_loss(p, mb):
        return lm.loss_fn(cfg, p, mb, backend=backend, shard=shard,
                          remat=remat)

    def train_step(params, opt_state, batch):
        if M <= 1:
            (_, metrics), grads = jax.value_and_grad(
                mb_loss, has_aux=True)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda a: a.reshape((M, a.shape[0] // M) + a.shape[1:]),
                batch)

            def body(acc, mb):
                (_, metrics), g = jax.value_and_grad(
                    mb_loss, has_aux=True)(params, mb)
                acc = jax.tree.map(
                    lambda x, y: x + y.astype(jnp.float32) / M, acc, g)
                return acc, metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics_stack = jax.lax.scan(body, zeros, mbs)
            metrics = jax.tree.map(lambda m: m[-1], metrics_stack)
        new_p, new_s, stats = adamw.update(grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, **stats)
        return new_p, new_s, metrics

    p_struct = param_struct(cfg)
    o_struct = jax.eval_shape(adamw.init, p_struct)
    b_struct = data_pipeline.batch_spec(cfg, shape)

    if mesh is not None:
        p_sh = param_shardings(p_struct, mesh)
        o_sh = {"m": p_sh, "v": p_sh, "step": _replicated(mesh)}
        b_sh = batch_shardings(b_struct, mesh, cfg, profile_for(cfg))
        fn = jax.jit(train_step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
    else:
        fn = jax.jit(train_step, donate_argnums=(0, 1))
    return StepBundle(fn, (p_struct, o_struct, b_struct), "train")


# ----------------------------------------------------------------- prefill
def build_prefill_step(cfg: ArchConfig, shape: InputShape, mesh=None,
                       backend: str = "xla",
                       max_seq: Optional[int] = None) -> StepBundle:
    shard = _sharder(mesh, cfg)
    max_seq = max_seq or shape.seq_len

    def prefill_step(params, batch):
        return lm.prefill(cfg, params, batch["tokens"],
                          max_seq=max_seq,
                          patches=batch.get("patches"),
                          enc_frames=batch.get("frames"),
                          backend=backend, shard=shard)

    p_struct = param_struct(cfg)
    b_struct = data_pipeline.batch_spec(cfg, shape)

    if mesh is not None:
        p_sh = param_shardings(p_struct, mesh)
        b_sh = batch_shardings(b_struct, mesh, cfg, profile_for(cfg))
        _, cache_struct = jax.eval_shape(prefill_step, p_struct, b_struct)
        c_sh = cache_shardings(cache_struct, mesh, cfg)
        fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                     out_shardings=(None, c_sh))
    else:
        fn = jax.jit(prefill_step)
    return StepBundle(fn, (p_struct, b_struct), "prefill")


# ------------------------------------------------------------------ decode
def build_decode_step(cfg: ArchConfig, shape: InputShape, mesh=None,
                      backend: str = "xla") -> StepBundle:
    """serve_step: one new token for every sequence, KV cache of seq_len."""
    shard = _sharder(mesh, cfg)
    B = shape.global_batch

    def decode(params, token, caches, lengths):
        return lm.decode_step(cfg, params, token, caches, lengths,
                              backend=backend, shard=shard)

    p_struct = param_struct(cfg)
    # Cache structure comes from prefill's shape signature at max_seq=seq_len.
    prefill_shape = InputShape(shape.name, shape.seq_len, B, "prefill")
    b_struct = data_pipeline.batch_spec(cfg, prefill_shape)

    def _prefill(params, batch):
        return lm.prefill(cfg, params, batch["tokens"],
                          max_seq=shape.seq_len,
                          patches=batch.get("patches"),
                          enc_frames=batch.get("frames"))

    _, cache_struct = jax.eval_shape(_prefill, p_struct, b_struct)
    tok_struct = jax.ShapeDtypeStruct((B,), jnp.int32)
    len_struct = jax.ShapeDtypeStruct((B,), jnp.int32)

    if mesh is not None:
        p_sh = param_shardings(p_struct, mesh)
        c_sh = cache_shardings(cache_struct, mesh, cfg)
        baxes = batch_axes_of(mesh)
        bsize = 1
        for a in baxes:
            bsize *= mesh.shape[a]
        b_spec = P(baxes) if B % bsize == 0 else P()
        tok_sh = NamedSharding(mesh, b_spec)
        fn = jax.jit(decode,
                     in_shardings=(p_sh, tok_sh, c_sh, tok_sh),
                     out_shardings=(None, c_sh),
                     donate_argnums=(2,))
    else:
        fn = jax.jit(decode, donate_argnums=(2,))
    return StepBundle(fn, (p_struct, tok_struct, cache_struct, len_struct),
                      "decode")


def build_step(cfg: ArchConfig, shape: InputShape, mesh=None,
               backend: str = "xla", **kw) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, backend=backend, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, backend=backend, **kw)
    return build_decode_step(cfg, shape, mesh, backend=backend, **kw)


# ========================================================= per-layer probes
# XLA's cost analysis counts a while-loop (lax.scan) body ONCE, independent
# of trip count, so the main compile underreports flops/bytes/collectives by
# ~the layer count.  Each probe compiles ONE repeat of a stage's unit with
# no loop around it; the roofline then reconstructs
#   total = main + sum_s (repeats_s - 1) * probe_s.
def build_unit_probes(cfg: ArchConfig, shape: InputShape, mesh=None,
                      backend: str = "xla") -> Dict[str, Tuple[StepBundle, int]]:
    from repro.sharding.specs import unit_shardings, unit_struct

    shard = _sharder(mesh, cfg)
    plan = lm.build_plan(cfg)
    p_struct = param_struct(cfg)
    p_sh = param_shardings(p_struct, mesh) if mesh is not None else None
    probes: Dict[str, Tuple[StepBundle, int]] = {}

    B = shape.global_batch
    M = 1
    if shape.kind == "train" and mesh is not None:
        M = train_microbatches(cfg, shape, mesh)
        B = B // M          # probes see per-microbatch shapes
    S_tot = shape.seq_len + (cfg.n_patches or 0)
    D = cfg.d_model
    x_struct = jax.ShapeDtypeStruct((B, S_tot, D), jnp.bfloat16)
    xd_struct = jax.ShapeDtypeStruct((B, D), jnp.bfloat16)
    len_struct = jax.ShapeDtypeStruct((B,), jnp.int32)
    enc_struct = None
    if cfg.encoder is not None:
        enc_struct = jax.ShapeDtypeStruct(
            (B, cfg.encoder.n_frames, D), jnp.bfloat16)

    def x_sharding(struct=None):
        if mesh is None:
            return None
        from repro.sharding.specs import batch_shardings
        tree = {"x": struct if struct is not None else x_struct}
        return batch_shardings(tree, mesh, cfg, profile_for(cfg))["x"]

    for si, stage in enumerate(plan):
        key = f"stage{si}"
        u_struct = unit_struct(p_struct, key)
        u_sh = unit_shardings(p_sh, key) if mesh is not None else None

        has_cross = cfg.encoder is not None
        if shape.kind == "train":
            def probe(up, x, enc_out=None, stage=stage):
                def f(up, x):
                    y, _, aux = lm.apply_unit(
                        cfg, stage, up, x, enc_out=enc_out,
                        positions=jnp.arange(x.shape[1]), max_seq=None,
                        backend=backend, shard=shard)
                    return jnp.sum(y.astype(jnp.float32) ** 2) + aux
                f = jax.checkpoint(f)
                return jax.value_and_grad(f, argnums=(0, 1))(up, x)

            args = (u_struct, x_struct) + ((enc_struct,) if has_cross else ())
            if mesh is not None:
                in_sh = (u_sh, x_sharding()) + (
                    (x_sharding(enc_struct),) if has_cross else ())
        elif shape.kind == "prefill":
            def probe(up, x, enc_out=None, stage=stage):
                return lm.apply_unit(
                    cfg, stage, up, x, enc_out=enc_out,
                    positions=jnp.arange(x.shape[1]), max_seq=shape.seq_len,
                    backend=backend, shard=shard)

            args = (u_struct, x_struct) + ((enc_struct,) if has_cross else ())
            if mesh is not None:
                in_sh = (u_sh, x_sharding()) + (
                    (x_sharding(enc_struct),) if has_cross else ())
        else:
            # decode: cache slice from the decode bundle's cache struct
            bundle = build_decode_step(cfg, shape, mesh=None, backend=backend)
            cache_struct = bundle.arg_specs[2][key]
            c_struct = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                cache_struct)
            c_sh = None
            if mesh is not None:
                full_c_sh = cache_shardings(
                    {"c": bundle.arg_specs[2]}, mesh, cfg)["c"][key]
                from jax.sharding import NamedSharding as NS
                c_sh = jax.tree.map(
                    lambda ns: NS(ns.mesh, P(*ns.spec[1:])), full_c_sh)

            def probe(up, c, x, lengths, stage=stage):
                return lm.decode_unit(cfg, stage, up, c, x, lengths,
                                      backend=backend)

            args = (u_struct, c_struct, xd_struct, len_struct)
            if mesh is not None:
                baxes = batch_axes_of(mesh)
                bsz = 1
                for a in baxes:
                    bsz *= mesh.shape[a]
                tok_sh = NamedSharding(
                    mesh, P(baxes) if B % bsz == 0 else P())
                in_sh = (u_sh, c_sh, tok_sh, tok_sh)

        if mesh is not None:
            fn = jax.jit(probe, in_shardings=in_sh)
        else:
            fn = jax.jit(probe)
        probes[key] = (StepBundle(fn, args, f"probe-{shape.kind}"),
                       stage.repeats * M)

    # encoder probe (whisper): forward-only layer over the frame sequence
    if cfg.encoder is not None and shape.kind in ("train", "prefill"):
        enc_u_struct = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
            p_struct["encoder"]["layers"])

        def enc_probe(up, x):
            if shape.kind == "train":
                def f(up, x):
                    y = lm.encoder_unit(cfg, up, x, backend=backend,
                                        shard=shard)
                    return jnp.sum(y.astype(jnp.float32) ** 2)
                return jax.value_and_grad(jax.checkpoint(f),
                                          argnums=(0, 1))(up, x)
            return lm.encoder_unit(cfg, up, x, backend=backend, shard=shard)

        if mesh is not None:
            # reuse param rules on the encoder subtree, then strip stack axis
            full = param_shardings(p_struct, mesh)["encoder"]["layers"]
            enc_u_sh = jax.tree.map(
                lambda ns: NamedSharding(ns.mesh, P(*ns.spec[1:])), full)
            fn = jax.jit(enc_probe,
                         in_shardings=(enc_u_sh, x_sharding(enc_struct)))
        else:
            fn = jax.jit(enc_probe)
        probes["encoder"] = (
            StepBundle(fn, (enc_u_struct, enc_struct), f"probe-{shape.kind}"),
            cfg.encoder.n_layers * M)
    return probes
