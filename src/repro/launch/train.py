"""End-to-end training driver.

Single-job mode (default): train one architecture for N steps with the full
substrate stack — deterministic data pipeline, AdamW, async checkpointing,
restart (``--resume``), step-time telemetry feeding the structural
predictor's staircase estimate of job completion.

Multi-job mode (``--jobs a,b,...``): the paper's scenario — concurrent
training jobs scheduled on the lane executor under ``--policy``
(fifo|mpmax|srtf|srtf-adaptive), with preemption at step boundaries.

Reduced configs run on CPU; pass ``--full`` only on a real pod (the full
configs are exercised via launch.dryrun on this container).

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 20
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 50 \
        --checkpoint-dir /tmp/ck --checkpoint-every 10 --resume
    PYTHONPATH=src python -m repro.launch.train \
        --jobs yi-6b:30,mamba2-2.7b:12 --policy srtf
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCHS, get_arch
from repro.configs.shapes import InputShape
from repro.core.executor import LaneExecutor
from repro.core.jobs import make_train_job
from repro.core.metrics import evaluate
from repro.core.policies import make_policy
from repro.core.predictor import staircase_runtime
from repro.data import pipeline as data
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.optim import adamw


def train_single(args) -> None:
    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    shape = InputShape("train_cli", args.seq, args.batch, "train")
    opt_cfg = adamw.OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                              total_steps=max(args.steps, 2))
    bundle = build_train_step(cfg, shape, mesh=None, opt_cfg=opt_cfg,
                              remat=False)

    ck = None
    start_step = 0
    key = jax.random.PRNGKey(args.seed)
    params = lm.init(cfg, key)
    opt_state = adamw.init(params)
    if args.checkpoint_dir:
        ck = Checkpointer(args.checkpoint_dir)
        if args.resume and ck.latest_step() is not None:
            start_step, state, _ = ck.restore(
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            print(f"[train] resumed from step {start_step}")

    t_first = None
    t_accum = 0.0
    for step in range(start_step, args.steps):
        batch = data.batch_for_step(cfg, shape, step,
                                    data.DataConfig(seed=args.seed))
        t0 = time.perf_counter()
        params, opt_state, metrics = bundle.fn(params, opt_state, batch)
        jax.block_until_ready(metrics["nll"])
        dt = time.perf_counter() - t0
        t_accum += dt
        if t_first is None and step == start_step + 1:
            # structural runtime prediction for the whole job (Eq. 1 with
            # R=1 lane): profile one steady-state step, extrapolate.
            t_first = dt
            pred = staircase_runtime(args.steps - step, 1, dt)
            print(f"[predictor] t={dt:.3f}s/step -> predicted remaining "
                  f"{pred:.1f}s for {args.steps - step} steps")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step={step} nll={float(metrics['nll']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt:.3f}s")
        if ck is not None and args.checkpoint_every and \
                (step + 1) % args.checkpoint_every == 0:
            ck.save(step + 1, {"params": params, "opt": opt_state},
                    {"arch": args.arch})
    if ck is not None:
        ck.save(args.steps, {"params": params, "opt": opt_state},
                {"arch": args.arch})
        ck.wait()
    print(f"[train] done: {args.steps - start_step} steps, "
          f"{t_accum:.1f}s compute")


def train_multi(args) -> None:
    specs = []
    for i, item in enumerate(args.jobs.split(",")):
        arch_id, _, blocks = item.partition(":")
        cfg = get_arch(arch_id).reduced()
        specs.append(make_train_job(
            cfg, arch_id, blocks=int(blocks or 20), batch=args.batch,
            seq=args.seq, max_residency=args.lanes, seed=args.seed + i,
            arrival=0.05 * i, tenant=arch_id))
    # Solo baselines: one warmed job per distinct (arch, blocks) item,
    # measured once.  Job keys are "{arch}#{order}"; split on the last '#'
    # to recover the arch.
    solo = {}
    blocks_of = {}
    for order, js in enumerate(specs):
        blocks_of[f"{js.name}#{order}"] = js.num_blocks
        if (js.name, js.num_blocks) in solo:
            continue
        fresh = make_train_job(
            ARCHS[js.name].reduced(), js.name, blocks=js.num_blocks,
            batch=args.batch, seq=args.seq, max_residency=args.lanes,
            seed=args.seed)
        res = LaneExecutor(
            [fresh], make_policy("fifo"), n_lanes=args.lanes).run()
        solo[(js.name, js.num_blocks)] = next(iter(res.values())).turnaround
    ex = LaneExecutor(specs, make_policy(args.policy), n_lanes=args.lanes,
                      predictor=args.predictor)
    # SJF-style oracles are per kernel name; use the first item's baseline.
    for (name, _), rt in solo.items():
        ex.oracle_runtimes.setdefault(name, rt)
    results = ex.run()
    turnaround = {k: r.turnaround for k, r in results.items()}
    solo_map = {k: solo[(k.rsplit("#", 1)[0], blocks_of[k])]
                for k in turnaround}
    m = evaluate(turnaround, solo_map)
    print(f"[multi] policy={args.policy} STP={m.stp:.3f} ANTT={m.antt:.3f} "
          f"fairness={m.fairness:.3f}")
    for k, r in results.items():
        print(f"  {k}: turnaround={r.turnaround:.2f}s blocks={r.blocks}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-6b", choices=sorted(ARCHS))
    ap.add_argument("--jobs", default=None,
                    help="multi-job mode: arch:blocks,arch:blocks,...")
    ap.add_argument("--policy", default="srtf")
    ap.add_argument("--predictor", default="simple-slicing",
                    help="registered predictor name (simple-slicing, ewma)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="use the full (pod-scale) config — real pods only")
    args = ap.parse_args()
    if args.jobs:
        train_multi(args)
    else:
        train_single(args)


if __name__ == "__main__":
    main()
