# Compute hot-spot kernels: Pallas TPU implementations (validated with
# interpret=True on CPU), efficient XLA formulations (ops.py), and pure-jnp
# oracles (ref.py).  The paper's own contribution is a scheduler (no custom
# kernels); these serve the framework's model zoo.
