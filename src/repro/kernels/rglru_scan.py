"""Pallas TPU kernel for the RG-LRU gated linear recurrence.

Grid (batch, chunk) with the chunk axis innermost and the [C] hidden state
in VMEM scratch.  Within a chunk the recurrence is evaluated by a
``fori_loop`` over time steps — each step is a pure VPU (elementwise)
update across the channel lanes, so the kernel is bandwidth-bound exactly
like the recurrence itself; chunking exists to bound the VMEM-resident
gate/input tiles.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, ga_ref, gi_ref, loga_ref, h_ref, state_out_ref,
            state_scr, *, chunk: int, n_chunks: int, c_const: float):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)                    # [Q, C]
    ga = ga_ref[0].astype(jnp.float32)
    gi = gi_ref[0].astype(jnp.float32)
    la = loga_ref[...].astype(jnp.float32)              # [C]

    log_at = c_const * la[None, :] * ga                 # [Q, C] <= 0
    at = jnp.exp(log_at)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_at), 0.0))
    bt = beta * (gi * x)

    def step(t, h):
        h = at[t] * h + bt[t]
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = h

    @pl.when(cj == n_chunks - 1)
    def _finish():
        state_out_ref[0] = h


def rglru_pallas(
    x: jnp.ndarray,          # [B, S, C]
    gate_a: jnp.ndarray,     # [B, S, C]
    gate_i: jnp.ndarray,     # [B, S, C]
    log_a: jnp.ndarray,      # [C]
    *,
    initial_state: Optional[jnp.ndarray] = None,
    c: float = 8.0,
    chunk: int = 256,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if initial_state is not None:
        from . import ops
        return ops.rglru(x, gate_a, gate_i, log_a,
                         initial_state=initial_state, c=c, backend="xla")
    B, S, C = x.shape
    Q = min(chunk, S)
    if S % Q:
        Q = S
    nc = S // Q

    kernel = functools.partial(_kernel, chunk=Q, n_chunks=nc, c_const=c)
    h, state = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, Q, C), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, Q, C), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, Q, C), lambda b, j: (b, j, 0)),
            pl.BlockSpec((C,), lambda b, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, C), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, C), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, C), x.dtype),
            jax.ShapeDtypeStruct((B, C), jnp.float32),
        ],
        scratch_shapes=[_scratch((C,))],
        interpret=interpret,
    )(x, gate_a, gate_i, log_a)
    return h, state


def _scratch(shape):
    if hasattr(pl, "ScratchShape"):
        return pl.ScratchShape(shape, jnp.float32)
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
