"""Pallas TPU kernel for the Mamba-2 SSD (state-space duality) mixer.

Grid (batch, head, chunk) with the chunk axis innermost: the [P, N] SSD
state for each (batch, head) persists in VMEM scratch across chunk steps
(the sequential inter-chunk recurrence), while each chunk's quadratic
intra-chunk term runs on the MXU from VMEM-resident [Q, P] / [Q, N] tiles.
This is the TPU-native re-blocking of the paper's GPU algorithm: instead of
a warp-level scan, the sequential dimension rides the (ordered) TPU grid.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
            state_scr, *, chunk: int, n_chunks: int):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)              # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)            # [Q]
    a = a_ref[0].astype(jnp.float32)                    # scalar
    bm = b_ref[0, 0, 0].astype(jnp.float32)             # [Q, N]
    cm = c_ref[0, 0, 0].astype(jnp.float32)             # [Q, N]

    da = dt * a                                         # [Q], <= 0
    cum = jnp.cumsum(da)                                # [Q]
    total = cum[-1]

    # intra-chunk quadratic term
    diff = cum[:, None] - cum[None, :]                  # [Q, Q]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(tri, diff, -jnp.inf))
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))  # [Q,Q]
    L = scores * decay * dt[None, :]
    y = jax.lax.dot_general(L, x, (((1,), (0,)), ((), ())))          # [Q,P]

    # inter-chunk contribution from the carried state
    state = state_scr[...]                              # [P, N]
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())))            # [Q,P]
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update: decay + sum_s exp(total - cum_s) dt_s x_s (x) B_s
    w = jnp.exp(total - cum) * dt                       # [Q]
    chunk_state = jax.lax.dot_general(
        x * w[:, None], bm, (((0,), (0,)), ((), ())))   # [P, N]
    state_scr[...] = state * jnp.exp(total) + chunk_state

    @pl.when(cj == n_chunks - 1)
    def _finish():
        state_out_ref[0, 0] = state_scr[...]


def ssd_pallas(
    x: jnp.ndarray,          # [B, S, H, P]
    dt: jnp.ndarray,         # [B, S, H]
    A: jnp.ndarray,          # [H]
    Bmat: jnp.ndarray,       # [B, S, G, N]
    Cmat: jnp.ndarray,       # [B, S, G, N]
    *,
    chunk: int = 128,
    initial_state: Optional[jnp.ndarray] = None,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if initial_state is not None:
        # the kernel starts from a zero state; fall back for resumed scans
        from . import ops
        return ops._ssd_chunked_xla(x, dt, A, Bmat, Cmat, chunk,
                                    initial_state)
    B, S, H, P = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xr = x.transpose(0, 2, 1, 3).reshape(B, H, nc, Q, P)
    dtr = dt.transpose(0, 2, 1).reshape(B, H, nc, Q)
    br = Bmat.transpose(0, 2, 1, 3).reshape(B, G, nc, Q, N)
    cr = Cmat.transpose(0, 2, 1, 3).reshape(B, G, nc, Q, N)

    kernel = functools.partial(_kernel, chunk=Q, n_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, j: (b, h, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1,), lambda b, h, j: (h,)),
            pl.BlockSpec((1, 1, 1, Q, N),
                         lambda b, h, j, rep=rep: (b, h // rep, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, N),
                         lambda b, h, j, rep=rep: (b, h // rep, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, j: (b, h, j, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[_scratch((P, N))],
        interpret=interpret,
    )(xr, dtr, A, br, cr)
    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    return y, state


def _scratch(shape):
    if hasattr(pl, "ScratchShape"):
        return pl.ScratchShape(shape, jnp.float32)
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
