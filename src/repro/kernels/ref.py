"""Pure-jnp reference oracles for every kernel in this package.

These are the semantic ground truth: simple, quadratic/sequential,
numerically straightforward.  The efficient XLA implementations in
``ops.py`` and the Pallas TPU kernels are tested against these with
``assert_allclose`` over shape/dtype sweeps (see tests/test_kernels.py).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention(
    q: jnp.ndarray,          # [B, Sq, H, D]
    k: jnp.ndarray,          # [B, Sk, KV, D]
    v: jnp.ndarray,          # [B, Sk, KV, Dv]
    mask: Optional[jnp.ndarray] = None,   # [Sq, Sk] bool, True = attend
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Exact GQA attention (quadratic).  Returns [B, Sq, H, Dv]."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    assert H % KV == 0, (H, KV)
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vf)
    return out.reshape(B, Sq, H, -1).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,          # [B, H, D] single query token
    k_cache: jnp.ndarray,    # [B, S, KV, D]
    v_cache: jnp.ndarray,    # [B, S, KV, Dv]
    length: jnp.ndarray,     # [B] valid cache lengths
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """One-token attention against a (padded) KV cache.  [B, H, Dv]."""
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, KV, G, D)
    logits = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32))
    logits = logits * scale
    valid = jnp.arange(S)[None] < length[:, None]          # [B, S]
    logits = jnp.where(valid[:, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, H, -1).astype(q.dtype)


def ssd_scan(
    x: jnp.ndarray,          # [B, S, H, P]
    dt: jnp.ndarray,         # [B, S, H]        (softplus already applied)
    A: jnp.ndarray,          # [H]              (negative)
    Bmat: jnp.ndarray,       # [B, S, G, N]
    Cmat: jnp.ndarray,       # [B, S, G, N]
    initial_state: Optional[jnp.ndarray] = None,  # [B, H, P, N]
) -> tuple:
    """Mamba-2 SSD recurrence, sequential reference.

    h_t = exp(A dt_t) * h_{t-1} + dt_t * x_t B_t^T    (outer product P x N)
    y_t = h_t C_t
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    Bsz, S, H, P = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(Bmat.astype(jnp.float32), rep, axis=2)   # [B,S,H,N]
    Cf = jnp.repeat(Cmat.astype(jnp.float32), rep, axis=2)
    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((Bsz, H, P, N), jnp.float32))

    def step(h, inp):
        xt, dtt, bt, ct = inp                                # [B,H,P],[B,H],[B,H,N]x2
        decay = jnp.exp(Af[None] * dtt)                      # [B,H]
        h = h * decay[..., None, None] + \
            (dtt[..., None] * xt)[..., None] * bt[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                               # [B,S,H,P]
    return y.astype(x.dtype), hT


def rglru_scan(
    x: jnp.ndarray,          # [B, S, C] gated input
    gate_a: jnp.ndarray,     # [B, S, C] recurrence gate pre-activation in (0,1)
    gate_i: jnp.ndarray,     # [B, S, C] input gate in (0,1)
    log_a: jnp.ndarray,      # [C] per-channel base decay (log, negative)
    initial_state: Optional[jnp.ndarray] = None,  # [B, C]
    c: float = 8.0,
) -> tuple:
    """RG-LRU recurrence (RecurrentGemma), sequential reference.

    a_t = exp(c * log_a * r_t);  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t x_t)
    Returns (h [B,S,C], final_state [B,C]).
    """
    Bsz, S, C = x.shape
    xf = x.astype(jnp.float32)
    rf = gate_a.astype(jnp.float32)
    inf_ = gate_i.astype(jnp.float32)
    la = log_a.astype(jnp.float32)
    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((Bsz, C), jnp.float32))

    def step(h, inp):
        xt, rt, it = inp
        log_at = c * la[None] * rt                           # [B,C], <= 0
        at = jnp.exp(log_at)
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_at), 0.0))
        h = at * h + beta * (it * xt)
        return h, h

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(rf, 1, 0),
          jnp.moveaxis(inf_, 1, 0))
    hT, hs = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), hT


def moe_dense(
    x: jnp.ndarray,          # [T, D] tokens
    gate_w: jnp.ndarray,     # [E, D, F]
    up_w: jnp.ndarray,       # [E, D, F]
    down_w: jnp.ndarray,     # [E, F, D]
    probs: jnp.ndarray,      # [T, E] routing weights (0 where unrouted)
) -> jnp.ndarray:
    """Dense-einsum MoE oracle: every token through every expert, weighted.

    O(T*E*D*F) — only usable at test sizes; the efficient path uses
    capacity-based dispatch (ops.moe_apply).
    """
    xf = x.astype(jnp.float32)
    h = jnp.einsum("td,edf->tef", xf, gate_w.astype(jnp.float32))
    u = jnp.einsum("td,edf->tef", xf, up_w.astype(jnp.float32))
    h = jax.nn.silu(h) * u
    y = jnp.einsum("tef,efd->ted", h, down_w.astype(jnp.float32))
    return jnp.einsum("ted,te->td", y, probs.astype(jnp.float32)).astype(x.dtype)
