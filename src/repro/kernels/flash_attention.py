"""Pallas TPU flash attention (causal / sliding-window / full) with GQA.

Tiling: grid (batch, kv_head, q_block, kv_block); the kv_block axis is the
innermost (sequential) grid dimension, carrying the online-softmax state
(m, l, acc) in VMEM scratch across kv blocks — the canonical TPU flash
pattern.  Block shapes keep the working set in VMEM and the matmul operands
MXU-aligned (block_q x D and block_k x D tiles, D a multiple of 128 for the
zoo's head dims).

Validated against the pure-jnp oracle in interpret mode on CPU
(tests/test_kernels.py); TPU is the compilation target.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            mask_kind: str, window: int, block_q: int, block_k: int,
            n_k: int, sq: int, sk: int, scale: float, q_offset: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [G, bq, D]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, Dv]

    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())))  # [G, bq, bk]
    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_q, block_k), 1)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_q, block_k), 2)
    valid = k_pos < sk
    if mask_kind == "causal":
        valid = valid & (k_pos <= q_pos)
    elif mask_kind == "window":
        valid = valid & (k_pos <= q_pos) & (k_pos > q_pos - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                  # [G, bq]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(valid, p, 0.0)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
        p, v, (((2,), (0,)), ((), ())))                  # [G, bq, Dv]
    m_scr[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[..., None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,          # [B, Sq, H, D]
    k: jnp.ndarray,          # [B, Sk, KV, D]
    v: jnp.ndarray,          # [B, Sk, KV, Dv]
    *,
    mask_kind: str = "causal",
    window: int = 0,
    q_offset: int = 0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)

    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    qr = q.reshape(B, Sq, KV, G, D).transpose(0, 2, 3, 1, 4)  # [B,KV,G,Sq,D]
    if pad_q:
        qr = jnp.pad(qr, ((0, 0),) * 3 + ((0, pad_q), (0, 0)))
    kr = k.transpose(0, 2, 1, 3)                              # [B,KV,Sk,D]
    vr = v.transpose(0, 2, 1, 3)
    if pad_k:
        kr = jnp.pad(kr, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = qr.shape[3] // block_q
    n_k = kr.shape[2] // block_k

    kernel = functools.partial(
        _kernel, mask_kind=mask_kind, window=window, block_q=block_q,
        block_k=block_k, n_k=n_k, sq=Sq, sk=Sk, scale=scale,
        q_offset=int(q_offset))

    out = pl.pallas_call(
        kernel,
        grid=(B, KV, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, G, block_q, D),
                         lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, block_q, Dv),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, qr.shape[3], Dv), q.dtype),
        scratch_shapes=[
            pl.ScratchShape((G, block_q), jnp.float32)
            if hasattr(pl, "ScratchShape") else _vmem((G, block_q)),
            pl.ScratchShape((G, block_q), jnp.float32)
            if hasattr(pl, "ScratchShape") else _vmem((G, block_q)),
            pl.ScratchShape((G, block_q, Dv), jnp.float32)
            if hasattr(pl, "ScratchShape") else _vmem((G, block_q, Dv)),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, qr.shape[3], H, Dv)
    return out[:, :Sq]


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
