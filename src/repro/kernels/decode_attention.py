"""Pallas TPU decode attention: one query token per sequence against a
padded KV cache (flash-decode).

Grid (batch, kv_block) with kv_block innermost: the online-softmax state
for the single query position lives in VMEM scratch; each step streams one
[block_k, D] cache tile from HBM into VMEM — decode is bandwidth-bound, so
the tile size trades VMEM footprint against DMA efficiency.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_k: int, n_k: int, scale: float):
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale            # [KV, G, D]
    k = k_ref[0].astype(jnp.float32)                    # [bk, KV, D]
    v = v_ref[0].astype(jnp.float32)                    # [bk, KV, Dv]
    length = len_ref[0]

    s = jnp.einsum("hgd,khd->hgk", q, k)                # [KV, G, bk]
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 2)
    valid = k_pos < length
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[..., None] + \
        jnp.einsum("hgk,khd->hgd", p, v)
    m_scr[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[..., None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jnp.ndarray,          # [B, H, D]
    k_cache: jnp.ndarray,    # [B, S, KV, D]
    v_cache: jnp.ndarray,    # [B, S, KV, Dv]
    length: jnp.ndarray,     # [B]
    *,
    scale: Optional[float] = None,
    block_k: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    block_k = min(block_k, S)
    pad = (-S) % block_k
    kc, vc = k_cache, v_cache
    if pad:
        kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_k = kc.shape[1] // block_k
    qr = q.reshape(B, KV, G, D)

    kernel = functools.partial(_kernel, block_k=block_k, n_k=n_k,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, n_k),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,)),
            pl.BlockSpec((1, KV, G, D), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((1, block_k, KV, D), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_k, KV, Dv), lambda b, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, KV, G, Dv), lambda b, j: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Dv), q.dtype),
        scratch_shapes=[
            _scratch((KV, G)), _scratch((KV, G)), _scratch((KV, G, Dv)),
        ],
        interpret=interpret,
    )(length.astype(jnp.int32), qr, kc, vc)
    return out.reshape(B, H, Dv)


def _scratch(shape):
    if hasattr(pl, "ScratchShape"):
        return pl.ScratchShape(shape, jnp.float32)
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
