"""Jit-friendly op wrappers used by the model zoo.

Each op has up to three interchangeable implementations:

* ``backend="ref"``    — the pure-jnp oracle from :mod:`repro.kernels.ref`
  (quadratic / sequential; ground truth),
* ``backend="xla"``    — the efficient XLA formulation used by the
  distributed train/serve paths (online-softmax KV-chunk streaming for
  attention, chunked SSD, associative-scan RG-LRU, sort-based MoE dispatch),
* ``backend="pallas"`` — the Pallas TPU kernels (see flash_attention.py,
  ssd_scan.py, ...), validated on CPU with ``interpret=True``.

All implementations are tested against the reference over shape/dtype
sweeps in ``tests/test_kernels.py``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref

NEG_INF = -1e30


# ============================================================== attention
def flash_attention(
    q: jnp.ndarray,          # [B, Sq, H, D]
    k: jnp.ndarray,          # [B, Sk, KV, D]
    v: jnp.ndarray,          # [B, Sk, KV, Dv]
    *,
    mask_kind: str = "causal",        # causal|window|none
    window: int = 0,
    q_offset=0,
    kv_chunk: int = 512,
    scale: Optional[float] = None,
    backend: str = "xla",
) -> jnp.ndarray:
    """Streaming (online-softmax) attention.  Returns [B, Sq, H, Dv]."""
    if backend == "ref":
        return ref.attention(q, k, v, _full_mask(q, k, mask_kind, window,
                                                 q_offset), scale)
    if backend == "pallas":
        from .flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, mask_kind=mask_kind,
                                      window=window, q_offset=q_offset,
                                      scale=scale)
    return _flash_xla(q, k, v, mask_kind, window, q_offset, kv_chunk, scale)


def _full_mask(q, k, mask_kind, window, q_offset):
    from repro.models.layers import causal_mask, window_mask
    Sq, Sk = q.shape[1], k.shape[1]
    if mask_kind == "causal":
        return causal_mask(Sq, Sk, q_offset)
    if mask_kind == "window":
        return window_mask(Sq, Sk, q_offset, window)
    return None


def _chunk_mask(mask_kind, window, q_pos, k_pos, Sk):
    valid = k_pos < Sk
    if mask_kind == "causal":
        valid = valid & (k_pos <= q_pos)
    elif mask_kind == "window":
        valid = valid & (k_pos <= q_pos) & (k_pos > q_pos - window)
    return valid  # [Sq, C]


def _flash_fwd_core(qf, kc, vc, q_pos, mask_kind, window, kv_chunk, Sk):
    """Online-softmax forward over stacked KV chunks.

    qf: [B,Sq,KV,G,D] (pre-scaled fp32); kc/vc: [nc,B,C,KV,D*].
    Returns (out fp32 [B,Sq,KV,G,Dv], lse [B,Sq,KV,G])."""
    B, Sq, KV, G, D = qf.shape
    Dv = vc.shape[-1]
    n_chunks = kc.shape[0]

    def body(carry, inp):
        m, lsum, acc = carry
        idx, kci, vci = inp
        logits = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kci)
        k_pos = (idx * kv_chunk + jnp.arange(kv_chunk))[None, :]
        valid = _chunk_mask(mask_kind, window, q_pos, k_pos, Sk)
        logits = jnp.where(valid[None, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        lsum = lsum * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bqhgk,bkhd->bqhgd", p, vci)
        return (m_new, lsum, acc), None

    init = (jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32),
            jnp.zeros((B, Sq, KV, G), jnp.float32),
            jnp.zeros((B, Sq, KV, G, Dv), jnp.float32))
    (m, lsum, acc), _ = jax.lax.scan(body, init,
                                  (jnp.arange(n_chunks), kc, vc))
    lsum = jnp.maximum(lsum, 1e-30)
    out = acc / lsum[..., None]
    lse = m + jnp.log(lsum)
    return out, lse


@functools.lru_cache(maxsize=None)
def _flash_custom(mask_kind: str, window: int, kv_chunk: int, scale: float):
    """Flash attention with a custom VJP: the backward pass recomputes each
    KV chunk's probabilities from the saved logsumexp instead of letting
    scan-autodiff stash every chunk iteration's online-softmax carries
    (which measured tens of GiB on the 32k-context cells — EXPERIMENTS.md
    §Perf)."""

    def _prep(q, k, v):
        B, Sq, H, D = q.shape
        Sk, KV = k.shape[1], k.shape[2]
        Dv = v.shape[-1]
        G = H // KV
        chunk = min(kv_chunk, Sk)
        n_chunks = -(-Sk // chunk)
        pad = n_chunks * chunk - Sk
        qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, G, D)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        if pad:
            kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kc = kf.reshape(B, n_chunks, chunk, KV, D).transpose(1, 0, 2, 3, 4)
        vc = vf.reshape(B, n_chunks, chunk, KV, Dv).transpose(1, 0, 2, 3, 4)
        return qf, kc, vc, chunk, n_chunks, Sk, pad

    @jax.custom_vjp
    def fn(q, k, v, q_offset):
        qf, kc, vc, chunk, _, Sk, _ = _prep(q, k, v)
        q_pos = (jnp.asarray(q_offset) + jnp.arange(q.shape[1]))[:, None]
        out, _ = _flash_fwd_core(qf, kc, vc, q_pos, mask_kind, window,
                                 chunk, Sk)
        B, Sq, KV, G, Dv = out.shape
        return out.reshape(B, Sq, KV * G, Dv).astype(q.dtype)

    def fwd(q, k, v, q_offset):
        qf, kc, vc, chunk, _, Sk, _ = _prep(q, k, v)
        q_pos = (jnp.asarray(q_offset) + jnp.arange(q.shape[1]))[:, None]
        out, lse = _flash_fwd_core(qf, kc, vc, q_pos, mask_kind, window,
                                   chunk, Sk)
        B, Sq, KV, G, Dv = out.shape
        return (out.reshape(B, Sq, KV * G, Dv).astype(q.dtype),
                (q, k, v, q_offset, out, lse))

    def bwd(res, g):
        q, k, v, q_offset, out, lse = res
        qf, kc, vc, chunk, n_chunks, Sk, pad = _prep(q, k, v)
        B, Sq, H, D = q.shape
        KV = k.shape[2]
        G = H // KV
        Dv = v.shape[-1]
        q_pos = (jnp.asarray(q_offset) + jnp.arange(Sq))[:, None]
        do = g.astype(jnp.float32).reshape(B, Sq, KV, G, Dv)
        # D_i = rowsum(dO * O)
        delta = jnp.sum(do * out, axis=-1)                  # [B,Sq,KV,G]

        def body(dq, inp):
            idx, kci, vci = inp
            logits = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kci)
            k_pos = (idx * chunk + jnp.arange(chunk))[None, :]
            valid = _chunk_mask(mask_kind, window, q_pos, k_pos, Sk)
            p = jnp.exp(logits - lse[..., None])            # [B,Sq,KV,G,C]
            p = jnp.where(valid[None, :, None, None, :], p, 0.0)
            dv_c = jnp.einsum("bqhgk,bqhgd->bkhd", p, do)
            dp = jnp.einsum("bqhgd,bkhd->bqhgk", do, vci)
            ds = p * (dp - delta[..., None])
            dq = dq + jnp.einsum("bqhgk,bkhd->bqhgd", ds, kci)
            dk_c = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qf)
            return dq, (dk_c, dv_c)

        dq0 = jnp.zeros_like(qf)
        dq, (dk_c, dv_c) = jax.lax.scan(
            body, dq0, (jnp.arange(n_chunks), kc, vc))
        dq = (dq * scale).reshape(B, Sq, H, D).astype(q.dtype)
        dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(B, -1, KV, D)
        dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(B, -1, KV, Dv)
        if pad:
            dk = dk[:, :Sk]
            dv = dv[:, :Sk]
        return dq, dk.astype(k.dtype), dv.astype(v.dtype), None

    fn.defvjp(fwd, bwd)
    return fn


def _flash_xla(q, k, v, mask_kind, window, q_offset, kv_chunk, scale):
    D = q.shape[-1]
    scale = scale if scale is not None else D ** -0.5
    fn = _flash_custom(mask_kind, int(window), int(kv_chunk), float(scale))
    return fn(q, k, v, jnp.asarray(q_offset))


def decode_attention(
    q: jnp.ndarray,          # [B, H, D]
    k_cache: jnp.ndarray,    # [B, S, KV, D]
    v_cache: jnp.ndarray,    # [B, S, KV, Dv]
    length: jnp.ndarray,     # [B]
    *,
    scale: Optional[float] = None,
    backend: str = "xla",
) -> jnp.ndarray:
    """Single-token decode attention against a padded KV cache. [B, H, Dv].

    The XLA path materializes logits [B, H, S] (tiny) and lets SPMD insert
    the cross-shard softmax collectives when S is sharded (flash-decode
    style distributed softmax).
    """
    if backend == "ref":
        return ref.decode_attention(q, k_cache, v_cache, length, scale)
    if backend == "pallas":
        from .decode_attention import decode_attention_pallas
        return decode_attention_pallas(q, k_cache, v_cache, length, scale=scale)
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, KV, G, D)
    logits = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32))
    valid = jnp.arange(S)[None] < length[:, None]
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, H, -1).astype(q.dtype)


# ==================================================================== SSD
def ssd(
    x: jnp.ndarray,          # [B, S, H, P]
    dt: jnp.ndarray,         # [B, S, H]
    A: jnp.ndarray,          # [H]
    Bmat: jnp.ndarray,       # [B, S, G, N]
    Cmat: jnp.ndarray,       # [B, S, G, N]
    *,
    chunk: int = 128,
    initial_state: Optional[jnp.ndarray] = None,
    backend: str = "xla",
) -> tuple:
    """Mamba-2 SSD (state-space duality) mixer: (y, final_state)."""
    if backend == "ref":
        return ref.ssd_scan(x, dt, A, Bmat, Cmat, initial_state)
    if backend == "pallas":
        from .ssd_scan import ssd_pallas
        return ssd_pallas(x, dt, A, Bmat, Cmat, chunk=chunk,
                          initial_state=initial_state)
    return _ssd_chunked_xla(x, dt, A, Bmat, Cmat, chunk, initial_state)


def _ssd_chunked_xla(x, dt, A, Bmat, Cmat, chunk, initial_state):
    """Chunked SSD: quadratic intra-chunk (attention-like) + linear
    inter-chunk state recurrence — the Mamba-2 paper's algorithm."""
    Bsz, S, H, P = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Af = A.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32).reshape(Bsz, nc, Q, G, N)
    Cf = Cmat.astype(jnp.float32).reshape(Bsz, nc, Q, G, N)
    Bh = jnp.repeat(Bf, rep, axis=3)                     # [B,nc,Q,H,N]
    Ch = jnp.repeat(Cf, rep, axis=3)

    dA = dtf * Af[None, None, None, :]                   # [B,nc,Q,H] (<=0)
    cum = jnp.cumsum(dA, axis=2)                         # within-chunk cumsum
    total = cum[:, :, -1:, :]                            # [B,nc,1,H]

    # --- intra-chunk (attention-like) ------------------------------------
    # decay[t, s] = exp(cum_t - cum_s) for s <= t.  Mask inside the exponent:
    # for s > t the difference is positive and exp() overflows to inf, and
    # inf * 0 = NaN if masked after the fact.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,Q,Q,H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -jnp.inf))
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", Ch, Bh)    # [B,nc,Q,Q,H]
    L = scores * decay
    y_intra = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", L, dtf, xf)

    # --- chunk states ------------------------------------------------------
    # state_c = sum_s exp(total - cum_s) dt_s x_s (x) B_s   -> [B,nc,H,P,N]
    w = jnp.exp(total - cum) * dtf                       # [B,nc,Q,H]
    chunk_state = jnp.einsum("bcqh,bcqhp,bcqhn->bchpn", w, xf, Bh)

    # --- inter-chunk recurrence -------------------------------------------
    chunk_decay = jnp.exp(total[:, :, 0, :])             # [B,nc,H]
    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((Bsz, H, P, N), jnp.float32))

    def step(h, inp):
        dec, st = inp                                    # [B,H], [B,H,P,N]
        h_in = h                                         # state entering chunk
        h = h * dec[..., None, None] + st
        return h, h_in

    hT, h_prevs = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(chunk_state, 1, 0)))
    h_prev = jnp.moveaxis(h_prevs, 0, 1)                 # [B,nc,H,P,N]

    # --- inter-chunk contribution ------------------------------------------
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp",
                         Ch * jnp.exp(cum)[..., None], h_prev)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), hT


def ssd_decode_step(
    x: jnp.ndarray,          # [B, H, P]
    dt: jnp.ndarray,         # [B, H]
    A: jnp.ndarray,          # [H]
    Bvec: jnp.ndarray,       # [B, G, N]
    Cvec: jnp.ndarray,       # [B, G, N]
    state: jnp.ndarray,      # [B, H, P, N]
) -> tuple:
    """Single-token SSD update: (y [B,H,P], new_state)."""
    H = x.shape[1]
    G = Bvec.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bvec.astype(jnp.float32), rep, axis=1)
    Ch = jnp.repeat(Cvec.astype(jnp.float32), rep, axis=1)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(A.astype(jnp.float32)[None] * dtf)   # [B,H]
    new_state = state * decay[..., None, None] + \
        (dtf[..., None] * x.astype(jnp.float32))[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state


# ================================================================== RG-LRU
def rglru(
    x: jnp.ndarray,          # [B, S, C]
    gate_a: jnp.ndarray,     # [B, S, C]
    gate_i: jnp.ndarray,     # [B, S, C]
    log_a: jnp.ndarray,      # [C]
    *,
    initial_state: Optional[jnp.ndarray] = None,
    c: float = 8.0,
    backend: str = "xla",
) -> tuple:
    """RG-LRU linear recurrence: (h [B,S,C], final_state [B,C])."""
    if backend == "ref":
        return ref.rglru_scan(x, gate_a, gate_i, log_a, initial_state, c)
    if backend == "pallas":
        from .rglru_scan import rglru_pallas
        return rglru_pallas(x, gate_a, gate_i, log_a,
                            initial_state=initial_state, c=c)
    # Two-level scan: associative scan *within* chunks (parallel, O(log Q)
    # depth), lax.scan *across* chunks threading the [B, C] state.  The
    # chunk body is checkpointed so the backward pass recomputes one chunk
    # at a time instead of saving every associative-scan level over the
    # full sequence (which measured ~20 GiB/device on the 32k recurrent
    # cells — EXPERIMENTS.md §Perf).
    B, S, C = x.shape
    xf = x.astype(jnp.float32)
    log_at = c * log_a.astype(jnp.float32)[None, None, :] \
        * gate_a.astype(jnp.float32)                     # [B,S,C] <= 0
    at = jnp.exp(log_at)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_at), 0.0))
    bt = beta * (gate_i.astype(jnp.float32) * xf)

    def combine(u, w):
        a1, b1 = u
        a2, b2 = w
        return a1 * a2, b1 * a2 + b2

    Q = min(512, S)
    if S % Q:
        Q = S
    nc = S // Q
    a_c = at.reshape(B, nc, Q, C).transpose(1, 0, 2, 3)
    b_c = bt.reshape(B, nc, Q, C).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def chunk(h0, inp):
        a, b = inp                                       # [B,Q,C]
        a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = h + a_sc * h0[:, None, :]
        return h[:, -1], h

    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((B, C), jnp.float32))
    hT, hs = jax.lax.scan(chunk, h0, (a_c, b_c))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, C)
    return h.astype(x.dtype), hT


def rglru_decode_step(x, gate_a, gate_i, log_a, state, c: float = 8.0):
    """Single-token RG-LRU update: inputs [B, C], state [B, C]."""
    log_at = c * log_a.astype(jnp.float32)[None] * gate_a.astype(jnp.float32)
    at = jnp.exp(log_at)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_at), 0.0))
    h = at * state + beta * (gate_i.astype(jnp.float32) * x.astype(jnp.float32))
    return h.astype(x.dtype), h


# ===================================================================== MoE
def moe_dispatch(
    x: jnp.ndarray,          # [T, D]
    topk_idx: jnp.ndarray,   # [T, K]
    topk_gate: jnp.ndarray,  # [T, K]
    n_experts: int,
    capacity: int,
):
    """Sort tokens into per-expert capacity buffers.

    Returns (buf [E, C, D], meta) where meta lets ``moe_combine`` scatter
    expert outputs back to token order.
    """
    T, D = x.shape
    K = topk_idx.shape[1]
    TK = T * K
    flat_e = topk_idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_g = topk_gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(TK, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, 0)
    e_c = jnp.where(keep, se, 0)
    gathered = x[st] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((n_experts, capacity, D), x.dtype)
    buf = buf.at[e_c, pos_c].add(gathered, mode="drop")
    return buf, (e_c, pos_c, st, (sg * keep).astype(x.dtype))


def moe_combine(y: jnp.ndarray, meta, T: int) -> jnp.ndarray:
    """Inverse of ``moe_dispatch``: weighted scatter back to [T, D]."""
    e_c, pos_c, st, w = meta
    contrib = y[e_c, pos_c] * w[:, None]
    return jnp.zeros((T, y.shape[-1]), y.dtype).at[st].add(
        contrib, mode="drop")


def moe_apply(
    x: jnp.ndarray,          # [T, D] flattened tokens
    gate_w: jnp.ndarray,     # [E, D, F]
    up_w: jnp.ndarray,       # [E, D, F]
    down_w: jnp.ndarray,     # [E, F, D]
    topk_idx: jnp.ndarray,   # [T, K] int32
    topk_gate: jnp.ndarray,  # [T, K] float
    capacity: int,
    *,
    dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Capacity-based sort dispatch MoE (TPU-native GShard-style, but with
    sort instead of one-hot so long sequences stay feasible)."""
    T, D = x.shape
    E, _, F = gate_w.shape
    K = topk_idx.shape[1]
    TK = T * K

    flat_e = topk_idx.reshape(-1)                        # [TK]
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_g = topk_gate.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    st = flat_t[order]
    sg = flat_g[order]

    # position of each entry within its expert's segment
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(TK, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, 0)
    e_c = jnp.where(keep, se, 0)

    gathered = x[st] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E, capacity, D), x.dtype)
    buf = buf.at[e_c, pos_c].add(gathered, mode="drop")

    h = jnp.einsum("ecd,edf->ecf", buf, gate_w.astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, up_w.astype(dtype))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, down_w.astype(dtype))

    contrib = y[e_c, pos_c] * (sg * keep).astype(y.dtype)[:, None]
    out = jnp.zeros((T, D), y.dtype).at[st].add(contrib, mode="drop")
    return out.astype(x.dtype)
