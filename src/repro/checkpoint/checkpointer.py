"""Step-granular pytree checkpointing.

Design points (see DESIGN.md "Fault tolerance"):

* The checkpoint written every K steps and the checkpoint written when the
  scheduler preempts a job are the same artifact — preemption, node failure
  and planned restart all restore through one path.
* Writes are atomic (tmp + rename) and optionally asynchronous (background
  thread; the caller keeps training while the previous step serializes).
* Leaves are addressed by their pytree path, so restore validates against a
  template tree and tolerates reordering.
* On a real multi-host pod each host writes its addressable shards and
  restore re-shards via the template's NamedShardings; this container is
  single-host, so `jax.device_get` suffices (noted for deployment).
"""

from __future__ import annotations

import json
import os
import queue
import re
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SANITIZE = re.compile(r"[^A-Za-z0-9_.:-]")


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[_SANITIZE.sub("_", key)] = np.asarray(jax.device_get(leaf))
    return out


def _unflatten(template, arrays: Dict[str, np.ndarray]):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    new = []
    for path, leaf in leaves:
        key = _SANITIZE.sub("_", "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path))
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        new.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), new)


class Checkpointer:
    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._queue: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        if async_save:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # ----------------------------------------------------------------- save
    def save(self, step: int, state: Any, meta: Optional[Dict] = None) -> None:
        """Snapshot ``state`` for ``step``.  Device->host copy happens on the
        caller's thread (cheap); serialization happens async if enabled."""
        if self._error is not None:
            raise RuntimeError("async checkpoint writer failed") \
                from self._error
        arrays = _flatten(state)
        payload = (step, arrays, dict(meta or {}))
        if self.async_save:
            self._queue.put(payload)
        else:
            self._write(*payload)

    def wait(self) -> None:
        """Block until queued async saves hit disk."""
        if self.async_save:
            self._queue.join()
        if self._error is not None:
            raise RuntimeError("async checkpoint writer failed") \
                from self._error

    def _drain(self) -> None:
        while True:
            payload = self._queue.get()
            try:
                self._write(*payload)
            except BaseException as e:  # pragma: no cover
                self._error = e
            finally:
                self._queue.task_done()

    def _write(self, step: int, arrays: Dict[str, np.ndarray],
               meta: Dict) -> None:
        final = self.dir / f"step_{step:010d}"
        tmp = Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_"))
        np.savez(tmp / "arrays.npz", **arrays)
        meta = dict(meta, step=step, n_leaves=len(arrays))
        (tmp / "meta.json").write_text(json.dumps(meta))
        (tmp / "COMMITTED").write_text("ok")     # marker: write completed
        if final.exists():
            import shutil
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        ckpts = self.all_steps()
        for step in ckpts[: -self.keep]:
            import shutil
            shutil.rmtree(self.dir / f"step_{step:010d}", ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self):
        steps = []
        for d in self.dir.glob("step_*"):
            if (d / "COMMITTED").exists():      # ignore torn writes
                steps.append(int(d.name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any,
                step: Optional[int] = None) -> Tuple[int, Any, Dict]:
        """Returns (step, state, meta); raises if no committed checkpoint."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        with np.load(d / "arrays.npz") as z:
            arrays = {k: z[k] for k in z.files}
        meta = json.loads((d / "meta.json").read_text())
        return step, _unflatten(template, arrays), meta
