"""AdamW with global-norm clipping and LR schedules (pure pytree functional,
no external deps).  Optimizer state mirrors parameter sharding."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"      # cosine|linear|constant


def schedule_lr(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = jnp.ones_like(frac)
    return cfg.lr * warm * decay


def init(params) -> Dict:
    def zeros(p):
        return jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state: Dict, params, cfg: OptConfig) -> Tuple:
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.ones(())
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, stats
