"""yi-6b — dense llama-arch GQA [arXiv:2403.04652; hf].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="yi-6b",
    family="dense",
    vocab_size=64000,
    d_model=4096,
    n_layers=32,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
)
