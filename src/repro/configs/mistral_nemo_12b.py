"""mistral-nemo-12b — dense GQA, 128k context
[hf:mistralai/Mistral-Nemo-Base-2407; hf].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128
(not d_model/n_heads = 160).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="mistral-nemo-12b",
    family="dense",
    vocab_size=131072,
    d_model=5120,
    n_layers=40,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    head_dim=128,
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
