"""minicpm3-4b — dense with MLA [hf:openbmb/MiniCPM3-4B; hf].

62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448; MLA q_lora=768,
kv_lora=256, qk_nope=64, qk_rope=32, v_head=64 (per released config).
"""
from .base import ArchConfig, MLAConfig

CONFIG = ArchConfig(
    arch_id="minicpm3-4b",
    family="dense",
    vocab_size=73448,
    d_model=2560,
    n_layers=62,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    attn_kind="mla",
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=256, q_lora_rank=768,
                  qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64),
    tie_embeddings=True,
    source="hf:openbmb/MiniCPM3-4B",
)
