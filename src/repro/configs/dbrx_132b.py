"""dbrx-132b — fine-grained MoE, 16 experts top-4
[hf:databricks/dbrx-base; unverified].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 (per expert) vocab=100352.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="dbrx-132b",
    family="moe",
    vocab_size=100352,
    d_model=6144,
    n_layers=40,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    head_dim=128,
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=4),
    source="hf:databricks/dbrx-base",
)
