"""Architecture configuration dataclasses.

One :class:`ArchConfig` fully describes a model in the zoo.  The assigned
architectures (see ``src/repro/configs/<id>.py``) instantiate it with their
published hyper-parameters; smoke tests use :func:`ArchConfig.reduced`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

#: Pad vocabularies to a multiple of this so the vocab dim shards over the
#: 16-way model axis (standard practice for tensor-parallel embeddings).
VOCAB_PAD_MULTIPLE = 256


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0             # shared (always-on) experts, DeepSeek-style
    capacity_factor: float = 1.25
    first_dense_layers: int = 0   # leading layers that keep a dense FFN
    d_ff_dense: Optional[int] = None  # FFN width of those dense layers


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    kv_lora_rank: int
    q_lora_rank: Optional[int] = None   # None => full-rank queries
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD mixer."""

    d_inner: int
    head_dim: int = 64            # P
    state_dim: int = 128          # N
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128              # SSD chunk length


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma recurrent block + local-attention hybrid."""

    width: int                    # RG-LRU channel count (lru_width)
    conv_width: int = 4
    window: int = 2048            # local attention window
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")  # 1:2 attn:rec


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder half of an encoder-decoder model (whisper)."""

    n_layers: int
    n_frames: int = 1536          # padded from whisper's 1500 for sharding


@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                   # dense|moe|ssm|hybrid|audio|vlm
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    head_dim: Optional[int] = None
    norm: str = "rms"             # rms|layer
    act: str = "swiglu"           # swiglu|geglu|gelu
    attn_kind: str = "gqa"        # gqa|mla|none
    rope_theta: float = 10_000.0
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    n_patches: int = 0            # VLM stub: precomputed patch embeddings
    tie_embeddings: bool = False
    sub_quadratic: bool = False   # eligible for long_500k
    source: str = ""              # provenance note

    # ------------------------------------------------------------- derived
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = VOCAB_PAD_MULTIPLE
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def has_attention(self) -> bool:
        return self.attn_kind != "none" or self.rglru is not None

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, v = self.d_model, self.padded_vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        total += self.n_layers * self._params_per_layer()
        if self.encoder is not None:
            enc_layer = (4 * d * d  # self-attn (q,k,v,o at full width approx)
                         + 2 * d * self.d_ff + 4 * d)
            total += self.encoder.n_layers * enc_layer
        return total

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: only routed-active experts)."""
        if self.moe is None:
            return self.n_params()
        d, v = self.d_model, self.padded_vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        per_layer_attn = self._attn_params()
        ff = 0
        mult = 3 if self.act in ("swiglu", "geglu") else 2
        active_experts = self.moe.top_k + self.moe.n_shared
        ff = active_experts * mult * d * self.d_ff + d * self.moe.n_experts
        total += self.n_layers * (per_layer_attn + ff)
        return total

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim_
        if self.attn_kind == "mla":
            m = self.mla
            q_in = m.q_lora_rank if m.q_lora_rank else d
            p = d * (m.q_lora_rank or 0)
            p += q_in * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
            p += d * (m.kv_lora_rank + m.qk_rope_dim)
            p += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            p += self.n_heads * m.v_head_dim * d
            return p
        if self.attn_kind == "gqa":
            return d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d
        if self.ssm is not None:
            s = self.ssm
            heads = s.d_inner // s.head_dim
            proj_in = d * (2 * s.d_inner
                           + 2 * s.n_groups * s.state_dim + heads)
            return proj_in + s.d_inner * d + heads
        return 0

    def _params_per_layer(self) -> int:
        d = self.d_model
        mult = 3 if self.act in ("swiglu", "geglu") else 2
        if self.ssm is not None:
            return self._attn_params() + 2 * d  # mamba2 has no separate FFN
        ff = mult * d * self.d_ff
        if self.moe is not None:
            ff = self.moe.n_experts * mult * d * self.d_ff \
                + d * self.moe.n_experts \
                + self.moe.n_shared * mult * d * self.d_ff
        attn = self._attn_params()
        if self.rglru is not None:
            r = self.rglru
            n_rec = sum(1 for p in r.pattern if p == "rec")
            n_att = len(r.pattern) - n_rec
            rec = d * r.width * 2 + r.width * d + 4 * r.width \
                + r.conv_width * r.width
            att = self._attn_params()
            attn = (n_rec * rec + n_att * att) / len(r.pattern)
        return int(attn + ff + 2 * d)

    # ------------------------------------------------------------- reduced
    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        def shrink(cfg):
            changes = dict(
                d_model=128,
                n_layers=max(2, min(4, self.n_layers // 16)),
                n_heads=4,
                n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
                d_ff=256,
                head_dim=32 if self.head_dim else None,
                vocab_size=512,
            )
            if cfg.moe:
                changes["moe"] = dataclasses.replace(
                    cfg.moe, n_experts=4, top_k=2,
                    n_shared=min(1, cfg.moe.n_shared),
                    first_dense_layers=min(1, cfg.moe.first_dense_layers),
                    d_ff_dense=256 if cfg.moe.d_ff_dense else None)
            if cfg.mla:
                changes["mla"] = MLAConfig(
                    kv_lora_rank=64,
                    q_lora_rank=64 if cfg.mla.q_lora_rank else None,
                    qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
            if cfg.ssm:
                changes["ssm"] = SSMConfig(
                    d_inner=256, head_dim=32, state_dim=32,
                    n_groups=1, conv_width=4, chunk=16)
            if cfg.rglru:
                changes["rglru"] = dataclasses.replace(
                    cfg.rglru, width=128, window=64)
                changes["n_layers"] = 3  # one full (rec, rec, attn) pattern
            if cfg.encoder:
                changes["encoder"] = EncoderConfig(n_layers=2, n_frames=32)
            if cfg.n_patches:
                changes["n_patches"] = 8
            return changes

        base = shrink(self)
        base.update(overrides)
        return dataclasses.replace(self, **base)
