"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434; hf].

27L d_model=2048 16H d_ff=1408 (routed expert) vocab=102400, MLA
kv_lora=512, 2 shared + 64 routed experts top-6 (the assignment note lists
"64e top-6 ... 2 shared+160 routed"; 160 routed belongs to full V2 — V2-Lite
has 64 routed, so we follow the "64e" figure).  First layer keeps a dense
FFN (width 10944), as in the released model.
"""
from .base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    vocab_size=102400,
    d_model=2048,
    n_layers=27,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    attn_kind="mla",
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2,
                  first_dense_layers=1, d_ff_dense=10944),
    source="arXiv:2405.04434",
)
