"""recurrentgemma-2b — Griffin hybrid: RG-LRU + local attention, 1:2
[arXiv:2402.19427; hf].

26L d_model=2560 10H (GQA kv=1, i.e. MQA) d_ff=7680 vocab=256000,
pattern (rec, rec, attn), window 2048, lru_width 2560.  Sub-quadratic
(bounded window + O(1) recurrent state): runs the long_500k shape.
"""
from .base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    arch_id="recurrentgemma-2b",
    family="hybrid",
    vocab_size=256000,
    d_model=2560,
    n_layers=26,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    act="geglu",
    rglru=RGLRUConfig(width=2560, conv_width=4, window=2048,
                      pattern=("rec", "rec", "attn")),
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2402.19427",
)
