"""mamba2-2.7b — Mamba-2 SSD, attention-free [arXiv:2405.21060; unverified].

64L d_model=2560 d_ff=0 vocab=50280, ssm_state=128.  d_inner = 2*d_model,
head_dim 64 => 80 heads; the SSD (state-space duality) mixer is the whole
block (no separate FFN).  Sub-quadratic: runs the long_500k shape.
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="mamba2-2.7b",
    family="ssm",
    vocab_size=50280,
    d_model=2560,
    n_layers=64,
    n_heads=80,            # d_inner / head_dim
    n_kv_heads=80,
    d_ff=0,
    attn_kind="none",
    ssm=SSMConfig(d_inner=5120, head_dim=64, state_dim=128, n_groups=1,
                  conv_width=4, chunk=128),
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2405.21060",
)
