"""pixtral-12b — VLM: pixtral-ViT frontend (STUB) + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
input_specs() supplies precomputed patch embeddings [B, n_patches, d_model]
prepended to the token embeddings; loss is computed on token positions only.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="pixtral-12b",
    family="vlm",
    vocab_size=131072,
    d_model=5120,
    n_layers=40,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    head_dim=128,
    rope_theta=1_000_000.0,
    n_patches=1024,        # one 1024-patch image per sequence
    source="hf:mistralai/Pixtral-12B-2409",
)
