from .base import (ArchConfig, EncoderConfig, MLAConfig, MoEConfig,
                   RGLRUConfig, SSMConfig)
from .registry import ARCHS, get_arch
from .shapes import SHAPES, InputShape, shapes_for
