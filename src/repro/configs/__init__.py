from .base import (
    ArchConfig,
    EncoderConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)
from .registry import ARCHS, get_arch
from .shapes import InputShape, SHAPES, shapes_for

__all__ = [
    "ARCHS",
    "ArchConfig",
    "EncoderConfig",
    "InputShape",
    "MLAConfig",
    "MoEConfig",
    "RGLRUConfig",
    "SHAPES",
    "SSMConfig",
    "get_arch",
    "shapes_for",
]
