"""Architecture registry: ``--arch <id>`` lookup for all assigned configs."""

from __future__ import annotations

from typing import Dict

from .base import ArchConfig

from .dbrx_132b import CONFIG as _dbrx
from .deepseek_v2_lite_16b import CONFIG as _dsv2
from .mamba2_2p7b import CONFIG as _mamba2
from .minicpm3_4b import CONFIG as _minicpm3
from .mistral_nemo_12b import CONFIG as _nemo
from .pixtral_12b import CONFIG as _pixtral
from .recurrentgemma_2b import CONFIG as _rg
from .whisper_large_v3 import CONFIG as _whisper
from .yi_34b import CONFIG as _yi34
from .yi_6b import CONFIG as _yi6

ARCHS: Dict[str, ArchConfig] = {
    cfg.arch_id: cfg
    for cfg in [
        _mamba2, _dbrx, _dsv2, _whisper, _pixtral,
        _yi34, _nemo, _yi6, _minicpm3, _rg,
    ]
}


def get_arch(arch_id: str) -> ArchConfig:
    try:
        return ARCHS[arch_id]
    except KeyError:
        raise ValueError(
            f"unknown arch {arch_id!r}; choose from {sorted(ARCHS)}") from None
