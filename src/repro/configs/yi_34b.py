"""yi-34b — dense llama-arch GQA [arXiv:2403.04652; hf].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="yi-34b",
    family="dense",
    vocab_size=64000,
    d_model=7168,
    n_layers=60,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
)
