"""Assigned input shapes and the (arch x shape) cell matrix.

LM transformer shapes are seq_len x global_batch.  ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``.  ``long_500k`` requires sub-quadratic attention and runs
only for the SSM/hybrid architectures; the skip for full-attention archs is
recorded in DESIGN.md §Arch-applicability and surfaces as a "skipped" cell.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

from .base import ArchConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def shape_applicable(cfg: ArchConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether this (arch x shape) cell runs, and why not if it doesn't."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k requires sub-quadratic context (full-attention arch)"
    return True, ""


def shapes_for(cfg: ArchConfig) -> List[InputShape]:
    return [SHAPES[n] for n in SHAPE_ORDER if shape_applicable(cfg, SHAPES[n])[0]]


def all_cells() -> List[Tuple[str, str, bool, str]]:
    """Every assigned (arch, shape) cell with applicability."""
    from .registry import ARCHS
    out = []
    for arch_id, cfg in ARCHS.items():
        for name in SHAPE_ORDER:
            ok, why = shape_applicable(cfg, SHAPES[name])
            out.append((arch_id, name, ok, why))
    return out
