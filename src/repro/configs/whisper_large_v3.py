"""whisper-large-v3 — encoder-decoder audio backbone
[arXiv:2212.04356; unverified].

32L (x2: encoder+decoder) d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
The conv/mel frontend is a STUB: input_specs() supplies precomputed frame
embeddings [B, n_frames, d_model] (n_frames padded 1500 -> 1536 so the
encoder sequence shards over the 16-way model axis).  Decoder uses RoPE in
place of whisper's learned positions (uniform decode path; noted in
DESIGN.md).  Full (quadratic) attention => long_500k skipped.
"""
from .base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    arch_id="whisper-large-v3",
    family="audio",
    vocab_size=51866,
    d_model=1280,
    n_layers=32,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    norm="layer",
    act="gelu",
    encoder=EncoderConfig(n_layers=32, n_frames=1536),
    source="arXiv:2212.04356",
)
