"""The ``Machine`` protocol and the ``SchedulerCore`` that drives it.

This module formalizes the contract that used to be an informal duck-type
between the policies and the two machines (DES simulator, real-JAX lane
executor):

* :class:`Machine` — the minimal **read surface** a scheduling policy or
  predictor may touch: active runs, per-unit occupancy/fit/residency
  queries, the machine clock, and oracle runtimes.  Both
  :class:`repro.core.simulator.Simulator` and
  :class:`repro.core.executor.LaneExecutor` implement it (and the
  runtime-checkable protocol lets tests assert so).

* :class:`KernelRun` — dynamic per-kernel state shared by every machine;
  its attribute set is the run-level read surface policies see through
  :meth:`Machine.run_state`.

* :class:`MachineBase` — shared implementation of the protocol so machines
  stop re-implementing ``active_keys`` / ``can_fit`` / residency-cap
  propagation independently.  Concrete machines supply two hooks:
  ``_cap_residency`` (which occupancy count the residency cap constrains)
  and ``_fits_resources`` (whether one more block physically fits).  It
  also owns the closed-loop feedback edge: ``attach_arrival_source`` binds
  an :class:`~repro.core.events.ArrivalSource`, ``_feed_completion``
  reports each natural kernel completion to it, and machines that support
  dynamic arrivals implement ``inject_arrival`` to schedule what the
  source emits (DESIGN.md Section 7).

* :class:`SchedulerCore` — the scheduling brain: one
  :class:`~repro.core.policies.Policy` plus one
  :class:`~repro.core.predictor.Predictor`, bound to a machine.  Machines
  post typed events (:mod:`repro.core.events`) and ask for typed decisions;
  the core fans events out to the predictor's Algorithm-1 handlers and the
  policy's hooks in the paper's order.

Anything block-granular that exposes this surface — a GPGPU-Sim-style DES,
a TPU pod of gang-scheduled lanes, a cluster simulator — can be driven by
the unmodified SRTF + Simple Slicing core, which is the paper's central
engineering claim.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import (
    Callable,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Union,
    runtime_checkable,
)

from .events import (
    ArrivalSource,
    BlockEnded,
    BlockStarted,
    Decision,
    KernelArrived,
    KernelEnded,
    MachineEvent,
)
from .predictor import Predictor, make_predictor
from .workload import Arrival, KernelSpec


@dataclass(slots=True)
class KernelRun:
    """Dynamic state of one kernel instance on a machine.

    Slotted: machines read these fields in their innermost loops, and the
    attribute set IS the run-level read surface — ad-hoc extra attributes
    would bypass the protocol anyway."""

    key: str
    spec: KernelSpec
    arrival_time: float
    order: int
    issued: int = 0
    done: int = 0
    finish_time: Optional[float] = None
    first_issue_time: Optional[float] = None
    cancelled: bool = False
    #: True once the machine posted this run's KernelArrived event.  Until
    #: then the run is invisible to the scheduler even if its arrival
    #: timestamp has passed (two arrivals can share one instant; the second
    #: must not be dispatched before its own launch is processed).
    launched: bool = False
    #: Per-SM occupancy maps.  Dicts by default (sparse machines); a
    #: machine with dense per-unit state may normalize them to flat
    #: index-addressed lists (the DES does, at RNG init).
    issued_per_sm: Union[Dict[int, int], List[int]] = \
        field(default_factory=dict)
    resident_per_sm: Union[Dict[int, int], List[int]] = \
        field(default_factory=dict)
    issue_gate: Union[Dict[int, float], List[float]] = \
        field(default_factory=dict)
    stagger_sm: Union[Dict[int, bool], List[bool]] = \
        field(default_factory=dict)
    #: Per-block duration noise factors, indexed by global block number
    #: (a plain float list: the DES issue loop reads one entry per block).
    noise: Optional[Sequence[float]] = None

    @property
    def finished(self) -> bool:
        return self.finish_time is not None

    @property
    def unissued(self) -> int:
        return self.spec.num_blocks - self.issued

    def resident(self, sm: int) -> int:
        per = self.resident_per_sm
        if isinstance(per, dict):
            return per.get(sm, 0)
        return per[sm]     # machines may normalize the map to a flat list


@runtime_checkable
class Machine(Protocol):
    """Minimal machine read surface for policies and predictors.

    Everything a scheduling policy may legally touch goes through these
    members; machine internals (event queues, SM resource pools, lane
    states) are off-limits.
    """

    n_sm: int
    now: float
    predictor: Predictor

    def active_keys(self) -> List[str]:
        """Arrived, unfinished kernels in arrival order."""
        ...

    def run_state(self, key: str) -> KernelRun:
        """Dynamic state of one kernel (read-only by convention)."""
        ...

    def residency(self, key: str, sm: int) -> int:
        """Blocks of ``key`` currently resident on unit ``sm``."""
        ...

    def can_fit(self, key: str, sm: int) -> bool:
        """Whether one more block of ``key`` may issue on unit ``sm``."""
        ...

    def elapsed(self, key: str) -> float:
        """Machine time since ``key`` arrived."""
        ...

    def oracle_runtime(self, key: str) -> Optional[float]:
        """True solo runtime, if an oracle provided one (SJF/LJF/zero)."""
        ...

    def arrivals_pending(self) -> bool:
        """Whether any not-yet-launched kernel may still arrive (queued
        arrivals, closed-loop sources, external job intake).  Policies may
        use this to elide bookkeeping that only matters under future
        multiprogramming; machines that cannot know must answer True."""
        ...

    def sync_residency_caps(self) -> None:
        """Re-propagate policy residency caps into the predictor
        (Section 3.4.3: residency changes start a new slice)."""
        ...


class SchedulerCore:
    """One policy + one predictor, bound to one machine.

    The single entry point machines use:

    * :meth:`post` — feed a typed event; the core updates the predictor
      (Algorithm 1) and the policy hooks in the paper's order and returns
      the predictor's fresh Eq. 2 estimate for ``BlockEnded`` events.
    * :meth:`post_block_start` / :meth:`post_block_end` — **fused fast
      paths** for the two block-granular events, which dominate every run
      (two per executed block).  They perform the exact dispatch the typed
      branches of :meth:`post` perform, minus the per-block event-object
      allocation and the ``isinstance`` chain; the typed surface stays as
      the protocol seam for custom machines and for the rarer lifecycle
      events (and the fault path, which needs ``lost=True``).  A
      conformance test pins both paths to identical predictor/policy state.
    * :meth:`decide` — ask for a typed :class:`~repro.core.events.Decision`
      for one execution unit.
    * :meth:`residency_cap` — the policy's current per-(kernel, unit) cap.
    """

    def __init__(self, policy, predictor: Union[str, Predictor, None],
                 n_sm: int):
        self.policy = policy
        self.predictor = make_predictor(predictor, n_sm)
        self.machine: Optional[Machine] = None
        self._invalidate_active: Optional[Callable[..., None]] = None

    def bind(self, machine: Machine) -> "SchedulerCore":
        self.machine = machine
        self.policy.bind(machine)
        # Bound-method bindings for the per-block fast paths (skip the
        # attribute walks in the hot loop), plus the machine's active-set
        # invalidation hook, if it has one (MachineBase does; a custom
        # protocol-only machine may not cache and needs no notification).
        self._predictor_on_block_start = self.predictor.on_block_start
        self._predictor_on_block_end = self.predictor.on_block_end
        self._policy_on_block_end = self.policy.on_block_end
        self._invalidate_active = getattr(machine, "_invalidate_active", None)
        return self

    # -- fused per-block fast paths -----------------------------------------
    def post_block_start(self, key: str, sm: int, slot: int,
                         time: float) -> None:
        """Fused ``BlockStarted`` dispatch (no event object, no isinstance)."""
        self._predictor_on_block_start(key, sm, slot, time)

    def post_block_end(self, key: str, sm: int, slot: int,
                       time: float) -> Optional[float]:
        """Fused ``BlockEnded`` dispatch; returns the fresh Eq. 2 estimate.

        Lost blocks (the executor's fault path) must go through the typed
        :meth:`post` with ``lost=True`` — this path is the common case only.
        """
        pred = self._predictor_on_block_end(key, sm, slot, time)
        self._policy_on_block_end(key, sm)
        return pred

    def post(self, event: MachineEvent) -> Optional[float]:
        # Dispatch order: block events first — they dominate (two per
        # executed block vs. two per kernel lifetime).
        if isinstance(event, BlockStarted):
            self.predictor.on_block_start(
                event.key, event.sm, event.slot, event.time)
        elif isinstance(event, BlockEnded):
            if event.lost:
                # Fault path: the block's work is discarded; its duration
                # must not contaminate the estimate — start a new slice.
                self.predictor.reslice_all(event.key)
                return None
            pred = self.predictor.on_block_end(
                event.key, event.sm, event.slot, event.time)
            self.policy.on_block_end(event.key, event.sm)
            return pred
        elif isinstance(event, KernelArrived):
            run = self.machine.run_state(event.key)
            run.launched = True
            if self._invalidate_active is not None:
                self._invalidate_active()
            self.predictor.on_launch(
                event.key, run.spec.num_blocks, run.spec.max_residency)
            self.policy.on_arrival(event.key)
            self.machine.sync_residency_caps()
        elif isinstance(event, KernelEnded):
            if self._invalidate_active is not None:
                self._invalidate_active(ended=event.key)
            self.predictor.on_kernel_end(event.key)
            self.policy.on_kernel_end(event.key)
            self.machine.sync_residency_caps()
        else:  # pragma: no cover - exhaustive over MachineEvent
            raise TypeError(f"unknown machine event {event!r}")
        return None

    def decide(self, sm: int) -> Decision:
        return self.policy.decide(sm)

    def residency_cap(self, key: str, sm: int) -> int:
        return self.policy.residency_cap(key, sm)


class MachineBase:
    """Shared :class:`Machine` implementation for concrete machines.

    Subclasses own their event loop and resource model and provide:

    * ``_cap_residency(key, sm)`` — the occupancy count the policy's
      residency cap constrains (per-SM resident blocks on the GPU,
      machine-wide lane count on the pod),
    * ``_fits_resources(key, sm)`` — whether one more block of ``key``
      physically fits on unit ``sm`` right now.
    """

    def __init__(self, n_sm: int, policy,
                 predictor: Union[str, Predictor, None] = None,
                 oracle_runtimes: Optional[Dict[str, float]] = None):
        self.n_sm = n_sm
        self.now = 0.0
        self.runs: Dict[str, KernelRun] = {}
        self.oracle_runtimes: Dict[str, float] = dict(oracle_runtimes or {})
        self.core = SchedulerCore(policy, predictor, n_sm)
        #: Fast-path master switch (DESIGN.md Section 8).  Every fast path
        #: is bit-identical to the reference path by construction; the
        #: switch exists so the equivalence matrix suite can force the
        #: reference behavior and diff the two end to end.
        self.fast_path = True
        self._key_order: Optional[List[str]] = None  # active_keys() cache
        #: Event-driven active_keys() cache: the filtered list is reused
        #: until an arrival/kernel-end/injection dirties it (see
        #: :meth:`_invalidate_active`).
        self._active_cache: Optional[List[str]] = None
        #: Parallel cache of the KernelRun objects behind active_keys()
        #: (machine-internal: saves the per-key dict hop in hot loops).
        self._active_runs_cache: Optional[List[KernelRun]] = None
        #: Last residency cap pushed into the predictor per kernel
        #: (uniform-cap policies only): lets :meth:`sync_residency_caps`
        #: skip the per-SM fan-out when nothing changed.
        self._synced_caps: Dict[str, int] = {}
        #: Closed-loop feedback edge (None = open loop, the default).
        self._arrival_source: Optional[ArrivalSource] = None
        #: Machine seconds per source time unit (1.0 on the cycle-clocked
        #: DES; the executor attaches with its scenario time_scale).
        self._source_time_scale = 1.0
        # Plain attributes, not properties: policies and predictors read
        # machine.predictor in their innermost loops, and the core never
        # swaps its policy/predictor after construction.
        self.policy = self.core.policy
        self.predictor: Predictor = self.core.predictor

    # -- Machine protocol ---------------------------------------------------
    def active_keys(self) -> List[str]:
        """Arrived (launch event processed), unfinished kernels in arrival
        order.

        Hot path (policies call this on every decision): with
        :attr:`fast_path` on, the *filtered* list is cached under an
        event-driven dirty bit — rebuilt only after an arrival, a kernel
        end, or an injected run (:meth:`_invalidate_active`), since those
        are the only transitions of the launched/finished predicates.  The
        returned list is shared; callers must treat it as read-only (the
        protocol's convention for everything this surface exposes).  With
        :attr:`fast_path` off the launched/finished filter runs per call
        (the reference behavior).
        """
        if self.fast_path:
            cache = self._active_cache
            if cache is not None:
                return cache
        order = self._key_order
        if order is None or len(order) != len(self.runs):
            runs = self.runs
            order = sorted(runs, key=lambda k: runs[k].order)
            self._key_order = order
        runs = self.runs
        out = []
        for k in order:
            r = runs[k]
            if r.launched and r.finish_time is None:
                out.append(k)
        if self.fast_path:
            self._active_cache = out
        return out

    def _invalidate_active(self, ended: Optional[str] = None) -> None:
        """Dirty the :meth:`active_keys` cache (and drop the ended
        kernel's synced-cap memo).  Called by :class:`SchedulerCore` on
        arrival/kernel-end dispatch and by machines when they add runs."""
        self._active_cache = None
        self._active_runs_cache = None
        if ended is not None:
            self._synced_caps.pop(ended, None)

    def _active_runs(self) -> List[KernelRun]:
        """Machine-internal: the runs behind :meth:`active_keys`, cached
        under the same dirty bit (not part of the policy read surface)."""
        cache = self._active_runs_cache
        if cache is None:
            runs = self.runs
            cache = [runs[k] for k in self.active_keys()]
            self._active_runs_cache = cache
        return cache

    def run_state(self, key: str) -> KernelRun:
        return self.runs[key]

    def residency(self, key: str, sm: int) -> int:
        return self.runs[key].resident(sm)

    def can_fit(self, key: str, sm: int) -> bool:
        run = self.runs[key]
        spec = run.spec
        if spec.num_blocks - run.issued <= 0:
            return False
        cap = spec.max_residency
        policy = self.core.policy
        if not policy.unlimited_caps:
            pcap = policy.residency_cap(key, sm)
            if pcap < cap:
                cap = pcap
        if self._cap_residency(key, sm) >= cap:
            return False
        return self._fits_resources(key, sm)

    def elapsed(self, key: str) -> float:
        return self.now - self.runs[key].arrival_time

    def oracle_runtime(self, key: str) -> Optional[float]:
        return self.oracle_runtimes.get(self.runs[key].spec.name)

    def arrivals_pending(self) -> bool:
        # Conservative default: machines with external intake (the
        # executor's add_job, the async service) can gain kernels at any
        # time, so "more arrivals possible" is the safe answer.
        return True

    def sync_residency_caps(self) -> None:
        policy = self.core.policy
        predictor = self.predictor
        if self.fast_path and policy.uniform_caps:
            # Delta sync: built-in policies cap per kernel, not per unit
            # (``Policy.uniform_caps``), so one cap query covers all SMs
            # and the per-(key, sm) predictor fan-out only runs for keys
            # whose cap actually changed since the last sync.  The memo
            # mirrors predictor state exactly — every cap the predictor
            # holds was pushed through this method — so a memo hit is a
            # provable no-op fan-out.
            synced = self._synced_caps
            for key in self.active_keys():
                if not predictor.has_kernel(key):
                    continue
                run = self.runs[key]
                cap = run.spec.max_residency
                if not policy.unlimited_caps:
                    pcap = policy.residency_cap(key, 0)
                    if pcap < cap:
                        cap = pcap
                if synced.get(key) == cap:
                    continue
                for sm in range(self.n_sm):
                    predictor.on_residency_change(key, sm, cap)
                synced[key] = cap
            return
        for key in self.active_keys():
            if not predictor.has_kernel(key):
                # Defensive invariant: active_keys() only returns launched
                # runs, and SchedulerCore.post registers a run with the
                # predictor in the same KernelArrived dispatch that marks
                # it launched, so every key here should be known.  Skip
                # rather than crash if a custom machine drives events in a
                # different order.
                continue
            run = self.runs[key]
            for sm in range(self.n_sm):
                cap = min(run.spec.max_residency,
                          self.core.residency_cap(key, sm))
                predictor.on_residency_change(key, sm, cap)

    # -- closed-loop feedback edge ------------------------------------------
    def attach_arrival_source(self, source: ArrivalSource,
                              time_scale: float = 1.0) -> None:
        """Close the loop: feed ``source`` every natural kernel completion
        and schedule the arrivals it emits (DESIGN.md Section 7).

        ``time_scale`` is machine seconds per source time unit: completion
        times are reported to the source as ``now / time_scale`` and the
        machine's :meth:`inject_arrival` is responsible for scaling emitted
        arrival times back.  The source's :meth:`~repro.core.events
        .ArrivalSource.initial` arrivals are injected immediately; a source
        is single-use, so attaching twice is an error.
        """
        if self._arrival_source is not None:
            raise ValueError("an arrival source is already attached")
        if time_scale <= 0.0:
            raise ValueError("time_scale must be positive")
        self._arrival_source = source
        self._source_time_scale = time_scale
        for arrival in source.initial():
            self.inject_arrival(arrival)

    def _feed_completion(self, key: str) -> None:
        """Report one natural completion to the attached source (if any)
        and inject whatever arrivals it emits.  Machines call this right
        after posting :class:`~repro.core.events.KernelEnded`."""
        source = self._arrival_source
        if source is None:
            return
        now = self.now / self._source_time_scale
        for arrival in source.on_completion(key, now):
            self.inject_arrival(arrival)

    # -- machine-specific hooks ---------------------------------------------
    def inject_arrival(self, arrival: Arrival) -> str:
        """Schedule one dynamic arrival (closed-loop feedback); returns the
        kernel key.  Arrival times are in source units (machine-specific
        scaling applies) and are clipped to "now" — a feedback arrival can
        never land in the machine's past."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support dynamic arrivals")

    def _cap_residency(self, key: str, sm: int) -> int:
        """Occupancy count the residency cap constrains on ``sm``."""
        raise NotImplementedError

    def _fits_resources(self, key: str, sm: int) -> bool:
        """Whether one more block of ``key`` physically fits on ``sm``."""
        raise NotImplementedError


__all__ = [
    "KernelRun",
    "Machine",
    "MachineBase",
    "SchedulerCore",
]
