"""Real-JAX lane executor: the TPU-pod adaptation of the paper's thread
block scheduler, driving ACTUAL jit-compiled step functions.

Mapping (DESIGN.md Section 2): the machine is a pod partitioned into
``n_lanes`` gang-scheduled mesh slices; a *job* (training run / serving
batch) is a grid of ``num_blocks`` homogeneous *blocks* (steps); a job's
*residency* is the number of lanes it currently occupies.  Each lane runs
one block at a time, so the executor is the paper's machine with SMs=lanes.

Time model: lanes advance on a virtual clock ordered by *measured* wall
time of each real step execution (this container has one physical device,
so lane parallelism is virtual while every block's duration is a real
measurement — including JIT, cache and memory effects).  On a real pod the
same loop runs with concurrent lanes and wall-clock time.

The executor is the second concrete :class:`repro.core.machine.Machine`
(the DES simulator is the first): the same
:class:`repro.core.machine.SchedulerCore` — unmodified policies and
predictor — schedules both.  Jobs may be present up-front or arrive late
through :meth:`LaneExecutor.add_job` (the async
:mod:`repro.core.scheduler_service` frontend builds on this plus
:meth:`LaneExecutor.step` and :meth:`LaneExecutor.cancel`).

Fault tolerance: ``fail_lane_at`` kills a lane mid-run (its block is lost
and re-executed; the predictor starts a new slice since residency changed);
``straggler`` inflates one lane's durations until quarantined.
"""

from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from .events import BlockEnded, BlockStarted, KernelArrived, KernelEnded, grants_issue
from .machine import KernelRun, MachineBase
from .predictor import Predictor
from .workload import Arrival, KernelSpec


@dataclass
class ExecutorJob:
    """One schedulable job: ``make_block_fn(residency)`` returns a callable
    executing one block (one real jitted step) at that residency.
    ``warmup_fn`` AOT-compiles the job's step functions without mutating its
    state — the executor invokes it before scheduling so that measured block
    durations (and hence the predictor's sampled ``t``) reflect steady-state
    compute, not one-time JIT cost, as on a production system.
    ``tenant`` groups jobs for the multi-tenant service's per-tenant
    metrics; it defaults to the job name."""

    name: str
    num_blocks: int
    max_residency: int
    make_block_fn: Callable[[int], Callable[[], None]]
    arrival: float = 0.0
    est_block_seconds: float = 1.0   # only used by SJF's fallback oracle
    warmup_fn: Optional[Callable[[], None]] = None
    tenant: Optional[str] = None

    def grid_spec(self) -> KernelSpec:
        # Reuse KernelSpec so the unmodified policies see the paper's fields.
        return KernelSpec(
            name=self.name, num_blocks=self.num_blocks,
            max_residency=self.max_residency, threads_per_block=1,
            mean_t=self.est_block_seconds, rsd=0.0)


class _LaneState:
    __slots__ = ("index", "busy", "resident", "failed", "slow_factor")

    def __init__(self, index: int):
        self.index = index
        self.busy: Optional[str] = None       # job key currently running
        self.resident: Dict[int, str] = {}
        self.failed = False
        self.slow_factor = 1.0


@dataclass(frozen=True)
class ExecutorWindow:
    """Observation-window summary of one executor run.

    The executor-side mirror of :class:`repro.core.simulator.SimResult`'s
    window fields: per-job turnaround/finish times for jobs that completed
    inside the window, ``unfinished`` keys (cancelled jobs included) in
    arrival order, the machine clock at stop (``end_time``), a
    truncation-safe ``makespan`` and the busy-lane ``utilization``
    (in-flight blocks clipped at the window edge).  This is the record
    shape the sweep runner shares between both machines.
    """

    turnaround: Dict[str, float]
    finish: Dict[str, float]
    names: Dict[str, str]
    unfinished: Tuple[str, ...]
    end_time: float
    makespan: float
    utilization: float
    #: Arrival time of every job, finished or not (queueing metrics need
    #: the in-flight ones to integrate number-in-system over the window).
    arrival: Dict[str, float] = field(default_factory=dict)


@dataclass
class JobResult:
    key: str
    arrival: float
    finish: float
    blocks: int
    failures_absorbed: int = 0
    cancelled: bool = False

    @property
    def turnaround(self) -> float:
        return self.finish - self.arrival


class LaneExecutor(MachineBase):
    """:class:`Machine` implementation over real JAX step executions.

    Job keys follow the ``{name}#{order}`` convention: the part before the
    last ``#`` is the job/arch name (shared by solo-baseline maps), the part
    after is the machine-wide arrival order.  Split with
    ``key.rsplit("#", 1)[0]`` to recover the name.
    """

    def __init__(self, jobs: Sequence[ExecutorJob] = (), policy=None,
                 n_lanes: int = 4,
                 fail_lane_at: Optional[Tuple[int, float]] = None,
                 straggler: Optional[Tuple[int, float]] = None,
                 straggler_quarantine: float = 2.5,
                 predictor: Union[str, Predictor, None] = None,
                 job_bridge: Optional[Callable[[Arrival], ExecutorJob]] = None):
        super().__init__(n_lanes, policy, predictor=predictor)
        self.n_lanes = n_lanes
        #: Maps a scenario :class:`~repro.core.workload.Arrival` to a
        #: schedulable job — required for :meth:`inject_arrival` (the
        #: closed-loop feedback path; the sweep runner passes the real-JAX
        #: bridge from :mod:`repro.core.scenarios`, which also scales the
        #: arrival time from scenario cycles to lane seconds).
        self.job_bridge = job_bridge
        self.sms = [_LaneState(i) for i in range(n_lanes)]
        self.jobs: Dict[str, ExecutorJob] = {}
        self._block_fns: Dict[Tuple[str, int], Callable] = {}
        self.fail_lane_at = fail_lane_at
        self.straggler = straggler
        self.straggler_quarantine = straggler_quarantine
        self.failures_absorbed = 0
        self.lane_t_ewma: Dict[int, float] = {}
        self.results: Dict[str, JobResult] = {}
        self.trace: List[Tuple[str, int, float, float]] = []

        self._events: List[Tuple[float, int, int, tuple]] = []
        self._seq = itertools.count()
        self._bids = itertools.count()
        self._order = itertools.count()
        self._dead_blocks: set = set()
        self._lane_bid: Dict[int, int] = {}
        for job in sorted(jobs, key=lambda j: j.arrival):
            self.add_job(job, warmup=False)
        if fail_lane_at is not None:
            lane, t = fail_lane_at
            heapq.heappush(self._events, (t, 0, next(self._seq),
                                          ("fail_lane", lane)))
        if straggler is not None:
            self.sms[straggler[0]].slow_factor = straggler[1]
        for job in jobs:
            if job.warmup_fn is not None:
                job.warmup_fn()
        self.core.bind(self)

    # --------------------------------------------------------- job intake
    def add_job(self, job: ExecutorJob, *, key: Optional[str] = None,
                warmup: bool = True) -> str:
        """Register one job, possibly while the machine is running.

        The job arrives at ``max(now, job.arrival)`` — a late submission
        can never arrive in the machine's past.  Returns the job's key
        (``{name}#{order}`` — see the class docstring).
        """
        order = next(self._order)
        if key is None:
            key = f"{job.name}#{order}"
        if key in self.runs:
            raise ValueError(f"duplicate job key {key!r}")
        arrival = max(self.now, job.arrival)
        self.jobs[key] = job
        self.runs[key] = KernelRun(key, job.grid_spec(), arrival, order)
        self._invalidate_active()
        if warmup and job.warmup_fn is not None:
            job.warmup_fn()
        heapq.heappush(self._events,
                       (arrival, 0, next(self._seq), ("arrival", key)))
        return key

    def inject_arrival(self, arrival: Arrival) -> str:
        """Closed-loop feedback: bridge one scenario arrival to a job via
        :attr:`job_bridge` and register it with :meth:`add_job` (which
        clips the arrival to "now" and keeps the scenario uid as the key).
        """
        if self.job_bridge is None:
            raise ValueError(
                "LaneExecutor needs a job_bridge to inject scenario "
                "arrivals (pass job_bridge= at construction)")
        return self.add_job(self.job_bridge(arrival), key=arrival.key)

    def cancel(self, key: str) -> bool:
        """Cancel a job at the next block boundary.

        Already-running blocks complete (state stays consistent — the same
        property that makes preemption safe); no further blocks issue.
        Returns False if the job is unknown or already finished.
        """
        run = self.runs.get(key)
        if run is None or run.finished:
            return False
        run.cancelled = True
        run.finish_time = self.now
        self._invalidate_active(ended=key)
        self.results[key] = JobResult(
            key, run.arrival_time, self.now, run.done,
            self.failures_absorbed, cancelled=True)
        if run.launched:
            self.core.post(KernelEnded(key, self.now))
        self._dispatch()
        return True

    # ------------------------------------------------------------ machine
    def residency(self, key: str, sm: int) -> int:
        return int(self.sms[sm].busy == key)

    def _cap_residency(self, key: str, sm: int) -> int:
        # On the pod the residency cap constrains the machine-wide lane
        # count a job occupies (a lane runs one block at a time).
        return self._residency(key)

    def _fits_resources(self, key: str, sm: int) -> bool:
        lane = self.sms[sm]
        return lane.busy is None and not lane.failed

    def _residency(self, key: str) -> int:
        return sum(1 for ln in self.sms if ln.busy == key)

    # ------------------------------------------------------------ execution
    def _block_fn(self, key: str, residency: int) -> Callable[[], None]:
        job = self.jobs[key]
        residency = max(1, residency)
        ck = (key, residency)
        if ck not in self._block_fns:
            self._block_fns[ck] = job.make_block_fn(residency)
        return self._block_fns[ck]

    def pending_events(self) -> int:
        return len(self._events)

    def step(self) -> bool:
        """Process one machine event (then dispatch); False when idle."""
        if not self._events:
            return False
        t, _, _, payload = heapq.heappop(self._events)
        self.now = max(self.now, t)
        kind = payload[0]
        if kind == "arrival":
            self._on_arrival(payload[1])
        elif kind == "block_end":
            bid = payload[4]
            if bid >= 0 and bid in self._dead_blocks:
                return True                   # zombie event of lost block
            self._on_block_end(*payload[1:])
        elif kind == "fail_lane":
            self._on_fail_lane(payload[1])
        self._dispatch()
        return True

    def run(self, until: Optional[float] = None) -> Dict[str, JobResult]:
        """Drain the event queue; ``until`` truncates at a horizon.

        With ``until`` (seconds of virtual machine time) events past the
        horizon stay queued and the machine clock stops at the last
        processed event — the executor analogue of
        :meth:`repro.core.simulator.Simulator.run`'s open-loop mode.
        """
        while self._events:
            if until is not None and self._events[0][0] > until:
                break
            self.step()
        return self.results

    def window(self) -> "ExecutorWindow":
        """Observation-window view of the machine (see
        :class:`ExecutorWindow`); call after :meth:`run`."""
        turnaround: Dict[str, float] = {}
        finish: Dict[str, float] = {}
        names: Dict[str, str] = {}
        arrival: Dict[str, float] = {}
        unfinished: List[str] = []
        end_time = self.now
        for key, run in sorted(self.runs.items(), key=lambda kv: kv[1].order):
            names[key] = run.spec.name
            arrival[key] = run.arrival_time
            if run.finish_time is None or run.cancelled:
                unfinished.append(key)
                continue
            turnaround[key] = run.finish_time - run.arrival_time
            finish[key] = run.finish_time
        busy = sum(max(0.0, min(t1, end_time) - t0)
                   for _, _, t0, t1 in self.trace if t0 < end_time)
        util = (busy / (self.n_lanes * end_time)) if end_time > 0.0 else 0.0
        makespan = end_time if unfinished else max(finish.values(),
                                                   default=0.0)
        return ExecutorWindow(
            turnaround=turnaround, finish=finish, names=names,
            unfinished=tuple(unfinished), end_time=end_time,
            makespan=makespan, utilization=util, arrival=arrival)

    def _on_arrival(self, key: str) -> None:
        if self.runs[key].finished:
            return      # cancelled before its queued arrival event fired
        self.core.post(KernelArrived(key, self.now))

    def _on_block_end(self, key: str, lane_idx: int, lost: bool,
                      bid: int = -1) -> None:
        lane = self.sms[lane_idx]
        lane.busy = None
        run = self.runs[key]
        if lost:
            # failed lane: block's work is discarded, re-issue it
            run.issued -= 1
            self.failures_absorbed += 1
            self.core.post(BlockEnded(key, lane_idx, 0, self.now, lost=True))
            return
        if run.cancelled:
            # the job was cancelled while this block was in flight; the
            # block's work is kept (state is consistent), so count it and
            # settle the predictor's per-block bookkeeping — but nothing
            # more issues and the policy was already notified at cancel.
            run.done += 1
            self.results[key].blocks = run.done
            self.predictor.on_block_end(key, lane_idx, 0, self.now)
            return
        run.done += 1
        self.core.post(BlockEnded(key, lane_idx, 0, self.now))
        if run.done >= run.spec.num_blocks:
            run.finish_time = self.now
            self.results[key] = JobResult(
                key, run.arrival_time, self.now, run.done,
                self.failures_absorbed)
            self.core.post(KernelEnded(key, self.now))
            # Natural completion only: cancel() posts KernelEnded too, but
            # a frontend cancellation is not the machine finishing work and
            # must not trigger closed-loop resubmission.
            self._feed_completion(key)

    def _on_fail_lane(self, lane_idx: int) -> None:
        lane = self.sms[lane_idx]
        lane.failed = True
        if lane.busy is not None:
            # the in-flight block is lost: kill its completion event and
            # schedule the loss immediately
            key = lane.busy
            self._dead_blocks.add(self._lane_bid.get(lane_idx, -1))
            heapq.heappush(self._events,
                           (self.now, 0, next(self._seq),
                            ("block_end", key, lane_idx, True, -1)))
        # residency of every running job may have changed
        for key in self.active_keys():
            self.predictor.reslice_all(key)
        self.sync_residency_caps()

    def _dispatch(self) -> None:
        progressed = True
        while progressed:
            progressed = False
            for lane in self.sms:
                if lane.busy is not None or lane.failed:
                    continue
                key = grants_issue(self.core.decide(lane.index))
                if key is None or not self.can_fit(key, lane.index):
                    continue
                self._start_block(key, lane)
                progressed = True

    def _start_block(self, key: str, lane: _LaneState) -> None:
        run = self.runs[key]
        residency = self._residency(key) + 1
        fn = self._block_fn(key, residency)
        # Baselined determinism finding (wallclock): real wall time IS this
        # machine's time model — executor cells are measurements, marked
        # measured=True and nonce-keyed out of cross-run cache hits.
        t0 = time.perf_counter()
        fn()                                        # REAL computation
        dur = (time.perf_counter() - t0) * lane.slow_factor
        lane.busy = key
        run.issued += 1
        self.core.post(BlockStarted(key, lane.index, 0, self.now))
        self.trace.append((key, lane.index, self.now, self.now + dur))
        # straggler mitigation: quarantine lanes whose EWMA step time
        # exceeds the cross-lane median by the threshold factor
        ew = self.lane_t_ewma.get(lane.index, dur)
        self.lane_t_ewma[lane.index] = 0.7 * ew + 0.3 * dur
        self._maybe_quarantine()
        bid = next(self._bids)
        self._lane_bid[lane.index] = bid
        heapq.heappush(self._events,
                       (self.now + dur, 1, next(self._seq),
                        ("block_end", key, lane.index, False, bid)))

    def _maybe_quarantine(self) -> None:
        if len(self.lane_t_ewma) < max(3, self.n_lanes):
            return
        # The median covers IN-SERVICE lanes only: stale EWMAs of lanes
        # already failed/quarantined would otherwise anchor it low and let
        # the 2.5x threshold walk onto every healthy survivor in turn.
        vals = sorted(ew for idx, ew in self.lane_t_ewma.items()
                      if not self.sms[idx].failed)
        if not vals:
            return
        med = vals[len(vals) // 2]
        if med <= 0:
            return
        # Backstop: quarantining the last in-service lane would strand
        # pending jobs with a drained event queue (the service then awaits
        # forever), so keep at least one healthy lane no matter how the
        # EWMAs diverge; candidates go slowest-first.
        healthy = sum(1 for ln in self.sms if not ln.failed)
        candidates = sorted(
            ((ew, idx) for idx, ew in self.lane_t_ewma.items()
             if not self.sms[idx].failed
             and ew > self.straggler_quarantine * med),
            reverse=True)
        for _, idx in candidates:
            if healthy <= 1:
                break
            self.sms[idx].failed = True   # quarantined == out of service
            healthy -= 1


def solo_runtime_executor(job: ExecutorJob, policy_factory,
                          n_lanes: int = 4) -> float:
    ex = LaneExecutor([job], policy_factory(), n_lanes=n_lanes)
    res = ex.run()
    return next(iter(res.values())).turnaround
