"""Flat-array DES engine kernel (the compiled core's algorithm "twin").

This module holds ONE algorithm — :func:`advance` — written in nopython
style (scalar loops over preallocated NumPy arrays, no Python objects,
no dicts) so the same source runs three ways:

* interpreted (always importable): the byte-identical pure-NumPy
  fallback the ISSUE requires when numba is absent,
* under numba ``@njit`` when numba is importable (``REPRO_NO_NUMBA=1``
  forces it off),
* as the line-by-line template for the generated-C backend
  (:mod:`repro.core.fastsim_c`), which compiles the identical arithmetic
  with ``-ffp-contract=off`` so every float op matches CPython bit for
  bit.

:mod:`repro.core.fastsim` owns the array build/scatter protocol and the
segment driver; the layout constants below are THE contract between all
three implementations (``fastsim_c`` generates ``#define`` lines from
them).  Every float expression mirrors the reference implementation's
association order exactly (see DESIGN.md Section 10); the heap helpers
replicate CPython's ``heapq`` sift routines so even the heap's *array
layout* matches the reference event list element for element.

``advance`` processes events until it must return control to Python::

    exit 0  heap empty (run complete)
    exit 1  horizon truncation (event discarded, ``now`` not advanced)
    exit 2  kernel completed with an arrival source attached
            (driver feeds the source, rebuilds, re-enters with RESUME)
    exit 3  heap headroom low          } driver re-sizes and
    exit 4  trace buffer headroom low  } re-enters; margins below
    exit 5  decision buffer headroom   } guarantee forward
    exit 6  prediction buffer headroom } progress
    exit 7  staged-arrival variate pool exhausted (driver restages a
            fresh window and re-enters with RESUME)
"""

import math
import os

import numpy as np

# ----------------------------------------------------------------- layout
# SI: engine integer scalars.
SI_SEQ = 0           # next event sequence number (itertools.count twin)
SI_HEAP_LEN = 1
SI_PENDING = 2       # queued-but-unprocessed arrival events
SI_SAMPLING = 3      # SRTF sampling kernel (-1 = None)
SI_QHEAD = 4         # SRTF sample queue head/tail into Q
SI_QTAIL = 5
SI_SHARING = 6       # SRTFAdaptive sharing flag
SI_ACTIVE_N = 7
SI_ACTIVE_DIRTY = 8
SI_EXIT_RUN = 9      # run index reported with exit code 2
SI_TRACE_N = 10
SI_DEC_N = 11
SI_PRED_N = 12
SI_RESUME = 13       # enter with a machine-wide fan-out (post-completion)
SI_LEN = 14

# SD: engine float scalars.
SD_NOW = 0
SD_BUSY = 1
SD_HORIZON = 2       # +inf when until=None
SD_LEN = 3

# CI: integer configuration (never written by the engine).
CI_POLICY = 0
CI_NSM = 1
CI_NRUNS = 2
CI_UNLIMITED = 3     # policy.unlimited_caps
CI_FIXED_CAP = 4     # CappedFIFO.cap
CI_SAMPLE_SM = 5
CI_DRIVE_PRED = 6
CI_REC_TRACE = 7
CI_REC_DEC = 8
CI_REC_PRED = 9
CI_HAS_SOURCE = 10
CI_PRED_KIND = 11    # 0 = simple-slicing, 1 = ewma
CI_SHARED_RES = 12   # SRTFAdaptive.shared_residency
CI_HEAP_CAP = 13
CI_TRACE_CAP = 14
CI_DEC_CAP = 15
CI_PRED_CAP = 16
CI_SRC_MODE = 17     # 0 = python-mediated source, else SRCMODE_*
CI_SRC_RESERVE = 18  # max arrivals a single completion may inject
CI_LEN = 19

# CF: float configuration.
CF_ALPHA = 0         # EWMAPredictor.alpha
CF_THRESHOLD = 1     # SRTFAdaptive.unfairness_threshold
CF_HYSTERESIS = 2    # SRTFAdaptive.hysteresis
CF_LEN = 3

# RI: per-run integer state [nruns, RI_LEN].
RI_NUMB = 0          # spec.num_blocks
RI_MAXR = 1          # spec.max_residency
RI_TPB = 2           # spec.threads_per_block
RI_WARPS = 3         # spec.warps_per_block
RI_ISSUED = 4
RI_DONE = 5
RI_LAUNCHED = 6
RI_ELIG = 7          # SRTF eligible-set membership
RI_MPCAP = 8         # MPMax cap (-1 = absent from _caps)
RI_ADPCAP = 9        # SRTFAdaptive cap (-1 = absent from _caps)
RI_SYNCED = 10       # machine._synced_caps memo (-1 = absent)
RI_PKNOWN = 11       # predictor.has_kernel
RI_NOISE_OFF = 12    # offset into the noise pool
RI_BT_OFF = 13       # offset into the base_t_table pool
RI_EXPECTED = 14     # ceil(num_blocks / n_sm), precomputed at build
RI_SRC = 15          # emitted by the lowered arrival source (live set)
RI_STAGED = 16       # staged arrival row, not yet injected
RI_TENANT = 17       # think-time tenant id (-1 = none)
RI_LEN = 18

# RF: per-run float state [nruns, RF_LEN].
RF_MEANT = 0         # spec.mean_t
RF_FRAC = 1          # spec.resource_fraction
RF_CSENS = 2         # spec.corunner_sens
RF_CPRESS = 3        # spec.corunner_pressure
RF_STARTUP = 4       # spec.startup_factor
RF_STAGF = 5         # spec.stagger_frac
RF_ARRT = 6          # arrival_time
RF_FIN = 7           # finish_time (NaN = None)
RF_FIRST = 8         # first_issue_time (NaN = None)
RF_SJFKEY = 9        # sign * solo runtime (SJF/LJF rank key)
RF_ORACLE = 10       # oracle runtime (NaN = None)
RF_EXCL = 11         # SRTFAdaptive._excl_pred (NaN = absent)
RF_LEN = 12

# PS_I: per-(run, sm) integer state [nruns, nsm, PI_LEN].
PI_RES = 0           # run.resident_per_sm
PI_ISSD = 1          # run.issued_per_sm
PI_STAG = 2          # run.stagger_sm
PI_PDONE = 3         # predictor done_blocks
PI_PRESID = 4        # predictor resident_blocks
PI_PRESLICE = 5      # predictor reslice flag
PI_PRUN = 6          # predictor running_count
PI_LEN = 7

# PS_F: per-(run, sm) float state [nruns, nsm, PF_LEN].
PF_GATE = 0          # run.issue_gate
PF_PT = 1            # predictor t (NaN = None)
PF_PACT = 2          # predictor active_cycles
PF_PSINCE = 3        # predictor running_since
PF_LEN = 4

# SM_I: per-SM integer state [nsm, SMI_LEN]; cols 2.. are the free-slot
# stack, mirroring SMState.free_slots (a Python list used as a stack).
SMI_THR = 0          # used_threads
SMI_FREETOP = 1      # free-slot stack height
SMI_FS0 = 2
SMI_LEN = 2 + 8      # MAX_BLOCK_SLOTS

# SM_F: per-SM float state [nsm, 1].
SMF_FRAC = 0         # used_fraction

# HI/HF: binary heap of events [heap_cap, ...] — exact CPython heapq
# layout over rows compared by (time, kind, seq).
HI_KIND = 0
HI_SEQ = 1
HI_A = 2             # ARRIVAL: run | TRY_ISSUE: sm | BLOCK_END: run
HI_B = 3             # BLOCK_END: sm
HI_C = 4             # BLOCK_END: slot
HI_LEN = 5
HF_TIME = 0
HF_START = 1         # BLOCK_END: block start time
HF_LEN = 2

# TR_I/TR_F: trace records (run, sm, slot) + (start, end).
# DC_I/DC_F: decision records (sm, code, run) + (time,).
# PR_I/PR_F: prediction records (run, sm, done) + (time, pred).

# RWI/RWF: SRTFAdaptive fairness rows (run,) + (rem, elapsed, solo).
RW_REM = 0
RW_ELAPSED = 1
RW_SOLO = 2

# Event kinds (tie-break priority order, as in the reference).
EV_ARRIVAL = 0
EV_BLOCK_END = 1
EV_TRY_ISSUE = 2

# Decision codes (scattered back to events.Decision objects).
DEC_GRANT = 0
DEC_SAMPLE = 1
DEC_HOLD_HEAD = 2        # "head-of-line kernel does not fit"
DEC_HOLD_NO_UNDISP = 3   # "no kernel with undispatched blocks"
DEC_HOLD_SAMPLING = 4    # "sample in flight on the sampling SM"
DEC_HOLD_NO_ELIG = 5     # "no eligible kernel with a prediction"
DEC_HOLD_MPMAX = 6       # "all kernels at their MPMax reservation caps"
DEC_HOLD_ADAPTIVE = 7    # "all kernels at their adaptive sharing caps"
DEC_PREEMPT = 8          # PreemptAtBoundary(key)

# Lowered arrival-source modes (CI_SRC_MODE).
SRCMODE_MGK = 1          # MGkClosed, admission="defer"
SRCMODE_THINK = 2        # ThinkTime

# SRCI: arrival-source integer state (flat); SRCF holds one pre-drawn
# variate per staged row (mgk: offered absolute time; think: delay).
SRC_NEXT = 0         # staged variates consumed so far this staging
SRC_NSTAGED = 1      # staged window size
SRC_BASE = 2         # row index of the first staged run
SRC_MORE = 3         # variates exist beyond the staged window
SRC_INSYS = 4        # mgk: kernels currently in the closed system
SRC_POP = 5          # mgk: population bound
SRC_NROUNDS = 6      # think: rounds per tenant
SRC_PEND = 7         # think: tenant awaiting a variate (-1 = none)
SRC_RD0 = 8          # think: per-tenant rounds-done counters tail

# Policy ids.
POL_FIFO = 0
POL_FIFO_CAP = 1
POL_SJF = 2
POL_LJF = 3
POL_MPMAX = 4
POL_SRTF = 5
POL_SRTF_ZERO = 6
POL_SRTF_ADAPTIVE = 7

_EPS = 1e-9
_INF = float("inf")
MAX_BLOCK_SLOTS = 8
MAX_THREADS_PER_SM = 1536
MAX_WARPS_PER_SM = 48.0

#: None is encoded as NaN in every float cell (tested with ``x != x``).
_NAN = float("nan")

# S tuple layout (argument order of advance() and of the C entry point).
S_SI, S_SD, S_CI, S_CF, S_RI, S_RF = 0, 1, 2, 3, 4, 5
S_PSI, S_PSF, S_BS, S_SL, S_SMI, S_SMF = 6, 7, 8, 9, 10, 11
S_HI, S_HF, S_TRI, S_TRF, S_DCI, S_DCF = 12, 13, 14, 15, 16, 17
S_PRI, S_PRF, S_ACT, S_Q, S_RWI, S_RWF = 18, 19, 20, 21, 22, 23
S_NEWC, S_CAND, S_CREM, S_NP, S_BT = 24, 25, 26, 27, 28
S_SRCI, S_SRCF = 29, 30
S_LEN = 31


def _identity(fn):
    return fn


_jit = _identity
NUMBA_AVAILABLE = False
if os.environ.get("REPRO_NO_NUMBA", "") != "1":   # pragma: no cover
    try:
        import numba

        _jit = numba.njit(cache=True)
        NUMBA_AVAILABLE = True
    except ImportError:
        pass


# ------------------------------------------------------------------- heap
@_jit
def _heap_lt(hi, hf, i, j):
    ti = hf[i, HF_TIME]
    tj = hf[j, HF_TIME]
    if ti != tj:
        return ti < tj
    ki = hi[i, HI_KIND]
    kj = hi[j, HI_KIND]
    if ki != kj:
        return ki < kj
    return hi[i, HI_SEQ] < hi[j, HI_SEQ]


@_jit
def _lt_item(t, kind, seq, hi, hf, j):
    tj = hf[j, HF_TIME]
    if t != tj:
        return t < tj
    kj = hi[j, HI_KIND]
    if kind != kj:
        return kind < kj
    return seq < hi[j, HI_SEQ]


@_jit
def _copy_row(hi, hf, dst, src):
    hi[dst, 0] = hi[src, 0]
    hi[dst, 1] = hi[src, 1]
    hi[dst, 2] = hi[src, 2]
    hi[dst, 3] = hi[src, 3]
    hi[dst, 4] = hi[src, 4]
    hf[dst, 0] = hf[src, 0]
    hf[dst, 1] = hf[src, 1]


@_jit
def _heap_push(si, hi, hf, t, kind, seq, a, b, c, start):
    # CPython heapq.heappush: append then _siftdown(0, len-1) holding the
    # new item out of the array until its final position is known.
    pos = si[SI_HEAP_LEN]
    si[SI_HEAP_LEN] = pos + 1
    while pos > 0:
        parent = (pos - 1) >> 1
        if _lt_item(t, kind, seq, hi, hf, parent):
            _copy_row(hi, hf, pos, parent)
            pos = parent
        else:
            break
    hi[pos, HI_KIND] = kind
    hi[pos, HI_SEQ] = seq
    hi[pos, HI_A] = a
    hi[pos, HI_B] = b
    hi[pos, HI_C] = c
    hf[pos, HF_TIME] = t
    hf[pos, HF_START] = start


@_jit
def _heap_pop(si, hi, hf):
    # CPython heapq.heappop: take the last item, move the root out, then
    # _siftup(0) — unconditional child promotion down to a leaf followed
    # by a _siftdown — so the post-pop ARRAY LAYOUT matches list-based
    # heapq exactly (the truncation scan and the heap scatter rely on it).
    n = si[SI_HEAP_LEN] - 1
    si[SI_HEAP_LEN] = n
    lt = hf[n, HF_TIME]
    lk = hi[n, HI_KIND]
    ls = hi[n, HI_SEQ]
    la = hi[n, HI_A]
    lb = hi[n, HI_B]
    lc = hi[n, HI_C]
    lst = hf[n, HF_START]
    if n == 0:
        return lt, lk, ls, la, lb, lc, lst
    rt = hf[0, HF_TIME]
    rk = hi[0, HI_KIND]
    rs = hi[0, HI_SEQ]
    ra = hi[0, HI_A]
    rb = hi[0, HI_B]
    rc = hi[0, HI_C]
    rst = hf[0, HF_START]
    pos = 0
    childpos = 1
    while childpos < n:
        rightpos = childpos + 1
        if rightpos < n and not _heap_lt(hi, hf, childpos, rightpos):
            childpos = rightpos
        _copy_row(hi, hf, pos, childpos)
        pos = childpos
        childpos = 2 * pos + 1
    while pos > 0:
        parent = (pos - 1) >> 1
        if _lt_item(lt, lk, ls, hi, hf, parent):
            _copy_row(hi, hf, pos, parent)
            pos = parent
        else:
            break
    hi[pos, HI_KIND] = lk
    hi[pos, HI_SEQ] = ls
    hi[pos, HI_A] = la
    hi[pos, HI_B] = lb
    hi[pos, HI_C] = lc
    hf[pos, HF_TIME] = lt
    hf[pos, HF_START] = lst
    return rt, rk, rs, ra, rb, rc, rst


# ----------------------------------------------------- machine primitives
@_jit
def _refresh_active(S):
    """Rebuild the active list (launched, unfinished, arrival order)."""
    si = S[0]
    ci = S[2]
    ri = S[4]
    rf = S[5]
    act = S[20]
    if si[SI_ACTIVE_DIRTY] == 0:
        return
    n = 0
    for r in range(ci[CI_NRUNS]):
        if ri[r, RI_LAUNCHED] != 0 and rf[r, RF_FIN] != rf[r, RF_FIN]:
            act[n] = r
            n += 1
    si[SI_ACTIVE_N] = n
    si[SI_ACTIVE_DIRTY] = 0


@_jit
def _pol_residency_cap(S, r):
    """policy.residency_cap(key, sm) for the uniform built-in policies."""
    ci = S[2]
    ri = S[4]
    pol = ci[CI_POLICY]
    if pol == POL_FIFO_CAP:
        return ci[CI_FIXED_CAP]
    if pol == POL_MPMAX:
        cap = ri[r, RI_MPCAP]
        if cap >= 0:
            return cap
        return ri[r, RI_MAXR]
    if pol == POL_SRTF_ADAPTIVE:
        si = S[0]
        cap = ri[r, RI_ADPCAP]
        if si[SI_SHARING] != 0 and cap >= 0:
            return cap
        return ri[r, RI_MAXR]
    return ri[r, RI_MAXR]


@_jit
def _can_fit(S, r, sm):
    ci = S[2]
    ri = S[4]
    rf = S[5]
    psi = S[6]
    smi = S[10]
    smf = S[11]
    if ri[r, RI_NUMB] - ri[r, RI_ISSUED] <= 0:
        return False
    cap = ri[r, RI_MAXR]
    if ci[CI_UNLIMITED] == 0:
        pcap = _pol_residency_cap(S, r)
        if pcap < cap:
            cap = pcap
    if psi[r, sm, PI_RES] >= cap:
        return False
    if smi[sm, SMI_FREETOP] <= 0:
        return False
    if smi[sm, SMI_THR] + ri[r, RI_TPB] > MAX_THREADS_PER_SM:
        return False
    return smf[sm, SMF_FRAC] + rf[r, RF_FRAC] <= 1.0 + _EPS


# ----------------------------------------------------- predictor queries
@_jit
def _pred_remaining(S, r, sm):
    """predictor.remaining(key, sm); NaN stands in for None."""
    ri = S[4]
    psi = S[6]
    psf = S[7]
    if ri[r, RI_PKNOWN] == 0:
        return math.nan
    t = psf[r, sm, PF_PT]
    if t != t:
        return math.nan
    rb = ri[r, RI_EXPECTED] - psi[r, sm, PI_PDONE]
    if rb < 0:
        rb = 0
    res = psi[r, sm, PI_PRESID]
    if res <= 1:
        res = 1
    return (rb / res) * t


@_jit
def _gpu_remaining(S, r):
    """predictor.gpu_remaining(key): mean over SMs with a sample (NaN=None).

    The reference memoizes this per state version; the query is pure, so
    recomputing it here is bit-identical (same left-fold sum order).
    """
    ci = S[2]
    ri = S[4]
    psi = S[6]
    psf = S[7]
    if ri[r, RI_PKNOWN] == 0:
        return math.nan
    total = 0.0
    count = 0
    for sm in range(ci[CI_NSM]):
        t = psf[r, sm, PF_PT]
        if t != t:
            continue
        rb = ri[r, RI_EXPECTED] - psi[r, sm, PI_PDONE]
        if rb < 0:
            rb = 0
        res = psi[r, sm, PI_PRESID]
        if res <= 1:
            res = 1
        total = total + (rb / res) * t
        count += 1
    if count == 0:
        return math.nan
    return total / count


@_jit
def _gpu_predicted_total(S, r, now):
    """predictor.gpu_predicted_total(key, now) (NaN = None)."""
    ci = S[2]
    ri = S[4]
    psi = S[6]
    psf = S[7]
    if ri[r, RI_PKNOWN] == 0:
        return math.nan
    total = 0.0
    count = 0
    for sm in range(ci[CI_NSM]):
        t = psf[r, sm, PF_PT]
        if t != t:
            continue
        rb = ri[r, RI_EXPECTED] - psi[r, sm, PI_PDONE]
        if rb < 0:
            rb = 0
        res = psi[r, sm, PI_PRESID]
        if res <= 1:
            res = 1
        remaining = (rb / res) * t
        active = psf[r, sm, PF_PACT]
        if psi[r, sm, PI_PRUN] > 0:
            active = active + (now - psf[r, sm, PF_PSINCE])
        total = total + (active + remaining)
        count += 1
    if count == 0:
        return math.nan
    return total / count


# --------------------------------------------------- predictor handlers
@_jit
def _observe(S, r, sm, duration):
    """Predictor._observe — SS resamples at slice starts, EWMA blends."""
    ci = S[2]
    cf = S[3]
    psi = S[6]
    psf = S[7]
    if ci[CI_PRED_KIND] == 1:
        psi[r, sm, PI_PRESLICE] = 0
        if duration != duration:
            return
        t = psf[r, sm, PF_PT]
        if t != t:
            psf[r, sm, PF_PT] = duration
        else:
            alpha = cf[CF_ALPHA]
            psf[r, sm, PF_PT] = alpha * duration + (1.0 - alpha) * t
    else:
        if psi[r, sm, PI_PRESLICE] != 0 or psf[r, sm, PF_PT] != psf[r, sm, PF_PT]:
            if duration == duration:
                psf[r, sm, PF_PT] = duration
            psi[r, sm, PI_PRESLICE] = 0


@_jit
def _pred_on_launch(S, r):
    """SimpleSlicingPredictor.on_launch: fresh per-SM rows + reslice others."""
    ci = S[2]
    ri = S[4]
    psi = S[6]
    psf = S[7]
    bs = S[8]
    nsm = ci[CI_NSM]
    residency = ri[r, RI_MAXR]
    if residency < 1:
        residency = 1
    for sm in range(nsm):
        psi[r, sm, PI_PDONE] = 0
        psi[r, sm, PI_PRESID] = residency
        psi[r, sm, PI_PRESLICE] = 1
        psi[r, sm, PI_PRUN] = 0
        psf[r, sm, PF_PT] = math.nan
        psf[r, sm, PF_PACT] = 0.0
        psf[r, sm, PF_PSINCE] = 0.0
        for slot in range(MAX_BLOCK_SLOTS):
            bs[r, sm, slot] = math.nan
    ri[r, RI_PKNOWN] = 1
    for other in range(ci[CI_NRUNS]):
        if other == r or ri[other, RI_PKNOWN] == 0:
            continue
        for sm in range(nsm):
            psi[other, sm, PI_PRESLICE] = 1


@_jit
def _pred_on_kernel_end(S, r):
    ci = S[2]
    ri = S[4]
    psi = S[6]
    for other in range(ci[CI_NRUNS]):
        if other == r or ri[other, RI_PKNOWN] == 0:
            continue
        for sm in range(ci[CI_NSM]):
            psi[other, sm, PI_PRESLICE] = 1


@_jit
def _pred_on_block_start(S, r, sm, slot, now):
    psi = S[6]
    psf = S[7]
    bs = S[8]
    bs[r, sm, slot] = now
    if psi[r, sm, PI_PRUN] == 0:
        psf[r, sm, PF_PSINCE] = now
    psi[r, sm, PI_PRUN] += 1


@_jit
def _pred_on_block_end(S, r, sm, slot, now):
    """SimpleSlicingPredictor.on_block_end + Eq. 2 (NaN = None)."""
    ci = S[2]
    ri = S[4]
    psi = S[6]
    psf = S[7]
    bs = S[8]
    psi[r, sm, PI_PDONE] += 1
    start = bs[r, sm, slot]
    bs[r, sm, slot] = math.nan
    if (psi[r, sm, PI_PRESLICE] != 0
            or psf[r, sm, PF_PT] != psf[r, sm, PF_PT]
            or ci[CI_PRED_KIND] == 1):
        if start != start:
            _observe(S, r, sm, math.nan)
        else:
            _observe(S, r, sm, now - start)
    rc = psi[r, sm, PI_PRUN] - 1
    psi[r, sm, PI_PRUN] = rc if rc > 0 else 0
    if rc <= 0:
        psf[r, sm, PF_PACT] = psf[r, sm, PF_PACT] + (now - psf[r, sm, PF_PSINCE])
    t = psf[r, sm, PF_PT]
    if t != t:
        return math.nan
    rb = ri[r, RI_EXPECTED] - psi[r, sm, PI_PDONE]
    if rb < 0:
        rb = 0
    res = psi[r, sm, PI_PRESID]
    if res <= 1:
        res = 1
    remaining = (rb / res) * t
    active = psf[r, sm, PF_PACT]
    if psi[r, sm, PI_PRUN] > 0:
        active = active + (now - psf[r, sm, PF_PSINCE])
    return active + remaining


@_jit
def _pred_on_residency_change(S, r, sm, new_residency):
    psi = S[6]
    if new_residency < 1:
        new_residency = 1
    if psi[r, sm, PI_PRESID] != new_residency:
        psi[r, sm, PI_PRESID] = new_residency
        psi[r, sm, PI_PRESLICE] = 1


@_jit
def _broadcast_t(S, r, t, from_sm):
    ci = S[2]
    psi = S[6]
    psf = S[7]
    for sm in range(ci[CI_NSM]):
        if sm == from_sm:
            continue
        if psf[r, sm, PF_PT] != psf[r, sm, PF_PT]:
            psf[r, sm, PF_PT] = t
            psi[r, sm, PI_PRESLICE] = 0


@_jit
def _sync_residency_caps(S):
    """MachineBase.sync_residency_caps, fast/uniform delta branch."""
    si = S[0]
    ci = S[2]
    ri = S[4]
    act = S[20]
    _refresh_active(S)
    for i in range(si[SI_ACTIVE_N]):
        r = act[i]
        if ri[r, RI_PKNOWN] == 0:
            continue
        cap = ri[r, RI_MAXR]
        if ci[CI_UNLIMITED] == 0:
            pcap = _pol_residency_cap(S, r)
            if pcap < cap:
                cap = pcap
        if ri[r, RI_SYNCED] == cap:
            continue
        for sm in range(ci[CI_NSM]):
            _pred_on_residency_change(S, r, sm, cap)
        ri[r, RI_SYNCED] = cap


# ------------------------------------------------------------ policy layer
@_jit
def _mpmax_recompute(S):
    """MPMax._recompute: fresh caps over the active set (arrival order)."""
    si = S[0]
    ci = S[2]
    ri = S[4]
    rf = S[5]
    act = S[20]
    _refresh_active(S)
    for r in range(ci[CI_NRUNS]):
        ri[r, RI_MPCAP] = -1
    n = si[SI_ACTIVE_N]
    for i in range(n):
        r = act[i]
        reserved = 0.0
        for j in range(n):
            other = act[j]
            if other != r:
                reserved = reserved + rf[other, RF_FRAC]
        cap = int(math.floor(ri[r, RI_MAXR] * (1.0 - reserved)))
        if cap < 1:
            cap = 1
        ri[r, RI_MPCAP] = cap


@_jit
def _start_next_sample(S):
    """SRTF._start_next_sample: pop the queue to the next sampling kernel."""
    si = S[0]
    ri = S[4]
    rf = S[5]
    q = S[21]
    while si[SI_SAMPLING] < 0 and si[SI_QHEAD] < si[SI_QTAIL]:
        r = q[si[SI_QHEAD]]
        si[SI_QHEAD] += 1
        if ri[r, RI_ELIG] != 0:
            continue
        if rf[r, RF_FIN] == rf[r, RF_FIN]:   # run.finished
            continue
        si[SI_SAMPLING] = r


@_jit
def _queue_remove(S, r):
    """deque.remove(key): drop the first occurrence, shift the tail left."""
    si = S[0]
    q = S[21]
    head = si[SI_QHEAD]
    tail = si[SI_QTAIL]
    for i in range(head, tail):
        if q[i] == r:
            for j in range(i, tail - 1):
                q[j] = q[j + 1]
            si[SI_QTAIL] = tail - 1
            return


@_jit
def _srtf_remaining(S, r, sm):
    """SRTF._remaining (base) / SRTFZeroSampling._remaining override."""
    ci = S[2]
    ri = S[4]
    rf = S[5]
    if ci[CI_POLICY] == POL_SRTF_ZERO:
        rt = rf[r, RF_ORACLE]
        if rt == rt:
            numb = ri[r, RI_NUMB]
            if numb < 1:
                numb = 1
            frac_left = 1.0 - ri[r, RI_DONE] / numb
            return rt * frac_left
    rem = _pred_remaining(S, r, sm)
    if rem == rem:
        return rem
    rem = _gpu_remaining(S, r)
    if rem == rem:
        return rem
    return _INF


@_jit
def _best_candidate(S, sm):
    """SRTF._best_candidate: census first, then a min scan on
    (remaining, order) — order IS the active-array position's run index
    ordering, and run indices are arrival-ordered."""
    si = S[0]
    ri = S[4]
    act = S[20]
    _refresh_active(S)
    n = si[SI_ACTIVE_N]
    sole = -1
    count = 0
    for i in range(n):
        r = act[i]
        if ri[r, RI_ELIG] == 0:
            continue
        if ri[r, RI_NUMB] > ri[r, RI_ISSUED]:
            count += 1
            if count > 1:
                break
            sole = r
    if count == 0:
        return -1
    if count == 1:
        return sole
    best = -1
    best_rem = 0.0
    for i in range(n):
        r = act[i]
        if ri[r, RI_ELIG] == 0:
            continue
        if ri[r, RI_NUMB] <= ri[r, RI_ISSUED]:
            continue
        rem = _srtf_remaining(S, r, sm)
        # run order is monotone in r, so "rem == best and order < best"
        # can never fire on a later r: strict < suffices.
        if best < 0 or rem < best_rem:
            best = r
            best_rem = rem
    return best


@_jit
def _adaptive_candidates(S, sm):
    """SRTFAdaptive sharing-mode candidate list: eligible actives with
    unissued blocks, stably sorted by predicted remaining time."""
    si = S[0]
    ri = S[4]
    act = S[20]
    cand = S[25]
    crem = S[26]
    _refresh_active(S)
    m = 0
    for i in range(si[SI_ACTIVE_N]):
        r = act[i]
        if ri[r, RI_ELIG] != 0 and ri[r, RI_NUMB] > ri[r, RI_ISSUED]:
            cand[m] = r
            crem[m] = _srtf_remaining(S, r, sm)
            m += 1
    # Stable insertion sort by remaining time == sorted(key=(rem, order))
    # because the gather order above is already the order tie-break.
    for i in range(1, m):
        kr = cand[i]
        kv = crem[i]
        j = i - 1
        while j >= 0 and crem[j] > kv:
            cand[j + 1] = cand[j]
            crem[j + 1] = crem[j]
            j -= 1
        cand[j + 1] = kr
        crem[j + 1] = kv
    return m


@_jit
def _adaptive_loser_cap(S, r, winner):
    """SRTFAdaptive._loser_cap(spec, winner_spec)."""
    ci = S[2]
    ri = S[4]
    rf = S[5]
    shared_w = ci[CI_SHARED_RES]
    wmax = ri[winner, RI_MAXR]
    if wmax < shared_w:
        shared_w = wmax
    free_frac = 1.0 - shared_w * rf[winner, RF_FRAC]
    cap = int(math.floor(free_frac * ri[r, RI_MAXR]))
    if cap < 1:
        cap = 1
    return cap


@_jit
def _adaptive_cap_now(S, r):
    """SRTFAdaptive._cap_now: the stored cap regardless of sharing flag."""
    ri = S[4]
    cap = ri[r, RI_ADPCAP]
    if cap >= 0:
        return cap
    return ri[r, RI_MAXR]


@_jit
def _adaptive_reevaluate(S, now):
    """SRTFAdaptive._reevaluate: fairness projections + cap updates."""
    si = S[0]
    ci = S[2]
    cf = S[3]
    ri = S[4]
    rf = S[5]
    act = S[20]
    rwi = S[22]
    rwf = S[23]
    newc = S[24]
    _refresh_active(S)
    sharing = si[SI_SHARING] != 0
    if not sharing and si[SI_ACTIVE_N] < 2:
        return
    # _predictions(): rows over active-and-eligible kernels, or None.
    nrows = 0
    ok = True
    for i in range(si[SI_ACTIVE_N]):
        r = act[i]
        if ri[r, RI_ELIG] == 0:
            continue
        rwi[nrows] = r
        nrows += 1
    if nrows < 2:
        ok = False
    if ok:
        for i in range(nrows):
            r = rwi[i]
            rem = _gpu_remaining(S, r)
            if rem != rem:
                ok = False
                break
            solo = rf[r, RF_EXCL]
            if solo != solo:
                solo = _gpu_predicted_total(S, r, now)
            if solo != solo or solo <= 0.0:
                ok = False
                break
            rwf[i, RW_REM] = rem
            rwf[i, RW_ELAPSED] = now - rf[r, RF_ARRT]
            rwf[i, RW_SOLO] = solo
    if not ok:
        if sharing:
            si[SI_SHARING] = 0
            for r in range(ci[CI_NRUNS]):
                ri[r, RI_ADPCAP] = -1
            _sync_residency_caps(S)
        return
    # rows.sort(key=remaining) — stable insertion sort (gather order is
    # the arrival order, so ties keep it, exactly like list.sort).
    for i in range(1, nrows):
        kr = rwi[i]
        v0 = rwf[i, RW_REM]
        v1 = rwf[i, RW_ELAPSED]
        v2 = rwf[i, RW_SOLO]
        j = i - 1
        while j >= 0 and rwf[j, RW_REM] > v0:
            rwi[j + 1] = rwi[j]
            rwf[j + 1, RW_REM] = rwf[j, RW_REM]
            rwf[j + 1, RW_ELAPSED] = rwf[j, RW_ELAPSED]
            rwf[j + 1, RW_SOLO] = rwf[j, RW_SOLO]
            j -= 1
        rwi[j + 1] = kr
        rwf[j + 1, RW_REM] = v0
        rwf[j + 1, RW_ELAPSED] = v1
        rwf[j + 1, RW_SOLO] = v2
    # _project_exclusive: cumulative hand-off, gap tracked on the fly
    # (max(list) - min(list) is comparison-only, so no FP difference).
    acc = 0.0
    ex_max = 0.0
    ex_min = 0.0
    for i in range(nrows):
        acc = acc + rwf[i, RW_REM]
        s = (rwf[i, RW_ELAPSED] + acc) / rwf[i, RW_SOLO]
        if i == 0:
            ex_max = s
            ex_min = s
        else:
            if s > ex_max:
                ex_max = s
            if s < ex_min:
                ex_min = s
    gap_excl = ex_max - ex_min
    # _project_sharing.
    winner = rwi[0]
    w_cap_now = _adaptive_cap_now(S, winner)
    wmax = ri[winner, RI_MAXR]
    cur_cap = w_cap_now if w_cap_now < wmax else wmax
    if cur_cap < 1:
        cur_cap = 1
    shared_w = ci[CI_SHARED_RES]
    if wmax < shared_w:
        shared_w = wmax
    ts1 = rwf[0, RW_REM] * cur_cap / shared_w
    s0 = (rwf[0, RW_ELAPSED] + ts1) / rwf[0, RW_SOLO]
    sh_max = s0
    sh_min = s0
    for i in range(1, nrows):
        r = rwi[i]
        full = ri[r, RI_MAXR]
        shared_cap = _adaptive_loser_cap(S, r, winner)
        cur = _adaptive_cap_now(S, r)
        if cur > full:
            cur = full
        if cur < 1:
            cur = 1
        s_l = rwf[i, RW_REM] * cur / shared_cap
        if s_l <= ts1:
            s = (rwf[i, RW_ELAPSED] + s_l) / rwf[i, RW_SOLO]
        else:
            tail = (s_l - ts1) * shared_cap / full
            s = (rwf[i, RW_ELAPSED] + ts1 + tail) / rwf[i, RW_SOLO]
        if s > sh_max:
            sh_max = s
        if s < sh_min:
            sh_min = s
    gap_shared = sh_max - sh_min
    want = (gap_excl > cf[CF_THRESHOLD]
            and gap_shared < gap_excl - cf[CF_HYSTERESIS])
    # new_caps and the dict-inequality test against the current caps.
    if want:
        for i in range(nrows):
            r = rwi[i]
            if r == winner:
                cap = ci[CI_SHARED_RES]
                if ri[r, RI_MAXR] < cap:
                    cap = ri[r, RI_MAXR]
            else:
                cap = _adaptive_loser_cap(S, r, winner)
            newc[i] = cap
    changed = want != sharing
    if not changed:
        old_n = 0
        for r in range(ci[CI_NRUNS]):
            if ri[r, RI_ADPCAP] >= 0:
                old_n += 1
        if want:
            if old_n != nrows:
                changed = True
            else:
                for i in range(nrows):
                    if ri[rwi[i], RI_ADPCAP] != newc[i]:
                        changed = True
                        break
        else:
            changed = old_n != 0
    if changed:
        si[SI_SHARING] = 1 if want else 0
        for r in range(ci[CI_NRUNS]):
            ri[r, RI_ADPCAP] = -1
        if want:
            for i in range(nrows):
                ri[rwi[i], RI_ADPCAP] = newc[i]
        _sync_residency_caps(S)


@_jit
def _decide(S, sm):
    """Policy.decide(sm) → (decision code, kernel index or -1).

    Pure function of scheduler state, mirroring each policy's decide
    method branch for branch.  The engine always asks (no min-footprint
    precheck, no era memo): decisions are side-effect-free and
    era-stable, so the reference's skipped/memoized asks return exactly
    what a fresh ask would — the recorded decision log is identical.
    """
    si = S[0]
    ci = S[2]
    ri = S[4]
    rf = S[5]
    act = S[20]
    cand = S[25]
    pol = ci[CI_POLICY]
    if pol == POL_FIFO or pol == POL_FIFO_CAP:
        _refresh_active(S)
        for i in range(si[SI_ACTIVE_N]):
            r = act[i]
            if ri[r, RI_NUMB] > ri[r, RI_ISSUED]:
                if _can_fit(S, r, sm):
                    return DEC_GRANT, r
                return DEC_HOLD_HEAD, -1
        return DEC_HOLD_NO_UNDISP, -1
    if pol == POL_SJF or pol == POL_LJF:
        # Head-of-line over the (sign * runtime, order) sorted actives ==
        # min over actives WITH undispatched blocks (exhausted kernels
        # are skipped by the reference walk; run index == arrival order,
        # so scanning r ascending makes strict < the whole tie-break).
        _refresh_active(S)
        best = -1
        best_key = 0.0
        for i in range(si[SI_ACTIVE_N]):
            r = act[i]
            if ri[r, RI_NUMB] <= ri[r, RI_ISSUED]:
                continue
            k = rf[r, RF_SJFKEY]
            if best < 0 or k < best_key:
                best = r
                best_key = k
        if best < 0:
            return DEC_HOLD_NO_UNDISP, -1
        if _can_fit(S, best, sm):
            return DEC_GRANT, best
        return DEC_HOLD_HEAD, -1
    if pol == POL_MPMAX:
        _refresh_active(S)
        for i in range(si[SI_ACTIVE_N]):
            r = act[i]
            if ri[r, RI_NUMB] > ri[r, RI_ISSUED] and _can_fit(S, r, sm):
                return DEC_GRANT, r
        return DEC_HOLD_MPMAX, -1
    # SRTF family.
    if pol == POL_SRTF_ADAPTIVE and si[SI_SHARING] != 0:
        if si[SI_SAMPLING] >= 0 and sm == ci[CI_SAMPLE_SM]:
            k = si[SI_SAMPLING]
            if ri[k, RI_NUMB] > ri[k, RI_ISSUED] and _can_fit(S, k, sm):
                return DEC_SAMPLE, k
            return DEC_HOLD_SAMPLING, -1
        m = _adaptive_candidates(S, sm)
        for i in range(m):
            if _can_fit(S, cand[i], sm):
                return DEC_GRANT, cand[i]
        return DEC_HOLD_ADAPTIVE, -1
    if si[SI_SAMPLING] >= 0 and sm == ci[CI_SAMPLE_SM]:
        k = si[SI_SAMPLING]
        if ri[k, RI_NUMB] > ri[k, RI_ISSUED] and _can_fit(S, k, sm):
            return DEC_SAMPLE, k
        return DEC_HOLD_SAMPLING, -1
    k = _best_candidate(S, sm)
    if k < 0:
        return DEC_HOLD_NO_ELIG, -1
    if _can_fit(S, k, sm):
        return DEC_GRANT, k
    # Exclusive execution: no backfilling behind the SRTF winner.
    return DEC_PREEMPT, k


@_jit
def _pol_on_arrival(S, r, now):
    si = S[0]
    ci = S[2]
    ri = S[4]
    q = S[21]
    pol = ci[CI_POLICY]
    if pol == POL_MPMAX:
        _mpmax_recompute(S)
        return
    if pol == POL_SRTF_ZERO:
        ri[r, RI_ELIG] = 1          # no sampling phase
        return
    if pol == POL_SRTF or pol == POL_SRTF_ADAPTIVE:
        _refresh_active(S)
        if si[SI_ACTIVE_N] == 1:
            # Arrived on an idle machine: runs immediately.
            ri[r, RI_ELIG] = 1
        else:
            q[si[SI_QTAIL]] = r
            si[SI_QTAIL] += 1
            _start_next_sample(S)
        if pol == POL_SRTF_ADAPTIVE:
            _adaptive_reevaluate(S, now)


@_jit
def _pol_on_block_end(S, r, sm, now):
    si = S[0]
    ci = S[2]
    ri = S[4]
    rf = S[5]
    psf = S[7]
    pol = ci[CI_POLICY]
    if pol < POL_SRTF:
        return
    # SRTF.on_block_end: the sampling SM finishing a sampled block
    # promotes the sampled kernel to eligible.
    if r == si[SI_SAMPLING] and sm == ci[CI_SAMPLE_SM]:
        t = psf[r, sm, PF_PT]       # predictor.sampled_t(key, sm)
        if t == t:
            _broadcast_t(S, r, t, sm)
            ri[r, RI_ELIG] = 1
            si[SI_SAMPLING] = -1
            _start_next_sample(S)
    if pol == POL_SRTF_ADAPTIVE:
        if si[SI_SHARING] == 0:
            _refresh_active(S)
            if (si[SI_ACTIVE_N] > 1 or si[SI_PENDING] > 0
                    or ci[CI_HAS_SOURCE] != 0):
                pred = _gpu_predicted_total(S, r, now)
                if pred == pred:
                    rf[r, RF_EXCL] = pred
        _adaptive_reevaluate(S, now)


@_jit
def _pol_on_kernel_end(S, r, now):
    si = S[0]
    ci = S[2]
    ri = S[4]
    rf = S[5]
    act = S[20]
    pol = ci[CI_POLICY]
    if pol == POL_MPMAX:
        _mpmax_recompute(S)
        return
    if pol < POL_SRTF:
        return
    ri[r, RI_ELIG] = 0
    if si[SI_SAMPLING] == r:
        si[SI_SAMPLING] = -1
    _queue_remove(S, r)
    _start_next_sample(S)
    # If only one kernel remains un-predicted, it no longer needs a
    # sample to be scheduled.
    _refresh_active(S)
    if si[SI_ACTIVE_N] == 1:
        ri[act[0], RI_ELIG] = 1
    if pol == POL_SRTF_ADAPTIVE:
        rf[r, RF_EXCL] = _NAN
        _adaptive_reevaluate(S, now)


# ------------------------------------------------------------- issue loop
@_jit
def _finalize_block(S, r, sm, slot, noise_idx, first_wave, now):
    """Simulator._finalize_block: duration at post-batch SM conditions."""
    si = S[0]
    ci = S[2]
    ri = S[4]
    rf = S[5]
    psi = S[6]
    act = S[20]
    hi = S[12]
    hf = S[13]
    tri = S[14]
    trf = S[15]
    np_pool = S[27]
    bt_pool = S[28]
    residency = psi[r, sm, PI_RES]
    # Co-runner pressure summed in arrival order over resident kernels.
    corunner_warps = 0.0
    _refresh_active(S)
    for i in range(si[SI_ACTIVE_N]):
        other = act[i]
        if other == r:
            continue
        cnt = psi[other, sm, PI_RES]
        if cnt != 0:
            corunner_warps = corunner_warps + (
                (rf[other, RF_CPRESS] * cnt) * ri[other, RI_WARPS])
    maxr = ri[r, RI_MAXR]
    idx = residency if residency < maxr else maxr
    t = bt_pool[ri[r, RI_BT_OFF] + idx]
    if corunner_warps > 0.0:
        t = t * (1.0 + rf[r, RF_CSENS] * (corunner_warps
                                          / MAX_WARPS_PER_SM))
    if first_wave != 0 and rf[r, RF_STARTUP] > 0.0:
        t = t * (1.0 + rf[r, RF_STARTUP])
    base = t if t > 1.0 else 1.0    # max(t, 1.0)
    duration = base * np_pool[ri[r, RI_NOISE_OFF] + noise_idx]
    if ci[CI_DRIVE_PRED] != 0:
        _pred_on_block_start(S, r, sm, slot, now)
    end = now + duration
    seq = si[SI_SEQ]
    si[SI_SEQ] = seq + 1
    _heap_push(si, hi, hf, end, EV_BLOCK_END, seq, r, sm, slot, now)
    if ci[CI_REC_TRACE] != 0:
        n = si[SI_TRACE_N]
        tri[n, 0] = r
        tri[n, 1] = sm
        tri[n, 2] = slot
        trf[n, 0] = now
        trf[n, 1] = end
        si[SI_TRACE_N] = n + 1


@_jit
def _try_issue(S, sm, now):
    """Simulator._try_issue: batch-grant, then finalize at post-batch
    residency.  The batch is bounded by MAX_BLOCK_SLOTS (every grant
    consumes a slot and grants require a free slot)."""
    si = S[0]
    ci = S[2]
    ri = S[4]
    rf = S[5]
    psi = S[6]
    psf = S[7]
    sl = S[9]
    smi_a = S[10]
    smf = S[11]
    hi = S[12]
    hf = S[13]
    dci = S[16]
    dcf = S[17]
    batch = np.empty((MAX_BLOCK_SLOTS, 4), np.int64)
    nb = 0
    while True:
        code, r = _decide(S, sm)
        if ci[CI_REC_DEC] != 0:
            n = si[SI_DEC_N]
            dci[n, 0] = sm
            dci[n, 1] = code
            dci[n, 2] = r
            dcf[n, 0] = now
            si[SI_DEC_N] = n + 1
        if code > DEC_SAMPLE:
            break
        gate = psf[r, sm, PF_GATE]
        if gate > now + _EPS:
            seq = si[SI_SEQ]
            si[SI_SEQ] = seq + 1
            _heap_push(si, hi, hf, gate, EV_TRY_ISSUE, seq, sm, 0, 0, 0.0)
            break
        # --- allocate (inlined, mirrors the reference field for field) --
        top = smi_a[sm, SMI_FREETOP] - 1
        smi_a[sm, SMI_FREETOP] = top
        slot = smi_a[sm, SMI_FS0 + top]
        sl[sm, slot] = r
        smi_a[sm, SMI_THR] = smi_a[sm, SMI_THR] + ri[r, RI_TPB]
        smf[sm, SMF_FRAC] = smf[sm, SMF_FRAC] + rf[r, RF_FRAC]
        psi[r, sm, PI_RES] += 1
        issued_on_sm = psi[r, sm, PI_ISSD]
        psi[r, sm, PI_ISSD] = issued_on_sm + 1
        if rf[r, RF_FIRST] != rf[r, RF_FIRST]:
            rf[r, RF_FIRST] = now
        first_wave = 1 if issued_on_sm < ri[r, RI_MAXR] else 0
        noise_idx = ri[r, RI_ISSUED]
        ri[r, RI_ISSUED] = noise_idx + 1
        if first_wave != 0 and psi[r, sm, PI_STAG] != 0:
            psf[r, sm, PF_GATE] = now + rf[r, RF_STAGF] * rf[r, RF_MEANT]
        batch[nb, 0] = r
        batch[nb, 1] = slot
        batch[nb, 2] = noise_idx
        batch[nb, 3] = first_wave
        nb += 1
    for i in range(nb):
        _finalize_block(S, batch[i, 0], sm, batch[i, 1], batch[i, 2],
                        batch[i, 3], now)


@_jit
def _fan_out(S, now):
    """Machine-wide issue opportunity (arrival / kernel end)."""
    ci = S[2]
    for sm in range(ci[CI_NSM]):
        _try_issue(S, sm, now)


@_jit
def _src_inject(S, r2, t, now):
    """Inject one staged arrival: the in-engine twin of
    Simulator.inject_arrival (clip to now, push EV_ARRIVAL, invalidate)."""
    si = S[0]
    ri = S[4]
    rf = S[5]
    hi = S[12]
    hf = S[13]
    if t < now:
        t = now
    ri[r2, RI_STAGED] = 0
    rf[r2, RF_ARRT] = t
    si[SI_PENDING] += 1
    seq = si[SI_SEQ]
    si[SI_SEQ] = seq + 1
    _heap_push(si, hi, hf, t, EV_ARRIVAL, seq, r2, 0, 0, 0.0)
    si[SI_ACTIVE_DIRTY] = 1


@_jit
def _src_release_mgk(S, now):
    """Release staged offered arrivals while the population has room.

    Returns 7 when the staged window is exhausted but more offered
    arrivals exist (the driver restages and resumes), else 0."""
    srci = S[29]
    srcf = S[30]
    while srci[SRC_INSYS] < srci[SRC_POP]:
        k = srci[SRC_NEXT]
        if k >= srci[SRC_NSTAGED]:
            if srci[SRC_MORE] != 0:
                return 7
            return 0
        srci[SRC_NEXT] = k + 1
        srci[SRC_INSYS] += 1
        _src_inject(S, srci[SRC_BASE] + k, srcf[k], now)
    return 0


@_jit
def _src_feed_think(S, r, now):
    """Resubmit for the completed kernel's tenant (think-time twin).

    Returns 7 when a variate is needed but the staged pool is empty
    (the tenant is parked in SRC_PEND for the resume), else 0."""
    ri = S[4]
    srci = S[29]
    srcf = S[30]
    ten = ri[r, RI_TENANT]
    if ten < 0:
        return 0
    if srci[SRC_RD0 + ten] >= srci[SRC_NROUNDS]:
        return 0
    k = srci[SRC_NEXT]
    if k >= srci[SRC_NSTAGED]:
        srci[SRC_PEND] = ten
        return 7
    srci[SRC_NEXT] = k + 1
    srci[SRC_RD0 + ten] += 1
    r2 = srci[SRC_BASE] + k
    ri[r2, RI_TENANT] = ten
    _src_inject(S, r2, now + srcf[k], now)
    return 0


@_jit
def _src_on_completion(S, r, now):
    """In-engine ``_feed_completion`` for lowered arrival sources.

    Returns 0 (handled natively), 7 (variate pool exhausted) or 2 (the
    source is not lowered: python must mediate)."""
    ci = S[2]
    ri = S[4]
    srci = S[29]
    mode = ci[CI_SRC_MODE]
    if mode == SRCMODE_MGK:
        if ri[r, RI_SRC] == 0:
            return 0
        srci[SRC_INSYS] -= 1
        return _src_release_mgk(S, now)
    if mode == SRCMODE_THINK:
        return _src_feed_think(S, r, now)
    return 2


@_jit
def _src_resume(S, now):
    """Finish the injection interrupted by a pool-exhaustion exit.

    Runs on RESUME entry after the driver restaged a fresh window;
    returns 7 if the fresh pool is somehow still exhausted, else 0."""
    ci = S[2]
    ri = S[4]
    srci = S[29]
    srcf = S[30]
    mode = ci[CI_SRC_MODE]
    if mode == SRCMODE_MGK:
        return _src_release_mgk(S, now)
    if mode == SRCMODE_THINK:
        ten = srci[SRC_PEND]
        if ten < 0:
            return 0
        k = srci[SRC_NEXT]
        if k >= srci[SRC_NSTAGED]:
            return 7
        srci[SRC_PEND] = -1
        srci[SRC_NEXT] = k + 1
        srci[SRC_RD0 + ten] += 1
        r2 = srci[SRC_BASE] + k
        ri[r2, RI_TENANT] = ten
        _src_inject(S, r2, now + srcf[k], now)
    return 0


@_jit
def _handle_block_end(S, r, sm, slot, start, now):
    """Returns 2 or 7 when a kernel completion must hand control back to
    the driver (feed a python-mediated source / restage the variate
    pool), else -1."""
    si = S[0]
    sd = S[1]
    ci = S[2]
    ri = S[4]
    rf = S[5]
    psi = S[6]
    sl = S[9]
    smi_a = S[10]
    smf = S[11]
    pri = S[18]
    prf = S[19]
    frac = rf[r, RF_FRAC]
    sd[SD_BUSY] = sd[SD_BUSY] + (now - start) * frac
    # Inlined SMState.free (same clamps), fused event dispatch.
    sl[sm, slot] = -1
    top = smi_a[sm, SMI_FREETOP]
    smi_a[sm, SMI_FS0 + top] = slot
    smi_a[sm, SMI_FREETOP] = top + 1
    ut = smi_a[sm, SMI_THR] - ri[r, RI_TPB]
    smi_a[sm, SMI_THR] = ut if ut > 0 else 0
    uf = smf[sm, SMF_FRAC] - frac
    smf[sm, SMF_FRAC] = uf if uf > 0.0 else 0.0
    psi[r, sm, PI_RES] -= 1
    ri[r, RI_DONE] += 1
    pred = _NAN
    if ci[CI_DRIVE_PRED] != 0:
        pred = _pred_on_block_end(S, r, sm, slot, now)
        _pol_on_block_end(S, r, sm, now)
    else:
        _pol_on_block_end(S, r, sm, now)
    if ci[CI_REC_PRED] != 0 and pred == pred:
        n = si[SI_PRED_N]
        pri[n, 0] = r
        pri[n, 1] = sm
        pri[n, 2] = psi[r, sm, PI_PDONE]
        prf[n, 0] = now
        prf[n, 1] = pred
        si[SI_PRED_N] = n + 1
    if ri[r, RI_DONE] == ri[r, RI_NUMB]:
        rf[r, RF_FIN] = now
        # SchedulerCore.post(KernelEnded): invalidate, predictor hook,
        # policy hook, cap sync — all BEFORE the completion feed/fan-out.
        si[SI_ACTIVE_DIRTY] = 1
        ri[r, RI_SYNCED] = -1
        _pred_on_kernel_end(S, r)
        _pol_on_kernel_end(S, r, now)
        _sync_residency_caps(S)
        if ci[CI_HAS_SOURCE] != 0:
            # _feed_completion may inject arrivals: lowered sources are
            # fed in-engine (0 = done, 7 = pool exhausted); otherwise
            # hand control back to the driver, which feeds the source
            # and re-enters with RESUME set (the engine then runs the
            # pending _fan_out).
            si[SI_EXIT_RUN] = r
            rc = _src_on_completion(S, r, now)
            if rc != 0:
                return rc
        _fan_out(S, now)
    else:
        _try_issue(S, sm, now)
    return -1


@_jit
def _handle_arrival(S, r, now):
    si = S[0]
    ri = S[4]
    si[SI_PENDING] -= 1
    # SchedulerCore.post(KernelArrived): launch, invalidate, predictor
    # on_launch, policy on_arrival, cap sync — then the machine-wide
    # issue fan-out.
    ri[r, RI_LAUNCHED] = 1
    si[SI_ACTIVE_DIRTY] = 1
    _pred_on_launch(S, r)
    _pol_on_arrival(S, r, now)
    _sync_residency_caps(S)
    _fan_out(S, now)


@_jit
def advance(S):
    """Process events until an exit condition (module docstring table)."""
    si = S[0]
    sd = S[1]
    ci = S[2]
    rf = S[5]
    hi = S[12]
    hf = S[13]
    nsm = ci[CI_NSM]
    if si[SI_RESUME] != 0:
        si[SI_RESUME] = 0
        rc = _src_resume(S, sd[SD_NOW])
        if rc != 0:
            return rc
        _fan_out(S, sd[SD_NOW])
    while True:
        # Headroom checks BEFORE the pop: one event dispatch can fan out
        # over every SM (<= 8 grants + 1 gate retry each) and record one
        # prediction, so these margins guarantee the buffers never
        # overflow mid-dispatch.
        if si[SI_HEAP_LEN] + 9 * nsm + 8 + ci[CI_SRC_RESERVE] > ci[CI_HEAP_CAP]:
            return 3
        if (ci[CI_REC_TRACE] != 0
                and si[SI_TRACE_N] + 8 * nsm + 8 > ci[CI_TRACE_CAP]):
            return 4
        if (ci[CI_REC_DEC] != 0
                and si[SI_DEC_N] + 9 * nsm + 8 > ci[CI_DEC_CAP]):
            return 5
        if ci[CI_REC_PRED] != 0 and si[SI_PRED_N] + 4 > ci[CI_PRED_CAP]:
            return 6
        if si[SI_HEAP_LEN] == 0:
            return 0
        t, kind, seq, a, b, c, start = _heap_pop(si, hi, hf)
        if t > sd[SD_HORIZON]:
            # Truncated: credit in-flight busy time; the popped event is
            # credited last and ``now`` is NOT advanced, exactly like the
            # reference's in-place scan.  The heap array layout matches
            # the reference event list element for element, so the
            # accumulation order is identical too.
            now = sd[SD_NOW]
            for i in range(si[SI_HEAP_LEN]):
                if hi[i, HI_KIND] == EV_BLOCK_END:
                    frac = rf[hi[i, HI_A], RF_FRAC]
                    d = now - hf[i, HF_START]
                    sd[SD_BUSY] = sd[SD_BUSY] + (d if d > 0.0 else 0.0) * frac
            if kind == EV_BLOCK_END:
                frac = rf[a, RF_FRAC]
                d = now - start
                sd[SD_BUSY] = sd[SD_BUSY] + (d if d > 0.0 else 0.0) * frac
            return 1
        sd[SD_NOW] = t
        if kind == EV_BLOCK_END:
            rc = _handle_block_end(S, a, b, c, start, t)
            if rc >= 0:
                return rc
        elif kind == EV_ARRIVAL:
            _handle_arrival(S, a, t)
        else:
            _try_issue(S, a, t)
