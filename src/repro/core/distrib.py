"""Distributed sweep fan-out: cell execution, the packed record cache, and
a pull-based cell dispatcher with worker cache sync.

This module is the *execution tier* under :mod:`repro.core.sweep`.  The
sweep runner owns cache **keys** (what a cell is); this module owns cache
**bytes** (how a record is stored) and cell **execution** (how a record is
produced), on either machine, under either dispatcher:

* **cell runners** — :func:`run_des_cell` / :func:`run_executor_cell` /
  :func:`run_cell` are the functions every dispatch path executes.  They
  live here (not in ``sweep.py``) so the dispatcher/runner tier is part of
  every machine's code fingerprint: an edit to how records are produced
  invalidates cached records, whichever dispatcher produced them
  (DESIGN.md Section 12; ``repro.analysis`` pins the closure).
* **record store** — per-key ``<sha256>.json`` files plus per-chunk
  ``<digest>.pack.jsonl`` packfiles (one atomic write per result chunk
  instead of one per cell), an LRU in-memory mirror with a size cap, and a
  startup scavenge for ``.<key>.<pid>.tmp`` orphans left by writers that
  died between ``write_text`` and ``os.replace``.
* **queue dispatcher** — :class:`QueueDispatcher` serializes the sweep's
  pending cells into self-contained tasks and serves them to N pull-based
  workers (local spawned ``python -m repro.launch.worker`` processes
  and/or remote workers connected over TCP), LPT-ordered, with
  heartbeat/death detection, bounded re-dispatch of a dead worker's
  in-flight cells, and two-way cache sync: each worker receives the run's
  queued-key manifest on connect and *prefills* any records its own local
  cache already holds; the parent ingests **only** keys it queued
  (duplicate and unqueued results are counted and dropped).
* **batched in-worker runner** — :func:`worker_serve` keeps one long-lived
  engine process per worker: the compiled DES backend, imports and ctypes
  setup are paid once, then every dispatched *chunk* of cells runs
  in-process and returns as one packed result frame (and one local
  packfile write when the worker keeps a cache), amortizing per-cell
  dispatch overhead by the chunk size.

The queue tier is DES-only by design: executor cells are wall-clock
measurements whose solo baselines are calibrated against local pool
contention (DESIGN.md Section 6); shipping them to other machines would
silently mix measurement conditions.  ``run_sweep(dispatcher="local")``
remains the bit-identical default path for both machines.

Everything on a result path here is deterministic; the wall-clock reads
are confined to the dispatcher/worker *control plane* (heartbeats, death
timeouts, stall detection) and are baselined individually in
``repro.analysis`` — they never shape a record or a key.
"""

from __future__ import annotations

import dataclasses
import gc
import hashlib
import heapq
import itertools
import json
import math
import os
import pickle
import socket
import struct
import subprocess
import sys
import threading
import time
from collections import OrderedDict
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from .metrics import evaluate_window
from .policies import make_policy
from .scenarios import executor_job, executor_workload
from .simulator import simulate

# =====================================================================
# Record store: NaN-safe JSON, LRU memo, packfiles, tmp scavenge
# =====================================================================


def nan_to_null(obj):
    """Replace float NaN with ``None``, recursively.

    ``json.dumps`` would otherwise emit the non-standard ``NaN`` token
    (rejected by strict parsers) into cache records and digest payloads;
    nothing-finished cells carry NaN STP/ANTT/fairness by design.
    """
    if isinstance(obj, float):
        return None if math.isnan(obj) else obj
    if isinstance(obj, dict):
        return {k: nan_to_null(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [nan_to_null(v) for v in obj]
    return obj


def canonical_digest(payload: dict) -> str:
    """SHA-256 over the canonical (sorted, compact, NaN-free) JSON form."""
    try:
        # Fast path: NaN-free payloads (the overwhelming majority) dump
        # directly; ``allow_nan=False`` makes json raise on the rest, and
        # only those pay the recursive nan_to_null rebuild.  Identical
        # bytes either way (tuples serialize as JSON arrays regardless).
        blob = json.dumps(payload, sort_keys=True,
                          separators=(",", ":"), allow_nan=False)
    except ValueError:
        blob = json.dumps(nan_to_null(payload), sort_keys=True,
                          separators=(",", ":"), allow_nan=False)
    return hashlib.sha256(blob.encode()).hexdigest()


def record_text(record: dict) -> str:
    """THE serialized form of a cache record.

    Every store path — per-key file, packfile line — must produce exactly
    these bytes, so records are byte-identical across dispatchers and the
    equivalence gate can compare text, not just parsed floats.
    """
    try:
        # Same fast path as canonical_digest: NaN-free records (the
        # common case — only nothing-finished cells carry NaN metrics)
        # skip the recursive rebuild; json raises on NaN/inf and the
        # exceptional records take nan_to_null.
        return json.dumps(record, sort_keys=True, allow_nan=False)
    except ValueError:
        return json.dumps(nan_to_null(record), sort_keys=True,
                          allow_nan=False)


#: Entry cap of the in-memory record mirror.  Multi-spec batch drivers
#: (the benchmark suite runs every table over one shared cache) used to
#: grow the mirror without bound; an LRU keeps warm-rerun hits for the
#: records still in play while old sweeps age out.
MEMO_CAP = int(os.environ.get("REPRO_SWEEP_MEMO_CAP", "4096"))


class RecordMemo:
    """Bounded LRU mirror of the on-disk cache, keyed (cache_dir, key).

    Content-addressed records never legitimately change, so a hit is
    always valid; the cap only bounds memory.  Thread-safe: dispatcher
    handler threads commit records concurrently.
    """

    def __init__(self, cap: int = MEMO_CAP):
        self.cap = max(1, int(cap))
        self._d: "OrderedDict[Tuple[str, str], dict]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Tuple[str, str]) -> Optional[dict]:
        with self._lock:
            hit = self._d.get(key)
            if hit is None:
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return hit

    def put(self, key: Tuple[str, str], record: dict) -> None:
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
            self._d[key] = record
            while len(self._d) > self.cap:
                # Baselined determinism finding (dict-popitem): on an
                # OrderedDict, popitem(last=False) IS the explicit
                # least-recently-used order — and eviction only bounds
                # memory; a record re-reads identically from disk.
                self._d.popitem(last=False)
                self.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._d.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._d), "cap": self.cap,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions}


#: The per-process record mirror (``sweep`` re-exports ``clear_cache_memo``).
_MEMO = RecordMemo()


def cache_memo_stats() -> Dict[str, int]:
    """Counters of the in-memory record mirror (exposed in sweep stats)."""
    return _MEMO.stats()


#: Per-cache-dir packfile index: dir -> {"files": set of seen pack paths,
#: "keys": key -> pack path}.  Rebuilt lazily when the dir's packfile set
#: changes (another process may append packs between reads).
_PACK_INDEX: Dict[str, Dict] = {}
_PACK_LOCK = threading.Lock()

PACK_SUFFIX = ".pack.jsonl"


def clear_cache_memo() -> None:
    """Drop the in-memory record mirror and the packfile index (tests that
    mutate cache files on disk out-of-band call this to force re-reads)."""
    _MEMO.clear()
    with _PACK_LOCK:
        _PACK_INDEX.clear()


def _pack_path_for(cache_dir: Path, key: str) -> Optional[Path]:
    """Packfile holding ``key``, per the (lazily refreshed) index."""
    ds = str(cache_dir)
    try:
        snapshot = {str(p) for p in cache_dir.glob(f"*{PACK_SUFFIX}")}
    except OSError:
        return None
    with _PACK_LOCK:
        entry = _PACK_INDEX.get(ds)
        if entry is None or entry["files"] != snapshot:
            keys = dict(entry["keys"]) if entry is not None else {}
            known = entry["files"] if entry is not None else set()
            new_files = sorted(snapshot - known)
            stale = known - snapshot
            if stale:
                keys = {k: p for k, p in keys.items() if p not in stale}
            for path in new_files:
                try:
                    with open(path, "r") as fh:
                        for line in fh:
                            k, _, _ = line.partition("\t")
                            keys[k] = path
                except OSError:
                    continue
            entry = {"files": snapshot, "keys": keys}
            _PACK_INDEX[ds] = entry
        hit = entry["keys"].get(key)
    return Path(hit) if hit is not None else None


def cache_read(cache_dir: Optional[Path], key: str) -> Optional[dict]:
    """Read one record: memo -> per-key file -> packfile."""
    if cache_dir is None:
        return None
    memo_key = (str(cache_dir), key)
    hit = _MEMO.get(memo_key)
    if hit is not None:
        return hit
    path = cache_dir / f"{key}.json"
    try:
        record = json.loads(path.read_text())
    except (FileNotFoundError, json.JSONDecodeError):
        record = None
    if record is not None:
        _MEMO.put(memo_key, record)
        return record
    pack = _pack_path_for(cache_dir, key)
    if pack is None:
        return None
    found = None
    try:
        with open(pack, "r") as fh:
            for line in fh:
                k, _, text = line.partition("\t")
                try:
                    rec = json.loads(text)
                except json.JSONDecodeError:
                    continue
                # Chunk locality: neighbours in a pack are neighbours in a
                # sweep — memo the whole pack while it is in hand.
                _MEMO.put((str(cache_dir), k), rec)
                if k == key:
                    found = rec
    except OSError:
        return None
    return found


def cache_write(cache_dir: Optional[Path], key: str, record: dict) -> None:
    """Atomically write one per-key record file and mirror it in memory."""
    if cache_dir is None:
        return
    cache_dir.mkdir(parents=True, exist_ok=True)
    path = cache_dir / f"{key}.json"
    tmp = cache_dir / f".{key}.{os.getpid()}.tmp"
    tmp.write_text(record_text(record))
    os.replace(tmp, path)  # atomic under concurrent writers
    # Mirror what a reader would decode (NaN -> null -> NaN round-trips in
    # the consumers), so a same-process warm hit is indistinguishable from
    # a disk hit.
    _MEMO.put((str(cache_dir), key), record)


def write_pack(cache_dir: Optional[Path],
               records: Dict[str, dict]) -> Optional[Path]:
    """Atomically write one packfile holding a whole chunk of records.

    One ``write + rename`` per chunk replaces one per cell — the queue
    dispatcher's ingest path and the worker's local cache both use this.
    The pack name is content-addressed over the contained keys, so two
    writers racing on the same chunk converge on the same file.  Each line
    is ``<key>\\t<record_text>``: the record bytes are exactly what
    :func:`cache_write` would have put in the per-key file.
    """
    if cache_dir is None or not records:
        return None
    cache_dir.mkdir(parents=True, exist_ok=True)
    body = "".join(f"{k}\t{record_text(records[k])}\n"
                   for k in sorted(records))
    digest = hashlib.sha256("\n".join(sorted(records)).encode()).hexdigest()
    path = cache_dir / f"{digest[:16]}{PACK_SUFFIX}"
    tmp = cache_dir / f".{path.name}.{os.getpid()}.tmp"
    tmp.write_text(body)
    os.replace(tmp, path)
    for k, rec in records.items():
        _MEMO.put((str(cache_dir), k), rec)
    with _PACK_LOCK:
        entry = _PACK_INDEX.get(str(cache_dir))
        if entry is not None:
            entry["files"].add(str(path))
            for k in records:
                entry["keys"][k] = str(path)
    return path


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True  # exists, owned by someone else
    return True


def scavenge_cache_dir(cache_dir: Optional[Path]) -> int:
    """Remove ``.<name>.<pid>.tmp`` orphans whose writer pid is dead.

    A worker killed between ``write_text`` and ``os.replace`` leaves its
    tmp file behind forever (the committed ``<key>.json`` it was about to
    replace — if any — stays intact: readers only ever open the final
    name, so a crashed writer can neither corrupt nor shadow a committed
    record).  The pid is part of the tmp name, so liveness is decidable;
    a live writer's in-flight tmp is left alone.  Returns the number of
    files removed; callers run this once per sweep before dispatch.
    """
    if cache_dir is None or not cache_dir.is_dir():
        return 0
    removed = 0
    for path in sorted(cache_dir.glob(".*.tmp")):
        parts = path.name[:-len(".tmp")].rsplit(".", 1)
        if len(parts) != 2 or not parts[1].isdigit():
            continue
        if _pid_alive(int(parts[1])):
            continue
        try:
            path.unlink()
            removed += 1
        except FileNotFoundError:
            pass
    return removed


# =====================================================================
# Cell runners (every dispatcher executes cells through these)
# =====================================================================


def _cell_record(res, solo: Dict[str, float]) -> dict:
    """Assemble the label-free cell record from a :class:`SimResult`."""
    solo_by_key = {k: solo[res.name[k]] for k in res.turnaround}
    window = evaluate_window(
        res.turnaround, solo_by_key, unfinished=res.unfinished,
        end_time=res.end_time, makespan=res.makespan,
        utilization=res.utilization)
    return {
        # WindowMetrics is a flat scalar dataclass; vars() is asdict()
        # without the per-field deepcopy recursion (hot: once per cell).
        "window": dict(vars(window)),
        "turnaround": dict(res.turnaround),
        "finish": dict(res.finish),
        "unfinished": list(res.unfinished),
        "names": dict(res.name),
        "arrival": dict(res.arrival),
    }


def run_des_cell(payload: dict) -> dict:
    """One DES simulation, evaluated over its observation window.

    Open-loop payloads carry materialized ``arrivals``; closed-loop
    payloads carry the scenario + workload name, and the worker builds a
    fresh single-use arrival process (the completions of *this* cell's
    policy drive it — that coupling is the experiment).
    """
    solo: Dict[str, float] = payload["solo"]
    if payload.get("closed_loop"):
        scn = payload["scenario_obj"]
        arrivals, source = [], scn.make_process(payload["workload_name"])
    else:
        arrivals, source = payload["arrivals"], None
    res = simulate(
        arrivals,
        lambda: make_policy(payload["policy"]),
        n_sm=payload["n_sm"],
        seed=payload["seed"],
        oracle_runtimes=solo,
        predictor=payload["predictor"],
        until=payload["until"],
        arrival_source=source,
        engine=payload.get("engine"),
    )
    return _cell_record(res, solo)


def _same_body(a: dict, b: dict) -> bool:
    """Whether two open-loop DES payloads share one simulation *body* —
    arrivals, solo oracle, seed, n_sm, until, engine — so only the
    policy/predictor axes differ.  Identity (not equality) on the shared
    objects: sibling payloads hold fresh list shells around one
    workload's :class:`Arrival` objects, and pickle preserves that
    sharing within one chunk frame.  Oracle-reordered siblings (SJF/LJF)
    share the same arrivals in a different order — order is part of the
    body, so the element-wise zip rejects them."""
    if a.get("closed_loop") or b.get("closed_loop"):
        return False
    arr_a, arr_b = a["arrivals"], b["arrivals"]
    return (len(arr_a) == len(arr_b)
            and all(x is y for x, y in zip(arr_a, arr_b))
            and a["solo"] is b["solo"]
            and a["seed"] == b["seed"]
            and a["n_sm"] == b["n_sm"]
            and a["until"] == b["until"]
            and a.get("engine") == b.get("engine"))


def _run_des_cell_fast(payload: dict, proto: Optional[dict]) -> dict:
    """:func:`run_des_cell` for the chunk runner: result-only mode.

    Compiled open-loop cells build the simulator directly (the exact
    construction :func:`~repro.core.simulator.simulate` performs) so the
    chunk runner can enable the two in-chunk amortizations: the lean
    terminal scatter (commit only what the record reads) and the shared
    staging prototype ``proto`` (siblings memcpy the staged arrays
    instead of rebuilding — DESIGN.md Section 13).  Everything else —
    closed-loop cells, the reference engine — takes the plain per-cell
    path; records are byte-identical either way.
    """
    from .fastsim import FastSimulator, default_engine

    engine = payload.get("engine") or default_engine()
    if engine != "compiled" or payload.get("closed_loop"):
        return run_des_cell(payload)
    solo: Dict[str, float] = payload["solo"]
    sim = FastSimulator(
        payload["arrivals"], make_policy(payload["policy"]),
        n_sm=payload["n_sm"], seed=payload["seed"],
        oracle_runtimes=solo, predictor=payload["predictor"])
    sim._lean_result = True
    if proto is not None:
        sim._stage_proto = proto
    return _cell_record(sim.run(until=payload["until"]), solo)


def run_des_chunk(payloads: Sequence[dict],
                  cache_dir: Optional[Path] = None, *,
                  read_cache: bool = True,
                  on_computed: Optional[Callable[[str], None]] = None
                  ) -> Dict[str, dict]:
    """Run a whole chunk of DES cell payloads in one call.

    The chunk is the amortization unit of both dispatch tiers: one
    packfile write for all computed records (instead of one file per
    cell), and one staging prototype shared by each run of adjacent
    same-body payloads (the sweep emits policy siblings adjacently, and
    LPT tie-breaks preserve that adjacency).  ``read_cache=False`` skips
    the per-cell cache probe — the local dispatcher resolves hits before
    queueing, so its pending cells are known misses.  ``on_computed`` is
    called with the key after each computed (non-hit) cell; the worker
    loop uses it for ``die_after`` failure injection.  Records are
    byte-identical to per-cell :func:`run_des_cell` runs.
    """
    records: Dict[str, dict] = {}
    fresh: Dict[str, dict] = {}
    proto: dict = {}
    prev: Optional[dict] = None
    # Cycle collection off for the chunk: each cell retires one simulator
    # object graph (cyclic through core.bind), and letting the collector
    # walk those mid-chunk costs ~10% of tiny-cell throughput.  The
    # garbage is bounded by the chunk and collected normally afterwards.
    gc_was_enabled = gc.isenabled()
    if gc_was_enabled:
        gc.disable()
    try:
        for payload in payloads:
            key = payload["key"]
            if read_cache:
                hit = cache_read(cache_dir, key)
                if hit is not None:
                    records[key] = hit
                    continue
            if prev is None or not _same_body(prev, payload):
                proto = {}
            prev = payload
            records[key] = fresh[key] = _run_des_cell_fast(payload, proto)
            if on_computed is not None:
                on_computed(key)
    finally:
        if gc_was_enabled:
            gc.enable()
    write_pack(cache_dir, fresh)
    return records


def _run_chunk(args: Tuple[Sequence[dict], Optional[Path]]
               ) -> Dict[str, dict]:
    """Module-level chunk entry point (pickles into pool workers)."""
    payloads, cache_dir = args
    return run_des_chunk(payloads, cache_dir, read_cache=False)


def run_executor_cell(payload: dict) -> dict:
    """One real-JAX executor run over the bridged workload.

    Same label-free record shape as the DES path (``window`` /
    ``turnaround`` / ``finish`` / ``unfinished`` / ``names`` /
    ``arrival``), plus ``measured: true`` — every float here is a
    wall-clock measurement.  Closed-loop payloads attach the arrival
    process through the same feedback edge as the DES, with the bridge
    scaling scenario cycles to lane seconds in both directions.
    """
    from .executor import LaneExecutor

    solo: Dict[str, float] = payload["solo"]
    n_lanes = payload["n_sm"]
    time_scale = payload["time_scale"]
    ex = LaneExecutor([], make_policy(payload["policy"]),
                      n_lanes=n_lanes,
                      predictor=payload["predictor"],
                      job_bridge=lambda a: executor_job(
                          a, n_lanes=n_lanes, time_scale=time_scale))
    ex.oracle_runtimes.update(solo)
    if payload.get("closed_loop"):
        scn = payload["scenario_obj"]
        ex.attach_arrival_source(scn.make_process(payload["workload_name"]),
                                 time_scale=time_scale)
    else:
        for key, job in executor_workload(payload["arrivals"],
                                          n_lanes=n_lanes,
                                          time_scale=time_scale):
            ex.add_job(job, key=key)
    ex.run(until=payload["until"])
    w = ex.window()
    solo_by_key = {k: solo[w.names[k]] for k in w.turnaround}
    window = evaluate_window(
        w.turnaround, solo_by_key, unfinished=w.unfinished,
        end_time=w.end_time, makespan=w.makespan,
        utilization=w.utilization)
    return {
        "window": dataclasses.asdict(window),
        "turnaround": dict(w.turnaround),
        "finish": dict(w.finish),
        "unfinished": list(w.unfinished),
        "names": dict(w.names),
        "arrival": dict(w.arrival),
        "measured": True,
    }


def run_cell(payload: dict) -> dict:
    """Execute one cell (module-level: pickles into worker processes).

    The payload carries *effective* arrivals/policy and the solo-runtime
    oracle; the returned record is label-free.  This is the local
    dispatcher's unit of work: DES records are written to the cache here,
    in the pool worker (the queue dispatcher instead ingests whole chunks
    parent-side through :func:`write_pack`).
    """
    if payload["machine"] == "executor":
        # Not written to disk: the key folds in a per-run nonce, so the
        # record could never be read back — persisting it would only grow
        # the cache directory without bound.
        return run_executor_cell(payload)
    record = run_des_cell(payload)
    cache_write(payload["cache_dir"], payload["key"], record)
    return record


def payload_cost(payload: dict) -> float:
    """LPT dispatch cost of one cell: total block count (DES cell cost
    tracks it); closed-loop cells are unknown-cost and go first."""
    arrivals = payload.get("arrivals")
    if arrivals is None:
        return math.inf
    return float(sum(a.spec.num_blocks for a in arrivals))


# =====================================================================
# Wire protocol: length-prefixed pickle frames over TCP
# =====================================================================

PROTOCOL_VERSION = 1

#: Refuse frames beyond this size — a corrupt length prefix must not
#: allocate unbounded memory.
_MAX_FRAME = 1 << 30

_HEADER = struct.Struct(">I")


class DispatchError(RuntimeError):
    """The queue dispatcher could not complete the sweep."""


def send_frame(sock: socket.socket, obj: dict,
               lock: Optional[threading.Lock] = None) -> None:
    blob = pickle.dumps(obj)
    if len(blob) > _MAX_FRAME:
        raise DispatchError(f"frame of {len(blob)} bytes exceeds the "
                            f"{_MAX_FRAME}-byte protocol cap")
    data = _HEADER.pack(len(blob)) + blob
    if lock is not None:
        with lock:
            sock.sendall(data)
    else:
        sock.sendall(data)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf.extend(chunk)
    return bytes(buf)


def recv_frame(sock: socket.socket) -> Optional[dict]:
    """One frame, or ``None`` on clean EOF.  Raises ``socket.timeout``
    when the peer goes silent past the socket timeout (the dispatcher
    treats that as worker death — no mid-frame resync is attempted)."""
    header = _recv_exact(sock, _HEADER.size)
    if header is None:
        return None
    (length,) = _HEADER.unpack(header)
    if length > _MAX_FRAME:
        raise DispatchError(f"peer announced a {length}-byte frame "
                            f"(cap {_MAX_FRAME}); stream corrupt")
    blob = _recv_exact(sock, length)
    if blob is None:
        return None
    return pickle.loads(blob)


# =====================================================================
# The pull-based queue dispatcher
# =====================================================================

#: Upper bound on cells per task frame; chunks smaller than this are used
#: when the worklist is short so every worker stays busy (see
#: :func:`chunk_size_for`).  384 balances the parent's per-turn cost
#: (each chunk is one result frame + one pack ingest, and with the
#: in-engine chunk runner the parent turn is a visible fraction of a
#: tiny-cell sweep) against re-dispatch granularity when a worker dies
#: mid-chunk and the task-frame size (a tiny-cell chunk of 384 is well
#: under 100 ms of work and ~100 KB of frame).
DEFAULT_CHUNK_MAX = 384

#: A chunk target of ~2 chunks per worker: LPT puts the heavy cells in
#: the first chunk of each worker, so the second-round chunks form the
#: tail — at most half a worker's share, while every committed chunk
#: amortizes one parent ingest turn over more cells.
_CHUNKS_PER_WORKER = 2


def chunk_size_for(n_cells: int, workers: int,
                   chunk_cells: Optional[int] = None,
                   chunk_max: int = DEFAULT_CHUNK_MAX) -> int:
    """The chunking policy (DESIGN.md Section 12): explicit override, else
    ``ceil(n / (4 * workers))`` clamped to [1, chunk_max]."""
    if chunk_cells is not None:
        return max(1, int(chunk_cells))
    per = math.ceil(n_cells / max(1, _CHUNKS_PER_WORKER * max(1, workers)))
    return max(1, min(chunk_max, per))


class _WorkerConn:
    """Dispatcher-side state of one connected worker."""

    _ids = itertools.count(1)

    def __init__(self, sock: socket.socket, addr):
        self.sock = sock
        self.addr = addr
        self.wid = next(self._ids)
        self.pid: Optional[int] = None
        self.hostname = "?"
        self.inflight: List[str] = []   # keys of the task in flight

    def label(self) -> str:
        return f"worker#{self.wid} pid={self.pid} @ {self.hostname}"


class QueueDispatcher:
    """Pull-based cell dispatcher: serve pending sweep cells to workers.

    ``pending`` is the sweep runner's list of self-contained cell payloads
    (each carries its cache ``key``).  Workers connect over TCP — either
    the ``workers`` local processes this dispatcher spawns
    (``spawn_workers=True``) or external ``python -m repro.launch.worker
    --connect host:port`` processes on any machine that shares the code
    fingerprint.  Cells are handed out in LPT order, ``chunk`` cells per
    task; a worker that dies (EOF, error, or heartbeat silence past
    ``heartbeat_timeout_s``) gets its un-committed in-flight cells
    re-queued, at most ``max_requeues`` times each before the run aborts.

    Cache sync: the welcome frame carries the run's queued-key manifest;
    a worker with a local cache immediately *prefills* the records it
    already holds and persists newly computed chunks locally, so a farm
    warms across runs.  The parent ingests only queued keys — duplicate
    or unqueued results are counted and dropped — and writes one packfile
    per result chunk.
    """

    def __init__(self, pending: Sequence[dict], *,
                 cache_dir: Optional[Union[str, Path]] = None,
                 workers: int = 2,
                 host: str = "127.0.0.1", port: int = 0,
                 chunk_cells: Optional[int] = None,
                 spawn_workers: bool = True,
                 heartbeat_s: float = 1.0,
                 heartbeat_timeout_s: Optional[float] = None,
                 stall_timeout_s: float = 120.0,
                 max_requeues: int = 3,
                 fingerprints: Optional[Dict[str, str]] = None,
                 worker_cache_dir: Optional[Union[str, Path]] = None,
                 worker_argv_extra: Sequence[str] = (),
                 spawn_mode: Optional[str] = None):
        for p in pending:
            if p.get("machine") == "executor":
                raise ValueError(
                    "the queue dispatcher is DES-only: executor cells are "
                    "wall-clock measurements calibrated against local pool "
                    "contention (DESIGN.md Section 6); run them with "
                    "dispatcher='local'")
        self.cache_dir = Path(cache_dir) if cache_dir is not None else None
        self.workers = max(1, int(workers))
        self.host, self.port = host, port
        self.spawn_workers = spawn_workers
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout_s = (heartbeat_timeout_s
                                    if heartbeat_timeout_s is not None
                                    else max(10.0, 10.0 * heartbeat_s))
        self.stall_timeout_s = stall_timeout_s
        self.max_requeues = max_requeues
        self.fingerprints = dict(fingerprints or {})
        self.worker_cache_dir = (Path(worker_cache_dir)
                                 if worker_cache_dir is not None else None)
        self.worker_argv_extra = list(worker_argv_extra)
        # Local workers fork from the parent by default: the interpreter,
        # NumPy, and the loaded compiled DES engine (ctypes .so / numba
        # dispatcher) are inherited instead of re-imported, so a farm is
        # serving chunks within milliseconds — the same amortization the
        # local fork pool already relies on.  "subprocess" spawns fresh
        # ``python -m repro.launch.worker`` processes (required when
        # ``worker_argv_extra`` carries CLI-only options, and the shape
        # remote workers use).
        if spawn_mode is None:
            spawn_mode = ("subprocess" if (worker_argv_extra or
                                           not hasattr(os, "fork"))
                          else "fork")
        if spawn_mode not in ("fork", "subprocess"):
            raise ValueError(f"unknown spawn_mode {spawn_mode!r}")
        if spawn_mode == "fork" and worker_argv_extra:
            raise ValueError(
                "worker_argv_extra needs spawn_mode='subprocess' (forked "
                "workers never re-parse the CLI)")
        self.spawn_mode = spawn_mode

        self._bykey: Dict[str, dict] = {}
        for p in pending:
            self._bykey.setdefault(p["key"], p)
        # LPT order: heaviest cells first; seq breaks ties deterministically
        # in queue order.  Dispatch order never affects record content —
        # results are keyed — only the straggler tail.
        self._heap: List[Tuple[float, int, str]] = sorted(
            (-payload_cost(p), seq, key)
            for seq, (key, p) in enumerate(self._bykey.items()))
        self._state: Dict[str, str] = {k: "queued" for k in self._bykey}
        self._requeues: Dict[str, int] = {}
        self.records: Dict[str, dict] = {}
        self.chunk = chunk_size_for(len(self._bykey), self.workers,
                                    chunk_cells)
        self.stats: Dict[str, int] = {
            "queue_workers": 0, "queue_chunk": self.chunk,
            "queue_tasks": 0, "queue_requeued_cells": 0,
            "queue_dead_workers": 0, "queue_duplicate_results": 0,
            "queue_unqueued_results": 0, "queue_prefilled": 0,
            "queue_packs_written": 0,
        }
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._done = len(self._bykey) == 0
        self._fatal: Optional[str] = None
        self._n_done = 0
        self._live = 0
        # Baselined determinism finding (wallclock): control-plane progress
        # stamp for stall detection only; never enters a record or a key.
        self._last_progress = time.monotonic()
        self._listener: Optional[socket.socket] = None
        self._threads: List[threading.Thread] = []
        self._procs: List[subprocess.Popen] = []
        self._fork_pids: List[int] = []

    # ------------------------------------------------------------- setup
    def start(self) -> int:
        """Bind, listen, start the accept loop (and local workers).
        Returns the bound port."""
        if self.cache_dir is not None:
            scavenge_cache_dir(self.cache_dir)
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((self.host, self.port))
        self._listener.listen(self.workers + 8)
        self._listener.settimeout(0.25)
        self.port = self._listener.getsockname()[1]
        # Workers are spawned BEFORE the accept/handler threads exist:
        # forking a process whose other threads may hold locks can deadlock
        # the child.  Early connections just sit in the listen backlog.
        if self.spawn_workers and not self._done:
            for _ in range(self.workers):
                if self.spawn_mode == "fork":
                    self._fork_pids.append(self._fork_worker())
                else:
                    self._procs.append(self._spawn_worker())
        acceptor = threading.Thread(target=self._accept_loop,
                                    name="dispatch-accept", daemon=True)
        acceptor.start()
        self._threads.append(acceptor)
        return self.port

    def _fork_worker(self) -> int:
        pid = os.fork()
        if pid != 0:
            return pid
        # Child: drop the inherited listener, serve over a fresh TCP
        # connection like any remote worker, and never return into the
        # parent's stack.  The handshake is vacuous (same process image ⇒
        # same fingerprints) but still exercised — the frames are the
        # protocol conformance surface the tests pin.
        code = 1
        try:
            self._listener.close()
            code = worker_serve(
                self.host or "127.0.0.1", self.port,
                cache_dir=self.worker_cache_dir,
                fingerprints=self.fingerprints,
                heartbeat_s=self.heartbeat_s)
        except BaseException:
            code = 1
        finally:
            os._exit(code)

    def _spawn_worker(self) -> subprocess.Popen:
        argv = [sys.executable, "-m", "repro.launch.worker",
                "--connect", f"{self.host or '127.0.0.1'}:{self.port}",
                "--heartbeat", str(self.heartbeat_s)]
        if self.worker_cache_dir is not None:
            argv += ["--cache-dir", str(self.worker_cache_dir)]
        argv += self.worker_argv_extra
        env = dict(os.environ)
        # The worker must resolve the same code tree as the parent (the
        # fingerprint handshake would reject anything else anyway).
        env["PYTHONPATH"] = os.pathsep.join(
            [p for p in sys.path if p] +
            ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
        return subprocess.Popen(argv, env=env, stdout=subprocess.DEVNULL)

    # ----------------------------------------------------------- serving
    def serve(self) -> Tuple[Dict[str, dict], Dict[str, int]]:
        """Block until every queued cell is committed; return
        ``(records, stats)``.  Raises :class:`DispatchError` on fatal
        conditions (fingerprint mismatch, a cell exceeding its re-dispatch
        budget, or no progress for ``stall_timeout_s``)."""
        try:
            with self._cond:
                while not self._done and self._fatal is None:
                    self._cond.wait(timeout=0.25)
                    # Baselined determinism finding (wallclock): stall
                    # watchdog on the control plane.
                    idle = time.monotonic() - self._last_progress
                    if not self._done and idle > self.stall_timeout_s:
                        self._fatal = (
                            f"no dispatch progress for {idle:.0f}s with "
                            f"{len(self._bykey) - self._n_done} cells left "
                            f"and {self._live} live worker(s)")
        finally:
            self._shutdown()
        if self._fatal is not None:
            raise DispatchError(self._fatal)
        return self.records, dict(self.stats)

    def run(self) -> Tuple[Dict[str, dict], Dict[str, int]]:
        self.start()
        return self.serve()

    def _shutdown(self) -> None:
        with self._cond:
            if self._fatal is None and not self._done:
                self._fatal = "dispatcher shut down with cells outstanding"
            self._cond.notify_all()
        # Closing the listener does not wake a thread already blocked in
        # accept(); a throwaway self-connection does, immediately —
        # otherwise every run pays the accept timeout as shutdown latency.
        if self._listener is not None:
            try:
                with socket.create_connection(
                        (self.host or "127.0.0.1", self.port), timeout=1.0):
                    pass
            except OSError:
                pass
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout=10.0)
        for proc in self._procs:
            try:
                proc.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                proc.terminate()
                try:
                    proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:
                    proc.kill()
        for pid in self._fork_pids:
            self._reap(pid)
        self._fork_pids = []

    @staticmethod
    def _reap(pid: int, grace_s: float = 5.0) -> None:
        """waitpid with a polling grace period, then SIGTERM/SIGKILL."""
        import signal
        for sig in (None, signal.SIGTERM, signal.SIGKILL):
            if sig is not None:
                try:
                    os.kill(pid, sig)
                except (OSError, ProcessLookupError):
                    return
            # Exponential backoff from 1 ms: a worker honouring the
            # shutdown frame exits within a millisecond or two, and this
            # runs inside the dispatch bracket — a fixed 50 ms poll would
            # tax every run's shutdown for the rare straggler's sake.
            waited, pause = 0.0, 0.001
            while waited < grace_s:
                try:
                    done, _status = os.waitpid(pid, os.WNOHANG)
                except ChildProcessError:
                    return
                if done == pid:
                    return
                time.sleep(pause)
                waited += pause
                pause = min(pause * 2, 0.05)

    # ------------------------------------------------------ accept/handle
    def _accept_loop(self) -> None:
        while True:
            with self._lock:
                if self._done or self._fatal is not None:
                    return
            try:
                sock, addr = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            with self._lock:
                if self._done or self._fatal is not None:
                    # The _shutdown wake-up connection (or a worker racing
                    # the end of the run) — drop it and retire.
                    try:
                        sock.close()
                    except OSError:
                        pass
                    return
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            sock.settimeout(self.heartbeat_timeout_s)
            conn = _WorkerConn(sock, addr)
            handler = threading.Thread(target=self._handle, args=(conn,),
                                       name=f"dispatch-w{conn.wid}",
                                       daemon=True)
            handler.start()
            self._threads.append(handler)

    def _handle(self, conn: _WorkerConn) -> None:
        alive_counted = False
        try:
            hello = recv_frame(conn.sock)
            if not isinstance(hello, dict) or hello.get("t") != "hello":
                return
            conn.pid = hello.get("pid")
            conn.hostname = hello.get("host", "?")
            with self._lock:
                self._live += 1
                self.stats["queue_workers"] += 1
                # Baselined determinism finding (wallclock): control-plane
                # progress stamp (a worker arriving is progress).
                self._last_progress = time.monotonic()
                alive_counted = True
                manifest = sorted(self._bykey)
            send_frame(conn.sock, {
                "t": "welcome", "version": PROTOCOL_VERSION,
                "fingerprints": self.fingerprints,
                "heartbeat_s": self.heartbeat_s,
                "queued": manifest,
            })
            # Drain prefill frames until the worker reports ready, so local
            # cache hits land before the first chunk is assembled.
            while True:
                frame = recv_frame(conn.sock)
                if frame is None:
                    return
                t = frame.get("t")
                if t == "ready":
                    break
                if t == "reject":
                    with self._cond:
                        self._fatal = (f"{conn.label()} rejected the run: "
                                       f"{frame.get('reason', '?')}")
                        self._cond.notify_all()
                    return
                self._consume(frame, prefill=True)
            while True:
                chunk = self._next_chunk(conn)
                if chunk is None:
                    self._farewell(conn)
                    return
                send_frame(conn.sock, {"t": "task", "id": conn.wid,
                                       "cells": chunk})
                if not self._await_result(conn):
                    return
        except (OSError, socket.timeout, pickle.PickleError, EOFError,
                DispatchError):
            pass
        finally:
            self._abandon(conn, alive_counted)
            try:
                conn.sock.close()
            except OSError:
                pass

    def _farewell(self, conn: _WorkerConn) -> None:
        try:
            send_frame(conn.sock, {"t": "shutdown"})
            conn.sock.settimeout(5.0)
            while True:
                frame = recv_frame(conn.sock)
                if frame is None or frame.get("t") == "bye":
                    return
        except (OSError, socket.timeout, pickle.PickleError, EOFError):
            return

    def _await_result(self, conn: _WorkerConn) -> bool:
        """Frames until the in-flight task's result lands.  Heartbeats and
        prefills are consumed in passing; silence past the socket timeout
        (or EOF) means the worker is dead."""
        while True:
            frame = recv_frame(conn.sock)
            if frame is None:
                return False
            t = frame.get("t")
            if t == "hb":
                continue
            if t == "result":
                self._consume(frame)
                with self._lock:
                    conn.inflight = []
                return True
            self._consume(frame, prefill=(t == "prefill"))

    def _consume(self, frame: dict, prefill: bool = False) -> None:
        """Ingest one result/prefill frame: commit queued keys, drop the
        rest, write one packfile per frame."""
        got = frame.get("records")
        if not isinstance(got, dict):
            return
        committed: Dict[str, dict] = {}
        with self._cond:
            for key, record in got.items():
                state = self._state.get(key)
                if state is None:
                    self.stats["queue_unqueued_results"] += 1
                    continue
                if state == "done":
                    self.stats["queue_duplicate_results"] += 1
                    continue
                self._state[key] = "done"
                self._n_done += 1
                self.records[key] = record
                committed[key] = record
                if prefill:
                    self.stats["queue_prefilled"] += 1
            if committed:
                # Baselined determinism finding (wallclock): progress
                # stamp; the committed records themselves are untouched.
                self._last_progress = time.monotonic()
            if self._n_done == len(self._bykey):
                self._done = True
            self._cond.notify_all()
        if committed:
            if write_pack(self.cache_dir, committed) is not None:
                with self._lock:
                    self.stats["queue_packs_written"] += 1

    def _next_chunk(self, conn: _WorkerConn) -> Optional[List[dict]]:
        """Pull up to ``self.chunk`` queued cells for this worker; blocks
        while the queue is empty but cells are still in flight elsewhere
        (their worker may die and requeue them).  ``None`` = run over."""
        with self._cond:
            while True:
                if self._done or self._fatal is not None:
                    return None
                keys: List[str] = []
                while self._heap and len(keys) < self.chunk:
                    _, _, key = heapq.heappop(self._heap)
                    if self._state.get(key) != "queued":
                        continue  # committed while queued (e.g. prefill)
                    self._state[key] = "inflight"
                    keys.append(key)
                if keys:
                    conn.inflight = keys
                    self.stats["queue_tasks"] += 1
                    return [self._task_payload(k) for k in keys]
                self._cond.wait(timeout=0.25)

    def _task_payload(self, key: str) -> dict:
        # Self-contained: the worker never sees the parent's cache dir.
        payload = {k: v for k, v in self._bykey[key].items()
                   if k != "cache_dir"}
        payload["cache_dir"] = None
        return payload

    def _abandon(self, conn: _WorkerConn, alive_counted: bool) -> None:
        """Requeue a dead worker's un-committed in-flight cells (each at
        most ``max_requeues`` times) and retire the connection."""
        with self._cond:
            if alive_counted:
                self._live -= 1
            requeued = 0
            for key in conn.inflight:
                if self._state.get(key) != "inflight":
                    continue
                n = self._requeues.get(key, 0) + 1
                self._requeues[key] = n
                if n > self.max_requeues:
                    self._fatal = (
                        f"cell {key[:12]}… was re-dispatched {n} times "
                        "without completing (poison cell or a dying farm)")
                    self._cond.notify_all()
                    return
                self._state[key] = "queued"
                heapq.heappush(self._heap,
                               (-payload_cost(self._bykey[key]), 0, key))
                requeued += 1
            conn.inflight = []
            if requeued:
                self.stats["queue_requeued_cells"] += requeued
                # Baselined determinism finding (wallclock): a requeue
                # restarts the stall watchdog; cells are re-run from their
                # self-contained payloads, bit-identically.
                self._last_progress = time.monotonic()
            if alive_counted and not self._done:
                self.stats["queue_dead_workers"] += 1
            self._cond.notify_all()


# =====================================================================
# The batched in-worker cell runner
# =====================================================================


def worker_serve(host: str, port: int, *,
                 cache_dir: Optional[Union[str, Path]] = None,
                 fingerprints: Optional[Dict[str, str]] = None,
                 heartbeat_s: float = 1.0,
                 connect_timeout_s: float = 10.0,
                 die_after: Optional[int] = None,
                 log: Callable[[str], None] = lambda msg: None) -> int:
    """One worker: connect, handshake, then pull and run cell chunks until
    the dispatcher says shutdown.  Returns a process exit code.

    The process is long-lived on purpose: interpreter start-up, NumPy, the
    compiled DES engine (ctypes ``.so`` load or numba JIT) are paid once,
    then every chunk reuses them — the amortization the queue tier exists
    for.  With a local ``cache_dir`` the worker prefills queued keys it
    already holds (manifest sync) and persists each computed chunk as one
    packfile.

    ``fingerprints`` are this worker's own code fingerprints; a mismatch
    against the dispatcher's welcome frame aborts the run (a farm running
    mixed code would poison the parent cache with records keyed by the
    wrong fingerprint).  ``die_after`` is failure injection for the
    re-dispatch tests: hard-exit after computing that many cells.
    """
    cache_dir = Path(cache_dir) if cache_dir is not None else None
    if cache_dir is not None:
        scavenge_cache_dir(cache_dir)
    deadline_tries = max(1, int(connect_timeout_s / 0.1))
    sock = None
    for attempt in range(deadline_tries):
        try:
            sock = socket.create_connection((host, port), timeout=30.0)
            break
        except OSError:
            if attempt == deadline_tries - 1:
                raise
            time.sleep(0.1)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(None)
    send_lock = threading.Lock()
    computed = 0
    try:
        send_frame(sock, {"t": "hello", "pid": os.getpid(),
                          "host": socket.gethostname(),
                          "version": PROTOCOL_VERSION}, send_lock)
        welcome = recv_frame(sock)
        if not isinstance(welcome, dict) or welcome.get("t") != "welcome":
            return 1
        theirs = welcome.get("fingerprints") or {}
        ours = fingerprints or {}
        drift = sorted(m for m in set(theirs) & set(ours)
                       if theirs[m] != ours[m])
        if drift:
            send_frame(sock, {
                "t": "reject",
                "reason": ("code fingerprint mismatch on "
                           f"{'/'.join(drift)}: worker and dispatcher run "
                           "different result-determining code")}, send_lock)
            return 3
        hb_s = float(welcome.get("heartbeat_s", heartbeat_s))

        # Manifest sync: offer every queued record the local cache holds.
        if cache_dir is not None:
            have = {}
            for key in welcome.get("queued", ()):
                hit = cache_read(cache_dir, key)
                if hit is not None:
                    have[key] = hit
            if have:
                send_frame(sock, {"t": "prefill", "records": have},
                           send_lock)
                log(f"prefilled {len(have)} record(s) from local cache")
        send_frame(sock, {"t": "ready"}, send_lock)

        stop_hb = threading.Event()

        def _heartbeat() -> None:
            while not stop_hb.wait(hb_s):
                try:
                    send_frame(sock, {"t": "hb"}, send_lock)
                except OSError:
                    return

        hb_thread = threading.Thread(target=_heartbeat, name="worker-hb",
                                     daemon=True)
        hb_thread.start()
        try:
            while True:
                frame = recv_frame(sock)
                if frame is None:
                    return 1
                t = frame.get("t")
                if t == "shutdown":
                    send_frame(sock, {"t": "bye"}, send_lock)
                    return 0
                if t != "task":
                    continue

                def _tick(_key: str) -> None:
                    nonlocal computed
                    computed += 1
                    if die_after is not None and computed >= die_after:
                        # Failure injection: a worker crashing mid-chunk
                        # (no result frame ever sent).
                        os._exit(17)

                # The whole chunk runs in-engine (shared staging
                # prototype, lean result scatter), then one packed local
                # write and one result frame.
                before = computed
                records = run_des_chunk(frame["cells"], cache_dir,
                                        on_computed=_tick)
                send_frame(sock, {"t": "result", "id": frame.get("id"),
                                  "records": records}, send_lock)
                log(f"chunk of {len(records)} done "
                    f"({computed - before} computed)")
        finally:
            stop_hb.set()
    finally:
        try:
            sock.close()
        except OSError:
            pass


__all__ = [
    "DispatchError",
    "MEMO_CAP",
    "PROTOCOL_VERSION",
    "QueueDispatcher",
    "RecordMemo",
    "cache_memo_stats",
    "cache_read",
    "cache_write",
    "canonical_digest",
    "chunk_size_for",
    "clear_cache_memo",
    "nan_to_null",
    "payload_cost",
    "record_text",
    "recv_frame",
    "run_cell",
    "run_des_cell",
    "run_des_chunk",
    "run_executor_cell",
    "scavenge_cache_dir",
    "send_frame",
    "worker_serve",
    "write_pack",
]
