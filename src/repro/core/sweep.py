"""Declarative experiment sweeps with a content-addressed cache and
multiprocess fan-out.

One :class:`SweepSpec` names the whole grid — scenarios x policies x
predictors x seeds — and :func:`run_sweep` executes it:

* **cells** are (workload, policy, predictor, seed) simulations; SJF/LJF
  are realized the way the paper realizes them (FIFO with oracle-chosen
  arrival order, Section 2), and every cell gets the measured solo
  runtimes as its oracle, exactly like the hand-rolled benchmark loops
  this module replaces;
* **fan-out**: with ``jobs > 1`` cells run in a process pool (the DES is
  pure Python, so processes — not threads — buy real parallelism);
* **cache**: with ``cache_dir`` every cell and solo-runtime measurement is
  stored content-addressed, keyed by a SHA-256 over the *workload content*
  (every :class:`~repro.core.workload.KernelSpec` field, arrival times,
  uids — see :func:`repro.core.scenarios.workload_digest`), the policy,
  the resolved predictor name, the simulation seed, machine size, horizon
  and the solo-runtime oracle.  A warm rerun touches no simulator code and
  returns bit-identical :class:`~repro.core.metrics.WorkloadMetrics`
  (floats survive the JSON round-trip exactly).  The key does NOT cover
  the simulator/policy *code*: bump :data:`CACHE_VERSION` (or clear the
  cache directory) when a schedule-changing code change is intended.

Open-loop runs are first-class: cells carry
:class:`~repro.core.metrics.WindowMetrics` (completion-window STP/ANTT/
fairness + makespan/utilization/finished counts), and ``until`` truncates
every simulation at a horizon.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .metrics import (
    MetricsError,
    WindowMetrics,
    WorkloadMetrics,
    evaluate_window,
    geomean,
)
from .policies import make_policy
from .predictor import DEFAULT_PREDICTOR
from .scenarios import Scenario, make_scenario, workload_digest
from .simulator import simulate, solo_runtime
from .workload import Arrival, KernelSpec, N_SM, reorder_for_oracle

#: Bump when simulator/policy/predictor changes intentionally alter
#: schedules: cached cells are only valid for the code that produced them.
CACHE_VERSION = 1

#: Policies realized as FIFO over an oracle-reordered arrival list.
ORACLE_ORDER_POLICIES = ("sjf", "ljf")

#: Placeholder marking a cache key as scheduled-for-computation.
_PENDING: dict = {}


# ------------------------------------------------------------------ spec
@dataclass(frozen=True)
class SweepSpec:
    """The declarative experiment grid.

    ``scenarios`` holds registered names and/or :class:`Scenario`
    instances (names are constructed with default parameters).  ``seeds``
    are *sweep* seeds: each reseeds the scenario's arrival draws and the
    simulator's noise streams coherently.  ``until`` (cycles) truncates
    every cell at an observation horizon — the open-loop mode.
    """

    scenarios: Tuple[Union[str, Scenario], ...]
    policies: Tuple[str, ...]
    predictors: Tuple[Optional[str], ...] = (None,)
    seeds: Tuple[int, ...] = (0,)
    n_sm: int = N_SM
    until: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "scenarios", tuple(self.scenarios))
        object.__setattr__(self, "policies", tuple(self.policies))
        object.__setattr__(self, "predictors", tuple(self.predictors))
        object.__setattr__(self, "seeds", tuple(self.seeds))


@dataclass(frozen=True)
class CellResult:
    """One executed (workload, policy, predictor, seed) cell."""

    scenario: str
    workload: str
    policy: str
    predictor: str
    seed: int
    window: WindowMetrics
    turnaround: Dict[str, float]
    finish: Dict[str, float]
    unfinished: Tuple[str, ...]
    names: Dict[str, str]          # kernel key -> spec name

    @property
    def metrics(self) -> Optional[WorkloadMetrics]:
        """Closed-workload STP/ANTT/fairness (``None`` if nothing
        finished inside the window)."""
        return self.window.workload_metrics

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["unfinished"] = list(self.unfinished)
        return d

    @classmethod
    def from_record(cls, record: dict, **labels) -> "CellResult":
        """Attach sweep labels to one cached simulation record.

        Records are label-free on purpose: an SJF cell and the FIFO cell
        of the mirrored workload are the *same simulation* and share one
        cache entry; only the labels differ.
        """
        return cls(
            window=WindowMetrics(**record["window"]),
            turnaround=dict(record["turnaround"]),
            finish=dict(record["finish"]),
            unfinished=tuple(record["unfinished"]),
            names=dict(record["names"]), **labels)


class SweepResult:
    """All cells of one sweep plus cache/runtime statistics."""

    def __init__(self, cells: List[CellResult], stats: Dict[str, float]):
        self.cells = cells
        self.stats = stats

    def select(self, scenario: Optional[str] = None,
               policy: Optional[str] = None,
               predictor: Optional[str] = None,
               seed: Optional[int] = None) -> List[CellResult]:
        return [
            c for c in self.cells
            if (scenario is None or c.scenario == scenario)
            and (policy is None or c.policy == policy)
            and (predictor is None or c.predictor == predictor)
            and (seed is None or c.seed == seed)
        ]

    def summary(self, **filters) -> WorkloadMetrics:
        """Geometric-mean STP/ANTT/fairness over the selected cells'
        finished-kernel metrics (paper Table-5 style)."""
        ms = [c.metrics for c in self.select(**filters)]
        ms = [m for m in ms if m is not None]
        if not ms:
            raise MetricsError(f"no finished cells match {filters!r}")
        return WorkloadMetrics(
            stp=geomean(m.stp for m in ms),
            antt=geomean(m.antt for m in ms),
            fairness=geomean(m.fairness for m in ms))

    def unfinished_total(self, **filters) -> int:
        return sum(c.window.n_unfinished for c in self.select(**filters))


# ----------------------------------------------------------------- cache
def _canonical_digest(payload: dict) -> str:
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def _cache_read(cache_dir: Optional[Path], key: str) -> Optional[dict]:
    if cache_dir is None:
        return None
    path = cache_dir / f"{key}.json"
    try:
        return json.loads(path.read_text())
    except (FileNotFoundError, json.JSONDecodeError):
        return None


def _cache_write(cache_dir: Optional[Path], key: str, record: dict) -> None:
    if cache_dir is None:
        return
    cache_dir.mkdir(parents=True, exist_ok=True)
    path = cache_dir / f"{key}.json"
    tmp = cache_dir / f".{key}.{os.getpid()}.tmp"
    tmp.write_text(json.dumps(record, sort_keys=True))
    os.replace(tmp, path)  # atomic under concurrent writers


def solo_runtime_cached(spec: KernelSpec, seed: int = 0, n_sm: int = N_SM,
                        cache_dir: Optional[Union[str, Path]] = None
                        ) -> float:
    """Measured FIFO solo runtime of ``spec``, through the sweep cache."""
    cache_dir = Path(cache_dir) if cache_dir is not None else None
    key = _canonical_digest({
        "version": CACHE_VERSION, "kind": "solo",
        "spec": dataclasses.asdict(spec), "seed": seed, "n_sm": n_sm,
    })
    hit = _cache_read(cache_dir, key)
    if hit is not None:
        return float(hit["runtime"])
    rt = solo_runtime(spec, lambda: make_policy("fifo"), n_sm=n_sm,
                      seed=seed)
    _cache_write(cache_dir, key, {"runtime": rt})
    return rt


def _cell_key(arrivals: Sequence[Arrival], policy: str, predictor: str,
              seed: int, n_sm: int, until: Optional[float],
              solo: Dict[str, float]) -> str:
    # The workload content enters through scenarios.workload_digest — the
    # one canonical payload (spec fields + times + uids) shared with tests
    # and documentation.
    return _canonical_digest({
        "version": CACHE_VERSION, "kind": "cell",
        "workload": workload_digest(arrivals),
        "policy": policy, "predictor": predictor, "seed": seed,
        "n_sm": n_sm, "until": until, "solo": solo,
    })


# ---------------------------------------------------------------- worker
def _effective(arrivals: Sequence[Arrival], policy: str,
               solo: Dict[str, float]) -> Tuple[List[Arrival], str]:
    """The (arrival list, policy) a cell actually simulates.

    SJF/LJF are realized the way the paper realizes them (Section 2): FIFO
    over the oracle-reordered arrival list.  Keying the cache on this
    *effective* content dedups them against the FIFO cells of the mirrored
    workloads — a pre-refactor ``run_workload`` invariant, now exploited.
    """
    if policy in ORACLE_ORDER_POLICIES:
        return (reorder_for_oracle(arrivals, solo,
                                   longest_first=(policy == "ljf")), "fifo")
    return list(arrivals), policy


def _run_cell(payload: dict) -> dict:
    """Execute one simulation (module-level: pickles into worker processes).

    The payload carries *effective* arrivals/policy (see :func:`_effective`)
    and the solo-runtime oracle; the returned record is label-free.
    """
    solo: Dict[str, float] = payload["solo"]
    res = simulate(
        payload["arrivals"],
        lambda: make_policy(payload["policy"]),
        n_sm=payload["n_sm"],
        seed=payload["seed"],
        oracle_runtimes=solo,
        predictor=payload["predictor"],
        until=payload["until"],
    )
    solo_by_key = {k: solo[res.name[k]] for k in res.turnaround}
    window = evaluate_window(
        res.turnaround, solo_by_key, unfinished=res.unfinished,
        end_time=res.end_time, makespan=res.makespan,
        utilization=res.utilization)
    record = {
        "window": dataclasses.asdict(window),
        "turnaround": dict(res.turnaround),
        "finish": dict(res.finish),
        "unfinished": list(res.unfinished),
        "names": dict(res.name),
    }
    _cache_write(payload["cache_dir"], payload["key"], record)
    return record


# ---------------------------------------------------------------- runner
def run_sweep(spec: SweepSpec, jobs: int = 1,
              cache_dir: Optional[Union[str, Path]] = None) -> SweepResult:
    """Execute every cell of ``spec``; see the module docstring."""
    t0 = time.perf_counter()
    cache_dir = Path(cache_dir) if cache_dir is not None else None

    # Materialize workloads once per (scenario, seed) and measure the solo
    # oracle for every kernel they mention (cached; cheap next to cells).
    pending: List[dict] = []
    ordered: List[Tuple[str, dict]] = []   # (key, labels) in cell order
    records: Dict[str, dict] = {}          # key -> raw record (disk hits)
    solo_memo: Dict[tuple, float] = {}     # in-memory; scenarios share kernels
    hits = 0
    for scn_ref in spec.scenarios:
        base = make_scenario(scn_ref)
        for seed in spec.seeds:
            scn = base.reseeded(seed)
            workloads = scn.workloads()
            names = sorted({a.spec.name for _, wl in workloads for a in wl})
            specs = {a.spec.name: a.spec for _, wl in workloads for a in wl}
            solo = {}
            for n in names:
                memo_key = (specs[n], seed, spec.n_sm)
                if memo_key not in solo_memo:
                    solo_memo[memo_key] = solo_runtime_cached(
                        specs[n], seed=seed, n_sm=spec.n_sm,
                        cache_dir=cache_dir)
                solo[n] = solo_memo[memo_key]
            for wl_name, arrivals in workloads:
                wl_solo = {a.spec.name: solo[a.spec.name] for a in arrivals}
                for policy in spec.policies:
                    eff_arrivals, eff_policy = _effective(
                        arrivals, policy, wl_solo)
                    for pred in spec.predictors:
                        pred_name = DEFAULT_PREDICTOR if pred is None else pred
                        key = _cell_key(eff_arrivals, eff_policy, pred_name,
                                        seed, spec.n_sm, spec.until, wl_solo)
                        ordered.append((key, {
                            "scenario": scn.name, "workload": wl_name,
                            "policy": policy, "predictor": pred_name,
                            "seed": seed,
                        }))
                        if key in records:
                            continue   # in-flight dedup (e.g. SJF == FIFO)
                        hit = _cache_read(cache_dir, key)
                        if hit is not None:
                            hits += 1
                            records[key] = hit
                            continue
                        records[key] = _PENDING
                        pending.append({
                            "key": key, "arrivals": eff_arrivals,
                            "policy": eff_policy, "predictor": pred_name,
                            "seed": seed, "n_sm": spec.n_sm,
                            "until": spec.until, "solo": wl_solo,
                            "cache_dir": cache_dir,
                        })

    if pending:
        if jobs > 1:
            with ProcessPoolExecutor(max_workers=jobs) as pool:
                results = list(pool.map(_run_cell, pending, chunksize=1))
        else:
            results = [_run_cell(p) for p in pending]
        for payload, record in zip(pending, results):
            records[payload["key"]] = record

    cells = [CellResult.from_record(records[key], **labels)
             for key, labels in ordered]
    stats = {
        "cells": len(ordered), "cache_hits": hits,
        "computed": len(pending),
        "deduplicated": len(ordered) - len(records),
        "jobs": jobs, "elapsed_s": time.perf_counter() - t0,
    }
    return SweepResult(cells, stats)


__all__ = [
    "CACHE_VERSION",
    "CellResult",
    "SweepResult",
    "SweepSpec",
    "run_sweep",
    "solo_runtime_cached",
]
