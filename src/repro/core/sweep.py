"""Declarative experiment sweeps with a content-addressed cache and
multiprocess fan-out.

One :class:`SweepSpec` names the whole grid — scenarios x policies x
predictors x seeds, on either **machine** — and :func:`run_sweep`
executes it:

* **cells** are (workload, policy, predictor, seed) runs; SJF/LJF are
  realized the way the paper realizes them (FIFO with oracle-chosen
  arrival order, Section 2), and every cell gets the measured solo
  runtimes as its oracle, exactly like the hand-rolled benchmark loops
  this module replaces;
* **tiers**: open-loop scenarios materialize fixed arrival lists; a
  :class:`~repro.core.scenarios.ClosedLoopScenario` instead names seeded
  arrival *processes* — each cell builds a fresh process and the machine
  feeds it completions (the :class:`~repro.core.events.ArrivalSource`
  edge), so the arrival sequence reacts to the policy under test.
  Closed-loop cell cache keys digest the **process parameters + seed**
  (there is no arrival list to digest), their solo oracles cover the
  declared kernel mix, their DES code fingerprint widens to include
  ``scenarios.py`` (the process code is result-determining), and SJF/LJF
  — which need a materialized list to reorder — are rejected explicitly;
* **machines**: ``machine="des"`` (default) simulates cells on the
  discrete-event simulator; ``machine="executor"`` drives the same
  workloads through the real-JAX :class:`~repro.core.executor.LaneExecutor`
  — each scenario arrival is bridged to a job of actual jit-compiled
  blocks (:func:`repro.core.scenarios.executor_workload`) and block
  durations are wall-clock measurements;
* **fan-out**: with ``jobs > 1`` cells run in a process pool (fork for the
  pure-Python DES; spawn for executor cells, because forking a process
  with an initialized JAX runtime can deadlock).  Executor solo baselines
  are measured under the *same* pool-contention conditions as the cells:
  with ``jobs > 1`` they go through an identical spawn pool of the same
  width (serial parent-process baselines would be systematically faster
  than co-run cells on a small container, inflating every slowdown), and
  the pool width is part of the solo cache key.  DES solo baselines are
  deterministic simulations and fan out through a fork pool of the same
  width when there is more than one to measure;
* **dispatchers**: ``dispatcher="local"`` (default) is the per-cell
  process-pool path above.  ``dispatcher="queue"`` serves DES cells in
  LPT-ordered *chunks* to long-lived pull-based workers — local spawned
  processes and/or remote ``python -m repro.launch.worker`` nodes — with
  heartbeat/death detection, bounded re-dispatch, and two-way cache sync
  (:class:`repro.core.distrib.QueueDispatcher`, DESIGN.md Section 12).
  Records are byte-identical across dispatchers (the PR-5/7 gate);
  executor sweeps reject the queue tier because their cells are
  wall-clock measurements calibrated against local pool contention;
* **cache**: with ``cache_dir`` every cell and solo-runtime measurement is
  stored content-addressed, keyed by a SHA-256 over the *workload content*
  (every :class:`~repro.core.workload.KernelSpec` field, arrival times,
  uids — see :func:`repro.core.scenarios.workload_digest`), the policy,
  the resolved predictor name, the simulation seed, machine size, horizon,
  the solo-runtime oracle and a **code fingerprint** (a digest of the
  schedule-determining sources — simulator/policies/predictor for the DES
  — so schedule-changing commits auto-invalidate; :data:`CACHE_VERSION`
  stays as the manual override).  A warm DES rerun touches no simulator
  code and returns bit-identical
  :class:`~repro.core.metrics.WorkloadMetrics` (floats survive the JSON
  round-trip exactly; NaN is encoded as ``null`` on disk and decoded back,
  keeping every cache record standard JSON).

Executor cells are **measurements**, not pure functions: their records
carry ``measured: true`` and their cell keys fold in a per-run nonce, so
every :func:`run_sweep` invocation re-measures cells (in-run SJF/FIFO
dedup still applies) instead of pretending wall-time is bit-reproducible;
their records stay in memory and are never persisted (a nonce-keyed file
could not be read back).
Executor *solo* runtimes are deterministic cache keys (spec content +
lane count + code fingerprint) and ARE reused across runs — rerunning an
executor sweep skips the solo-baseline measurements.

Open-loop runs are first-class: cells carry
:class:`~repro.core.metrics.WindowMetrics` (completion-window STP/ANTT/
fairness + makespan/utilization/finished counts), and ``until`` truncates
every simulation at a horizon.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import multiprocessing
import time
import uuid
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .distrib import (
    DispatchError,
    QueueDispatcher,
    cache_memo_stats,
    cache_read as _cache_read,
    cache_write as _cache_write,
    canonical_digest as _canonical_digest,
    chunk_size_for,
    clear_cache_memo,
    run_cell as _run_cell,
    run_des_chunk,
    _run_chunk,
    scavenge_cache_dir,
)
from .executor import solo_runtime_executor
from .fastsim import default_engine, engine_token
from .metrics import (
    MetricsError,
    QueueingMetrics,
    WindowMetrics,
    WorkloadMetrics,
    evaluate_queueing,
    geomean,
)
from .policies import make_policy
from .predictor import DEFAULT_PREDICTOR
from .scenarios import (
    ClosedLoopScenario,
    DEFAULT_EXECUTOR_TIME_SCALE,
    Scenario,
    executor_job,
    make_scenario,
    workload_digest,
)
from .simulator import solo_runtime
from .workload import Arrival, KernelSpec, N_SM, reorder_for_oracle

#: Bump when simulator/policy/predictor changes intentionally alter
#: schedules: cached cells are only valid for the code that produced them.
#: (Schedule-changing *commits* are caught automatically by the code
#: fingerprint in every key — see :func:`_code_fingerprint`; this constant
#: remains the manual override.)
#: 2: DES cell keys fold in the engine token (compiled flat-array engine,
#:    DESIGN.md Section 10) and the "des"/"des-closed" fingerprints widen
#:    to the engine sources.
#: 3: the cell runners and record store move to distrib.py (the
#:    distributed sweep tier, DESIGN.md Section 12) and every machine's
#:    fingerprint widens to the same 13-module closure — records produced
#:    by any dispatcher share one provenance domain.
CACHE_VERSION = 3

#: The two concrete machines a sweep can target.
MACHINES = ("des", "executor")

#: The two DES event-loop engines a sweep can pin (``None`` = pick the
#: compiled engine exactly when a fast backend is available).
ENGINES = ("python", "compiled")

#: Policies realized as FIFO over an oracle-reordered arrival list.
ORACLE_ORDER_POLICIES = ("sjf", "ljf")

#: Placeholder marking a cache key as scheduled-for-computation.
_PENDING: dict = {}


# ------------------------------------------------------------------ spec
@dataclass(frozen=True)
class SweepSpec:
    """The declarative experiment grid.

    ``scenarios`` holds registered names and/or :class:`Scenario`
    instances (names are constructed with default parameters).  ``seeds``
    are *sweep* seeds: each reseeds the scenario's arrival draws and the
    simulator's noise streams coherently.  ``until`` truncates every cell
    at an observation horizon — the open-loop mode (cycles on the DES,
    seconds of lane time on the executor).

    ``machine`` selects the cell substrate: ``"des"`` (discrete-event
    simulator) or ``"executor"`` (real-JAX lane executor; ``n_sm`` is then
    the lane count and ``time_scale`` maps scenario cycles to seconds of
    arrival time — see :func:`repro.core.scenarios.executor_workload`).

    ``engine`` pins the DES event-loop implementation (``"python"`` /
    ``"compiled"``; ``None`` = compiled-when-available).  Both engines are
    gated bit-identical, but every DES cell key folds in the resolved
    engine token — :func:`repro.core.fastsim.engine_token`, which also
    encodes which compiled backend (native C / numba / interpreted twin)
    is active — so a gating regression could never silently mix
    provenance across cached records.  Executor sweeps reject the axis:
    their cells never run the DES event loop.
    """

    scenarios: Tuple[Union[str, Scenario], ...]
    policies: Tuple[str, ...]
    predictors: Tuple[Optional[str], ...] = (None,)
    seeds: Tuple[int, ...] = (0,)
    n_sm: int = N_SM
    until: Optional[float] = None
    machine: str = "des"
    time_scale: float = DEFAULT_EXECUTOR_TIME_SCALE
    engine: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "scenarios", tuple(self.scenarios))
        object.__setattr__(self, "policies", tuple(self.policies))
        object.__setattr__(self, "predictors", tuple(self.predictors))
        object.__setattr__(self, "seeds", tuple(self.seeds))
        if self.machine not in MACHINES:
            raise ValueError(
                f"unknown machine {self.machine!r}; choose from {MACHINES}")
        if self.engine is not None:
            if self.engine not in ENGINES:
                raise ValueError(f"unknown engine {self.engine!r}; choose "
                                 f"from {ENGINES} (or None = auto)")
            if self.machine == "executor":
                raise ValueError(
                    "engine selects the DES event loop; executor sweeps "
                    "have no engine axis (leave it as None)")


@dataclass(frozen=True)
class CellResult:
    """One executed (workload, policy, predictor, seed) cell."""

    scenario: str
    workload: str
    policy: str
    predictor: str
    seed: int
    window: WindowMetrics
    turnaround: Dict[str, float]
    finish: Dict[str, float]
    unfinished: Tuple[str, ...]
    names: Dict[str, str]          # kernel key -> spec name
    #: Arrival time of every kernel, finished or in flight (queueing
    #: metrics integrate number-in-system over the window).
    arrival: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: True for executor cells: the numbers are wall-clock measurements of
    #: real JAX executions, not deterministic simulation outputs.
    measured: bool = False

    @property
    def metrics(self) -> Optional[WorkloadMetrics]:
        """Closed-workload STP/ANTT/fairness (``None`` if nothing
        finished inside the window)."""
        return self.window.workload_metrics

    def queueing(self, warmup_frac: float = 0.2) -> QueueingMetrics:
        """Steady-state queueing metrics of this cell
        (:func:`repro.core.metrics.evaluate_queueing`; raises
        :class:`~repro.core.metrics.MetricsError` when nothing completed
        after the warmup trim)."""
        return evaluate_queueing(self.arrival, self.finish,
                                 end_time=self.window.end_time,
                                 warmup_frac=warmup_frac)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["unfinished"] = list(self.unfinished)
        return d

    @classmethod
    def from_record(cls, record: dict, **labels) -> "CellResult":
        """Attach sweep labels to one cached simulation record.

        Records are label-free on purpose: an SJF cell and the FIFO cell
        of the mirrored workload are the *same simulation* and share one
        cache entry; only the labels differ.  NaN window metrics (nothing
        finished inside the window) are stored as ``null`` on disk —
        standard JSON — and decoded back to NaN here.
        """
        window = {k: (float("nan") if v is None else v)
                  for k, v in record["window"].items()}
        return cls(
            window=WindowMetrics(**window),
            turnaround=dict(record["turnaround"]),
            finish=dict(record["finish"]),
            unfinished=tuple(record["unfinished"]),
            names=dict(record["names"]),
            arrival=dict(record.get("arrival", {})),
            measured=bool(record.get("measured", False)), **labels)


@dataclass(frozen=True)
class MetricsCI:
    """Multi-seed spread of a sweep summary.

    Each metric is a ``(geomean, min, max)`` triple over the per-seed
    Table-5-style summaries — the lightweight confidence band the ROADMAP's
    multi-seed item asks for (min/max, not a parametric interval: seed
    counts are small and the spread is what readers compare).
    """

    stp: Tuple[float, float, float]
    antt: Tuple[float, float, float]
    fairness: Tuple[float, float, float]
    n_seeds: int

    @property
    def point(self) -> WorkloadMetrics:
        """The centers alone, as a plain :class:`WorkloadMetrics`."""
        return WorkloadMetrics(
            stp=self.stp[0], antt=self.antt[0], fairness=self.fairness[0])


class SweepResult:
    """All cells of one sweep plus cache/runtime statistics."""

    def __init__(self, cells: List[CellResult], stats: Dict[str, float]):
        self.cells = cells
        self.stats = stats

    def select(self, scenario: Optional[str] = None,
               workload: Optional[str] = None,
               policy: Optional[str] = None,
               predictor: Optional[str] = None,
               seed: Optional[int] = None) -> List[CellResult]:
        return [
            c for c in self.cells
            if (scenario is None or c.scenario == scenario)
            and (workload is None or c.workload == workload)
            and (policy is None or c.policy == policy)
            and (predictor is None or c.predictor == predictor)
            and (seed is None or c.seed == seed)
        ]

    def summary(self, **filters) -> WorkloadMetrics:
        """Geometric-mean STP/ANTT/fairness over the selected cells'
        finished-kernel metrics (paper Table-5 style)."""
        ms = [c.metrics for c in self.select(**filters)]
        ms = [m for m in ms if m is not None]
        if not ms:
            raise MetricsError(f"no finished cells match {filters!r}")
        return WorkloadMetrics(
            stp=geomean(m.stp for m in ms),
            antt=geomean(m.antt for m in ms),
            fairness=geomean(m.fairness for m in ms))

    def summary_ci(self, **filters) -> MetricsCI:
        """Multi-seed spread: per-seed :meth:`summary`, aggregated to
        geomean ± min/max per metric (see :class:`MetricsCI`)."""
        seeds = sorted({c.seed for c in self.select(**filters)})
        if not seeds:
            raise MetricsError(f"no cells match {filters!r}")
        per_seed = [self.summary(**{**filters, "seed": s}) for s in seeds]

        def agg(values) -> Tuple[float, float, float]:
            vals = list(values)
            return (geomean(vals), min(vals), max(vals))

        return MetricsCI(
            stp=agg(m.stp for m in per_seed),
            antt=agg(m.antt for m in per_seed),
            fairness=agg(m.fairness for m in per_seed),
            n_seeds=len(seeds))

    def unfinished_total(self, **filters) -> int:
        return sum(c.window.n_unfinished for c in self.select(**filters))


# ----------------------------------------------------------------- cache
# The record store itself (NaN-safe JSON, the bounded LRU mirror,
# packfiles, atomic writes, tmp scavenging) and the cell runners live in
# :mod:`repro.core.distrib` — the execution tier shared by every
# dispatcher.  This module owns the *keys*: what identifies a cell.

#: Result-determining source files per machine: any edit to these changes
#: every cache key, so result-changing commits auto-invalidate without a
#: manual CACHE_VERSION bump.  machine.py/events.py carry SchedulerCore's
#: dispatch logic and the decision types; workload.py holds the DES
#: duration model (KernelSpec.duration/base_t); scenarios.py holds the
#: executor bridge's block-cost mapping (_synthetic_shape/_jitted_block);
#: metrics.py shapes the window/queueing numbers *stored in* every cache
#: record.  Over-invalidation (e.g. an unrelated scenario edit) merely
#: recomputes; under-invalidation silently serves stale numbers.
#:
#: Each tuple must equal the transitive closure of repro.core-internal
#: imports from the machine's result-determining entry points
#: (``repro.analysis.importgraph.ENTRY_POINTS``) — enforced statically by
#: ``python -m repro.analysis`` and by tests/test_analysis.py.  The
#: closure over-approximates (an import edge counts even if unexercised:
#: scenarios.py pulls executor.py into the closed-loop DES fingerprint via
#: the ExecutorJob bridge import), which is the safe direction for a
#: cache key.
#: Since PR 9 the three tables are identical: distrib.py — the cell
#: runners + record store every dispatcher executes through — joins every
#: machine's entry points, and its own closure (simulator + engines for
#: the DES runner, scenarios + executor for the bridge) pulls each
#: machine's remaining sources in.  The unification over-invalidates
#: (e.g. an engine edit now also invalidates executor records) but keeps
#: one provenance domain across dispatchers: a record computed on a
#: remote worker is keyed by exactly the code the local path would have
#: run, and the worker handshake compares these same fingerprints.
_FINGERPRINT_SOURCES: Dict[str, Tuple[str, ...]] = {
    # fastsim/fastsim_c/fastsim_twin: the compiled event-loop engine
    # (DESIGN.md Section 10) is reachable from simulate()'s lazy engine
    # selection, and although it is gated bit-identical to the reference
    # loop, an edit to it must invalidate DES cells — under-invalidation
    # would silently serve records produced by unvetted engine code.
    "des": ("distrib", "simulator", "machine", "events", "policies",
            "predictor", "workload", "metrics", "scenarios", "executor",
            "fastsim", "fastsim_c", "fastsim_twin"),
    # Closed-loop DES cells also depend on scenarios.py directly: the
    # arrival *process* code (not a materialized list) determines what the
    # cell simulates, so an edit to it must invalidate those cells.
    "des-closed": ("distrib", "simulator", "machine", "events", "policies",
                   "predictor", "workload", "metrics", "scenarios",
                   "executor", "fastsim", "fastsim_c", "fastsim_twin"),
    "executor": ("distrib", "simulator", "machine", "events", "policies",
                 "predictor", "workload", "metrics", "scenarios",
                 "executor", "fastsim", "fastsim_c", "fastsim_twin"),
}


def fingerprint_sources() -> Dict[str, Tuple[str, ...]]:
    """Per-machine fingerprint tables, as a defensive copy.

    Public read surface for the static analyzer's coverage pass and the
    drift tests; the table itself stays private so nothing mutates what
    the cache keys are built from."""
    return dict(_FINGERPRINT_SOURCES)

_code_fp_memo: Dict[str, str] = {}


def _code_fingerprint(machine: str = "des") -> str:
    """Digest of the sources whose behavior cached results depend on."""
    fp = _code_fp_memo.get(machine)
    if fp is None:
        h = hashlib.sha256()
        for modname in _FINGERPRINT_SOURCES[machine]:
            h.update(Path(__file__).with_name(f"{modname}.py").read_bytes())
        fp = h.hexdigest()[:16]
        _code_fp_memo[machine] = fp
    return fp


def code_fingerprints() -> Dict[str, str]:
    """Every fingerprint this code tree produces, by machine key.

    The dispatcher/worker handshake payload: a worker whose fingerprints
    disagree with the dispatcher's refuses the run, because records it
    computed would be keyed by code the parent is not running."""
    return {m: _code_fingerprint(m) for m in _FINGERPRINT_SOURCES}


def _des_solo_key(spec: KernelSpec, seed: int, n_sm: int) -> str:
    return _canonical_digest({
        "version": CACHE_VERSION, "kind": "solo",
        "code": _code_fingerprint("des"),
        "spec": dataclasses.asdict(spec), "seed": seed, "n_sm": n_sm,
    })


def _executor_solo_key(spec: KernelSpec, n_lanes: int,
                       pool_jobs: int) -> str:
    # pool_jobs is the worker-pool width the baseline was measured under:
    # a baseline measured serially and one measured next to pool
    # neighbours contending for CPU are different measurements and must
    # not share a cache entry (the executor-sweep fidelity contract).
    return _canonical_digest({
        "version": CACHE_VERSION, "kind": "solo", "machine": "executor",
        "measured": True, "code": _code_fingerprint("executor"),
        "spec": dataclasses.asdict(spec), "n_lanes": n_lanes,
        "pool_jobs": pool_jobs,
    })


def solo_runtime_cached(spec: KernelSpec, seed: int = 0, n_sm: int = N_SM,
                        cache_dir: Optional[Union[str, Path]] = None
                        ) -> float:
    """Measured FIFO solo runtime of ``spec``, through the sweep cache."""
    cache_dir = Path(cache_dir) if cache_dir is not None else None
    key = _des_solo_key(spec, seed, n_sm)
    hit = _cache_read(cache_dir, key)
    if hit is not None:
        return float(hit["runtime"])
    rt = solo_runtime(spec, lambda: make_policy("fifo"), n_sm=n_sm,
                      seed=seed)
    _cache_write(cache_dir, key, {"runtime": rt})
    return rt


def _measure_des_solo(payload: dict) -> float:
    """Measure one DES solo baseline (module-level: pickles into the fork
    pool when a cold sweep has several baselines to simulate)."""
    return solo_runtime(payload["spec"], lambda: make_policy("fifo"),
                        n_sm=payload["n_sm"], seed=payload["seed"])


def _measure_executor_solo(payload: dict) -> float:
    """Measure one executor solo baseline (module-level: pickles into the
    spawn pool when solos are measured under cell-like pool contention)."""
    spec = payload["spec"]
    job = executor_job(Arrival(spec, 0.0, uid=f"{spec.name}#0"),
                       n_lanes=payload["n_lanes"],
                       time_scale=payload["time_scale"])
    return solo_runtime_executor(job, lambda: make_policy("fifo"),
                                 n_lanes=payload["n_lanes"])


def solo_runtime_executor_cached(
        spec: KernelSpec, n_lanes: int = 4,
        time_scale: float = DEFAULT_EXECUTOR_TIME_SCALE,
        cache_dir: Optional[Union[str, Path]] = None,
        pool_jobs: int = 1) -> float:
    """Measured solo runtime of ``spec`` bridged onto the real-JAX lane
    executor, through the sweep cache.

    Keyed like :func:`solo_runtime_cached` — spec content, machine size and
    code fingerprint — WITHOUT a per-run nonce: solo baselines are the
    expensive, stable part of an executor sweep and are deliberately reused
    across runs (the ``measured`` field marks the record as a wall-clock
    measurement, so consumers know reuse trades freshness for speed).
    ``pool_jobs`` labels the pool-contention conditions of the measurement
    and is part of the key (see :func:`_executor_solo_key`); this serial
    helper only reads/writes the ``pool_jobs`` it is told, the pooled
    measurement itself lives in :func:`run_sweep`.
    """
    cache_dir = Path(cache_dir) if cache_dir is not None else None
    key = _executor_solo_key(spec, n_lanes, pool_jobs)
    hit = _cache_read(cache_dir, key)
    if hit is not None:
        return float(hit["runtime"])
    rt = _measure_executor_solo(
        {"spec": spec, "n_lanes": n_lanes, "time_scale": time_scale})
    _cache_write(cache_dir, key,
                 {"runtime": rt, "measured": True, "pool_jobs": pool_jobs})
    return rt


def _cell_key(arrivals: Sequence[Arrival], policy: str, predictor: str,
              seed: int, n_sm: int, until: Optional[float],
              solo: Dict[str, float], machine: str = "des",
              nonce: Optional[str] = None,
              time_scale: Optional[float] = None,
              engine: Optional[str] = None,
              wl_digest: Optional[str] = None) -> str:
    # The workload content enters through scenarios.workload_digest — the
    # one canonical payload (spec fields + times + uids) shared with tests
    # and documentation.  ``wl_digest`` lets _queue_spec pass the digest it
    # already computed for this arrival list (non-reordering policies of
    # one workload all share it); the value is workload_digest(arrivals)
    # either way, so keys cannot depend on who computed it.
    payload = {
        "version": CACHE_VERSION, "kind": "cell", "machine": machine,
        "code": _code_fingerprint(machine),
        "workload": (workload_digest(arrivals)
                     if wl_digest is None else wl_digest),
        "policy": policy, "predictor": predictor, "seed": seed,
        "n_sm": n_sm, "until": until, "solo": solo,
    }
    if machine == "des":
        # The resolved engine token ("python" / "compiled-native" / ...)
        # also fingerprints numba/native availability — bit-identity is
        # gated, but provenance must never silently mix across records.
        payload["engine"] = engine_token(engine)
    if machine == "executor":
        # Executor cells are wall-clock measurements: the nonce makes every
        # run_sweep invocation re-measure (no cross-run hit pretending
        # bit-identity) while in-run dedup (SJF == FIFO) still applies.
        payload["measured"] = True
        payload["nonce"] = nonce
        payload["time_scale"] = time_scale
    return _canonical_digest(payload)


def _closed_cell_key(scn: ClosedLoopScenario, wl_name: str, policy: str,
                     predictor: str, seed: int, n_sm: int,
                     until: Optional[float], solo: Dict[str, float],
                     machine: str = "des", nonce: Optional[str] = None,
                     time_scale: Optional[float] = None,
                     engine: Optional[str] = None) -> str:
    # Closed-loop cells have no materialized arrival list to digest: the
    # key digests the *process parameters* + seed instead (the process +
    # the machine's deterministic completions fully determine the
    # arrivals).  The DES fingerprint widens to "des-closed" because the
    # process *code* in scenarios.py is now result-determining.
    payload = {
        "version": CACHE_VERSION, "kind": "cell", "machine": machine,
        "closed_loop": True,
        "code": _code_fingerprint(
            "des-closed" if machine == "des" else machine),
        "process": scn.process_params(),
        "workload": wl_name,
        "policy": policy, "predictor": predictor, "seed": seed,
        "n_sm": n_sm, "until": until, "solo": solo,
    }
    if machine == "des":
        payload["engine"] = engine_token(engine)
    if machine == "executor":
        payload["measured"] = True
        payload["nonce"] = nonce
        payload["time_scale"] = time_scale
    return _canonical_digest(payload)


# ---------------------------------------------------------------- worker
def _effective(arrivals: Sequence[Arrival], policy: str,
               solo: Dict[str, float]) -> Tuple[List[Arrival], str]:
    """The (arrival list, policy) a cell actually simulates.

    SJF/LJF are realized the way the paper realizes them (Section 2): FIFO
    over the oracle-reordered arrival list.  Keying the cache on this
    *effective* content dedups them against the FIFO cells of the mirrored
    workloads — a pre-refactor ``run_workload`` invariant, now exploited.
    """
    if policy in ORACLE_ORDER_POLICIES:
        return (reorder_for_oracle(arrivals, solo,
                                   longest_first=(policy == "ljf")), "fifo")
    return list(arrivals), policy


# ---------------------------------------------------------------- runner
def _materialize(spec: SweepSpec) -> Tuple[List[tuple], Dict[tuple, KernelSpec]]:
    """Pass 1: expand the grid into per-(scenario, seed) workloads and the
    solo-oracle demand.

    Returns ``(worklist, solo_specs)``: worklist entries are
    ``(scn, seed, wl_name, arrivals_or_None, wl_specs)`` — ``arrivals`` is
    ``None`` for closed-loop workloads (the worker builds the process) and
    ``wl_specs`` maps every kernel name the workload may mention to its
    spec; ``solo_specs`` maps solo memo keys to the spec to measure.

    Solo oracles are keyed by *spec content*, not name: two workloads may
    reuse a kernel name with different spec fields, and a name-keyed table
    would last-write-win and corrupt the earlier workload's STP/ANTT.
    Within one workload the name must be unambiguous (the machines look
    oracles up by spec name), so a same-name conflict there is an error.
    """
    on_executor = spec.machine == "executor"
    worklist: List[tuple] = []
    solo_specs: Dict[tuple, KernelSpec] = {}

    def memo_key(kspec: KernelSpec, seed: int) -> tuple:
        return (kspec, spec.machine, None if on_executor else seed,
                spec.n_sm)

    for scn_ref in spec.scenarios:
        base = make_scenario(scn_ref)
        for seed in spec.seeds:
            scn = base.reseeded(seed)
            if isinstance(scn, ClosedLoopScenario):
                # No arrival list exists yet — the mix declares every
                # kernel the process may emit, so the solo oracle covers
                # the full mix up front.
                mix = dict(scn.mix_specs())
                for name, kspec in mix.items():
                    if kspec.name != name:
                        raise ValueError(
                            f"mix_specs() of {scn.name!r} maps {name!r} "
                            f"to a spec named {kspec.name!r}")
                    solo_specs[memo_key(kspec, seed)] = kspec
                for wl_name in scn.process_names():
                    worklist.append((scn, seed, wl_name, None, mix))
                continue
            for wl_name, arrivals in scn.workloads():
                wl_specs: Dict[str, KernelSpec] = {}
                for a in arrivals:
                    name = a.spec.name
                    prev = wl_specs.get(name)
                    if prev is not None and prev != a.spec:
                        raise ValueError(
                            f"workload {wl_name!r} uses kernel name "
                            f"{name!r} for two different specs; solo "
                            "oracles are looked up by name within one "
                            "workload")
                    wl_specs[name] = a.spec
                    solo_specs[memo_key(a.spec, seed)] = a.spec
                worklist.append((scn, seed, wl_name, arrivals, wl_specs))
    return worklist, solo_specs


def _measure_solos(solo_specs: Dict[tuple, KernelSpec], spec: SweepSpec,
                   jobs: int, cache_dir: Optional[Path]
                   ) -> Tuple[Dict[tuple, float], Dict[str, int]]:
    """Measure (or load) every solo baseline the sweep needs.

    DES solos are deterministic simulations: cache misses fan out through
    a fork pool of the sweep's width (they were serial even under
    ``jobs > 1`` before PR 9 — pure fixed cost at the head of every cold
    sweep), and since each is a pure function of (spec, seed, n_sm), pool
    order cannot affect the values.
    Executor solos are wall-clock measurements, and with ``jobs > 1`` the
    *cells* will run inside a worker pool contending for CPU; baselines
    measured serially in the quiet parent would then be systematically
    faster than the co-run cells, inflating every slowdown (the ROADMAP
    executor-sweep fidelity item).  So with ``jobs > 1`` the baselines are
    measured through the same spawn pool, same width, the cache key
    records the pool width they were measured under, and any miss
    re-measures the sweep's *whole* solo set together (partial fills
    would measure nearly alone in the pool and undercount contention).
    """
    memo: Dict[tuple, float] = {}
    computed = 0
    if spec.machine != "executor":
        keys = {mk: _des_solo_key(kspec, mk[2], spec.n_sm)
                for mk, kspec in solo_specs.items()}
        misses = []
        for mk, key in keys.items():
            hit = _cache_read(cache_dir, key)
            if hit is not None:
                memo[mk] = float(hit["runtime"])
            else:
                misses.append(mk)
        pool_jobs = min(max(1, jobs), max(1, len(misses)))
        if misses:
            payloads = [{"spec": solo_specs[mk], "n_sm": spec.n_sm,
                         "seed": mk[2]} for mk in misses]
            if pool_jobs > 1:
                with ProcessPoolExecutor(max_workers=pool_jobs) as pool:
                    runtimes = list(pool.map(_measure_des_solo, payloads,
                                             chunksize=1))
            else:
                runtimes = [_measure_des_solo(p) for p in payloads]
            for mk, rt in zip(misses, runtimes):
                memo[mk] = float(rt)
                _cache_write(cache_dir, keys[mk], {"runtime": rt})
            computed = len(misses)
        return memo, {"solo_computed": computed,
                      "solo_pool_jobs": pool_jobs}

    pool_jobs = max(1, jobs)
    keys = {mk: _executor_solo_key(kspec, spec.n_sm, pool_jobs)
            for mk, kspec in solo_specs.items()}
    hits = {mk: _cache_read(cache_dir, key) for mk, key in keys.items()}
    if pool_jobs > 1 and any(hit is None for hit in hits.values()):
        # All-or-nothing under a pool: a lone miss dispatched through an
        # otherwise-idle pool would measure *uncontended* and then sit in
        # the cache next to contention-measured neighbours — the exact
        # bias this path exists to remove.  Re-measuring the whole solo
        # set together keeps every baseline of this sweep mutually
        # consistent (solo sets are small next to cells).
        hits = {mk: None for mk in hits}
    misses = [mk for mk, hit in hits.items() if hit is None]
    for mk, hit in hits.items():
        if hit is not None:
            memo[mk] = float(hit["runtime"])
    if misses:
        payloads = [{"spec": solo_specs[mk], "n_lanes": spec.n_sm,
                     "time_scale": spec.time_scale} for mk in misses]
        if pool_jobs > 1:
            ctx = multiprocessing.get_context("spawn")
            with ProcessPoolExecutor(max_workers=pool_jobs,
                                     mp_context=ctx) as pool:
                runtimes = list(pool.map(_measure_executor_solo, payloads,
                                         chunksize=1))
        else:
            runtimes = [_measure_executor_solo(p) for p in payloads]
        for mk, rt in zip(misses, runtimes):
            memo[mk] = float(rt)
            _cache_write(cache_dir, keys[mk],
                         {"runtime": rt, "measured": True,
                          "pool_jobs": pool_jobs})
        computed = len(misses)
    return memo, {"solo_computed": computed, "solo_pool_jobs": pool_jobs}


def _queue_spec(spec: SweepSpec, jobs: int, cache_dir: Optional[Path],
                records: Dict[str, dict], pending: List[dict]) -> dict:
    """Pass 2 for one spec: resolve every cell against the cache and the
    shared ``records``/``pending`` state; returns the spec's bookkeeping
    (ordered cell labels + per-spec stats)."""
    on_executor = spec.machine == "executor"
    # Executor cells are measurements: a fresh nonce per run keeps them out
    # of cross-run cache hits while in-run dedup still works.  Baselined
    # determinism finding (uuid): the nonce exists precisely to be unique
    # per run; it uniquifies keys and never shapes a result.
    nonce = uuid.uuid4().hex if on_executor else None
    # Resolve the engine axis once per spec: the resolved name goes into
    # every worker payload and its token into every DES cell key, so a
    # spec run under "auto" on two hosts with different backends can never
    # share records across engine provenance.
    engine = None if on_executor else (spec.engine or default_engine())

    worklist, solo_specs = _materialize(spec)
    solo_memo, solo_stats = _measure_solos(solo_specs, spec, jobs, cache_dir)

    ordered: List[Tuple[str, dict]] = []   # (key, labels) in cell order
    hits = dedup = queued = 0
    for scn, seed, wl_name, arrivals, wl_specs in worklist:
        closed = arrivals is None
        wl_solo = {
            name: solo_memo[(kspec, spec.machine,
                             None if on_executor else seed, spec.n_sm)]
            for name, kspec in wl_specs.items()
        }
        # One digest per arrival list, not one per cell: every
        # non-reordering policy of this workload keys the same content
        # (oracle-reordered SJF/LJF lists digest separately below).
        base_digest = None if closed else workload_digest(arrivals)
        for policy in spec.policies:
            if closed and policy in ORACLE_ORDER_POLICIES:
                raise ValueError(
                    f"policy {policy!r} is realized as FIFO over an "
                    "oracle-reordered arrival list, but closed-loop "
                    f"scenario {scn.name!r} has no materialized arrivals "
                    "to reorder")
            if closed:
                eff_arrivals, eff_policy = None, policy
                eff_digest = None
            else:
                eff_arrivals, eff_policy = _effective(
                    arrivals, policy, wl_solo)
                eff_digest = (workload_digest(eff_arrivals)
                              if policy in ORACLE_ORDER_POLICIES
                              else base_digest)
            for pred in spec.predictors:
                pred_name = DEFAULT_PREDICTOR if pred is None else pred
                if closed:
                    key = _closed_cell_key(
                        scn, wl_name, eff_policy, pred_name, seed,
                        spec.n_sm, spec.until, wl_solo,
                        machine=spec.machine, nonce=nonce,
                        time_scale=spec.time_scale, engine=engine)
                else:
                    key = _cell_key(eff_arrivals, eff_policy, pred_name,
                                    seed, spec.n_sm, spec.until, wl_solo,
                                    machine=spec.machine, nonce=nonce,
                                    time_scale=spec.time_scale,
                                    engine=engine, wl_digest=eff_digest)
                ordered.append((key, {
                    "scenario": scn.name, "workload": wl_name,
                    "policy": policy, "predictor": pred_name,
                    "seed": seed,
                }))
                if key in records:
                    # In-flight dedup: SJF == FIFO of the mirrored
                    # workload, or a sibling spec in the same batch.
                    dedup += 1
                    continue
                hit = _cache_read(cache_dir, key)
                if hit is not None:
                    hits += 1
                    records[key] = hit
                    continue
                records[key] = _PENDING
                queued += 1
                payload = {
                    "key": key, "arrivals": eff_arrivals,
                    "policy": eff_policy, "predictor": pred_name,
                    "seed": seed, "n_sm": spec.n_sm,
                    "until": spec.until, "solo": wl_solo,
                    "machine": spec.machine,
                    "time_scale": spec.time_scale,
                    "cache_dir": cache_dir,
                    "engine": engine,
                }
                if closed:
                    payload["closed_loop"] = True
                    payload["scenario_obj"] = scn
                    payload["workload_name"] = wl_name
                pending.append(payload)
    return {
        "ordered": ordered,
        "stats": {
            "cells": len(ordered), "cache_hits": hits,
            "computed": queued, "deduplicated": dedup,
            "jobs": jobs, "machine": spec.machine,
            "engine": None if engine is None else engine_token(engine),
            **solo_stats,
        },
    }


def _execute_pending(pending: List[dict], jobs: int,
                     records: Dict[str, dict]) -> None:
    """Run every queued payload (one pool per machine kind) and fill
    ``records``."""
    by_machine: Dict[str, List[dict]] = {}
    for payload in pending:
        by_machine.setdefault(payload["machine"], []).append(payload)
    for machine, batch in by_machine.items():
        # Longest-cells-first dispatch (LPT): DES cell cost tracks the
        # total block count, and launching the SHA1-sized cells first
        # keeps them off the pool's tail.  The sort is stable, so
        # equal-cost policy siblings stay adjacent — the chunk runner's
        # staging prototype depends on that adjacency.  Results are keyed
        # by cell key, so dispatch order never affects the output.
        def _cost(payload: dict) -> float:
            arrivals = payload.get("arrivals")
            if arrivals is None:
                return math.inf      # closed loop: unknown, go first
            return float(sum(a.spec.num_blocks for a in arrivals))

        if machine == "executor":
            if jobs > 1:
                # Executor cells run real JAX, and forking a process with
                # an initialized JAX runtime can deadlock — spawn workers
                # instead (they re-import and re-JIT, which the per-cell
                # compile cost dominates anyway).
                batch.sort(key=_cost, reverse=True)
                ctx = multiprocessing.get_context("spawn")
                with ProcessPoolExecutor(max_workers=jobs,
                                         mp_context=ctx) as pool:
                    results = list(pool.map(_run_cell, batch, chunksize=1))
            else:
                results = [_run_cell(p) for p in batch]
            for payload, record in zip(batch, results):
                records[payload["key"]] = record
            continue

        # DES: whole chunks run in-engine through run_des_chunk — one
        # packfile write per chunk instead of one cache file per cell,
        # and sibling cells share a staging prototype.  Pending cells are
        # known cache misses (pass 2 resolved hits), so the runner skips
        # the per-cell cache probe.  Fork is fine for the pure-Python DES.
        batch.sort(key=_cost, reverse=True)
        cache_dir = batch[0].get("cache_dir")
        if jobs > 1:
            size = chunk_size_for(len(batch), jobs)
            chunks = [(batch[i:i + size], cache_dir)
                      for i in range(0, len(batch), size)]
            with ProcessPoolExecutor(max_workers=jobs) as pool:
                for chunk_records in pool.map(_run_chunk, chunks):
                    records.update(chunk_records)
        else:
            records.update(run_des_chunk(batch, cache_dir,
                                         read_cache=False))


#: The two cell-dispatch tiers a sweep can run under.
DISPATCHERS = ("local", "queue")


def run_sweeps(specs: Sequence[SweepSpec], jobs: int = 1,
               cache_dir: Optional[Union[str, Path]] = None,
               dispatcher: str = "local",
               workers: Optional[int] = None,
               dispatch_opts: Optional[dict] = None) -> List[SweepResult]:
    """Execute several sweeps as ONE batch: all cache misses share one
    worker pool (one straggler tail instead of one per sweep) and cells
    shared between specs are computed once, in flight, instead of meeting
    through the on-disk cache.  Returns one :class:`SweepResult` per spec,
    exactly as consecutive :func:`run_sweep` calls would.

    ``dispatcher="local"`` (default) computes misses through the
    process-pool path; ``dispatcher="queue"`` serves them in chunks to
    ``workers`` (default ``jobs``) long-lived pull-based workers via
    :class:`repro.core.distrib.QueueDispatcher` — byte-identical records,
    DES specs only.  ``dispatch_opts`` passes through to the dispatcher
    (e.g. ``{"spawn_workers": False, "port": 5055}`` to serve remote
    workers, or ``{"chunk_cells": 16}`` to pin the chunking policy).
    """
    if dispatcher not in DISPATCHERS:
        raise ValueError(f"unknown dispatcher {dispatcher!r}; choose from "
                         f"{DISPATCHERS}")
    if dispatcher == "queue":
        for spec in specs:
            if spec.machine == "executor":
                raise ValueError(
                    "the queue dispatcher is DES-only: executor cells are "
                    "wall-clock measurements calibrated against local "
                    "pool contention (DESIGN.md Section 6); run executor "
                    "sweeps with dispatcher='local'")
    # Baselined determinism finding (wallclock): elapsed_s is driver-side
    # bookkeeping landing only in SweepResult.stats — never in a cell
    # record or a cache key.
    t0 = time.perf_counter()
    cache_dir = Path(cache_dir) if cache_dir is not None else None
    # Scavenge crashed writers' tmp orphans once per batch, before any
    # cell could race a fresh tmp file with the same name.
    scavenged = scavenge_cache_dir(cache_dir)
    records: Dict[str, dict] = {}          # key -> raw record
    pending: List[dict] = []
    queued = [_queue_spec(spec, jobs, cache_dir, records, pending)
              for spec in specs]
    batch_stats: Dict[str, float] = {"dispatcher": dispatcher,
                                     "tmp_scavenged": scavenged}
    # Baselined determinism finding (wallclock): dispatch_s brackets the
    # dispatch tier alone (pending list -> committed records) so the perf
    # lane can compare dispatchers on exactly the code the tier swaps;
    # stats-only, like elapsed_s.
    t_dispatch = time.perf_counter()
    if dispatcher == "queue" and pending:
        qd = QueueDispatcher(pending, cache_dir=cache_dir,
                             workers=workers if workers is not None else jobs,
                             fingerprints=code_fingerprints(),
                             **(dispatch_opts or {}))
        qrecords, qstats = qd.run()
        records.update(qrecords)
        batch_stats.update(qstats)
    else:
        _execute_pending(pending, jobs, records)
    batch_stats["dispatch_s"] = time.perf_counter() - t_dispatch
    elapsed = time.perf_counter() - t0
    memo = cache_memo_stats()
    batch_stats.update(elapsed_s=elapsed,
                       memo_entries=memo["entries"],
                       memo_hits=memo["hits"],
                       memo_evictions=memo["evictions"])
    out = []
    for entry in queued:
        cells = [CellResult.from_record(records[key], **labels)
                 for key, labels in entry["ordered"]]
        out.append(SweepResult(cells, {**entry["stats"], **batch_stats}))
    return out


def run_sweep(spec: SweepSpec, jobs: int = 1,
              cache_dir: Optional[Union[str, Path]] = None,
              dispatcher: str = "local",
              workers: Optional[int] = None,
              dispatch_opts: Optional[dict] = None) -> SweepResult:
    """Execute every cell of ``spec``; see the module docstring."""
    return run_sweeps([spec], jobs=jobs, cache_dir=cache_dir,
                      dispatcher=dispatcher, workers=workers,
                      dispatch_opts=dispatch_opts)[0]


__all__ = [
    "CACHE_VERSION",
    "CellResult",
    "DISPATCHERS",
    "DispatchError",
    "QueueDispatcher",
    "cache_memo_stats",
    "clear_cache_memo",
    "code_fingerprints",
    "ENGINES",
    "fingerprint_sources",
    "MACHINES",
    "MetricsCI",
    "scavenge_cache_dir",
    "SweepResult",
    "SweepSpec",
    "run_sweep",
    "run_sweeps",
    "solo_runtime_cached",
    "solo_runtime_executor_cached",
]
