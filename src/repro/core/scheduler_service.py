"""Async multi-tenant scheduling service on top of the lane executor.

This is the serving frontend the ROADMAP's production story needs: jobs are
not a fixed up-front list but arrive dynamically — ``submit(job)`` returns
a :class:`JobHandle` immediately, ``await handle.result()`` resolves when
the job's last block completes, and submissions made while the machine is
busy become late arrivals that the scheduling core (SRTF + structural
prediction, or any registered policy/predictor) sees exactly like the
paper's staggered kernel launches.

Architecture::

    asyncio world                      driver thread
    -------------                      -------------
    submit(job) ──► pending queue ──►  LaneExecutor.add_job(...)
    handle.result() ◄── Future ◄─────  LaneExecutor.step() loop
    handle.cancel() ──► cancel queue ► LaneExecutor.cancel(key)

A single daemon driver thread owns the :class:`LaneExecutor` (real JAX
computations run inside its ``step()``); the asyncio side communicates only
through thread-safe queues and ``concurrent.futures.Future``.  The executor
is a :class:`repro.core.machine.Machine`, so every policy/predictor in the
registry works unmodified.

Per-tenant accounting: each submission carries a ``tenant`` label (defaults
to the job name); :meth:`SchedulerService.tenant_metrics` reports STP and
ANTT per tenant, using caller-provided solo runtimes when available and the
structural (Eq. 1) estimate from the predictor's sampled ``t`` otherwise.
"""

from __future__ import annotations

import asyncio
import concurrent.futures
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

from .executor import ExecutorJob, JobResult, LaneExecutor
from .metrics import WorkloadMetrics, evaluate
from .policies import Policy, make_policy
from .predictor import Predictor, staircase_runtime


class JobCancelled(Exception):
    """Raised by ``handle.result()`` when the job was cancelled."""


class JobHandle:
    """Awaitable handle for one submitted job."""

    def __init__(self, key: str, tenant: str, service: "SchedulerService"):
        self.key = key
        self.tenant = tenant
        self._service = service
        self._future: concurrent.futures.Future = concurrent.futures.Future()

    async def result(self) -> JobResult:
        """Await the job's :class:`JobResult` (raises on cancellation)."""
        return await asyncio.wrap_future(self._future)

    def result_blocking(self, timeout: Optional[float] = None) -> JobResult:
        """Synchronous variant of :meth:`result` for non-async callers."""
        return self._future.result(timeout)

    def done(self) -> bool:
        return self._future.done()

    def cancel(self) -> None:
        """Request cancellation at the next block boundary."""
        self._service._request_cancel(self.key)


@dataclass
class _TenantLedger:
    """Finished-job accounting for one tenant."""

    results: List[JobResult] = field(default_factory=list)
    turnaround: Dict[str, float] = field(default_factory=dict)
    solo: Dict[str, float] = field(default_factory=dict)
    solo_estimated: bool = False
    cancelled: int = 0


class SchedulerService:
    """Multi-tenant async frontend over one :class:`LaneExecutor` machine.

    Parameters mirror the executor: ``policy``/``predictor`` accept registry
    names or instances.  Use as a context manager, or call :meth:`close`
    (or ``await aclose()``) when done; ``close`` waits for in-flight jobs
    unless ``cancel_pending=True``.
    """

    def __init__(self, n_lanes: int = 4,
                 policy: Union[str, Policy] = "srtf",
                 predictor: Union[str, Predictor, None] = None):
        if isinstance(policy, str):
            policy = make_policy(policy)
        self._ex = LaneExecutor([], policy, n_lanes=n_lanes,
                                predictor=predictor)
        self._lock = threading.Condition()
        self._pending: deque = deque()       # (job, key, tenant, solo)
        self._cancels: deque = deque()       # keys
        self._handles: Dict[str, JobHandle] = {}
        self._ledgers: Dict[str, _TenantLedger] = {}
        self._resolved: set = set()
        self._closed = False
        self._count = 0
        self._thread = threading.Thread(
            target=self._drive, name="scheduler-service", daemon=True)
        self._thread.start()

    # ----------------------------------------------------------- frontend
    def submit(self, job: ExecutorJob, tenant: Optional[str] = None,
               solo_runtime: Optional[float] = None) -> JobHandle:
        """Submit one job; returns immediately with an awaitable handle.

        ``solo_runtime`` (seconds, measured with the job running alone)
        makes the tenant's STP/ANTT exact; without it the service falls
        back to the predictor's structural estimate.
        Thread-safe; callable from sync or async code.
        """
        tenant = tenant if tenant is not None else job.tenant or job.name
        with self._lock:
            if self._closed:
                raise RuntimeError("service is closed")
            key = f"{job.name}#{self._count}"
            self._count += 1
            handle = JobHandle(key, tenant, self)
            self._handles[key] = handle
            self._pending.append((job, key, tenant, solo_runtime))
            self._lock.notify()
        return handle

    def _request_cancel(self, key: str) -> None:
        with self._lock:
            self._cancels.append(key)
            self._lock.notify()

    async def drain(self) -> List[JobResult]:
        """Await every handle submitted so far; cancelled jobs are skipped."""
        out = []
        for handle in list(self._handles.values()):
            try:
                out.append(await handle.result())
            except JobCancelled:
                pass
        return out

    def close(self, cancel_pending: bool = False) -> None:
        """Stop accepting jobs and shut the driver down.

        With ``cancel_pending`` the machine abandons unfinished jobs at the
        next block boundary; otherwise it runs them to completion.
        """
        with self._lock:
            if self._closed:
                return
            if cancel_pending:
                for key, h in self._handles.items():
                    if not h.done():
                        self._cancels.append(key)
            self._closed = True
            self._lock.notify()
        self._thread.join()

    async def aclose(self, cancel_pending: bool = False) -> None:
        await asyncio.to_thread(self.close, cancel_pending)

    def __enter__(self) -> "SchedulerService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- clocks
    @property
    def machine_time(self) -> float:
        """The machine's virtual clock (advances with executed blocks)."""
        return self._ex.now

    async def wait_until_busy(self, timeout: float = 5.0) -> None:
        """Await until the machine has executed at least one block.

        Useful to guarantee a subsequent :meth:`submit` is a *late* arrival
        (the machine clock has provably advanced past it).
        """
        deadline = time.monotonic() + timeout
        while self._ex.now == 0.0:
            if time.monotonic() > deadline:
                raise TimeoutError("machine never started executing")
            await asyncio.sleep(0.001)

    # ------------------------------------------------------------ metrics
    def tenant_metrics(self) -> Dict[str, WorkloadMetrics]:
        """STP/ANTT/fairness per tenant over finished (uncancelled) jobs."""
        with self._lock:
            ledgers = {t: (dict(led.turnaround), dict(led.solo))
                       for t, led in self._ledgers.items() if led.turnaround}
        return {t: evaluate(turn, solo) for t, (turn, solo) in ledgers.items()}

    def tenant_report(self) -> Dict[str, dict]:
        """Per-tenant summary: metrics plus job counts and estimation flag."""
        metrics = self.tenant_metrics()
        with self._lock:
            out = {}
            for tenant, ledger in self._ledgers.items():
                m = metrics.get(tenant)
                out[tenant] = {
                    "jobs": len(ledger.results),
                    "cancelled": ledger.cancelled,
                    "solo_estimated": ledger.solo_estimated,
                    "metrics": m.as_dict() if m else None,
                }
        return out

    # ------------------------------------------------------------- driver
    def _drive(self) -> None:
        try:
            self._drive_loop()
        except BaseException as exc:       # fail awaiters, don't hang them
            with self._lock:
                self._closed = True
                handles = list(self._handles.values())
            for handle in handles:
                if not handle.done():
                    handle._future.set_exception(exc)
            raise

    def _drive_loop(self) -> None:
        ex = self._ex
        tenants: Dict[str, str] = {}
        solo_hints: Dict[str, Optional[float]] = {}
        while True:
            with self._lock:
                # Block until there is work: every producer (submit,
                # _request_cancel, close) notifies under this lock, and the
                # machine's event queue only changes from this thread, so an
                # untimed wait cannot miss a wakeup.
                while (not self._pending and not self._cancels
                       and not ex.pending_events() and not self._closed):
                    self._lock.wait()
                if (self._closed and not self._pending and not self._cancels
                        and not ex.pending_events()):
                    break
                pending, self._pending = list(self._pending), deque()
                cancels, self._cancels = list(self._cancels), deque()
            for job, key, tenant, solo in pending:
                tenants[key] = tenant
                solo_hints[key] = solo
                ex.add_job(job, key=key)
            for key in cancels:
                ex.cancel(key)
            ex.step()
            self._harvest(tenants, solo_hints)
        self._harvest(tenants, solo_hints)
        # anything never started (e.g. closed with cancel_pending): fail it
        for key, handle in self._handles.items():
            if not handle.done():
                handle._future.set_exception(
                    JobCancelled(f"{key} cancelled at service shutdown"))

    def _harvest(self, tenants: Dict[str, str],
                 solo_hints: Dict[str, Optional[float]]) -> None:
        for key, result in list(self._ex.results.items()):
            if key in self._resolved:
                continue
            self._resolved.add(key)
            self._record(key, result, tenants, solo_hints)

    def _record(self, key: str, result: JobResult, tenants: Dict[str, str],
                solo_hints: Dict[str, Optional[float]]) -> None:
        with self._lock:
            tenant = tenants.get(key, key.rsplit("#", 1)[0])
            ledger = self._ledgers.setdefault(tenant, _TenantLedger())
            handle = self._handles.get(key)
            if result.cancelled:
                ledger.cancelled += 1
                if handle is not None:
                    handle._future.set_exception(
                        JobCancelled(f"{key} cancelled"))
                return
            ledger.results.append(result)
            ledger.turnaround[key] = result.turnaround
            solo = solo_hints.get(key)
            if solo is None:
                solo = self._estimate_solo(key, result)
                ledger.solo_estimated = True
            ledger.solo[key] = max(solo, 1e-9)
        if handle is not None:
            handle._future.set_result(result)

    def _estimate_solo(self, key: str, result: JobResult) -> float:
        """Structural (Eq. 1) solo-runtime estimate from the sampled ``t``.

        Running alone the job spreads over every healthy lane up to its own
        residency limit; with the predictor's per-block ``t`` the staircase
        model gives the isolated runtime.
        """
        run = self._ex.runs[key]
        ts = [t for t in (self._ex.predictor.sampled_t(key, sm)
                          for sm in range(self._ex.n_sm)) if t is not None]
        if not ts:
            return result.turnaround
        lanes = max(1, sum(1 for ln in self._ex.sms if not ln.failed))
        residency = min(run.spec.max_residency, lanes)
        return staircase_runtime(run.spec.num_blocks, residency,
                                 sum(ts) / len(ts))


__all__ = [
    "JobCancelled",
    "JobHandle",
    "SchedulerService",
]
