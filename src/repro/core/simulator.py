"""Event-driven simulator of a multi-SM GPU executing concurrent grids.

This is the GPGPU-Sim analogue used for the paper's evaluation (Section 6):
15 SMs (Table 4), block-granular resource allocation, a pluggable thread
block scheduler (:mod:`repro.core.policies`), and a pluggable structural
runtime predictor (:mod:`repro.core.predictor`) wired to the four
Algorithm-1 events.

The simulator is one concrete :class:`repro.core.machine.Machine`: the
scheduling brain lives in a :class:`repro.core.machine.SchedulerCore`
(policy + predictor) that the simulator drives with typed events and asks
for typed decisions (:mod:`repro.core.events`); the real-JAX lane executor
(:mod:`repro.core.executor`) implements the same protocol, so the identical
core schedules both.

Design notes
------------
* Resources: each SM has 8 block slots, 1536 threads, and one normalised
  "fraction" pool (1 block of kernel k consumes ``1/R_k`` of an SM — see
  ``KernelSpec.resource_fraction``).  A block is issued only if all three fit
  and the policy's residency cap for that kernel allows it.
* Block durations are sampled at issue time from the kernel's duration model
  under the *current* SM conditions (residency, co-resident warps), times a
  per-block noise factor that is indexed by global block number so that solo
  and multiprogrammed runs of the same kernel share an identical noise
  stream (slowdowns then measure scheduling, not sampling luck).
* Staggered starts (Section 3.3): on stagger-affected SMs, first-wave issues
  are serialised by an issue *gate*; the scheduler re-tries when the gate
  opens.
"""

from __future__ import annotations

import heapq
import itertools
import math
import zlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .events import (
    BlockEnded,
    BlockStarted,
    Decision,
    IssueGrant,
    KernelArrived,
    KernelEnded,
    SampleOnSM,
)
from .machine import KernelRun, MachineBase
from .predictor import Predictor
from .workload import (
    Arrival,
    KernelSpec,
    MAX_BLOCK_SLOTS,
    MAX_THREADS_PER_SM,
    MAX_WARPS_PER_SM,
    N_SM,
)

_EPS = 1e-9

#: Memoized per-kernel (noise, stagger) draws keyed by every input of the
#: draws — see Simulator._init_kernel_rng.  Entries never change once
#: stored (the draws are a pure function of the key), so a hit cannot
#: depend on history.
_NOISE_MEMO: Dict[tuple, Tuple[List[float], List[bool]]] = {}


@dataclass
class BlockRecord:
    """One executed thread block (for traces / figure benchmarks)."""

    kernel: str
    sm: int
    slot: int
    start: float
    end: float


@dataclass
class PredictionRecord:
    """One Eq. 2 prediction event (for predictor-accuracy benchmarks)."""

    kernel: str
    sm: int
    time: float            # when the prediction was made
    done_blocks: int       # blocks done on this SM at prediction time
    predicted_total: float # Pred_Cycles (total runtime from kernel start)


class SMState:
    """Resource pools of one streaming multiprocessor (Table 4)."""

    __slots__ = ("index", "used_threads", "used_fraction", "free_slots", "resident")

    def __init__(self, index: int):
        self.index = index
        self.used_threads = 0
        self.used_fraction = 0.0
        self.free_slots = list(range(MAX_BLOCK_SLOTS - 1, -1, -1))
        self.resident: Dict[int, str] = {}  # slot -> kernel key

    def fits(self, spec: KernelSpec) -> bool:
        return (
            bool(self.free_slots)
            and self.used_threads + spec.threads_per_block <= MAX_THREADS_PER_SM
            and self.used_fraction + spec.resource_fraction <= 1.0 + _EPS
        )

    def alloc(self, key: str, spec: KernelSpec) -> int:
        slot = self.free_slots.pop()
        self.resident[slot] = key
        self.used_threads += spec.threads_per_block
        self.used_fraction += spec.resource_fraction
        return slot

    def free(self, slot: int, spec: KernelSpec) -> None:
        del self.resident[slot]
        self.free_slots.append(slot)
        # Both pools clamp at zero: the fraction pool accumulates float
        # rounding, and a mis-specced spec must not drive either negative
        # (a negative pool would over-admit forever after).
        ut = self.used_threads - spec.threads_per_block
        self.used_threads = ut if ut > 0 else 0
        uf = self.used_fraction - spec.resource_fraction
        self.used_fraction = uf if uf > 0.0 else 0.0


# Event kinds, in tie-break priority order (lower sorts first at equal time).
# Heap items are flat tuples — (time, kind, seq, payload...) — where seq is
# unique, so comparison never reaches the payload: arrivals and issue
# retries carry one scalar (key / sm index), block ends carry
# (key, sm, slot, start).
_ARRIVAL, _BLOCK_END, _TRY_ISSUE = 0, 1, 2


class Simulator(MachineBase):
    """Discrete-event GPU simulator — a :class:`Machine` with a pluggable
    scheduling core (policy + predictor)."""

    def __init__(
        self,
        arrivals: Sequence[Arrival],
        policy,
        n_sm: int = N_SM,
        seed: int = 0,
        record_trace: bool = False,
        record_predictions: bool = False,
        record_decisions: bool = False,
        oracle_runtimes: Optional[Dict[str, float]] = None,
        predictor: Union[str, Predictor, None] = None,
        fast_path: bool = True,
    ):
        super().__init__(n_sm, policy, predictor=predictor,
                         oracle_runtimes=oracle_runtimes)
        #: Bit-identical fast paths (DESIGN.md Section 8): fused event
        #: dispatch, the incremental corunner aggregate, decision
        #: memoization and the targeted issue fan-out.  ``fast_path=False``
        #: forces the reference implementations; the equivalence matrix
        #: suite diffs the two end to end.  ``record_decisions=True``
        #: keeps the complete ask pattern (no targeted skips, memoization
        #: still active), so a recorded fast-path log is *identical* to
        #: the reference log — the memoization cross-check contract.
        self.fast_path = fast_path
        self.seed = seed
        self.sms = [SMState(i) for i in range(n_sm)]
        #: Resource-weighted busy time: each executing block contributes
        #: duration * spec.resource_fraction (one block = 1/R of an SM), so
        #: utilization = busy_time / (n_sm * window) lands in [0, 1].
        self.busy_time = 0.0
        self._events: List[tuple] = []   # flat (time, kind, seq, payload...)
        self._seq = itertools.count()
        #: Scheduler-state era: bumped once per processed event and per
        #: block allocation — every mutation a Decision may depend on is
        #: bracketed by a bump, so a memoized per-SM decision is valid
        #: exactly while the era stands still.
        self._era = 0
        self._decision_memo: List[Optional[Tuple[int, Decision]]] = \
            [None] * n_sm
        #: (min threads, min fraction) over active kernels with
        #: undispatched blocks; min threads is -1 when none exist.  The
        #: cheapest possible "could anything issue here?" test.  Dirtied
        #: only by the transitions that can change it: arrivals/kernel
        #: ends (via ``_invalidate_active``) and a kernel's last block
        #: issuing (in ``_allocate_block``).
        self._minfoot: Tuple[int, float] = (-1, 0.0)
        self._minfoot_dirty = True
        self.trace: List[BlockRecord] = [] if record_trace else None
        self.predictions: List[PredictionRecord] = [] if record_predictions else None
        self.decisions: List[Tuple[float, int, Decision]] = \
            [] if record_decisions else None

        #: Queued-but-unprocessed arrival events (for arrivals_pending()).
        self._pending_arrivals = 0
        for order, arr in enumerate(sorted(arrivals, key=lambda a: a.time)):
            run = KernelRun(arr.key, arr.spec, arr.time, order)
            self._init_kernel_rng(run)
            self.runs[arr.key] = run
            self._pending_arrivals += 1
            self._push(arr.time, _ARRIVAL, arr.key)
        # Dynamic (closed-loop) arrivals continue the same order sequence,
        # so injected kernels draw fresh per-order noise streams.
        self._arrival_order = itertools.count(len(self.runs))

        self.core.bind(self)
        # Bound once: the core never swaps its policy/predictor after
        # construction (machine.py documents the same invariant for
        # .policy/.predictor), so the per-block entry points skip the
        # attribute walks.
        self._policy_decide = self.core.policy.decide
        self._policy_on_block_end = self.core.policy.on_block_end
        self._policy_unlimited = self.core.policy.unlimited_caps
        #: Direct binding of the predictor's ONBLOCKEND handler: the fast
        #: block-end path performs SchedulerCore.post_block_end's exact
        #: dispatch (predictor first, then the policy hook) without the
        #: wrapper frame; the conformance suite pins the equivalence.
        self._predictor_on_block_end = self.core.predictor.on_block_end
        self._post_block_start = self.core.post_block_start
        #: Whether the per-block Algorithm-1 predictor bookkeeping runs.
        #: Prediction-free policies (``Policy.uses_predictor`` False) never
        #: read it, so the fast path elides it entirely — unless
        #: predictions are being recorded, or the reference path is forced
        #: (which always drives the full event surface).
        self._drive_predictor = (
            not fast_path
            or record_predictions
            or getattr(self.core.policy, "uses_predictor", True))

    # ------------------------------------------------------------ rng setup
    def _init_kernel_rng(self, run: KernelRun) -> None:
        # Stable per-kernel streams: identical noise per block index across
        # solo and multiprogrammed runs with the same seed, and across
        # processes (zlib.crc32 is stable; Python's hash() is salted).
        name_hash = zlib.crc32(run.spec.name.encode()) % (2 ** 31)
        spec = run.spec
        # SeedSequence expansion + generator construction is ~40us per
        # kernel per cell — dominant in tiny-cell sweeps.  Every draw below
        # (lognormal noise, then the stagger booleans off the SAME stream)
        # is a pure function of this key, so the drawn outputs themselves
        # are memoized; a hit hands back copies of exactly what a fresh
        # generator would produce, draw-for-draw, including the stream
        # position the stagger draw starts from.
        memo_key = (self.seed, name_hash, run.order, spec.rsd,
                    spec.num_blocks, self.n_sm, spec.stagger_frac,
                    spec.stagger_sm_prob)
        drawn = _NOISE_MEMO.get(memo_key)
        if drawn is None:
            rng = np.random.default_rng(np.random.SeedSequence(
                entropy=(self.seed, name_hash, run.order)))
            if spec.rsd > 0.0:
                sigma = math.sqrt(math.log(1.0 + spec.rsd * spec.rsd))
                # Stored as a plain list: the issue loop indexes one factor
                # per block, and float64 -> float via tolist() is exact.
                noise = rng.lognormal(
                    mean=-0.5 * sigma * sigma, sigma=sigma,
                    size=spec.num_blocks).tolist()
            else:
                noise = [1.0] * spec.num_blocks
            stagger = [
                spec.stagger_frac > 0.0 and rng.random() < spec.stagger_sm_prob
                for _ in range(self.n_sm)]
            drawn = (noise, stagger)
            if len(_NOISE_MEMO) >= 4096:
                _NOISE_MEMO.clear()
            _NOISE_MEMO[memo_key] = drawn
        run.noise = list(drawn[0])
        # The per-SM maps are dense on the DES (every SM is a candidate), so
        # they are normalized to flat index-addressed lists here; the
        # KernelRun fields default to dicts for machines with sparse
        # occupancy (the lane executor tracks residency its own way).
        run.resident_per_sm = [0] * self.n_sm
        run.issued_per_sm = [0] * self.n_sm
        run.issue_gate = [0.0] * self.n_sm
        run.stagger_sm = list(drawn[1])

    # --------------------------------------------------------------- events
    def _push(self, time: float, kind: int, payload) -> None:
        heapq.heappush(self._events, (time, kind, next(self._seq), payload))

    def inject_arrival(self, arrival: Arrival) -> str:
        """Schedule one dynamic arrival (the closed-loop feedback edge).

        The kernel arrives at ``max(now, arrival.time)`` — feedback can
        never rewrite the machine's past — and gets the next global arrival
        order, so its noise stream is as process-stable as the up-front
        ones (seed + crc32(name) + order).
        """
        key = arrival.key
        if key in self.runs:
            raise ValueError(f"duplicate kernel key {key!r}")
        time = max(self.now, arrival.time)
        run = KernelRun(key, arrival.spec, time, next(self._arrival_order))
        self._init_kernel_rng(run)
        self.runs[key] = run
        self._invalidate_active()
        self._pending_arrivals += 1
        self._push(time, _ARRIVAL, key)
        return key

    def run(self, until: Optional[float] = None) -> "SimResult":
        events = self._events
        sms = self.sms
        horizon = math.inf if until is None else until
        pop = heapq.heappop
        handle_block_end = self._handle_block_end
        handle_arrival = self._handle_arrival
        try_issue = self._try_issue
        while events:
            item = pop(events)
            time = item[0]
            if time > horizon:
                # Truncated: blocks still in flight have run from their
                # start to the window edge — credit that busy time so
                # utilization stays meaningful for open-loop runs.  The
                # remaining heap is scanned in place (no copy), with the
                # just-popped event credited last, exactly as the old
                # copy-and-append scan ordered it.
                runs = self.runs
                now = self.now
                for it in events:
                    if it[1] == _BLOCK_END:
                        frac = runs[it[3]].spec.resource_fraction
                        self.busy_time += max(0.0, now - it[6]) * frac
                if item[1] == _BLOCK_END:
                    frac = runs[item[3]].spec.resource_fraction
                    self.busy_time += max(0.0, now - item[6]) * frac
                break
            self.now = time
            kind = item[1]
            if kind == _BLOCK_END:
                self._era += 1
                handle_block_end(item[3], item[4], item[5], item[6])
            elif kind == _ARRIVAL:
                self._era += 1
                handle_arrival(item[3])
            else:
                # Gate retries mutate nothing themselves (allocations bump
                # the era): a retry with no intervening event is the one
                # place a memoized decision legitimately hits.
                try_issue(sms[item[3]])
        return SimResult(self)

    def arrivals_pending(self) -> bool:
        """Queued arrival events remain, or a closed-loop source may emit
        more — the DES knows its whole future arrival surface exactly."""
        return self._pending_arrivals > 0 or self._arrival_source is not None

    # ------------------------------------------------------------- handlers
    def _handle_arrival(self, key: str) -> None:
        self._pending_arrivals -= 1
        self.core.post(KernelArrived(key, self.now))
        self._fan_out()

    def _fan_out(self) -> None:
        """Offer an issue opportunity machine-wide (arrival / kernel end).

        The fast-path footprint precheck inside :meth:`_try_issue` makes
        each per-SM offer O(1) for SMs that could not physically accept a
        block of any active kernel (the targeted re-issue of DESIGN.md
        Section 8)."""
        for sm in self.sms:
            self._try_issue(sm)

    def _min_footprint(self) -> Tuple[int, float]:
        """(min threads/block, min resource fraction) over active kernels
        with undispatched blocks (-1 threads when none exist).

        An SM without headroom for even this footprint provably cannot
        receive an issue grant — every grant requires :meth:`can_fit`,
        which requires the resource fit — and decisions are
        side-effect-free, so not *asking* such an SM is schedule-identical
        (the skipped Hold merely goes unrecorded)."""
        min_tpb = -1
        min_frac = 0.0
        for run in self._active_runs():
            spec = run.spec
            if spec.num_blocks > run.issued:
                tpb = spec.threads_per_block
                frac = spec.resource_fraction
                if min_tpb < 0:
                    min_tpb = tpb
                    min_frac = frac
                else:
                    if tpb < min_tpb:
                        min_tpb = tpb
                    if frac < min_frac:
                        min_frac = frac
        mf = (min_tpb, min_frac)
        self._minfoot = mf
        self._minfoot_dirty = False
        return mf

    def _handle_block_end(self, key: str, sm_index: int, slot: int,
                          start: float) -> None:
        run = self.runs[key]
        sm = self.sms[sm_index]
        spec = run.spec
        now = self.now
        self.busy_time += (now - start) * spec.resource_fraction
        if self.fast_path:
            # Inlined SMState.free (same clamps), fused event dispatch.
            del sm.resident[slot]
            sm.free_slots.append(slot)
            ut = sm.used_threads - spec.threads_per_block
            sm.used_threads = ut if ut > 0 else 0
            uf = sm.used_fraction - spec.resource_fraction
            sm.used_fraction = uf if uf > 0.0 else 0.0
            run.resident_per_sm[sm_index] -= 1
            run.done += 1
            if self._drive_predictor:
                # SchedulerCore.post_block_end's exact dispatch, fused.
                pred = self._predictor_on_block_end(key, sm_index, slot,
                                                    now)
                self._policy_on_block_end(key, sm_index)
            else:
                # Prediction-free policy: Algorithm 1 is dead bookkeeping;
                # the policy hook still fires in the core's order.
                pred = None
                self._policy_on_block_end(key, sm_index)
        else:
            sm.free(slot, spec)
            run.resident_per_sm[sm_index] -= 1
            run.done += 1
            pred = self.core.post(BlockEnded(key, sm_index, slot, now))
        if self.predictions is not None and pred is not None:
            self.predictions.append(PredictionRecord(
                key, sm_index, now,
                self.predictor.done_blocks(key, sm_index), pred))
        if run.done == spec.num_blocks:
            run.finish_time = now
            self.core.post(KernelEnded(key, now))
            self._feed_completion(key)
            self._fan_out()
        else:
            self._try_issue(sm)

    def _invalidate_active(self, ended: Optional[str] = None) -> None:
        # Arrivals/kernel ends also change the min-footprint set.
        self._minfoot_dirty = True
        super()._invalidate_active(ended)

    # ---------------------------------------------------------------- issue
    def _cap_residency(self, key: str, sm: int) -> int:
        # On the GPU the residency cap constrains per-SM resident blocks.
        return self.runs[key].resident_per_sm[sm]

    def _fits_resources(self, key: str, sm: int) -> bool:
        return self.sms[sm].fits(self.runs[key].spec)

    def can_fit(self, key: str, sm: int) -> bool:
        # Fused override of MachineBase.can_fit — policies call this on
        # every issue opportunity, so the unissued/cap/resource checks are
        # inlined into one frame (identical semantics to the base
        # implementation driving the two hooks above).
        run = self.runs[key]
        spec = run.spec
        if spec.num_blocks - run.issued <= 0:
            return False
        cap = spec.max_residency
        if not self._policy_unlimited:
            pcap = self.core.policy.residency_cap(key, sm)
            if pcap < cap:
                cap = pcap
        if run.resident_per_sm[sm] >= cap:
            return False
        s = self.sms[sm]
        return (bool(s.free_slots)
                and s.used_threads + spec.threads_per_block
                <= MAX_THREADS_PER_SM
                and s.used_fraction + spec.resource_fraction <= 1.0 + _EPS)

    def _try_issue(self, sm: SMState) -> None:
        # Issue as many blocks as the core grants in this batch, then
        # compute durations with the *post-batch* SM conditions: blocks that
        # start at the same instant all execute at the final residency (as on
        # hardware, where a whole wave is dispatched together) rather than at
        # the transient residency seen mid-dispatch.
        smi = sm.index
        fast = self.fast_path
        record = self.decisions
        batch: List[tuple] = []  # (run, slot, noise_idx, first_wave)
        while True:
            if fast:
                if record is None:
                    # Targeted ask: skip the decision entirely when no
                    # active kernel's smallest block could physically land
                    # here (see :meth:`_min_footprint` for why this is
                    # schedule-safe).  With decision recording on, every
                    # SM is asked so the log stays the complete ask
                    # pattern (the memoization cross-check relies on it).
                    if self._minfoot_dirty:
                        mf = self._min_footprint()
                    else:
                        mf = self._minfoot
                    tpb = mf[0]
                    if (tpb < 0
                            or not sm.free_slots
                            or sm.used_threads + tpb > MAX_THREADS_PER_SM
                            or sm.used_fraction + mf[1] > 1.0 + _EPS):
                        break
                memo = self._decision_memo[smi]
                if memo is not None and memo[0] == self._era:
                    decision = memo[1]
                else:
                    decision = self._policy_decide(smi)
            else:
                decision = self.core.decide(smi)
            if record is not None:
                record.append((self.now, smi, decision))
            if isinstance(decision, (IssueGrant, SampleOnSM)):
                key = decision.key
            else:
                # Non-grant decisions are era-stable: memoize so a re-ask
                # with no intervening event (e.g. a gate retry) is free.
                if fast:
                    self._decision_memo[smi] = (self._era, decision)
                break
            run = self.runs[key]
            gate = run.issue_gate[smi]
            if gate > self.now + _EPS:
                self._push(gate, _TRY_ISSUE, smi)
                break
            if not fast and not self.can_fit(key, smi):
                # Defensive re-check on the reference path only: every
                # shipped policy verifies can_fit before granting, so the
                # fast path trusts the grant (conformance-tested).
                break
            # --- allocate (inlined; one call site, runs once per block) --
            spec = run.spec
            self._era += 1   # issue state changed: memoized decisions expire
            slot = sm.free_slots.pop()
            sm.resident[slot] = run.key
            sm.used_threads += spec.threads_per_block
            sm.used_fraction += spec.resource_fraction
            run.resident_per_sm[smi] += 1
            issued_on_sm = run.issued_per_sm[smi]
            run.issued_per_sm[smi] = issued_on_sm + 1
            if run.first_issue_time is None:
                run.first_issue_time = self.now
            first_wave = issued_on_sm < spec.max_residency
            noise_idx = run.issued
            run.issued += 1
            if run.issued == spec.num_blocks:
                self._minfoot_dirty = True   # last block issued
            if first_wave and run.stagger_sm[smi]:
                run.issue_gate[smi] = \
                    self.now + spec.stagger_frac * spec.mean_t
            batch.append((run, slot, noise_idx, first_wave))
        for run, slot, noise_idx, first_wave in batch:
            self._finalize_block(run, sm, slot, noise_idx, first_wave)

    def _finalize_block(self, run: KernelRun, sm: SMState, slot: int,
                        noise_idx: int, first_wave: bool) -> None:
        spec = run.spec
        smi = sm.index
        residency = run.resident_per_sm[smi]
        runs = self.runs
        # Co-runner pressure, summed in arrival order over the kernels with
        # blocks resident on this SM.  The per-(kernel, sm) residency
        # contributions are maintained incrementally on alloc/free
        # (``resident_per_sm``), so no rescan of the slot map is needed;
        # the reference path below recomputes the same sum from the
        # ground-truth slot map (same order, same per-term association, so
        # the two are bit-identical).
        corunner_warps = 0.0
        if self.fast_path:
            for other in self._active_runs():
                if other is run:
                    continue
                cnt = other.resident_per_sm[smi]
                if cnt:
                    corunner_warps += (
                        (other.spec.corunner_pressure * cnt)
                        * other.spec.warps_per_block)
        else:
            # Baselined determinism finding (set-iteration): the sort key
            # runs[k].order is unique per kernel, so the order is total and
            # the set's salted-hash iteration order can never leak through
            # a tie.  Reference path only (fast path sums unordered).
            resident = sorted(set(sm.resident.values()),
                              key=lambda k: runs[k].order)
            for other_key in resident:
                if other_key == run.key:
                    continue
                other = runs[other_key]
                corunner_warps += (
                    other.spec.corunner_pressure
                    * other.resident(smi) * other.spec.warps_per_block)

        if self.fast_path:
            # Inlined KernelSpec.duration (rng=None), reading the memoized
            # base-duration table: identical arithmetic, no call overhead.
            t = spec.base_t_table[
                residency if residency < spec.max_residency
                else spec.max_residency]
            if corunner_warps > 0.0:
                t *= 1.0 + spec.corunner_sens * (
                    corunner_warps / MAX_WARPS_PER_SM)
            if first_wave and spec.startup_factor > 0.0:
                t *= 1.0 + spec.startup_factor
            base = t if t > 1.0 else 1.0    # max(t, 1.0)
            duration = base * run.noise[noise_idx]
            if self._drive_predictor:
                self._post_block_start(run.key, smi, slot, self.now)
        else:
            base = spec.duration(None, residency, corunner_warps, first_wave)
            duration = base * float(run.noise[noise_idx])
            self.core.post(BlockStarted(run.key, smi, slot, self.now))
        heapq.heappush(self._events,
                       (self.now + duration, _BLOCK_END, next(self._seq),
                        run.key, smi, slot, self.now))
        if self.trace is not None:
            self.trace.append(BlockRecord(
                run.key, smi, slot, self.now, self.now + duration))


class SimResult:
    """Outcome of one simulation: per-kernel turnarounds and traces.

    Truncated (``run(until=...)``) and open-loop runs are first-class:
    kernels that did not finish inside the observation window are listed in
    :attr:`unfinished` (instead of silently dropped), :attr:`end_time` is
    the machine clock when the run stopped, and :attr:`makespan` stays
    well-defined (the window end while work is still in flight).
    """

    def __init__(self, sim: Simulator):
        self.sim = sim
        self.turnaround: Dict[str, float] = {}
        self.finish: Dict[str, float] = {}
        self.arrival: Dict[str, float] = {}
        self.name: Dict[str, str] = {}
        #: Keys of arrived-or-pending kernels without a finish time, in
        #: arrival order (cancelled kernels included — see ``cancelled``).
        self.unfinished: List[str] = []
        #: Machine clock when the run stopped (last processed event time).
        self.end_time: float = sim.now
        for key, run in sorted(sim.runs.items(), key=lambda kv: kv[1].order):
            self.name[key] = run.spec.name
            # Arrivals cover every run, finished or not: the queueing
            # metrics integrate number-in-system over the window, which
            # needs the arrival times of kernels still in flight.
            self.arrival[key] = run.arrival_time
            if run.finish_time is None:
                self.unfinished.append(key)
                continue
            self.turnaround[key] = run.finish_time - run.arrival_time
            self.finish[key] = run.finish_time

    @property
    def complete(self) -> bool:
        return not self.unfinished

    @property
    def cancelled(self) -> List[str]:
        return [k for k in self.unfinished if self.sim.runs[k].cancelled]

    @property
    def makespan(self) -> float:
        """Last finish time for complete runs; for truncated runs (work
        still in flight) the end of the observation window."""
        if self.unfinished:
            return self.end_time
        return max(self.finish.values(), default=0.0)

    @property
    def utilization(self) -> float:
        """Fraction of total SM-time spent executing blocks over the
        observation window (in-flight blocks are clipped at the window
        edge for truncated runs)."""
        if self.end_time <= 0.0:
            return 0.0
        return self.sim.busy_time / (self.sim.n_sm * self.end_time)


def simulate(
    arrivals: Sequence[Arrival],
    policy_factory: Callable[[], object],
    n_sm: int = N_SM,
    seed: int = 0,
    record_trace: bool = False,
    record_predictions: bool = False,
    oracle_runtimes: Optional[Dict[str, float]] = None,
    predictor: Union[str, Predictor, None] = None,
    until: Optional[float] = None,
    arrival_source=None,
    engine: Optional[str] = None,
) -> SimResult:
    """Run one simulation.  ``arrival_source`` attaches a closed-loop
    :class:`~repro.core.events.ArrivalSource` (completion-driven arrivals;
    typically with ``arrivals=[]`` so the source supplies the initial
    ones).

    ``engine`` selects the event-loop implementation: ``"python"`` runs
    the reference loop below, ``"compiled"`` the bit-identical flat-array
    engine (:class:`repro.core.fastsim.FastSimulator`; DESIGN.md
    Section 10), and ``None`` — the default — uses the compiled engine
    exactly when a fast backend is available
    (:func:`repro.core.fastsim.default_engine`).  The imports are lazy so
    the reference module never depends on the engine at import time.
    """
    if engine is None:
        from .fastsim import default_engine
        engine = default_engine()
    if engine == "compiled":
        from .fastsim import FastSimulator
        sim_cls = FastSimulator
    elif engine == "python":
        sim_cls = Simulator
    else:
        raise ValueError(
            f"unknown engine {engine!r}; choose from ('python', 'compiled')")
    sim = sim_cls(
        arrivals, policy_factory(), n_sm=n_sm, seed=seed,
        record_trace=record_trace, record_predictions=record_predictions,
        oracle_runtimes=oracle_runtimes, predictor=predictor)
    if arrival_source is not None:
        sim.attach_arrival_source(arrival_source)
    return sim.run(until=until)


def solo_runtime(
    spec: KernelSpec,
    policy_factory: Callable[[], object],
    n_sm: int = N_SM,
    seed: int = 0,
) -> float:
    """Runtime of ``spec`` running alone (same seed => same noise stream)."""
    res = simulate([Arrival(spec, 0.0, uid=f"{spec.name}#0")],
                   policy_factory, n_sm=n_sm, seed=seed)
    return res.turnaround[f"{spec.name}#0"]
