"""Event-driven simulator of a multi-SM GPU executing concurrent grids.

This is the GPGPU-Sim analogue used for the paper's evaluation (Section 6):
15 SMs (Table 4), block-granular resource allocation, a pluggable thread
block scheduler (:mod:`repro.core.policies`), and the Simple Slicing
predictor (:mod:`repro.core.predictor`) wired to the four Algorithm-1 events.

Design notes
------------
* Resources: each SM has 8 block slots, 1536 threads, and one normalised
  "fraction" pool (1 block of kernel k consumes ``1/R_k`` of an SM — see
  ``KernelSpec.resource_fraction``).  A block is issued only if all three fit
  and the policy's residency cap for that kernel allows it.
* Block durations are sampled at issue time from the kernel's duration model
  under the *current* SM conditions (residency, co-resident warps), times a
  per-block noise factor that is indexed by global block number so that solo
  and multiprogrammed runs of the same kernel share an identical noise
  stream (slowdowns then measure scheduling, not sampling luck).
* Staggered starts (Section 3.3): on stagger-affected SMs, first-wave issues
  are serialised by an issue *gate*; the scheduler re-tries when the gate
  opens.
* The same policy/predictor objects are reused unchanged by the real-JAX
  lane executor (:mod:`repro.core.executor`).
"""

from __future__ import annotations

import heapq
import itertools
import math
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .predictor import SimpleSlicingPredictor
from .workload import (
    Arrival,
    KernelSpec,
    MAX_BLOCK_SLOTS,
    MAX_THREADS_PER_SM,
    N_SM,
)

_EPS = 1e-9


@dataclass
class BlockRecord:
    """One executed thread block (for traces / figure benchmarks)."""

    kernel: str
    sm: int
    slot: int
    start: float
    end: float


@dataclass
class PredictionRecord:
    """One Eq. 2 prediction event (for predictor-accuracy benchmarks)."""

    kernel: str
    sm: int
    time: float            # when the prediction was made
    done_blocks: int       # blocks done on this SM at prediction time
    predicted_total: float # Pred_Cycles (total runtime from kernel start)


@dataclass
class KernelRun:
    """Dynamic state of one kernel instance inside the simulator."""

    key: str
    spec: KernelSpec
    arrival_time: float
    order: int
    issued: int = 0
    done: int = 0
    finish_time: Optional[float] = None
    first_issue_time: Optional[float] = None
    issued_per_sm: Dict[int, int] = field(default_factory=dict)
    resident_per_sm: Dict[int, int] = field(default_factory=dict)
    issue_gate: Dict[int, float] = field(default_factory=dict)
    stagger_sm: Dict[int, bool] = field(default_factory=dict)
    noise: Optional[np.ndarray] = None

    @property
    def finished(self) -> bool:
        return self.finish_time is not None

    @property
    def unissued(self) -> int:
        return self.spec.num_blocks - self.issued

    def resident(self, sm: int) -> int:
        return self.resident_per_sm.get(sm, 0)


class SMState:
    """Resource pools of one streaming multiprocessor (Table 4)."""

    __slots__ = ("index", "used_threads", "used_fraction", "free_slots", "resident")

    def __init__(self, index: int):
        self.index = index
        self.used_threads = 0
        self.used_fraction = 0.0
        self.free_slots = list(range(MAX_BLOCK_SLOTS - 1, -1, -1))
        self.resident: Dict[int, str] = {}  # slot -> kernel key

    def fits(self, spec: KernelSpec) -> bool:
        return (
            bool(self.free_slots)
            and self.used_threads + spec.threads_per_block <= MAX_THREADS_PER_SM
            and self.used_fraction + spec.resource_fraction <= 1.0 + _EPS
        )

    def alloc(self, key: str, spec: KernelSpec) -> int:
        slot = self.free_slots.pop()
        self.resident[slot] = key
        self.used_threads += spec.threads_per_block
        self.used_fraction += spec.resource_fraction
        return slot

    def free(self, slot: int, spec: KernelSpec) -> None:
        del self.resident[slot]
        self.free_slots.append(slot)
        self.used_threads -= spec.threads_per_block
        self.used_fraction = max(0.0, self.used_fraction - spec.resource_fraction)


# Event kinds, in tie-break priority order (lower sorts first at equal time).
_ARRIVAL, _BLOCK_END, _TRY_ISSUE = 0, 1, 2


class Simulator:
    """Discrete-event GPU simulator with a pluggable TBS policy."""

    def __init__(
        self,
        arrivals: Sequence[Arrival],
        policy,
        n_sm: int = N_SM,
        seed: int = 0,
        record_trace: bool = False,
        record_predictions: bool = False,
        oracle_runtimes: Optional[Dict[str, float]] = None,
    ):
        self.n_sm = n_sm
        self.policy = policy
        self.seed = seed
        self.now = 0.0
        self.predictor = SimpleSlicingPredictor(n_sm)
        self.sms = [SMState(i) for i in range(n_sm)]
        self.runs: Dict[str, KernelRun] = {}
        self.oracle_runtimes = oracle_runtimes or {}
        self._events: List[Tuple[float, int, int, tuple]] = []
        self._seq = itertools.count()
        self.trace: List[BlockRecord] = [] if record_trace else None
        self.predictions: List[PredictionRecord] = [] if record_predictions else None
        self._retry_scheduled: Dict[Tuple[int, float], bool] = {}

        for order, arr in enumerate(sorted(arrivals, key=lambda a: a.time)):
            run = KernelRun(arr.key, arr.spec, arr.time, order)
            self._init_kernel_rng(run)
            self.runs[arr.key] = run
            self._push(arr.time, _ARRIVAL, (arr.key,))

        policy.bind(self)

    # ------------------------------------------------------------ rng setup
    def _init_kernel_rng(self, run: KernelRun) -> None:
        # Stable per-kernel streams: identical noise per block index across
        # solo and multiprogrammed runs with the same seed, and across
        # processes (zlib.crc32 is stable; Python's hash() is salted).
        name_hash = zlib.crc32(run.spec.name.encode()) % (2 ** 31)
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=(self.seed, name_hash, run.order)))
        spec = run.spec
        if spec.rsd > 0.0:
            sigma = math.sqrt(math.log(1.0 + spec.rsd * spec.rsd))
            run.noise = rng.lognormal(
                mean=-0.5 * sigma * sigma, sigma=sigma, size=spec.num_blocks)
        else:
            run.noise = np.ones(spec.num_blocks)
        for sm in range(self.n_sm):
            run.stagger_sm[sm] = (
                spec.stagger_frac > 0.0 and rng.random() < spec.stagger_sm_prob)

    # --------------------------------------------------------------- events
    def _push(self, time: float, kind: int, data: tuple) -> None:
        heapq.heappush(self._events, (time, kind, next(self._seq), data))

    def run(self, until: Optional[float] = None) -> "SimResult":
        while self._events:
            time, kind, _, data = heapq.heappop(self._events)
            if until is not None and time > until:
                break
            self.now = time
            if kind == _ARRIVAL:
                self._handle_arrival(*data)
            elif kind == _BLOCK_END:
                self._handle_block_end(*data)
            else:
                self._try_issue(self.sms[data[0]])
        return SimResult(self)

    # ------------------------------------------------------------- handlers
    def _handle_arrival(self, key: str) -> None:
        run = self.runs[key]
        self.predictor.on_launch(key, run.spec.num_blocks, run.spec.max_residency)
        self.policy.on_arrival(key)
        self._sync_residency_caps()
        for sm in self.sms:
            self._try_issue(sm)

    def _handle_block_end(self, key: str, sm_index: int, slot: int) -> None:
        run = self.runs[key]
        sm = self.sms[sm_index]
        sm.free(slot, run.spec)
        run.resident_per_sm[sm_index] -= 1
        run.done += 1
        pred = self.predictor.on_block_end(key, sm_index, slot, self.now)
        if self.predictions is not None and pred is not None:
            st = self.predictor.state(key, sm_index)
            self.predictions.append(PredictionRecord(
                key, sm_index, self.now, st.done_blocks, pred))
        self.policy.on_block_end(key, sm_index)
        if run.done == run.spec.num_blocks:
            run.finish_time = self.now
            self.predictor.on_kernel_end(key)
            self.policy.on_kernel_end(key)
            self._sync_residency_caps()
            for other_sm in self.sms:
                self._try_issue(other_sm)
        else:
            self._try_issue(sm)

    # ---------------------------------------------------------------- issue
    def active_keys(self) -> List[str]:
        """Arrived, unfinished kernels in arrival order."""
        return [
            k for k, r in sorted(self.runs.items(), key=lambda kv: kv[1].order)
            if r.arrival_time <= self.now + _EPS and not r.finished
        ]

    def can_fit(self, key: str, sm: SMState) -> bool:
        run = self.runs[key]
        if run.unissued <= 0:
            return False
        cap = min(run.spec.max_residency,
                  self.policy.residency_cap(key, sm.index))
        if run.resident(sm.index) >= cap:
            return False
        return sm.fits(run.spec)

    def _try_issue(self, sm: SMState) -> None:
        # Issue as many blocks as the policy allows in this batch, then
        # compute durations with the *post-batch* SM conditions: blocks that
        # start at the same instant all execute at the final residency (as on
        # hardware, where a whole wave is dispatched together) rather than at
        # the transient residency seen mid-dispatch.
        batch: List[tuple] = []  # (run, slot, noise_idx, first_wave)
        while True:
            key = self.policy.pick(sm.index)
            if key is None:
                break
            run = self.runs[key]
            gate = run.issue_gate.get(sm.index, 0.0)
            if gate > self.now + _EPS:
                self._push(gate, _TRY_ISSUE, (sm.index,))
                break
            if not self.can_fit(key, sm):
                break  # defensive: policies only pick issuable kernels
            batch.append(self._allocate_block(run, sm))
        for run, slot, noise_idx, first_wave in batch:
            self._finalize_block(run, sm, slot, noise_idx, first_wave)

    def _allocate_block(self, run: KernelRun, sm: SMState) -> tuple:
        spec = run.spec
        slot = sm.alloc(run.key, spec)
        run.resident_per_sm[sm.index] = run.resident(sm.index) + 1
        issued_on_sm = run.issued_per_sm.get(sm.index, 0)
        run.issued_per_sm[sm.index] = issued_on_sm + 1
        if run.first_issue_time is None:
            run.first_issue_time = self.now
        first_wave = issued_on_sm < spec.max_residency
        noise_idx = run.issued
        run.issued += 1
        if first_wave and run.stagger_sm.get(sm.index, False):
            run.issue_gate[sm.index] = self.now + spec.stagger_frac * spec.mean_t
        return (run, slot, noise_idx, first_wave)

    def _finalize_block(self, run: KernelRun, sm: SMState, slot: int,
                        noise_idx: int, first_wave: bool) -> None:
        spec = run.spec
        residency = run.resident(sm.index)
        corunner_warps = 0.0
        for other_key in set(sm.resident.values()):
            if other_key == run.key:
                continue
            other = self.runs[other_key]
            corunner_warps += (
                other.spec.corunner_pressure
                * other.resident(sm.index) * other.spec.warps_per_block)

        base = spec.duration(
            _NO_NOISE_RNG, residency, corunner_warps, first_wave)
        duration = base * float(run.noise[noise_idx])

        self.predictor.on_block_start(run.key, sm.index, slot, self.now)
        self._push(self.now + duration, _BLOCK_END, (run.key, sm.index, slot))
        if self.trace is not None:
            self.trace.append(BlockRecord(
                run.key, sm.index, slot, self.now, self.now + duration))

    # ------------------------------------------------------------- plumbing
    def _sync_residency_caps(self) -> None:
        """Propagate the policy's current residency caps into the predictor
        (Section 3.4.3: residency changes start a new slice)."""
        for key in self.active_keys():
            run = self.runs[key]
            for sm in range(self.n_sm):
                cap = min(run.spec.max_residency,
                          self.policy.residency_cap(key, sm))
                self.predictor.on_residency_change(key, sm, cap)

    def elapsed(self, key: str) -> float:
        return self.now - self.runs[key].arrival_time

    def oracle_runtime(self, key: str) -> Optional[float]:
        run = self.runs[key]
        return self.oracle_runtimes.get(run.spec.name)


class _NoNoiseRNG:
    """Duration model RNG stub: noise is applied separately (see module doc)."""

    def lognormal(self, mean: float = 0.0, sigma: float = 1.0) -> float:  # pragma: no cover
        return 1.0


_NO_NOISE_RNG = _NoNoiseRNG()


class SimResult:
    """Outcome of one simulation: per-kernel turnarounds and traces."""

    def __init__(self, sim: Simulator):
        self.sim = sim
        self.turnaround: Dict[str, float] = {}
        self.finish: Dict[str, float] = {}
        self.arrival: Dict[str, float] = {}
        self.name: Dict[str, str] = {}
        for key, run in sim.runs.items():
            if run.finish_time is None:
                continue
            self.turnaround[key] = run.finish_time - run.arrival_time
            self.finish[key] = run.finish_time
            self.arrival[key] = run.arrival_time
            self.name[key] = run.spec.name

    @property
    def makespan(self) -> float:
        return max(self.finish.values(), default=0.0)


def simulate(
    arrivals: Sequence[Arrival],
    policy_factory: Callable[[], object],
    n_sm: int = N_SM,
    seed: int = 0,
    record_trace: bool = False,
    record_predictions: bool = False,
    oracle_runtimes: Optional[Dict[str, float]] = None,
) -> SimResult:
    sim = Simulator(
        arrivals, policy_factory(), n_sm=n_sm, seed=seed,
        record_trace=record_trace, record_predictions=record_predictions,
        oracle_runtimes=oracle_runtimes)
    return sim.run()


def solo_runtime(
    spec: KernelSpec,
    policy_factory: Callable[[], object],
    n_sm: int = N_SM,
    seed: int = 0,
) -> float:
    """Runtime of ``spec`` running alone (same seed => same noise stream)."""
    res = simulate([Arrival(spec, 0.0, uid=f"{spec.name}#0")],
                   policy_factory, n_sm=n_sm, seed=seed)
    return res.turnaround[f"{spec.name}#0"]
