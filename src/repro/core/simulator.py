"""Event-driven simulator of a multi-SM GPU executing concurrent grids.

This is the GPGPU-Sim analogue used for the paper's evaluation (Section 6):
15 SMs (Table 4), block-granular resource allocation, a pluggable thread
block scheduler (:mod:`repro.core.policies`), and a pluggable structural
runtime predictor (:mod:`repro.core.predictor`) wired to the four
Algorithm-1 events.

The simulator is one concrete :class:`repro.core.machine.Machine`: the
scheduling brain lives in a :class:`repro.core.machine.SchedulerCore`
(policy + predictor) that the simulator drives with typed events and asks
for typed decisions (:mod:`repro.core.events`); the real-JAX lane executor
(:mod:`repro.core.executor`) implements the same protocol, so the identical
core schedules both.

Design notes
------------
* Resources: each SM has 8 block slots, 1536 threads, and one normalised
  "fraction" pool (1 block of kernel k consumes ``1/R_k`` of an SM — see
  ``KernelSpec.resource_fraction``).  A block is issued only if all three fit
  and the policy's residency cap for that kernel allows it.
* Block durations are sampled at issue time from the kernel's duration model
  under the *current* SM conditions (residency, co-resident warps), times a
  per-block noise factor that is indexed by global block number so that solo
  and multiprogrammed runs of the same kernel share an identical noise
  stream (slowdowns then measure scheduling, not sampling luck).
* Staggered starts (Section 3.3): on stagger-affected SMs, first-wave issues
  are serialised by an issue *gate*; the scheduler re-tries when the gate
  opens.
"""

from __future__ import annotations

import heapq
import itertools
import math
import zlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .events import (
    BlockEnded,
    BlockStarted,
    Decision,
    KernelArrived,
    KernelEnded,
    grants_issue,
)
from .machine import KernelRun, MachineBase
from .predictor import Predictor
from .workload import (
    Arrival,
    KernelSpec,
    MAX_BLOCK_SLOTS,
    MAX_THREADS_PER_SM,
    N_SM,
)

_EPS = 1e-9


@dataclass
class BlockRecord:
    """One executed thread block (for traces / figure benchmarks)."""

    kernel: str
    sm: int
    slot: int
    start: float
    end: float


@dataclass
class PredictionRecord:
    """One Eq. 2 prediction event (for predictor-accuracy benchmarks)."""

    kernel: str
    sm: int
    time: float            # when the prediction was made
    done_blocks: int       # blocks done on this SM at prediction time
    predicted_total: float # Pred_Cycles (total runtime from kernel start)


class SMState:
    """Resource pools of one streaming multiprocessor (Table 4)."""

    __slots__ = ("index", "used_threads", "used_fraction", "free_slots", "resident")

    def __init__(self, index: int):
        self.index = index
        self.used_threads = 0
        self.used_fraction = 0.0
        self.free_slots = list(range(MAX_BLOCK_SLOTS - 1, -1, -1))
        self.resident: Dict[int, str] = {}  # slot -> kernel key

    def fits(self, spec: KernelSpec) -> bool:
        return (
            bool(self.free_slots)
            and self.used_threads + spec.threads_per_block <= MAX_THREADS_PER_SM
            and self.used_fraction + spec.resource_fraction <= 1.0 + _EPS
        )

    def alloc(self, key: str, spec: KernelSpec) -> int:
        slot = self.free_slots.pop()
        self.resident[slot] = key
        self.used_threads += spec.threads_per_block
        self.used_fraction += spec.resource_fraction
        return slot

    def free(self, slot: int, spec: KernelSpec) -> None:
        del self.resident[slot]
        self.free_slots.append(slot)
        self.used_threads -= spec.threads_per_block
        self.used_fraction = max(0.0, self.used_fraction - spec.resource_fraction)


# Event kinds, in tie-break priority order (lower sorts first at equal time).
_ARRIVAL, _BLOCK_END, _TRY_ISSUE = 0, 1, 2


class Simulator(MachineBase):
    """Discrete-event GPU simulator — a :class:`Machine` with a pluggable
    scheduling core (policy + predictor)."""

    def __init__(
        self,
        arrivals: Sequence[Arrival],
        policy,
        n_sm: int = N_SM,
        seed: int = 0,
        record_trace: bool = False,
        record_predictions: bool = False,
        record_decisions: bool = False,
        oracle_runtimes: Optional[Dict[str, float]] = None,
        predictor: Union[str, Predictor, None] = None,
    ):
        super().__init__(n_sm, policy, predictor=predictor,
                         oracle_runtimes=oracle_runtimes)
        self.seed = seed
        self.sms = [SMState(i) for i in range(n_sm)]
        #: Resource-weighted busy time: each executing block contributes
        #: duration * spec.resource_fraction (one block = 1/R of an SM), so
        #: utilization = busy_time / (n_sm * window) lands in [0, 1].
        self.busy_time = 0.0
        self._events: List[Tuple[float, int, int, tuple]] = []
        self._seq = itertools.count()
        self.trace: List[BlockRecord] = [] if record_trace else None
        self.predictions: List[PredictionRecord] = [] if record_predictions else None
        self.decisions: List[Tuple[float, int, Decision]] = \
            [] if record_decisions else None

        for order, arr in enumerate(sorted(arrivals, key=lambda a: a.time)):
            run = KernelRun(arr.key, arr.spec, arr.time, order)
            self._init_kernel_rng(run)
            self.runs[arr.key] = run
            self._push(arr.time, _ARRIVAL, (arr.key,))
        # Dynamic (closed-loop) arrivals continue the same order sequence,
        # so injected kernels draw fresh per-order noise streams.
        self._arrival_order = itertools.count(len(self.runs))

        self.core.bind(self)

    # ------------------------------------------------------------ rng setup
    def _init_kernel_rng(self, run: KernelRun) -> None:
        # Stable per-kernel streams: identical noise per block index across
        # solo and multiprogrammed runs with the same seed, and across
        # processes (zlib.crc32 is stable; Python's hash() is salted).
        name_hash = zlib.crc32(run.spec.name.encode()) % (2 ** 31)
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=(self.seed, name_hash, run.order)))
        spec = run.spec
        if spec.rsd > 0.0:
            sigma = math.sqrt(math.log(1.0 + spec.rsd * spec.rsd))
            run.noise = rng.lognormal(
                mean=-0.5 * sigma * sigma, sigma=sigma, size=spec.num_blocks)
        else:
            run.noise = np.ones(spec.num_blocks)
        for sm in range(self.n_sm):
            run.stagger_sm[sm] = (
                spec.stagger_frac > 0.0 and rng.random() < spec.stagger_sm_prob)

    # --------------------------------------------------------------- events
    def _push(self, time: float, kind: int, data: tuple) -> None:
        heapq.heappush(self._events, (time, kind, next(self._seq), data))

    def inject_arrival(self, arrival: Arrival) -> str:
        """Schedule one dynamic arrival (the closed-loop feedback edge).

        The kernel arrives at ``max(now, arrival.time)`` — feedback can
        never rewrite the machine's past — and gets the next global arrival
        order, so its noise stream is as process-stable as the up-front
        ones (seed + crc32(name) + order).
        """
        key = arrival.key
        if key in self.runs:
            raise ValueError(f"duplicate kernel key {key!r}")
        time = max(self.now, arrival.time)
        run = KernelRun(key, arrival.spec, time, next(self._arrival_order))
        self._init_kernel_rng(run)
        self.runs[key] = run
        self._push(time, _ARRIVAL, (key,))
        return key

    def run(self, until: Optional[float] = None) -> "SimResult":
        while self._events:
            time, kind, _, data = heapq.heappop(self._events)
            if until is not None and time > until:
                # Truncated: blocks still in flight have run from their
                # start to the window edge — credit that busy time so
                # utilization stays meaningful for open-loop runs.
                for _, k, _, d in self._events + [(time, kind, 0, data)]:
                    if k == _BLOCK_END:
                        frac = self.runs[d[0]].spec.resource_fraction
                        self.busy_time += max(0.0, self.now - d[3]) * frac
                break
            self.now = time
            if kind == _ARRIVAL:
                self._handle_arrival(*data)
            elif kind == _BLOCK_END:
                self._handle_block_end(*data)
            else:
                self._try_issue(self.sms[data[0]])
        return SimResult(self)

    # ------------------------------------------------------------- handlers
    def _handle_arrival(self, key: str) -> None:
        self.core.post(KernelArrived(key, self.now))
        for sm in self.sms:
            self._try_issue(sm)

    def _handle_block_end(self, key: str, sm_index: int, slot: int,
                          start: float) -> None:
        run = self.runs[key]
        sm = self.sms[sm_index]
        self.busy_time += (self.now - start) * run.spec.resource_fraction
        sm.free(slot, run.spec)
        run.resident_per_sm[sm_index] -= 1
        run.done += 1
        pred = self.core.post(BlockEnded(key, sm_index, slot, self.now))
        if self.predictions is not None and pred is not None:
            self.predictions.append(PredictionRecord(
                key, sm_index, self.now,
                self.predictor.done_blocks(key, sm_index), pred))
        if run.done == run.spec.num_blocks:
            run.finish_time = self.now
            self.core.post(KernelEnded(key, self.now))
            self._feed_completion(key)
            for other_sm in self.sms:
                self._try_issue(other_sm)
        else:
            self._try_issue(sm)

    # ---------------------------------------------------------------- issue
    def _cap_residency(self, key: str, sm: int) -> int:
        # On the GPU the residency cap constrains per-SM resident blocks.
        return self.runs[key].resident(sm)

    def _fits_resources(self, key: str, sm: int) -> bool:
        return self.sms[sm].fits(self.runs[key].spec)

    def _try_issue(self, sm: SMState) -> None:
        # Issue as many blocks as the core grants in this batch, then
        # compute durations with the *post-batch* SM conditions: blocks that
        # start at the same instant all execute at the final residency (as on
        # hardware, where a whole wave is dispatched together) rather than at
        # the transient residency seen mid-dispatch.
        batch: List[tuple] = []  # (run, slot, noise_idx, first_wave)
        while True:
            decision = self.core.decide(sm.index)
            if self.decisions is not None:
                self.decisions.append((self.now, sm.index, decision))
            key = grants_issue(decision)
            if key is None:
                break
            run = self.runs[key]
            gate = run.issue_gate.get(sm.index, 0.0)
            if gate > self.now + _EPS:
                self._push(gate, _TRY_ISSUE, (sm.index,))
                break
            if not self.can_fit(key, sm.index):
                break  # defensive: the core only grants issuable kernels
            batch.append(self._allocate_block(run, sm))
        for run, slot, noise_idx, first_wave in batch:
            self._finalize_block(run, sm, slot, noise_idx, first_wave)

    def _allocate_block(self, run: KernelRun, sm: SMState) -> tuple:
        spec = run.spec
        slot = sm.alloc(run.key, spec)
        run.resident_per_sm[sm.index] = run.resident(sm.index) + 1
        issued_on_sm = run.issued_per_sm.get(sm.index, 0)
        run.issued_per_sm[sm.index] = issued_on_sm + 1
        if run.first_issue_time is None:
            run.first_issue_time = self.now
        first_wave = issued_on_sm < spec.max_residency
        noise_idx = run.issued
        run.issued += 1
        if first_wave and run.stagger_sm.get(sm.index, False):
            run.issue_gate[sm.index] = self.now + spec.stagger_frac * spec.mean_t
        return (run, slot, noise_idx, first_wave)

    def _finalize_block(self, run: KernelRun, sm: SMState, slot: int,
                        noise_idx: int, first_wave: bool) -> None:
        spec = run.spec
        residency = run.resident(sm.index)
        corunner_warps = 0.0
        for other_key in set(sm.resident.values()):
            if other_key == run.key:
                continue
            other = self.runs[other_key]
            corunner_warps += (
                other.spec.corunner_pressure
                * other.resident(sm.index) * other.spec.warps_per_block)

        base = spec.duration(None, residency, corunner_warps, first_wave)
        duration = base * float(run.noise[noise_idx])

        self.core.post(BlockStarted(run.key, sm.index, slot, self.now))
        self._push(self.now + duration, _BLOCK_END,
                   (run.key, sm.index, slot, self.now))
        if self.trace is not None:
            self.trace.append(BlockRecord(
                run.key, sm.index, slot, self.now, self.now + duration))


class SimResult:
    """Outcome of one simulation: per-kernel turnarounds and traces.

    Truncated (``run(until=...)``) and open-loop runs are first-class:
    kernels that did not finish inside the observation window are listed in
    :attr:`unfinished` (instead of silently dropped), :attr:`end_time` is
    the machine clock when the run stopped, and :attr:`makespan` stays
    well-defined (the window end while work is still in flight).
    """

    def __init__(self, sim: Simulator):
        self.sim = sim
        self.turnaround: Dict[str, float] = {}
        self.finish: Dict[str, float] = {}
        self.arrival: Dict[str, float] = {}
        self.name: Dict[str, str] = {}
        #: Keys of arrived-or-pending kernels without a finish time, in
        #: arrival order (cancelled kernels included — see ``cancelled``).
        self.unfinished: List[str] = []
        #: Machine clock when the run stopped (last processed event time).
        self.end_time: float = sim.now
        for key, run in sorted(sim.runs.items(), key=lambda kv: kv[1].order):
            self.name[key] = run.spec.name
            # Arrivals cover every run, finished or not: the queueing
            # metrics integrate number-in-system over the window, which
            # needs the arrival times of kernels still in flight.
            self.arrival[key] = run.arrival_time
            if run.finish_time is None:
                self.unfinished.append(key)
                continue
            self.turnaround[key] = run.finish_time - run.arrival_time
            self.finish[key] = run.finish_time

    @property
    def complete(self) -> bool:
        return not self.unfinished

    @property
    def cancelled(self) -> List[str]:
        return [k for k in self.unfinished if self.sim.runs[k].cancelled]

    @property
    def makespan(self) -> float:
        """Last finish time for complete runs; for truncated runs (work
        still in flight) the end of the observation window."""
        if self.unfinished:
            return self.end_time
        return max(self.finish.values(), default=0.0)

    @property
    def utilization(self) -> float:
        """Fraction of total SM-time spent executing blocks over the
        observation window (in-flight blocks are clipped at the window
        edge for truncated runs)."""
        if self.end_time <= 0.0:
            return 0.0
        return self.sim.busy_time / (self.sim.n_sm * self.end_time)


def simulate(
    arrivals: Sequence[Arrival],
    policy_factory: Callable[[], object],
    n_sm: int = N_SM,
    seed: int = 0,
    record_trace: bool = False,
    record_predictions: bool = False,
    oracle_runtimes: Optional[Dict[str, float]] = None,
    predictor: Union[str, Predictor, None] = None,
    until: Optional[float] = None,
    arrival_source=None,
) -> SimResult:
    """Run one simulation.  ``arrival_source`` attaches a closed-loop
    :class:`~repro.core.events.ArrivalSource` (completion-driven arrivals;
    typically with ``arrivals=[]`` so the source supplies the initial
    ones)."""
    sim = Simulator(
        arrivals, policy_factory(), n_sm=n_sm, seed=seed,
        record_trace=record_trace, record_predictions=record_predictions,
        oracle_runtimes=oracle_runtimes, predictor=predictor)
    if arrival_source is not None:
        sim.attach_arrival_source(arrival_source)
    return sim.run(until=until)


def solo_runtime(
    spec: KernelSpec,
    policy_factory: Callable[[], object],
    n_sm: int = N_SM,
    seed: int = 0,
) -> float:
    """Runtime of ``spec`` running alone (same seed => same noise stream)."""
    res = simulate([Arrival(spec, 0.0, uid=f"{spec.name}#0")],
                   policy_factory, n_sm=n_sm, seed=seed)
    return res.turnaround[f"{spec.name}#0"]
