"""Typed machine events and scheduling decisions (DESIGN.md Section 3).

These small frozen dataclasses are the vocabulary of the ``SchedulerCore``
/ ``Machine`` contract:

* **Events** (machine → core) are the paper's Algorithm-1 surface plus the
  TPU-adaptation fault path: :class:`KernelArrived`, :class:`BlockStarted`,
  :class:`BlockEnded` (with ``lost=True`` when a failed lane discards a
  block's work) and :class:`KernelEnded`.  A machine posts them through
  :meth:`repro.core.machine.SchedulerCore.post`, which fans them out to the
  predictor (Algorithm 1 handlers) and the policy (hooks).

* **Decisions** (core → machine) replace the old ``pick() -> key|None``
  duck-type with explicit intent.  A machine asks ``core.decide(sm)``
  whenever execution unit ``sm`` could issue and acts on the answer:

  - :class:`IssueGrant`       — dispatch the next block of ``key`` now.
  - :class:`SampleOnSM`       — dispatch a block of ``key`` for SRTF's
    online sampling phase (Section 5.1.1); an issue, but distinguishable
    so machines/telemetry can attribute sampling cost.
  - :class:`Hold`             — nothing may issue; wait for the next event.
  - :class:`PreemptAtBoundary` — ``key`` should take the unit exclusively,
    but blocks already running must drain first: do not backfill, re-ask at
    the next block boundary.  This is the paper's preemption-at-block-
    boundary made explicit (Section 5.1.1).

Machines only need :func:`grants_issue` to act; the richer types exist for
telemetry, testing and future machines (e.g. real pod lanes) that want to
treat sampling or draining specially.

* **Feedback** (machine → workload): :class:`ArrivalSource` is the
  completion→arrival feedback edge that makes closed-loop workloads
  possible.  A machine with an attached source (see
  :meth:`repro.core.machine.MachineBase.attach_arrival_source`) feeds it
  every natural kernel completion *after* posting the corresponding
  :class:`KernelEnded` event, and schedules whatever
  :class:`~repro.core.workload.Arrival`\\ s the source emits in response —
  the next kernels of an M/G/k offered-load stream, a tenant's think-time
  resubmission, and so on (:mod:`repro.core.scenarios` closed-loop tier).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Protocol, Union, runtime_checkable

from .workload import Arrival

# --------------------------------------------------------------------- events


@dataclass(frozen=True)
class KernelArrived:
    """A kernel/job became visible to the scheduler (Algorithm 1 ONLAUNCH)."""

    key: str
    time: float


@dataclass(frozen=True)
class BlockStarted:
    """One block began executing on unit ``sm`` (Algorithm 1 ONBLOCKSTART)."""

    key: str
    sm: int
    slot: int
    time: float


@dataclass(frozen=True)
class BlockEnded:
    """One block finished on unit ``sm`` (Algorithm 1 ONBLOCKEND).

    ``lost=True`` marks the executor's fault path: the unit failed mid-block,
    the work is discarded and the block will be re-issued; the predictor
    starts a new slice instead of ingesting the bogus duration.
    """

    key: str
    sm: int
    slot: int
    time: float
    lost: bool = False


@dataclass(frozen=True)
class KernelEnded:
    """Every block of the kernel completed (Algorithm 1 ONKERNELEND).

    This event is also the trigger of the completion→arrival feedback
    edge: machines with an attached :class:`ArrivalSource` feed it the
    completed key right after posting this event, so closed-loop arrival
    processes observe completions in machine-event order.
    """

    key: str
    time: float


MachineEvent = Union[KernelArrived, BlockStarted, BlockEnded, KernelEnded]


# ------------------------------------------------------------------ feedback
@runtime_checkable
class ArrivalSource(Protocol):
    """Completion-driven arrival generator (the closed-loop feedback edge).

    A source is *stateful and single-use*: one machine run consumes one
    source.  The machine calls :meth:`initial` exactly once when the source
    is attached and :meth:`on_completion` once per natural kernel
    completion (cancelled kernels do not count — a cancellation is a
    frontend action, not the machine finishing work).  Returned arrivals
    carry times in **source time units**; machines with a different clock
    (the real-JAX executor counts seconds, scenarios count cycles) convert
    via the ``time_scale`` given at attach time.  Arrival times in the past
    are clipped to "now" by the machine, never reordered into its history.
    """

    def initial(self) -> List[Arrival]:
        """Arrivals to schedule before the machine starts running."""
        ...

    def on_completion(self, key: str, now: float) -> List[Arrival]:
        """Arrivals emitted in response to ``key`` completing at ``now``."""
        ...


# ------------------------------------------------------------------ decisions


@dataclass(frozen=True)
class IssueGrant:
    """Dispatch the next block of ``key`` on the asking unit now."""

    key: str
    reason: str = ""


@dataclass(frozen=True)
class SampleOnSM:
    """Dispatch a block of ``key`` on the asking unit for online sampling."""

    key: str
    reason: str = "srtf-sampling"


@dataclass(frozen=True)
class Hold:
    """Nothing may issue on the asking unit until the next event."""

    reason: str = ""


@dataclass(frozen=True)
class PreemptAtBoundary:
    """``key`` must take the unit exclusively; drain running blocks first.

    The machine must not backfill other kernels behind ``key`` — it re-asks
    at the next block boundary, at which point the freed resources go to
    ``key``.  Hand-off delay (Section 6.2.2) emerges from this decision.
    """

    key: str
    reason: str = "draining for exclusive winner"


Decision = Union[IssueGrant, SampleOnSM, Hold, PreemptAtBoundary]


def grants_issue(decision: Decision) -> Optional[str]:
    """Kernel key the machine may issue right now, or ``None`` to wait."""
    if isinstance(decision, (IssueGrant, SampleOnSM)):
        return decision.key
    return None


__all__ = [
    "ArrivalSource",
    "BlockEnded",
    "BlockStarted",
    "Decision",
    "Hold",
    "IssueGrant",
    "KernelArrived",
    "KernelEnded",
    "MachineEvent",
    "PreemptAtBoundary",
    "SampleOnSM",
    "grants_issue",
]
