"""Compiled DES engine behind the Machine protocol (DESIGN.md Section 10).

:class:`FastSimulator` is a :class:`repro.core.simulator.Simulator` whose
``run()`` executes the event loop over flat NumPy arrays via one of three
interchangeable backends of the SAME algorithm
(:mod:`repro.core.fastsim_twin`):

* ``native`` — generated C compiled with ``-ffp-contract=off``
  (:mod:`repro.core.fastsim_c`); the fast one.
* ``numba`` — the twin under ``@njit`` when numba is importable
  (``REPRO_NO_NUMBA=1`` forces it off).
* ``interp`` — the twin interpreted over NumPy arrays: always
  importable, byte-identical, slow (the correctness oracle for the
  other two; never the default).

The engine is bit-identical to the reference ``Simulator.run`` by
construction: every float expression, every container iteration order and
even the event heap's array layout mirror the reference (the twin's
module docstring and DESIGN.md Section 10 spell out the invariants).
Unsupported configurations — custom policy/predictor subclasses,
``fast_path=False``, cancelled runs — transparently fall back to the
reference loop.

Segment protocol: ``run()`` repeatedly (1) gathers all Python-object
state into the twin's array layout, (2) calls ``advance`` until it exits
(completion, horizon truncation, a kernel completion that must feed the
closed-loop arrival source, or buffer-headroom exits), (3) scatters the
arrays back into the Python objects.  After every scatter the simulator
is a valid reference ``Simulator`` mid-run — the two implementations can
hand a simulation to each other at any segment boundary.
"""

from __future__ import annotations

import itertools
import math
import os
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import fastsim_twin as tw
from .events import Hold, IssueGrant, PreemptAtBoundary, SampleOnSM
from .policies import (
    _HOLD_ADAPTIVE,
    _HOLD_HEAD_OF_LINE,
    _HOLD_MPMAX,
    _HOLD_NO_ELIGIBLE,
    _HOLD_NO_UNDISPATCHED,
    _HOLD_SAMPLING,
    CappedFIFO,
    FIFO,
    LJF,
    MPMax,
    SJF,
    SRTF,
    SRTFAdaptive,
    SRTFZeroSampling,
)
from .predictor import EWMAPredictor, PerSMState, SimpleSlicingPredictor
from .machine import KernelRun
from .simulator import (
    _ARRIVAL,
    _BLOCK_END,
    BlockRecord,
    PredictionRecord,
    SimResult,
    Simulator,
)

_NAN = float("nan")

#: Exact-type -> twin policy id.  Exact types only: a user subclass may
#: override any hook, so it must take the reference path.
_POLICY_IDS = {
    FIFO: tw.POL_FIFO,
    CappedFIFO: tw.POL_FIFO_CAP,
    SJF: tw.POL_SJF,
    LJF: tw.POL_LJF,
    MPMax: tw.POL_MPMAX,
    SRTF: tw.POL_SRTF,
    SRTFZeroSampling: tw.POL_SRTF_ZERO,
    SRTFAdaptive: tw.POL_SRTF_ADAPTIVE,
}

_SRTF_FAMILY = (tw.POL_SRTF, tw.POL_SRTF_ZERO, tw.POL_SRTF_ADAPTIVE)

_HOLD_BY_CODE = {
    tw.DEC_HOLD_HEAD: _HOLD_HEAD_OF_LINE,
    tw.DEC_HOLD_NO_UNDISP: _HOLD_NO_UNDISPATCHED,
    tw.DEC_HOLD_SAMPLING: _HOLD_SAMPLING,
    tw.DEC_HOLD_NO_ELIG: _HOLD_NO_ELIGIBLE,
    tw.DEC_HOLD_MPMAX: _HOLD_MPMAX,
    tw.DEC_HOLD_ADAPTIVE: _HOLD_ADAPTIVE,
}


# ------------------------------------------------------ backend resolution
_native_fn = "unresolved"


def _native_advance():
    """The generated-C advance callable, or None (build unavailable)."""
    global _native_fn
    if _native_fn == "unresolved":
        _native_fn = None
        if os.environ.get("REPRO_NO_NATIVE") != "1":
            try:
                from .fastsim_c import native_advance
                _native_fn = native_advance()
            except Exception:
                _native_fn = None
    return _native_fn


def backend_name() -> str:
    """Which backend the compiled engine would use right now."""
    if _native_advance() is not None:
        return "native"
    if tw.NUMBA_AVAILABLE:
        return "numba"
    return "interp"


def default_engine() -> str:
    """``"compiled"`` when a *fast* backend exists, else ``"python"``.

    The interpreted twin is byte-identical but slower than the reference
    loop — it exists as the numba-absent correctness fallback, not as a
    default (ISSUE 7: import must never hard-require numba).
    """
    return "compiled" if backend_name() != "interp" else "python"


def engine_token(engine: str) -> str:
    """Result-determining engine fingerprint for sweep cache keys.

    All backends are gated bit-identical, but the cache key still records
    which one produced a cell (``compiled-native`` / ``compiled-numba`` /
    ``compiled-interp``) so a gating regression can never silently mix
    provenance across cached results.
    """
    if engine == "compiled":
        return f"compiled-{backend_name()}"
    return "python"


def _decision_object(code: int, key: Optional[str]):
    if code == tw.DEC_GRANT:
        return IssueGrant(key)
    if code == tw.DEC_SAMPLE:
        return SampleOnSM(key)
    if code == tw.DEC_PREEMPT:
        return PreemptAtBoundary(key)
    return _HOLD_BY_CODE[code]


class FastSimulator(Simulator):
    """Simulator whose event loop runs on the compiled flat-array engine.

    Constructor signature matches :class:`Simulator`; ``backend`` pins a
    specific engine backend (``"native"``/``"numba"``/``"interp"``, None =
    best available) — used by the equivalence tests to force each one.
    """

    def __init__(self, *args, backend: Optional[str] = None, **kwargs):
        super().__init__(*args, **kwargs)
        self._backend = backend
        #: Decision-buffer capacity, persisted across segments and doubled
        #: on buffer-headroom exits (decision volume is the one record
        #: stream with no cheap a-priori bound).
        self._dec_cap = 4096
        #: Staged-arrival window handed to a lowered closed-loop source
        #: per rebuild (tests shrink it to force pool-exhaustion resumes).
        self._stage_cap = 4096
        #: uid -> staged KernelRun, reused across rebuilds (uid, order and
        #: RNG draws are all stable under restaging).
        self._staged_memo: Dict[str, KernelRun] = {}
        self._build_staged: List[KernelRun] = []
        self._build_staged_base = 0
        self._build_lower_mode: Optional[str] = None
        self._build_n_tenants = 0
        #: Think-time tenant parked by a pool-exhaustion exit (-1 = none).
        self._src_pend = -1
        #: Exit-code -> count over every engine segment this simulator
        #: ran (the python-boundary crossing histogram; see the twin's
        #: module docstring for the code table).
        self.segment_exits: Dict[int, int] = {}
        #: Result-only mode (the sweep chunk runner): terminal exits take
        #: the lean scatter — the simulator is NOT a valid mid-run
        #: reference afterwards, only its result fields are.
        self._lean_result = False
        #: Shared staging prototype (chunk runner, DESIGN.md Section 13):
        #: a dict shared by sibling cells built from the same body
        #: (arrivals, seed, n_sm, until, oracle) so later siblings clone
        #: the staged arrays instead of rebuilding them.
        self._stage_proto: Optional[dict] = None

    # ------------------------------------------------------------- driver
    def _engine_supported(self) -> bool:
        if not self.fast_path:
            return False
        if type(self.core.policy) not in _POLICY_IDS:
            return False
        if type(self.predictor) not in (SimpleSlicingPredictor,
                                        EWMAPredictor):
            return False
        for run in self.runs.values():
            if run.cancelled:
                return False
        return True

    def _advance_fn(self):
        backend = self._backend
        if backend is None:
            backend = backend_name()
        if backend == "native":
            return _native_advance()
        if backend == "numba" and not tw.NUMBA_AVAILABLE:
            return None
        return tw.advance

    def run(self, until: Optional[float] = None) -> SimResult:
        if not self._engine_supported():
            return Simulator.run(self, until)
        advance = self._advance_fn()
        if advance is None:
            return Simulator.run(self, until)
        resume = False
        first = True
        while True:
            state = None
            if first and self._stage_proto is not None:
                state, keys = self._proto_clone(until)
            if state is None:
                state, keys = self._build_state(until, resume)
                if first and self._stage_proto is not None:
                    self._proto_store(state, keys, until, resume)
            first = False
            resume = False
            rc = int(advance(state))
            self.segment_exits[rc] = self.segment_exits.get(rc, 0) + 1
            if (rc == 0 or rc == 1) and self._lean_result:
                self._scatter_result(state, keys)
                break
            self._scatter(state, keys)
            if rc == 0 or rc == 1:
                break
            if rc == 2:
                # A kernel finished with a python-mediated arrival source
                # attached: the reference calls _feed_completion between
                # KernelEnded and the machine-wide fan-out, so the engine
                # exits there and re-enters with RESUME (= run the
                # pending fan-out first).
                self._feed_completion(keys[int(state[tw.S_SI][tw.SI_EXIT_RUN])])
                resume = True
            elif rc == 7:
                # Lowered source ran its staged variate pool dry mid
                # injection: the rebuild stages a fresh window and the
                # engine resumes the interrupted release before the
                # pending fan-out.
                resume = True
            elif rc == 5:
                self._dec_cap *= 2
            # rc 3/4/6: capacities are recomputed from the just-scattered
            # state on rebuild, so re-entry always has fresh headroom.
        return SimResult(self)

    # ------------------------------------------------- staging prototype
    def _proto_fits(self, pol: Optional[int]) -> bool:
        """Whether this simulator's policy/predictor state is covered by
        the prototype patch set: a policy the clone path knows how to
        re-apply, still in its freshly-constructed (empty) state, over a
        predictor with no per-kernel state.  SJF/LJF bake per-run sort
        keys (``RF_SJFKEY``) into the arrays, so they neither seed nor
        clone a prototype."""
        if pol is None or pol == tw.POL_SJF or pol == tw.POL_LJF:
            return False
        if self.predictor._state:
            return False
        policy = self.core.policy
        if pol in _SRTF_FAMILY and (policy.eligible or policy.sample_queue
                                    or policy.sampling is not None):
            return False
        if pol == tw.POL_MPMAX and policy._caps:
            return False
        if pol == tw.POL_SRTF_ADAPTIVE and (policy._caps
                                            or policy._excl_pred):
            return False
        return True

    def _proto_store(self, state: tuple, keys: List[str],
                     until: Optional[float], resume: bool) -> None:
        """Seed the group's staging prototype from a just-built state.

        Only a fresh, source-free, record-free first segment is general
        enough for siblings to clone; anything else leaves the prototype
        empty and every sibling builds normally."""
        proto = self._stage_proto
        if proto is None or proto.get("state") is not None:
            return
        if (resume or self.now != 0.0 or self._arrival_source is not None
                or self._build_lower_mode is not None
                or self.trace is not None or self.decisions is not None
                or self.predictions is not None):
            return
        if not self._proto_fits(_POLICY_IDS.get(type(self.core.policy))):
            return
        proto["state"] = tuple(arr.copy() for arr in state)
        proto["keys"] = list(keys)
        proto["until"] = until

    def _proto_clone(self, until: Optional[float]):
        """Clone the group's staging prototype instead of rebuilding.

        The chunk runner guarantees every simulator sharing one proto
        dict was constructed from the same body (arrivals, seed, n_sm,
        until, oracle runtimes); only the freshly-built policy/predictor
        differ.  The clone memcpys the staged arrays and re-applies
        exactly the policy/predictor-dependent entries ``_build_state``
        writes; a configuration outside the patch set falls back to a
        normal build (returns ``(None, None)``)."""
        proto = self._stage_proto
        if (proto.get("state") is None or proto["until"] != until
                or self._arrival_source is not None
                or self.trace is not None or self.decisions is not None
                or self.predictions is not None):
            return None, None
        policy = self.core.policy
        predictor = self.predictor
        pol = _POLICY_IDS.get(type(policy))
        if not self._proto_fits(pol):
            return None, None
        # One scratch state per proto, refreshed in place: siblings run
        # strictly serially in the chunk runner and read everything they
        # need out of the arrays before the next cell starts, so reusing
        # the buffers (same tuple object — the native backend caches the
        # ctypes pointers by tuple identity) is safe and skips 31
        # allocations per sibling.
        state = proto.get("scratch")
        if state is None:
            state = tuple(arr.copy() for arr in proto["state"])
            proto["scratch"] = state
        else:
            for dst, src in zip(state, proto["state"]):
                np.copyto(dst, src)
        si, ci, cf = state[0], state[2], state[3]
        si[tw.SI_SEQ] = next(self._seq)
        si[tw.SI_SHARING] = 0
        ci[tw.CI_POLICY] = pol
        ci[tw.CI_UNLIMITED] = 1 if policy.unlimited_caps else 0
        ci[tw.CI_DRIVE_PRED] = 1 if self._drive_predictor else 0
        ci[tw.CI_FIXED_CAP] = 0
        ci[tw.CI_SAMPLE_SM] = 0
        ci[tw.CI_SHARED_RES] = 0
        ci[tw.CI_PRED_KIND] = 0
        cf[tw.CF_THRESHOLD] = 0.0
        cf[tw.CF_HYSTERESIS] = 0.0
        cf[tw.CF_ALPHA] = 0.0
        if pol == tw.POL_FIFO_CAP:
            ci[tw.CI_FIXED_CAP] = policy.cap
        if pol in _SRTF_FAMILY:
            ci[tw.CI_SAMPLE_SM] = policy.sample_sm
        if pol == tw.POL_SRTF_ADAPTIVE:
            ci[tw.CI_SHARED_RES] = policy.shared_residency
            cf[tw.CF_THRESHOLD] = policy.unfairness_threshold
            cf[tw.CF_HYSTERESIS] = policy.hysteresis
            si[tw.SI_SHARING] = 1 if policy.sharing else 0
        if type(predictor) is EWMAPredictor:
            ci[tw.CI_PRED_KIND] = 1
            cf[tw.CF_ALPHA] = predictor.alpha
        self._build_staged = []
        self._build_lower_mode = None
        return state, proto["keys"]

    # -------------------------------------------------------------- build
    def _stage_source(self) -> Tuple[List[KernelRun], Optional[dict]]:
        """Stage a window of pre-drawn future arrivals from a lowered
        closed-loop source.

        Returns ``(staged_runs, lowering)``; ``(.., None)`` when the
        attached source (if any) is not lowerable and completions must
        keep crossing the python boundary (exit 2).  Staged KernelRuns
        carry their final uid/order/RNG state already — the engine only
        decides WHEN (and for think-time, for which tenant) each one is
        injected."""
        source = self._arrival_source
        if source is None or self._source_time_scale != 1.0:
            return [], None
        stage = getattr(source, "engine_stage", None)
        if stage is None:
            return [], None
        lower = stage(self._stage_cap)
        if lower is None:
            return [], None
        base = next(self._arrival_order)
        self._arrival_order = itertools.count(base)
        memo = self._staged_memo
        times = lower.get("times")
        staged: List[KernelRun] = []
        for k, uid in enumerate(lower["uids"]):
            run = memo.get(uid)
            if run is None:
                # Provisional arrival time; _src_inject decides the real
                # one (clipped to `now`) and _scatter copies it back.
                at = times[k] if times is not None else 0.0
                run = KernelRun(uid, lower["specs"][k], at, base + k)
                self._init_kernel_rng(run)
                memo[uid] = run
            staged.append(run)
        self._build_staged_base = base
        return staged, lower

    def _build_state(self, until: Optional[float],
                     resume: bool) -> Tuple[tuple, List[str]]:
        """Gather all simulation state into the twin's array layout."""
        n_sm = self.n_sm
        runs = sorted(self.runs.values(), key=lambda r: r.order)
        staged, lower = self._stage_source()
        n_real = len(runs)
        if staged:
            runs = runs + staged
        self._build_staged = staged
        self._build_lower_mode = None if lower is None else lower["mode"]
        keys = [run.key for run in runs]
        index = {key: i for i, key in enumerate(keys)}
        n = len(runs)
        policy = self.core.policy
        predictor = self.predictor
        pol = _POLICY_IDS[type(policy)]

        si = np.zeros(tw.SI_LEN, np.int64)
        sd = np.zeros(tw.SD_LEN, np.float64)
        ci = np.zeros(tw.CI_LEN, np.int64)
        cf = np.zeros(tw.CF_LEN, np.float64)
        ri = np.zeros((n, tw.RI_LEN), np.int64)
        rf = np.zeros((n, tw.RF_LEN), np.float64)
        psi = np.zeros((n, n_sm, tw.PI_LEN), np.int64)
        psf = np.zeros((n, n_sm, tw.PF_LEN), np.float64)
        bs = np.full((n, n_sm, tw.MAX_BLOCK_SLOTS), _NAN, np.float64)
        sl = np.full((n_sm, tw.MAX_BLOCK_SLOTS), -1, np.int64)
        smi = np.zeros((n_sm, tw.SMI_LEN), np.int64)
        smf = np.zeros((n_sm, 1), np.float64)

        # -- scalars ----------------------------------------------------
        events = self._events
        si[tw.SI_SEQ] = next(self._seq)
        si[tw.SI_HEAP_LEN] = len(events)
        si[tw.SI_PENDING] = self._pending_arrivals
        si[tw.SI_SAMPLING] = -1
        si[tw.SI_ACTIVE_DIRTY] = 1
        si[tw.SI_EXIT_RUN] = -1
        si[tw.SI_RESUME] = 1 if resume else 0
        sd[tw.SD_NOW] = self.now
        sd[tw.SD_BUSY] = self.busy_time
        sd[tw.SD_HORIZON] = math.inf if until is None else until

        # -- configuration ----------------------------------------------
        rec_trace = self.trace is not None
        rec_dec = self.decisions is not None
        rec_pred = self.predictions is not None
        remaining_issue = sum(r.spec.num_blocks - r.issued for r in runs)
        remaining_done = sum(r.spec.num_blocks - r.done for r in runs)
        src_reserve = 0
        if lower is not None:
            src_reserve = (lower["population"] if lower["mode"] == "mgk"
                           else 1)
        heap_cap = max(256, 2 * len(events) + 9 * n_sm + 16 + src_reserve)
        trace_cap = remaining_issue + 8 * n_sm + 32 if rec_trace else 1
        dec_cap = max(self._dec_cap, 9 * n_sm + 64) if rec_dec else 1
        pred_cap = remaining_done + 16 if rec_pred else 1

        ci[tw.CI_POLICY] = pol
        ci[tw.CI_NSM] = n_sm
        ci[tw.CI_NRUNS] = n
        ci[tw.CI_UNLIMITED] = 1 if policy.unlimited_caps else 0
        ci[tw.CI_DRIVE_PRED] = 1 if self._drive_predictor else 0
        ci[tw.CI_REC_TRACE] = 1 if rec_trace else 0
        ci[tw.CI_REC_DEC] = 1 if rec_dec else 0
        ci[tw.CI_REC_PRED] = 1 if rec_pred else 0
        ci[tw.CI_HAS_SOURCE] = 1 if self._arrival_source is not None else 0
        if lower is not None:
            ci[tw.CI_SRC_MODE] = (tw.SRCMODE_MGK if lower["mode"] == "mgk"
                                  else tw.SRCMODE_THINK)
            ci[tw.CI_SRC_RESERVE] = src_reserve
        ci[tw.CI_HEAP_CAP] = heap_cap
        ci[tw.CI_TRACE_CAP] = trace_cap
        ci[tw.CI_DEC_CAP] = dec_cap
        ci[tw.CI_PRED_CAP] = pred_cap
        if pol == tw.POL_FIFO_CAP:
            ci[tw.CI_FIXED_CAP] = policy.cap
        if pol in _SRTF_FAMILY:
            ci[tw.CI_SAMPLE_SM] = policy.sample_sm
        if pol == tw.POL_SRTF_ADAPTIVE:
            ci[tw.CI_SHARED_RES] = policy.shared_residency
            cf[tw.CF_THRESHOLD] = policy.unfairness_threshold
            cf[tw.CF_HYSTERESIS] = policy.hysteresis
        if type(predictor) is EWMAPredictor:
            ci[tw.CI_PRED_KIND] = 1
            cf[tw.CF_ALPHA] = predictor.alpha

        # -- event heap (array layout == reference list layout) ----------
        heap_i = np.zeros((heap_cap, tw.HI_LEN), np.int64)
        heap_f = np.zeros((heap_cap, tw.HF_LEN), np.float64)
        for i, ev in enumerate(events):
            kind = ev[1]
            heap_f[i, tw.HF_TIME] = ev[0]
            heap_i[i, tw.HI_KIND] = kind
            heap_i[i, tw.HI_SEQ] = ev[2]
            if kind == _BLOCK_END:
                heap_i[i, tw.HI_A] = index[ev[3]]
                heap_i[i, tw.HI_B] = ev[4]
                heap_i[i, tw.HI_C] = ev[5]
                heap_f[i, tw.HF_START] = ev[6]
            elif kind == _ARRIVAL:
                heap_i[i, tw.HI_A] = index[ev[3]]
            else:
                heap_i[i, tw.HI_A] = ev[3]

        # -- per-run state + noise / base-duration pools -----------------
        oracle = self.oracle_runtimes
        synced = self._synced_caps
        sign = getattr(policy, "_sign", 1.0)
        noise_parts: List[np.ndarray] = []
        bt_parts: List[np.ndarray] = []
        noise_off = 0
        bt_off = 0
        ri[:, tw.RI_MPCAP] = -1
        ri[:, tw.RI_ADPCAP] = -1
        ri[:, tw.RI_SYNCED] = -1
        ri[:, tw.RI_TENANT] = -1
        for i, run in enumerate(runs):
            spec = run.spec
            ri[i, tw.RI_NUMB] = spec.num_blocks
            ri[i, tw.RI_MAXR] = spec.max_residency
            ri[i, tw.RI_TPB] = spec.threads_per_block
            ri[i, tw.RI_WARPS] = spec.warps_per_block
            ri[i, tw.RI_ISSUED] = run.issued
            ri[i, tw.RI_DONE] = run.done
            ri[i, tw.RI_LAUNCHED] = 1 if run.launched else 0
            cap = synced.get(run.key)
            if cap is not None:
                ri[i, tw.RI_SYNCED] = cap
            ri[i, tw.RI_PKNOWN] = 1 if predictor.has_kernel(run.key) else 0
            ri[i, tw.RI_NOISE_OFF] = noise_off
            ri[i, tw.RI_BT_OFF] = bt_off
            ri[i, tw.RI_EXPECTED] = math.ceil(spec.num_blocks / n_sm)
            noise = np.asarray(run.noise, np.float64)
            noise_parts.append(noise)
            noise_off += len(noise)
            table = np.asarray(spec.base_t_table, np.float64)
            bt_parts.append(table)
            bt_off += len(table)

            rf[i, tw.RF_MEANT] = spec.mean_t
            rf[i, tw.RF_FRAC] = spec.resource_fraction
            rf[i, tw.RF_CSENS] = spec.corunner_sens
            rf[i, tw.RF_CPRESS] = spec.corunner_pressure
            rf[i, tw.RF_STARTUP] = spec.startup_factor
            rf[i, tw.RF_STAGF] = spec.stagger_frac
            rf[i, tw.RF_ARRT] = run.arrival_time
            rf[i, tw.RF_FIN] = (_NAN if run.finish_time is None
                                else run.finish_time)
            rf[i, tw.RF_FIRST] = (_NAN if run.first_issue_time is None
                                  else run.first_issue_time)
            rt = oracle.get(spec.name)
            rf[i, tw.RF_ORACLE] = _NAN if rt is None else rt
            if pol == tw.POL_SJF or pol == tw.POL_LJF:
                if rt is None:
                    rt = spec.solo_staircase_runtime()
                rf[i, tw.RF_SJFKEY] = sign * rt
            rf[i, tw.RF_EXCL] = _NAN

            # Per-SM machine maps are flat lists after RNG init.
            for sm in range(n_sm):
                psi[i, sm, tw.PI_RES] = run.resident_per_sm[sm]
                psi[i, sm, tw.PI_ISSD] = run.issued_per_sm[sm]
                psi[i, sm, tw.PI_STAG] = 1 if run.stagger_sm[sm] else 0
                psf[i, sm, tw.PF_GATE] = run.issue_gate[sm]
            if ri[i, tw.RI_PKNOWN]:
                for sm, st in enumerate(predictor._state[run.key]):
                    psi[i, sm, tw.PI_PDONE] = st.done_blocks
                    psi[i, sm, tw.PI_PRESID] = st.resident_blocks
                    psi[i, sm, tw.PI_PRESLICE] = 1 if st.reslice else 0
                    psi[i, sm, tw.PI_PRUN] = st.running_count
                    psf[i, sm, tw.PF_PT] = _NAN if st.t is None else st.t
                    psf[i, sm, tw.PF_PACT] = st.active_cycles
                    psf[i, sm, tw.PF_PSINCE] = st.running_since
                    for slot, t0 in st.block_start.items():
                        bs[i, sm, slot] = t0
        noise_pool = (np.concatenate(noise_parts) if noise_parts
                      else np.zeros(0, np.float64))
        bt_pool = (np.concatenate(bt_parts) if bt_parts
                   else np.zeros(0, np.float64))

        # -- lowered arrival source (staged variate pool) -----------------
        n_staged = n - n_real
        n_tenants = 0
        if lower is not None and lower["mode"] == "think":
            n_tenants = len(lower["rounds_done"])
        self._build_n_tenants = n_tenants
        srci = np.zeros(tw.SRC_RD0 + n_tenants, np.int64)
        srcf = np.zeros(max(1, n_staged), np.float64)
        srci[tw.SRC_PEND] = -1
        if lower is not None:
            srci[tw.SRC_NSTAGED] = n_staged
            srci[tw.SRC_BASE] = n_real
            srci[tw.SRC_MORE] = 1 if lower["more"] else 0
            if n_staged:
                ri[n_real:, tw.RI_STAGED] = 1
                ri[n_real:, tw.RI_SRC] = 1
            if lower["mode"] == "mgk":
                srci[tw.SRC_INSYS] = lower["in_system"]
                srci[tw.SRC_POP] = lower["population"]
                if n_staged:
                    srcf[:n_staged] = lower["times"]
                live = lower["live"]
                for i in range(n_real):
                    if keys[i] in live:
                        ri[i, tw.RI_SRC] = 1
            else:
                srci[tw.SRC_NROUNDS] = lower["n_rounds"]
                srci[tw.SRC_PEND] = self._src_pend
                for j, done in enumerate(lower["rounds_done"]):
                    srci[tw.SRC_RD0 + j] = done
                if n_staged:
                    srcf[:n_staged] = lower["delays"]
                tenants = lower["tenants"]
                for i in range(n_real):
                    ten = tenants.get(keys[i])
                    if ten is not None:
                        ri[i, tw.RI_TENANT] = ten

        # -- policy-specific state ---------------------------------------
        queue = np.zeros(n + 1, np.int64)
        if pol == tw.POL_MPMAX:
            for key, cap in policy._caps.items():
                ri[index[key], tw.RI_MPCAP] = cap
        if pol in _SRTF_FAMILY:
            for key in policy.eligible:
                ri[index[key], tw.RI_ELIG] = 1
            if policy.sampling is not None:
                si[tw.SI_SAMPLING] = index[policy.sampling]
            for j, key in enumerate(policy.sample_queue):
                queue[j] = index[key]
            si[tw.SI_QTAIL] = len(policy.sample_queue)
        if pol == tw.POL_SRTF_ADAPTIVE:
            si[tw.SI_SHARING] = 1 if policy.sharing else 0
            for key, cap in policy._caps.items():
                ri[index[key], tw.RI_ADPCAP] = cap
            for key, pred in policy._excl_pred.items():
                rf[index[key], tw.RF_EXCL] = pred

        # -- SM resource pools -------------------------------------------
        for s, sm_state in enumerate(self.sms):
            smi[s, tw.SMI_THR] = sm_state.used_threads
            smi[s, tw.SMI_FREETOP] = len(sm_state.free_slots)
            for j, slot in enumerate(sm_state.free_slots):
                smi[s, tw.SMI_FS0 + j] = slot
            smf[s, 0] = sm_state.used_fraction
            for slot, key in sm_state.resident.items():
                sl[s, slot] = index[key]

        # -- record buffers + scratch ------------------------------------
        tri = np.zeros((trace_cap, 3), np.int64)
        trf = np.zeros((trace_cap, 2), np.float64)
        dci = np.zeros((dec_cap, 3), np.int64)
        dcf = np.zeros((dec_cap, 1), np.float64)
        pri = np.zeros((pred_cap, 3), np.int64)
        prf = np.zeros((pred_cap, 2), np.float64)
        act = np.zeros(max(n, 1), np.int64)
        rwi = np.zeros(max(n, 1), np.int64)
        rwf = np.zeros((max(n, 1), 3), np.float64)
        newc = np.zeros(max(n, 1), np.int64)
        cand = np.zeros(max(n, 1), np.int64)
        crem = np.zeros(max(n, 1), np.float64)

        state = (si, sd, ci, cf, ri, rf, psi, psf, bs, sl, smi, smf,
                 heap_i, heap_f, tri, trf, dci, dcf, pri, prf,
                 act, queue, rwi, rwf, newc, cand, crem,
                 noise_pool, bt_pool, srci, srcf)
        return state, keys

    # ------------------------------------------------------------ scatter
    def _scatter(self, state: tuple, keys: List[str]) -> None:
        """Write the complete array state back into the Python objects.

        Runs at EVERY engine exit: afterwards ``self`` is a valid
        reference :class:`Simulator` mid-run (same heap list, same run /
        SM / policy / predictor state the reference loop would hold)."""
        (si, sd, ci, cf, ri, rf, psi, psf, bs, sl, smi, smf,
         heap_i, heap_f, tri, trf, dci, dcf, pri, prf,
         act, queue, rwi, rwf, newc, cand, crem, _np_pool, _bt_pool,
         srci, _srcf) = state
        n_sm = self.n_sm
        policy = self.core.policy
        predictor = self.predictor
        pol = _POLICY_IDS[type(policy)]

        self.now = float(sd[tw.SD_NOW])
        self.busy_time = float(sd[tw.SD_BUSY])
        self._pending_arrivals = int(si[tw.SI_PENDING])
        self._seq = itertools.count(int(si[tw.SI_SEQ]))

        # -- event heap back to reference tuples (same list layout) ------
        events: List[tuple] = []
        for i in range(int(si[tw.SI_HEAP_LEN])):
            kind = int(heap_i[i, tw.HI_KIND])
            seq = int(heap_i[i, tw.HI_SEQ])
            t = float(heap_f[i, tw.HF_TIME])
            if kind == _BLOCK_END:
                events.append((t, kind, seq, keys[int(heap_i[i, tw.HI_A])],
                               int(heap_i[i, tw.HI_B]),
                               int(heap_i[i, tw.HI_C]),
                               float(heap_f[i, tw.HF_START])))
            elif kind == _ARRIVAL:
                events.append((t, kind, seq, keys[int(heap_i[i, tw.HI_A])]))
            else:
                events.append((t, kind, seq, int(heap_i[i, tw.HI_A])))
        self._events = events

        # -- lowered arrival source: commit consumed stagings -------------
        # Engine-injected staged runs enter self.runs in injection order
        # (same dict insertion order the reference's inject_arrival would
        # produce); the source's python state is rolled forward so the
        # simulator remains a valid reference Simulator mid-run.
        staged = self._build_staged
        mode = self._build_lower_mode
        n_live = len(keys)
        if mode is not None:
            consumed = int(srci[tw.SRC_NEXT])
            n_live = len(keys) - len(staged) + consumed
            for k in range(consumed):
                run = staged[k]
                run.arrival_time = float(rf[n_live - consumed + k,
                                            tw.RF_ARRT])
                self.runs[run.key] = run
                self._staged_memo.pop(run.key, None)
            if staged:
                self._arrival_order = itertools.count(
                    self._build_staged_base + consumed)
            source = self._arrival_source
            if mode == "mgk":
                live = {keys[i] for i in range(n_live)
                        if ri[i, tw.RI_SRC]
                        and rf[i, tw.RF_FIN] != rf[i, tw.RF_FIN]}
                source.engine_commit(
                    consumed, int(srci[tw.SRC_INSYS]), live)
            else:
                self._src_pend = int(srci[tw.SRC_PEND])
                nt = self._build_n_tenants
                rounds = [int(v)
                          for v in srci[tw.SRC_RD0:tw.SRC_RD0 + nt]]
                tenants = {keys[i]: int(ri[i, tw.RI_TENANT])
                           for i in range(n_live)
                           if ri[i, tw.RI_TENANT] >= 0
                           and rf[i, tw.RF_FIN] != rf[i, tw.RF_FIN]}
                source.engine_commit(consumed, rounds, tenants)

        # -- runs ---------------------------------------------------------
        finished_now: List[str] = []
        for i in range(n_live):
            key = keys[i]
            run = self.runs[key]
            run.issued = int(ri[i, tw.RI_ISSUED])
            run.done = int(ri[i, tw.RI_DONE])
            run.launched = bool(ri[i, tw.RI_LAUNCHED])
            fin = rf[i, tw.RF_FIN]
            if fin == fin:
                if run.finish_time is None:
                    finished_now.append(key)
                run.finish_time = float(fin)
            else:
                run.finish_time = None
            first = rf[i, tw.RF_FIRST]
            run.first_issue_time = float(first) if first == first else None
            run.resident_per_sm = [int(v) for v in psi[i, :, tw.PI_RES]]
            run.issued_per_sm = [int(v) for v in psi[i, :, tw.PI_ISSD]]
            run.issue_gate = [float(v) for v in psf[i, :, tw.PF_GATE]]

        # -- SM resource pools --------------------------------------------
        for s, sm_state in enumerate(self.sms):
            sm_state.used_threads = int(smi[s, tw.SMI_THR])
            sm_state.used_fraction = float(smf[s, 0])
            sm_state.free_slots = [
                int(smi[s, tw.SMI_FS0 + j])
                for j in range(int(smi[s, tw.SMI_FREETOP]))]
            resident = {}
            for slot in range(tw.MAX_BLOCK_SLOTS):
                r = int(sl[s, slot])
                if r >= 0:
                    resident[slot] = keys[r]
            sm_state.resident = resident

        # -- policy state -------------------------------------------------
        if pol == tw.POL_MPMAX:
            policy._caps = {
                keys[i]: int(ri[i, tw.RI_MPCAP])
                for i in range(len(keys)) if ri[i, tw.RI_MPCAP] >= 0}
        if pol in _SRTF_FAMILY:
            policy.eligible = {
                keys[i] for i in range(len(keys)) if ri[i, tw.RI_ELIG]}
            samp = int(si[tw.SI_SAMPLING])
            policy.sampling = keys[samp] if samp >= 0 else None
            policy.sample_queue = deque(
                keys[int(queue[j])]
                for j in range(int(si[tw.SI_QHEAD]), int(si[tw.SI_QTAIL])))
        if pol == tw.POL_SRTF_ADAPTIVE:
            policy.sharing = bool(si[tw.SI_SHARING])
            policy._caps = {
                keys[i]: int(ri[i, tw.RI_ADPCAP])
                for i in range(len(keys)) if ri[i, tw.RI_ADPCAP] >= 0}
            policy._excl_pred = {
                keys[i]: float(rf[i, tw.RF_EXCL])
                for i in range(len(keys))
                if rf[i, tw.RF_EXCL] == rf[i, tw.RF_EXCL]}
        # Mirror the decision-singleton cache eviction of on_kernel_end.
        for key in finished_now:
            policy._grants.pop(key, None)
            if pol in _SRTF_FAMILY:
                policy._samples.pop(key, None)
                policy._preempts.pop(key, None)
            if pol == tw.POL_SRTF_ZERO:
                policy._oracle_cache.pop(key, None)

        # -- predictor state ----------------------------------------------
        # Rebuilt fresh in run-index order == launch order (arrival events
        # pop in (time, seq) order and seq is assigned in run order), so
        # dict iteration order matches the reference's insertion order.
        pstate = {}
        for i, key in enumerate(keys):
            if not ri[i, tw.RI_PKNOWN]:
                continue
            expected = int(ri[i, tw.RI_EXPECTED])
            per_sm = []
            for sm in range(n_sm):
                t = psf[i, sm, tw.PF_PT]
                st = PerSMState(
                    total_blocks=expected,
                    done_blocks=int(psi[i, sm, tw.PI_PDONE]),
                    resident_blocks=int(psi[i, sm, tw.PI_PRESID]),
                    t=float(t) if t == t else None,
                    reslice=bool(psi[i, sm, tw.PI_PRESLICE]),
                    active_cycles=float(psf[i, sm, tw.PF_PACT]),
                    running_count=int(psi[i, sm, tw.PI_PRUN]),
                    running_since=float(psf[i, sm, tw.PF_PSINCE]),
                )
                st.blocks_started = st.done_blocks + st.running_count
                starts = {}
                for slot in range(tw.MAX_BLOCK_SLOTS):
                    t0 = bs[i, sm, slot]
                    if t0 == t0:
                        starts[slot] = float(t0)
                st.block_start = starts
                per_sm.append(st)
            pstate[key] = per_sm
        predictor._state = pstate
        # Pure version-counter memo: cleared, the next query recomputes
        # the bit-identical value.
        predictor._rem_version.clear()
        predictor._rem_memo.clear()

        # -- machine caches ------------------------------------------------
        self._era += 1
        self._decision_memo = [None] * n_sm
        self._minfoot_dirty = True
        self._invalidate_active()
        self._synced_caps = {
            keys[i]: int(ri[i, tw.RI_SYNCED])
            for i in range(len(keys)) if ri[i, tw.RI_SYNCED] >= 0}

        # -- record streams ------------------------------------------------
        if self.trace is not None:
            trace = self.trace
            for j in range(int(si[tw.SI_TRACE_N])):
                trace.append(BlockRecord(
                    keys[int(tri[j, 0])], int(tri[j, 1]), int(tri[j, 2]),
                    float(trf[j, 0]), float(trf[j, 1])))
        if self.decisions is not None:
            decisions = self.decisions
            for j in range(int(si[tw.SI_DEC_N])):
                r = int(dci[j, 2])
                decisions.append((
                    float(dcf[j, 0]), int(dci[j, 0]),
                    _decision_object(int(dci[j, 1]),
                                     keys[r] if r >= 0 else None)))
        if self.predictions is not None:
            predictions = self.predictions
            for j in range(int(si[tw.SI_PRED_N])):
                predictions.append(PredictionRecord(
                    keys[int(pri[j, 0])], int(pri[j, 1]),
                    float(prf[j, 0]), int(pri[j, 2]), float(prf[j, 1])))

    def _scatter_result(self, state: tuple, keys: List[str]) -> None:
        """Terminal-exit scatter committing only what :class:`SimResult`
        and ``evaluate_window`` read: now, busy_time, the staged-run
        commit, and per-run issued/done/finish/first-issue.  Skips the
        heap, SM pools, policy/predictor state, record streams and
        source ``engine_commit`` — afterwards ``self`` is NOT a valid
        mid-run reference, only its result fields are."""
        si, sd = state[0], state[1]
        ri, rf = state[4], state[5]
        srci = state[29]
        self.now = float(sd[tw.SD_NOW])
        self.busy_time = float(sd[tw.SD_BUSY])
        staged = self._build_staged
        n_live = len(keys)
        if self._build_lower_mode is not None:
            consumed = int(srci[tw.SRC_NEXT])
            n_live = len(keys) - len(staged) + consumed
            for k in range(consumed):
                run = staged[k]
                run.arrival_time = float(rf[n_live - consumed + k,
                                            tw.RF_ARRT])
                self.runs[run.key] = run
        for i in range(n_live):
            run = self.runs[keys[i]]
            run.issued = int(ri[i, tw.RI_ISSUED])
            run.done = int(ri[i, tw.RI_DONE])
            fin = rf[i, tw.RF_FIN]
            run.finish_time = float(fin) if fin == fin else None
            first = rf[i, tw.RF_FIRST]
            run.first_issue_time = float(first) if first == first else None


__all__ = [
    "FastSimulator",
    "backend_name",
    "default_engine",
    "engine_token",
]
