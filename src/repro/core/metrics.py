"""Multiprogram performance metrics (paper Section 6).

* STP  — system throughput (Eyerman & Eeckhout [9]): sum of normalized
  progress, ``STP = sum_i T_solo_i / T_multi_i`` (higher is better).
* ANTT — average normalized turnaround time: ``mean_i T_multi_i / T_solo_i``
  (lower is better).
* StrictF — fairness (Vandierendonck & Seznec [36]): ratio of minimum to
  maximum slowdown; 1.0 means perfectly fair.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence


@dataclass(frozen=True)
class WorkloadMetrics:
    stp: float
    antt: float
    fairness: float

    def as_dict(self) -> Dict[str, float]:
        return {"stp": self.stp, "antt": self.antt, "fairness": self.fairness}


def slowdowns(turnaround: Dict[str, float],
              solo: Dict[str, float]) -> List[float]:
    out = []
    for key, multi in turnaround.items():
        base = solo[key]
        if base <= 0:
            raise ValueError(f"non-positive solo runtime for {key}")
        out.append(multi / base)
    return out


def evaluate(turnaround: Dict[str, float],
             solo: Dict[str, float]) -> WorkloadMetrics:
    """Compute STP/ANTT/StrictF for one multiprogrammed run.

    ``turnaround`` maps kernel keys to multiprogram turnaround times;
    ``solo`` maps the same keys to their isolated runtimes.
    """
    sd = slowdowns(turnaround, solo)
    stp = sum(1.0 / s for s in sd)
    antt = sum(sd) / len(sd)
    fairness = min(sd) / max(sd)
    return WorkloadMetrics(stp=stp, antt=antt, fairness=fairness)


def geomean(values: Iterable[float]) -> float:
    vals = [v for v in values]
    if not vals:
        return float("nan")
    if any(v <= 0 for v in vals):
        raise ValueError("geomean requires positive values")
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def summarize(per_workload: Sequence[WorkloadMetrics]) -> WorkloadMetrics:
    """Geometric means across workloads (as in the paper's Table 5)."""
    return WorkloadMetrics(
        stp=geomean(m.stp for m in per_workload),
        antt=geomean(m.antt for m in per_workload),
        fairness=geomean(m.fairness for m in per_workload),
    )
