"""Multiprogram performance metrics (paper Section 6).

* STP  — system throughput (Eyerman & Eeckhout [9]): sum of normalized
  progress, ``STP = sum_i T_solo_i / T_multi_i`` (higher is better).
* ANTT — average normalized turnaround time: ``mean_i T_multi_i / T_solo_i``
  (lower is better).
* StrictF — fairness (Vandierendonck & Seznec [36]): ratio of minimum to
  maximum slowdown; 1.0 means perfectly fair.

Closed two-program workloads always finish, so :func:`evaluate` demands at
least one finished kernel and raises :class:`MetricsError` on degenerate
inputs (empty turnaround map, non-positive runtimes) instead of letting a
``ZeroDivisionError`` surface from deep inside a sweep.  Open-loop and
truncated runs (``run(until=...)``) go through :func:`evaluate_window`:
STP/ANTT/fairness over the kernels that *finished* inside the observation
window, plus makespan, utilization and finished/unfinished counts, so
results with unfinished kernels are first-class instead of silently
dropped.

Closed-loop (sustained-traffic) runs additionally go through
:func:`evaluate_queueing`: steady-state queueing metrics — mean/p95
response time, time-averaged number in system, throughput — over the
post-warmup part of the observation window.  Warmup trimming discards
kernels that *arrived* before ``warmup_frac`` of the window, so transient
cold-start behavior does not pollute the steady-state numbers; degenerate
trims (nothing completed after the trim, empty window) raise
:class:`MetricsError` following the same convention as :func:`evaluate`
and :func:`geomean`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence


class MetricsError(ValueError):
    """Degenerate metric input (empty or non-positive runtimes)."""


@dataclass(frozen=True)
class WorkloadMetrics:
    stp: float
    antt: float
    fairness: float

    def as_dict(self) -> Dict[str, float]:
        return {"stp": self.stp, "antt": self.antt, "fairness": self.fairness}


@dataclass(frozen=True)
class WindowMetrics:
    """Completion-window evaluation of one (possibly truncated) run.

    ``stp``/``antt``/``fairness`` are computed over the ``n_finished``
    kernels that completed inside the window; they are ``nan`` when nothing
    finished (a truncated run is data, not an error).  ``makespan`` and
    ``end_time`` come from the machine (see
    :attr:`repro.core.simulator.SimResult.makespan`), ``utilization`` is
    the busy fraction of the machine over the window, and ``throughput``
    is finished kernels per unit machine time.
    """

    stp: float
    antt: float
    fairness: float
    n_finished: int
    n_unfinished: int
    makespan: float
    end_time: float
    utilization: float

    @property
    def complete(self) -> bool:
        return self.n_unfinished == 0

    @property
    def throughput(self) -> float:
        if self.end_time <= 0.0:
            return 0.0
        return self.n_finished / self.end_time

    def as_dict(self) -> Dict[str, float]:
        return {
            "stp": self.stp, "antt": self.antt, "fairness": self.fairness,
            "n_finished": self.n_finished, "n_unfinished": self.n_unfinished,
            "makespan": self.makespan, "end_time": self.end_time,
            "utilization": self.utilization,
        }

    @property
    def workload_metrics(self) -> Optional[WorkloadMetrics]:
        """The closed-workload view, or ``None`` if nothing finished."""
        if self.n_finished == 0:
            return None
        return WorkloadMetrics(self.stp, self.antt, self.fairness)


def slowdowns(turnaround: Dict[str, float],
              solo: Dict[str, float]) -> List[float]:
    out = []
    for key, multi in turnaround.items():
        try:
            base = solo[key]
        except KeyError:
            raise MetricsError(f"no solo runtime for kernel {key!r}") from None
        if base <= 0:
            raise MetricsError(f"non-positive solo runtime for {key!r}")
        if multi <= 0:
            raise MetricsError(f"non-positive turnaround for {key!r}")
        out.append(multi / base)
    return out


def evaluate(turnaround: Dict[str, float],
             solo: Dict[str, float]) -> WorkloadMetrics:
    """Compute STP/ANTT/StrictF for one multiprogrammed run.

    ``turnaround`` maps kernel keys to multiprogram turnaround times;
    ``solo`` maps the same keys to their isolated runtimes.  Raises
    :class:`MetricsError` on an empty or degenerate input; for truncated
    open-loop runs use :func:`evaluate_window` instead.
    """
    if not turnaround:
        raise MetricsError(
            "no finished kernels to evaluate "
            "(open-loop/truncated runs: use evaluate_window)")
    sd = slowdowns(turnaround, solo)
    stp = sum(1.0 / s for s in sd)
    antt = sum(sd) / len(sd)
    fairness = min(sd) / max(sd)
    return WorkloadMetrics(stp=stp, antt=antt, fairness=fairness)


def evaluate_window(
    turnaround: Dict[str, float],
    solo: Dict[str, float],
    unfinished: Sequence[str] = (),
    end_time: float = 0.0,
    makespan: Optional[float] = None,
    utilization: float = float("nan"),
) -> WindowMetrics:
    """Evaluate a run over its observation window (open-loop first-class).

    ``turnaround`` covers the kernels that finished inside the window;
    ``unfinished`` lists the keys that did not.  When nothing finished the
    quality metrics are ``nan`` rather than an error.
    """
    if turnaround:
        m = evaluate(turnaround, solo)
        stp, antt, fairness = m.stp, m.antt, m.fairness
    else:
        stp = antt = fairness = float("nan")
    if makespan is None:
        makespan = end_time
    return WindowMetrics(
        stp=stp, antt=antt, fairness=fairness,
        n_finished=len(turnaround), n_unfinished=len(unfinished),
        makespan=makespan, end_time=end_time, utilization=utilization)


@dataclass(frozen=True)
class QueueingMetrics:
    """Steady-state queueing view of one sustained-traffic run.

    All quantities are computed over the post-warmup observation window
    ``[warmup, end_time]``:

    * ``mean_response`` / ``p95_response`` — response (sojourn) time of the
      kernels that arrived after warmup *and* completed inside the window
      (``n_completed`` of ``n_observed`` such arrivals; pre-warmup
      arrivals are excluded because part of their sojourn lies in the
      transient),
    * ``mean_in_system`` — time-averaged number of kernels in the system
      (arrived, not yet finished), counting kernels still in flight,
    * ``throughput`` — **all** departures inside the post-warmup window
      per unit machine time, including kernels that arrived during warmup
      (a backlogged completion is a real steady-state departure).

    By Little's law ``mean_in_system ~= throughput * mean_response`` when
    the run is long enough to be stationary — a useful self-check, not an
    enforced identity.
    """

    mean_response: float
    p95_response: float
    mean_in_system: float
    throughput: float
    n_completed: int
    n_observed: int
    warmup: float
    end_time: float

    def as_dict(self) -> Dict[str, float]:
        return {
            "mean_response": self.mean_response,
            "p95_response": self.p95_response,
            "mean_in_system": self.mean_in_system,
            "throughput": self.throughput,
            "n_completed": self.n_completed,
            "n_observed": self.n_observed,
            "warmup": self.warmup,
            "end_time": self.end_time,
        }


def evaluate_queueing(
    arrival: Dict[str, float],
    finish: Dict[str, float],
    end_time: float,
    warmup_frac: float = 0.2,
) -> QueueingMetrics:
    """Steady-state queueing metrics over one observation window.

    ``arrival`` maps **every** kernel key (finished or in flight) to its
    arrival time; ``finish`` maps the finished subset to completion times;
    ``end_time`` is the machine clock when the run stopped.  The first
    ``warmup_frac`` of the window is trimmed: response-time statistics
    cover kernels arriving at or after ``warmup_frac * end_time`` (and
    inside the window), while the number-in-system integral and the
    departure-counting throughput run over ``[warmup, end_time]`` with
    kernels straddling the warmup edge clipped, not dropped.

    Raises :class:`MetricsError` on degenerate input — no arrivals, a
    non-positive window, ``warmup_frac`` outside ``[0, 1)``, a completion
    before its own arrival, or **zero completions after the warmup trim**
    (a run too short or too truncated to say anything about steady state).
    """
    if not arrival:
        raise MetricsError("no arrivals to evaluate")
    if end_time <= 0.0:
        raise MetricsError(f"non-positive observation window {end_time!r}")
    if not 0.0 <= warmup_frac < 1.0:
        raise MetricsError(
            f"warmup_frac must be in [0, 1); got {warmup_frac!r}")
    for key, t_done in finish.items():
        if key not in arrival:
            raise MetricsError(f"finished kernel {key!r} has no arrival")
        if t_done < arrival[key]:
            raise MetricsError(f"kernel {key!r} finished before it arrived")
    warmup = warmup_frac * end_time
    # Post-warmup arrivals *inside* the window: closed-loop feedback can
    # schedule arrivals past a truncation horizon, and those never entered
    # the observed system.
    observed = [k for k, t in arrival.items() if warmup <= t <= end_time]
    responses = sorted(
        finish[k] - arrival[k] for k in observed
        if k in finish and finish[k] <= end_time)
    if not responses:
        raise MetricsError(
            f"no completions after warmup trim (warmup={warmup:g}, "
            f"end_time={end_time:g}, {len(observed)} observed arrivals): "
            "run longer, truncate later, or lower warmup_frac")
    # time-averaged number in system over [warmup, end_time]: every kernel
    # contributes its in-system overlap with the window, in flight included.
    span = end_time - warmup
    busy = 0.0
    for key, t_in in arrival.items():
        t_out = min(finish.get(key, end_time), end_time)
        busy += max(0.0, t_out - max(t_in, warmup))
    # throughput counts every post-warmup departure (backlog drained from
    # warmup-era arrivals included), not just the response-stat cohort.
    departures = sum(1 for t in finish.values() if warmup < t <= end_time)
    p95_rank = max(0, math.ceil(0.95 * len(responses)) - 1)
    return QueueingMetrics(
        mean_response=sum(responses) / len(responses),
        p95_response=responses[p95_rank],
        mean_in_system=busy / span,
        throughput=departures / span,
        n_completed=len(responses),
        n_observed=len(observed),
        warmup=warmup,
        end_time=end_time)


def geomean(values: Iterable[float]) -> float:
    vals = [v for v in values]
    if not vals:
        raise MetricsError("geomean of an empty sequence")
    if any(v <= 0 or math.isnan(v) for v in vals):
        raise MetricsError(
            "geomean requires positive finite values; got degenerate input "
            f"{[v for v in vals if not v > 0 or math.isnan(v)][:4]!r}")
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def summarize(per_workload: Sequence[WorkloadMetrics]) -> WorkloadMetrics:
    """Geometric means across workloads (as in the paper's Table 5)."""
    if not per_workload:
        raise MetricsError("summarize of an empty workload list")
    return WorkloadMetrics(
        stp=geomean(m.stp for m in per_workload),
        antt=geomean(m.antt for m in per_workload),
        fairness=geomean(m.fairness for m in per_workload),
    )
