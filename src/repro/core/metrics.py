"""Multiprogram performance metrics (paper Section 6).

* STP  — system throughput (Eyerman & Eeckhout [9]): sum of normalized
  progress, ``STP = sum_i T_solo_i / T_multi_i`` (higher is better).
* ANTT — average normalized turnaround time: ``mean_i T_multi_i / T_solo_i``
  (lower is better).
* StrictF — fairness (Vandierendonck & Seznec [36]): ratio of minimum to
  maximum slowdown; 1.0 means perfectly fair.

Closed two-program workloads always finish, so :func:`evaluate` demands at
least one finished kernel and raises :class:`MetricsError` on degenerate
inputs (empty turnaround map, non-positive runtimes) instead of letting a
``ZeroDivisionError`` surface from deep inside a sweep.  Open-loop and
truncated runs (``run(until=...)``) go through :func:`evaluate_window`:
STP/ANTT/fairness over the kernels that *finished* inside the observation
window, plus makespan, utilization and finished/unfinished counts, so
results with unfinished kernels are first-class instead of silently
dropped.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence


class MetricsError(ValueError):
    """Degenerate metric input (empty or non-positive runtimes)."""


@dataclass(frozen=True)
class WorkloadMetrics:
    stp: float
    antt: float
    fairness: float

    def as_dict(self) -> Dict[str, float]:
        return {"stp": self.stp, "antt": self.antt, "fairness": self.fairness}


@dataclass(frozen=True)
class WindowMetrics:
    """Completion-window evaluation of one (possibly truncated) run.

    ``stp``/``antt``/``fairness`` are computed over the ``n_finished``
    kernels that completed inside the window; they are ``nan`` when nothing
    finished (a truncated run is data, not an error).  ``makespan`` and
    ``end_time`` come from the machine (see
    :attr:`repro.core.simulator.SimResult.makespan`), ``utilization`` is
    the busy fraction of the machine over the window, and ``throughput``
    is finished kernels per unit machine time.
    """

    stp: float
    antt: float
    fairness: float
    n_finished: int
    n_unfinished: int
    makespan: float
    end_time: float
    utilization: float

    @property
    def complete(self) -> bool:
        return self.n_unfinished == 0

    @property
    def throughput(self) -> float:
        if self.end_time <= 0.0:
            return 0.0
        return self.n_finished / self.end_time

    def as_dict(self) -> Dict[str, float]:
        return {
            "stp": self.stp, "antt": self.antt, "fairness": self.fairness,
            "n_finished": self.n_finished, "n_unfinished": self.n_unfinished,
            "makespan": self.makespan, "end_time": self.end_time,
            "utilization": self.utilization,
        }

    @property
    def workload_metrics(self) -> Optional[WorkloadMetrics]:
        """The closed-workload view, or ``None`` if nothing finished."""
        if self.n_finished == 0:
            return None
        return WorkloadMetrics(self.stp, self.antt, self.fairness)


def slowdowns(turnaround: Dict[str, float],
              solo: Dict[str, float]) -> List[float]:
    out = []
    for key, multi in turnaround.items():
        try:
            base = solo[key]
        except KeyError:
            raise MetricsError(f"no solo runtime for kernel {key!r}") from None
        if base <= 0:
            raise MetricsError(f"non-positive solo runtime for {key!r}")
        if multi <= 0:
            raise MetricsError(f"non-positive turnaround for {key!r}")
        out.append(multi / base)
    return out


def evaluate(turnaround: Dict[str, float],
             solo: Dict[str, float]) -> WorkloadMetrics:
    """Compute STP/ANTT/StrictF for one multiprogrammed run.

    ``turnaround`` maps kernel keys to multiprogram turnaround times;
    ``solo`` maps the same keys to their isolated runtimes.  Raises
    :class:`MetricsError` on an empty or degenerate input; for truncated
    open-loop runs use :func:`evaluate_window` instead.
    """
    if not turnaround:
        raise MetricsError(
            "no finished kernels to evaluate "
            "(open-loop/truncated runs: use evaluate_window)")
    sd = slowdowns(turnaround, solo)
    stp = sum(1.0 / s for s in sd)
    antt = sum(sd) / len(sd)
    fairness = min(sd) / max(sd)
    return WorkloadMetrics(stp=stp, antt=antt, fairness=fairness)


def evaluate_window(
    turnaround: Dict[str, float],
    solo: Dict[str, float],
    unfinished: Sequence[str] = (),
    end_time: float = 0.0,
    makespan: Optional[float] = None,
    utilization: float = float("nan"),
) -> WindowMetrics:
    """Evaluate a run over its observation window (open-loop first-class).

    ``turnaround`` covers the kernels that finished inside the window;
    ``unfinished`` lists the keys that did not.  When nothing finished the
    quality metrics are ``nan`` rather than an error.
    """
    if turnaround:
        m = evaluate(turnaround, solo)
        stp, antt, fairness = m.stp, m.antt, m.fairness
    else:
        stp = antt = fairness = float("nan")
    if makespan is None:
        makespan = end_time
    return WindowMetrics(
        stp=stp, antt=antt, fairness=fairness,
        n_finished=len(turnaround), n_unfinished=len(unfinished),
        makespan=makespan, end_time=end_time, utilization=utilization)


def geomean(values: Iterable[float]) -> float:
    vals = [v for v in values]
    if not vals:
        raise MetricsError("geomean of an empty sequence")
    if any(v <= 0 or math.isnan(v) for v in vals):
        raise MetricsError(
            "geomean requires positive finite values; got degenerate input "
            f"{[v for v in vals if not v > 0 or math.isnan(v)][:4]!r}")
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def summarize(per_workload: Sequence[WorkloadMetrics]) -> WorkloadMetrics:
    """Geometric means across workloads (as in the paper's Table 5)."""
    if not per_workload:
        raise MetricsError("summarize of an empty workload list")
    return WorkloadMetrics(
        stp=geomean(m.stp for m in per_workload),
        antt=geomean(m.antt for m in per_workload),
        fairness=geomean(m.fairness for m in per_workload),
    )
