"""Thread block scheduling policies (paper Section 5).

All policies target the formal :class:`repro.core.machine.Machine` protocol
— the only surface they may touch on the machine driving them (DES
simulator, real-JAX lane executor, or any future backend):

* ``bind(machine)``         — attach to a :class:`Machine`,
* ``decide(sm) -> Decision`` — typed scheduling decision for unit ``sm``
  (:class:`IssueGrant` / :class:`SampleOnSM` / :class:`Hold` /
  :class:`PreemptAtBoundary`, see :mod:`repro.core.events`),
* ``residency_cap(key, sm) -> int`` — per-kernel residency limit on ``sm``,
* event hooks ``on_arrival`` / ``on_block_end`` / ``on_kernel_end``
  (driven through :class:`repro.core.machine.SchedulerCore`).

Policies:

* :class:`FIFO`      — Fermi baseline (Section 5.2.1): strict arrival order;
  a later kernel issues only once every block of all earlier kernels has
  been dispatched.
* :class:`SJF` / :class:`LJF` — oracle orderings by true solo runtime
  (Section 2 / Fig. 1).  SJF is the unrealizable upper bound.
* :class:`MPMax`     — Just-in-Time MPMax (Section 5.2.2): FIFO order, but
  each kernel reserves resources for one block of each *currently running*
  co-runner; reservations are dropped when concurrency ceases.
* :class:`SRTF`      — Section 5.1.1: sample newly arrived kernels on one SM,
  broadcast the sampled ``t``, then run the predicted shortest-remaining-time
  kernel exclusively; preemption happens only at block boundaries, so
  hand-off delay emerges naturally (the :class:`PreemptAtBoundary` decision).
* :class:`SRTFAdaptive` — Section 5.1.2: SRTF plus a fairness monitor; when
  the projected slowdown gap exceeds ``unfairness_threshold`` (0.5), switch
  to sharing mode with the fastest kernel's residency capped at
  ``shared_residency`` (3) and co-runners taking the remaining resources.
"""

from __future__ import annotations

import math
from collections import deque
from typing import Dict, List, Optional

from .events import (
    Decision,
    Hold,
    IssueGrant,
    PreemptAtBoundary,
    SampleOnSM,
)

_INF = float("inf")
MAX_RESIDENCY_DEFAULT = 8


class Policy:
    """Base class: unlimited residency, no issue grants."""

    name = "base"

    def __init__(self):
        self.machine = None

    def bind(self, machine) -> None:
        """Attach to a :class:`repro.core.machine.Machine`."""
        self.machine = machine

    # -- event hooks ---------------------------------------------------------
    def on_arrival(self, key: str) -> None:
        pass

    def on_block_end(self, key: str, sm: int) -> None:
        pass

    def on_kernel_end(self, key: str) -> None:
        pass

    # -- decisions ------------------------------------------------------------
    def residency_cap(self, key: str, sm: int) -> int:
        return self._run(key).spec.max_residency

    def decide(self, sm: int) -> Decision:
        raise NotImplementedError

    # -- Machine-protocol helpers ---------------------------------------------
    def _run(self, key: str):
        return self.machine.run_state(key)

    def _active(self) -> List[str]:
        return self.machine.active_keys()

    def _fits(self, key: str, sm: int) -> bool:
        return self.machine.can_fit(key, sm)


class _OrderedPolicy(Policy):
    """Strict-priority issue: the highest-priority kernel with undispatched
    blocks blocks all later kernels (head-of-line semantics, as on Fermi)."""

    def order(self) -> List[str]:
        raise NotImplementedError

    def decide(self, sm: int) -> Decision:
        for key in self.order():
            if self._run(key).unissued > 0:
                if self._fits(key, sm):
                    return IssueGrant(key)
                return Hold("head-of-line kernel does not fit")
        return Hold("no kernel with undispatched blocks")


class FIFO(_OrderedPolicy):
    name = "fifo"

    def order(self) -> List[str]:
        return self._active()


class SJF(_OrderedPolicy):
    """Oracle Shortest Job First: requires true solo runtimes."""

    name = "sjf"
    _sign = 1.0

    def _runtime(self, key: str) -> float:
        rt = self.machine.oracle_runtime(key)
        if rt is None:
            rt = self._run(key).spec.solo_staircase_runtime()
        return rt

    def order(self) -> List[str]:
        keys = self._active()
        return sorted(keys, key=lambda k: (self._sign * self._runtime(k),
                                           self._run(k).order))


class LJF(SJF):
    name = "ljf"
    _sign = -1.0


class MPMax(Policy):
    """Just-in-Time MPMax (Section 5.2.2).

    In the normalised-resource model one block of kernel ``j`` occupies
    ``1/R_j`` of an SM, so kernel ``k`` reserving one block for each running
    co-runner caps its own residency at
    ``floor(R_k * (1 - sum_j 1/R_j))`` (>= 1).
    """

    name = "mpmax"

    def __init__(self):
        super().__init__()
        self._caps: Dict[str, int] = {}

    def _recompute(self) -> None:
        active = self._active()
        self._caps = {}
        for key in active:
            spec = self._run(key).spec
            reserved = sum(
                self._run(other).spec.resource_fraction
                for other in active if other != key)
            cap = int(math.floor(spec.max_residency * (1.0 - reserved)))
            self._caps[key] = max(1, cap)

    def on_arrival(self, key: str) -> None:
        self._recompute()

    def on_kernel_end(self, key: str) -> None:
        self._recompute()

    def residency_cap(self, key: str, sm: int) -> int:
        return self._caps.get(key, self._run(key).spec.max_residency)

    def decide(self, sm: int) -> Decision:
        # FIFO order up to each kernel's MPMax limit; when a kernel hits its
        # limit the next kernel in FIFO order gets to issue (Section 5.2.2).
        for key in self._active():
            if self._run(key).unissued > 0 and self._fits(key, sm):
                return IssueGrant(key)
        return Hold("all kernels at their MPMax reservation caps")


class SRTF(Policy):
    """Shortest Remaining Time First with online sampling (Section 5.1.1)."""

    name = "srtf"
    sample_sm = 0

    def __init__(self):
        super().__init__()
        self.eligible: set = set()       # kernels with a usable prediction
        self.sampling: Optional[str] = None
        self.sample_queue: deque = deque()

    # ------------------------------------------------------------- sampling
    def _start_next_sample(self) -> None:
        while self.sampling is None and self.sample_queue:
            key = self.sample_queue.popleft()
            if key in self.eligible:
                continue
            try:
                run = self._run(key)
            except KeyError:
                continue
            if run.finished:
                continue
            self.sampling = key

    def on_arrival(self, key: str) -> None:
        active = self._active()
        if len(active) == 1:
            # Arrived on an idle machine: runs immediately; its predictions
            # accumulate from its own execution.
            self.eligible.add(key)
        else:
            self.sample_queue.append(key)
            self._start_next_sample()

    def on_block_end(self, key: str, sm: int) -> None:
        if key == self.sampling and sm == self.sample_sm:
            t = self.machine.predictor.sampled_t(key, sm)
            if t is not None:
                self.machine.predictor.broadcast_t(key, t, from_sm=sm)
                self.eligible.add(key)
                self.sampling = None
                self._start_next_sample()

    def on_kernel_end(self, key: str) -> None:
        self.eligible.discard(key)
        if self.sampling == key:
            self.sampling = None
        if key in self.sample_queue:
            self.sample_queue.remove(key)
        self._start_next_sample()
        # If only one kernel remains un-predicted, it no longer needs a
        # sample to be scheduled.
        active = self._active()
        if len(active) == 1:
            self.eligible.add(active[0])

    # ------------------------------------------------------------- ranking
    def _remaining(self, key: str, sm: int) -> float:
        r = self.machine.predictor.remaining(key, sm)
        if r is None:
            r = self.machine.predictor.gpu_remaining(key)
        return r if r is not None else _INF

    def _candidates(self, sm: int) -> List[str]:
        keys = [k for k in self._active()
                if k in self.eligible and self._run(k).unissued > 0]
        return sorted(keys, key=lambda k: (self._remaining(k, sm),
                                           self._run(k).order))

    def _best_candidate(self, sm: int) -> Optional[str]:
        """First entry of :meth:`_candidates` without building the sorted
        list — exclusive-mode ``decide`` only ever consults the winner."""
        best_key = None
        best_rank = None
        for k in self._active():
            if k not in self.eligible or self._run(k).unissued <= 0:
                continue
            rank = (self._remaining(k, sm), self._run(k).order)
            if best_rank is None or rank < best_rank:
                best_key, best_rank = k, rank
        return best_key

    # --------------------------------------------------------------- decide
    def decide(self, sm: int) -> Decision:
        if self.sampling is not None and sm == self.sample_sm:
            key = self.sampling
            if self._run(key).unissued > 0 and self._fits(key, sm):
                return SampleOnSM(key)
            return Hold("sample in flight on the sampling SM")
        key = self._best_candidate(sm)
        if key is None:
            return Hold("no eligible kernel with a prediction")
        if self._fits(key, sm):
            return IssueGrant(key)
        # Exclusive execution: do not backfill behind the SRTF winner
        # while its blocks (or a draining co-runner's) occupy the SM.
        return PreemptAtBoundary(key)


class SRTFAdaptive(SRTF):
    """SRTF with fairness-driven adaptive resource sharing (Section 5.1.2)."""

    name = "srtf-adaptive"

    def __init__(self, unfairness_threshold: float = 0.5,
                 shared_residency: int = 3, hysteresis: float = 0.05):
        super().__init__()
        self.unfairness_threshold = unfairness_threshold
        self.shared_residency = shared_residency
        self.hysteresis = hysteresis
        self.sharing = False
        self._caps: Dict[str, int] = {}
        self._excl_pred: Dict[str, float] = {}

    # -------------------------------------------------------------- fairness
    def _predictions(self) -> Optional[List[tuple]]:
        """Return [(key, elapsed, remaining, solo_estimate)] or None."""
        active = [k for k in self._active() if k in self.eligible]
        if len(active) < 2:
            return None
        rows = []
        for key in active:
            rem = self.machine.predictor.gpu_remaining(key)
            if rem is None:
                return None
            elapsed = self.machine.elapsed(key)
            solo = self._excl_pred.get(key)
            if solo is None:
                solo = self.machine.predictor.gpu_predicted_total(
                    key, self.machine.now)
            if solo is None or solo <= 0:
                return None
            rows.append((key, elapsed, rem, solo))
        return rows

    @staticmethod
    def _gap(slowdowns: List[float]) -> float:
        return max(slowdowns) - min(slowdowns)

    def _project_exclusive(self, rows) -> List[float]:
        rows = sorted(rows, key=lambda r: r[2])
        slow, acc = [], 0.0
        for _, elapsed, rem, solo in rows:
            acc += rem
            slow.append((elapsed + acc) / solo)
        return slow

    def _project_sharing(self, rows) -> List[float]:
        rows = sorted(rows, key=lambda r: r[2])
        winner_key, w_elapsed, w_rem, w_solo = rows[0]
        w_spec = self._run(winner_key).spec
        cur_cap = max(1, min(self._cap_now(winner_key), w_spec.max_residency))
        shared_w = min(self.shared_residency, w_spec.max_residency)
        ts1 = w_rem * cur_cap / shared_w
        slow = [(w_elapsed + ts1) / w_solo]
        for key, elapsed, rem, solo in rows[1:]:
            spec = self._run(key).spec
            full = spec.max_residency
            shared_cap = self._loser_cap(spec, rows[0][0])
            cur = max(1, min(self._cap_now(key), full))
            s_l = rem * cur / shared_cap      # time to finish at shared cap
            if s_l <= ts1:
                slow.append((elapsed + s_l) / solo)
            else:
                tail = (s_l - ts1) * shared_cap / full
                slow.append((elapsed + ts1 + tail) / solo)
        return slow

    def _cap_now(self, key: str) -> int:
        return self._caps.get(key, self._run(key).spec.max_residency)

    def _loser_cap(self, spec, winner_key: str) -> int:
        w_spec = self._run(winner_key).spec
        shared_w = min(self.shared_residency, w_spec.max_residency)
        free_frac = 1.0 - shared_w * w_spec.resource_fraction
        return max(1, int(math.floor(free_frac * spec.max_residency)))

    def _reevaluate(self) -> None:
        rows = self._predictions()
        if rows is None:
            if self.sharing:
                self.sharing = False
                self._caps = {}
                self.machine.sync_residency_caps()
            return
        gap_excl = self._gap(self._project_exclusive(rows))
        gap_shared = self._gap(self._project_sharing(rows))
        want_sharing = (
            gap_excl > self.unfairness_threshold
            and gap_shared < gap_excl - self.hysteresis)
        new_caps: Dict[str, int] = {}
        if want_sharing:
            winner = min(rows, key=lambda r: r[2])[0]
            for key, *_ in rows:
                spec = self._run(key).spec
                if key == winner:
                    new_caps[key] = min(self.shared_residency,
                                        spec.max_residency)
                else:
                    new_caps[key] = self._loser_cap(spec, winner)
        if want_sharing != self.sharing or new_caps != self._caps:
            self.sharing = want_sharing
            self._caps = new_caps
            self.machine.sync_residency_caps()

    # ------------------------------------------------------------------ hooks
    def on_arrival(self, key: str) -> None:
        super().on_arrival(key)
        self._reevaluate()

    def on_block_end(self, key: str, sm: int) -> None:
        super().on_block_end(key, sm)
        if not self.sharing:
            # Remember the exclusive-conditions prediction (Section 5.1.2:
            # "the prediction from the exclusive part of a run").
            pred = self.machine.predictor.gpu_predicted_total(
                key, self.machine.now)
            if pred is not None:
                self._excl_pred[key] = pred
        self._reevaluate()

    def on_kernel_end(self, key: str) -> None:
        super().on_kernel_end(key)
        self._excl_pred.pop(key, None)
        self._reevaluate()

    # -------------------------------------------------------------- decisions
    def residency_cap(self, key: str, sm: int) -> int:
        if self.sharing and key in self._caps:
            return self._caps[key]
        return self._run(key).spec.max_residency

    def decide(self, sm: int) -> Decision:
        if not self.sharing:
            return super().decide(sm)
        if self.sampling is not None and sm == self.sample_sm:
            key = self.sampling
            if self._run(key).unissued > 0 and self._fits(key, sm):
                return SampleOnSM(key)
            return Hold("sample in flight on the sampling SM")
        # Sharing mode: co-run, shortest first, up to the adaptive caps.
        for key in self._candidates(sm):
            if self._fits(key, sm):
                return IssueGrant(key)
        return Hold("all kernels at their adaptive sharing caps")


class CappedFIFO(FIFO):
    """FIFO with a fixed residency cap — used to reproduce the paper's
    residency studies (Figs. 7/8/10), where residency is controlled by
    inflating dynamic shared memory."""

    name = "fifo-cap"

    def __init__(self, cap: int = MAX_RESIDENCY_DEFAULT):
        super().__init__()
        self.cap = cap

    def residency_cap(self, key: str, sm: int) -> int:
        return self.cap


class SRTFZeroSampling(SRTF):
    """SRTF with oracle-provided runtimes instead of online sampling
    (the paper's zero-sampling experiment, Section 6.2.2): isolates the
    cost of sampling from the cost of hand-off delay.  Unrealizable, like
    SJF, but diagnostic."""

    name = "srtf-zero"

    def on_arrival(self, key: str) -> None:
        self.eligible.add(key)              # no sampling phase

    def _remaining(self, key: str, sm: int) -> float:
        rt = self.machine.oracle_runtime(key)
        if rt is None:
            return super()._remaining(key, sm)
        run = self._run(key)
        frac_left = 1.0 - run.done / max(1, run.spec.num_blocks)
        return rt * frac_left


POLICIES = {
    "fifo": FIFO,
    "fifo-cap": CappedFIFO,
    "sjf": SJF,
    "ljf": LJF,
    "mpmax": MPMax,
    "srtf": SRTF,
    "srtf-zero": SRTFZeroSampling,
    "srtf-adaptive": SRTFAdaptive,
}


def make_policy(name: str, **kwargs) -> Policy:
    try:
        return POLICIES[name](**kwargs)
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; choose from {sorted(POLICIES)}") from None
