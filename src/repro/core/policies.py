"""Thread block scheduling policies (paper Section 5).

All policies target the formal :class:`repro.core.machine.Machine` protocol
— the only surface they may touch on the machine driving them (DES
simulator, real-JAX lane executor, or any future backend):

* ``bind(machine)``         — attach to a :class:`Machine`,
* ``decide(sm) -> Decision`` — typed scheduling decision for unit ``sm``
  (:class:`IssueGrant` / :class:`SampleOnSM` / :class:`Hold` /
  :class:`PreemptAtBoundary`, see :mod:`repro.core.events`),
* ``residency_cap(key, sm) -> int`` — per-kernel residency limit on ``sm``,
* event hooks ``on_arrival`` / ``on_block_end`` / ``on_kernel_end``
  (driven through :class:`repro.core.machine.SchedulerCore`).

Policies:

* :class:`FIFO`      — Fermi baseline (Section 5.2.1): strict arrival order;
  a later kernel issues only once every block of all earlier kernels has
  been dispatched.
* :class:`SJF` / :class:`LJF` — oracle orderings by true solo runtime
  (Section 2 / Fig. 1).  SJF is the unrealizable upper bound.
* :class:`MPMax`     — Just-in-Time MPMax (Section 5.2.2): FIFO order, but
  each kernel reserves resources for one block of each *currently running*
  co-runner; reservations are dropped when concurrency ceases.
* :class:`SRTF`      — Section 5.1.1: sample newly arrived kernels on one SM,
  broadcast the sampled ``t``, then run the predicted shortest-remaining-time
  kernel exclusively; preemption happens only at block boundaries, so
  hand-off delay emerges naturally (the :class:`PreemptAtBoundary` decision).
* :class:`SRTFAdaptive` — Section 5.1.2: SRTF plus a fairness monitor; when
  the projected slowdown gap exceeds ``unfairness_threshold`` (0.5), switch
  to sharing mode with the fastest kernel's residency capped at
  ``shared_residency`` (3) and co-runners taking the remaining resources.
"""

from __future__ import annotations

import math
import operator
from collections import deque
from typing import Dict, List, Optional

from .events import (
    Decision,
    Hold,
    IssueGrant,
    PreemptAtBoundary,
    SampleOnSM,
)

_INF = float("inf")
MAX_RESIDENCY_DEFAULT = 8

#: Sort key of the fairness rows (index 2 = predicted remaining time).
_BY_REMAINING = operator.itemgetter(2)

# Decisions are frozen dataclasses, so the recurring no-issue verdicts are
# shared module-level singletons: the DES asks for a decision on every
# issue opportunity, and allocating a fresh Hold per ask is pure overhead.
_HOLD_HEAD_OF_LINE = Hold("head-of-line kernel does not fit")
_HOLD_NO_UNDISPATCHED = Hold("no kernel with undispatched blocks")
_HOLD_SAMPLING = Hold("sample in flight on the sampling SM")
_HOLD_NO_ELIGIBLE = Hold("no eligible kernel with a prediction")
_HOLD_MPMAX = Hold("all kernels at their MPMax reservation caps")
_HOLD_ADAPTIVE = Hold("all kernels at their adaptive sharing caps")


class Policy:
    """Base class: unlimited residency, no issue grants."""

    name = "base"

    #: True when :meth:`residency_cap` never constrains below the spec's
    #: ``max_residency`` (the base behavior).  Machines then skip the cap
    #: query entirely on the per-issue fit path.  Subclasses that actually
    #: cap (MPMax, CappedFIFO, the adaptive sharing mode) set this False.
    unlimited_caps = True

    #: True when :meth:`residency_cap` is independent of the ``sm``
    #: argument — every built-in policy caps per *kernel*, so residency
    #: syncs query one unit and fan out the result.  A policy whose caps
    #: differ across units must set this False (the machine then falls
    #: back to the per-(kernel, unit) reference sync).
    uniform_caps = True

    #: True when the policy consumes runtime predictions (the SRTF
    #: family).  Policies that never read the predictor (FIFO, the oracle
    #: orderings, MPMax — the paper's baselines run on prediction-free
    #: hardware) set this False, and machines may then skip the per-block
    #: Algorithm-1 bookkeeping entirely; prediction *recording* or the
    #: reference path forces it back on.  Default True: a custom policy
    #: must opt out explicitly.
    uses_predictor = True

    def __init__(self):
        self.machine = None
        self._grants: Dict[str, IssueGrant] = {}

    def _grant(self, key: str) -> IssueGrant:
        """Shared per-kernel :class:`IssueGrant` (frozen => safe to reuse)."""
        g = self._grants.get(key)
        if g is None:
            g = self._grants[key] = IssueGrant(key)
        return g

    def bind(self, machine) -> None:
        """Attach to a :class:`repro.core.machine.Machine`."""
        self.machine = machine

    # -- event hooks ---------------------------------------------------------
    def on_arrival(self, key: str) -> None:
        pass

    def on_block_end(self, key: str, sm: int) -> None:
        pass

    def on_kernel_end(self, key: str) -> None:
        # Drop the finished kernel's cached decision singletons (subclass
        # hooks call super(): long-lived closed-loop machines inject
        # unboundedly many uniquely-keyed kernels).
        self._grants.pop(key, None)

    # -- decisions ------------------------------------------------------------
    def residency_cap(self, key: str, sm: int) -> int:
        return self._run(key).spec.max_residency

    def decide(self, sm: int) -> Decision:
        """Typed scheduling decision for unit ``sm``.

        Contract: decisions must be side-effect-free, pure functions of
        scheduler state (not of the clock), and an ``IssueGrant`` /
        ``SampleOnSM`` may only name a kernel the policy has verified
        with ``machine.can_fit(key, sm)`` — the DES fast path trusts
        grants and allocates without re-checking (the reference path,
        ``fast_path=False``, keeps a defensive re-check).
        """
        raise NotImplementedError

    # -- Machine-protocol helpers ---------------------------------------------
    def _run(self, key: str):
        return self.machine.run_state(key)

    def _active(self) -> List[str]:
        return self.machine.active_keys()

    def _fits(self, key: str, sm: int) -> bool:
        return self.machine.can_fit(key, sm)


class _OrderedPolicy(Policy):
    """Strict-priority issue: the highest-priority kernel with undispatched
    blocks blocks all later kernels (head-of-line semantics, as on Fermi)."""

    def order(self) -> List[str]:
        raise NotImplementedError

    def decide(self, sm: int) -> Decision:
        machine = self.machine
        for key in self.order():
            run = machine.run_state(key)
            if run.spec.num_blocks > run.issued:
                if machine.can_fit(key, sm):
                    return self._grant(key)
                return _HOLD_HEAD_OF_LINE
        return _HOLD_NO_UNDISPATCHED


class FIFO(_OrderedPolicy):
    name = "fifo"
    uses_predictor = False

    def order(self) -> List[str]:
        return self._active()

    def decide(self, sm: int) -> Decision:
        # Same head-of-line walk as _OrderedPolicy.decide, minus the
        # order() indirection: FIFO's order IS the active list, and this
        # is the single most-executed policy method in the repo.
        machine = self.machine
        for key in machine.active_keys():
            run = machine.run_state(key)
            if run.spec.num_blocks > run.issued:
                if machine.can_fit(key, sm):
                    return self._grant(key)
                return _HOLD_HEAD_OF_LINE
        return _HOLD_NO_UNDISPATCHED


class SJF(_OrderedPolicy):
    """Oracle Shortest Job First: requires true solo runtimes."""

    name = "sjf"
    uses_predictor = False
    _sign = 1.0

    def _runtime(self, key: str) -> float:
        rt = self.machine.oracle_runtime(key)
        if rt is None:
            rt = self._run(key).spec.solo_staircase_runtime()
        return rt

    def order(self) -> List[str]:
        keys = self._active()
        return sorted(keys, key=lambda k: (self._sign * self._runtime(k),
                                           self._run(k).order))


class LJF(SJF):
    name = "ljf"
    _sign = -1.0


class MPMax(Policy):
    """Just-in-Time MPMax (Section 5.2.2).

    In the normalised-resource model one block of kernel ``j`` occupies
    ``1/R_j`` of an SM, so kernel ``k`` reserving one block for each running
    co-runner caps its own residency at
    ``floor(R_k * (1 - sum_j 1/R_j))`` (>= 1).
    """

    name = "mpmax"
    unlimited_caps = False
    uses_predictor = False

    def __init__(self):
        super().__init__()
        self._caps: Dict[str, int] = {}

    def _recompute(self) -> None:
        active = self._active()
        self._caps = {}
        for key in active:
            spec = self._run(key).spec
            reserved = sum(
                self._run(other).spec.resource_fraction
                for other in active if other != key)
            cap = int(math.floor(spec.max_residency * (1.0 - reserved)))
            self._caps[key] = max(1, cap)

    def on_arrival(self, key: str) -> None:
        self._recompute()

    def on_kernel_end(self, key: str) -> None:
        super().on_kernel_end(key)
        self._recompute()

    def residency_cap(self, key: str, sm: int) -> int:
        return self._caps.get(key, self._run(key).spec.max_residency)

    def decide(self, sm: int) -> Decision:
        # FIFO order up to each kernel's MPMax limit; when a kernel hits its
        # limit the next kernel in FIFO order gets to issue (Section 5.2.2).
        machine = self.machine
        for key in machine.active_keys():
            run = machine.run_state(key)
            if run.spec.num_blocks > run.issued and machine.can_fit(key, sm):
                return self._grant(key)
        return _HOLD_MPMAX


class SRTF(Policy):
    """Shortest Remaining Time First with online sampling (Section 5.1.1)."""

    name = "srtf"
    sample_sm = 0

    def __init__(self):
        super().__init__()
        self.eligible: set = set()       # kernels with a usable prediction
        self.sampling: Optional[str] = None
        self.sample_queue: deque = deque()
        self._samples: Dict[str, SampleOnSM] = {}
        self._preempts: Dict[str, PreemptAtBoundary] = {}
        #: True while _remaining is the base implementation — the winner
        #: scan may then query the predictor inline instead of paying the
        #: polymorphic call per candidate (SRTFZeroSampling overrides it).
        self._plain_remaining = type(self)._remaining is SRTF._remaining

    # ------------------------------------------------------------- sampling
    def _start_next_sample(self) -> None:
        while self.sampling is None and self.sample_queue:
            key = self.sample_queue.popleft()
            if key in self.eligible:
                continue
            try:
                run = self._run(key)
            except KeyError:
                continue
            if run.finished:
                continue
            self.sampling = key

    def on_arrival(self, key: str) -> None:
        active = self._active()
        if len(active) == 1:
            # Arrived on an idle machine: runs immediately; its predictions
            # accumulate from its own execution.
            self.eligible.add(key)
        else:
            self.sample_queue.append(key)
            self._start_next_sample()

    def on_block_end(self, key: str, sm: int) -> None:
        if key == self.sampling and sm == self.sample_sm:
            t = self.machine.predictor.sampled_t(key, sm)
            if t is not None:
                self.machine.predictor.broadcast_t(key, t, from_sm=sm)
                self.eligible.add(key)
                self.sampling = None
                self._start_next_sample()

    def on_kernel_end(self, key: str) -> None:
        super().on_kernel_end(key)
        self._samples.pop(key, None)
        self._preempts.pop(key, None)
        self.eligible.discard(key)
        if self.sampling == key:
            self.sampling = None
        if key in self.sample_queue:
            self.sample_queue.remove(key)
        self._start_next_sample()
        # If only one kernel remains un-predicted, it no longer needs a
        # sample to be scheduled.
        active = self._active()
        if len(active) == 1:
            self.eligible.add(active[0])

    # ------------------------------------------------------------- ranking
    def _remaining(self, key: str, sm: int) -> float:
        predictor = self.machine.predictor
        r = predictor.remaining(key, sm)
        if r is None:
            r = predictor.gpu_remaining(key)
        return r if r is not None else _INF

    def _candidates(self, sm: int) -> List[str]:
        keys = [k for k in self._active()
                if k in self.eligible and self._run(k).unissued > 0]
        return sorted(keys, key=lambda k: (self._remaining(k, sm),
                                           self._run(k).order))

    def _best_candidate(self, sm: int) -> Optional[str]:
        """First entry of :meth:`_candidates` without building the sorted
        list — exclusive-mode ``decide`` only ever consults the winner.
        (Manual min over ``(remaining, order)``: same comparison the rank
        tuples performed, without allocating them.)"""
        machine = self.machine
        eligible = self.eligible
        active = machine.active_keys()
        # Candidate census first: a lone candidate wins regardless of its
        # predicted remaining time (the tie-break never fires), so the
        # predictor is only consulted when there is an actual race
        # (prediction reads are pure — skipping them cannot change state).
        sole = None
        count = 0
        for k in active:
            if k not in eligible:
                continue
            run = machine.run_state(k)
            if run.spec.num_blocks > run.issued:
                count += 1
                if count > 1:
                    break
                sole = k
        if count == 0:
            return None
        if count == 1:
            return sole
        predictor = machine.predictor if self._plain_remaining else None
        best_key = None
        best_rem = 0.0
        best_order = 0
        for k in active:
            if k not in eligible:
                continue
            run = machine.run_state(k)
            if run.spec.num_blocks <= run.issued:
                continue
            if predictor is not None:
                # Inline of the base _remaining (public predictor queries).
                rem = predictor.remaining(k, sm)
                if rem is None:
                    rem = predictor.gpu_remaining(k)
                    if rem is None:
                        rem = _INF
            else:
                rem = self._remaining(k, sm)
            if (best_key is None or rem < best_rem
                    or (rem == best_rem and run.order < best_order)):
                best_key, best_rem, best_order = k, rem, run.order
        return best_key

    def _sample(self, key: str) -> SampleOnSM:
        s = self._samples.get(key)
        if s is None:
            s = self._samples[key] = SampleOnSM(key)
        return s

    def _preempt(self, key: str) -> PreemptAtBoundary:
        p = self._preempts.get(key)
        if p is None:
            p = self._preempts[key] = PreemptAtBoundary(key)
        return p

    # --------------------------------------------------------------- decide
    def decide(self, sm: int) -> Decision:
        if self.sampling is not None and sm == self.sample_sm:
            key = self.sampling
            run = self.machine.run_state(key)
            if run.spec.num_blocks > run.issued \
                    and self.machine.can_fit(key, sm):
                return self._sample(key)
            return _HOLD_SAMPLING
        key = self._best_candidate(sm)
        if key is None:
            return _HOLD_NO_ELIGIBLE
        if self.machine.can_fit(key, sm):
            return self._grant(key)
        # Exclusive execution: do not backfill behind the SRTF winner
        # while its blocks (or a draining co-runner's) occupy the SM.
        return self._preempt(key)


class SRTFAdaptive(SRTF):
    """SRTF with fairness-driven adaptive resource sharing (Section 5.1.2)."""

    name = "srtf-adaptive"
    unlimited_caps = False

    def __init__(self, unfairness_threshold: float = 0.5,
                 shared_residency: int = 3, hysteresis: float = 0.05):
        super().__init__()
        self.unfairness_threshold = unfairness_threshold
        self.shared_residency = shared_residency
        self.hysteresis = hysteresis
        self.sharing = False
        self._caps: Dict[str, int] = {}
        self._excl_pred: Dict[str, float] = {}

    # -------------------------------------------------------------- fairness
    def _predictions(self) -> Optional[List[tuple]]:
        """Return [(key, elapsed, remaining, solo_estimate, spec)] or None.

        The spec rides along so the projections below never re-resolve
        runs through the machine (this runs on every block end)."""
        machine = self.machine
        eligible = self.eligible
        active = [k for k in machine.active_keys() if k in eligible]
        if len(active) < 2:
            return None
        predictor = machine.predictor
        now = machine.now
        rows = []
        for key in active:
            rem = predictor.gpu_remaining(key)
            if rem is None:
                return None
            run = machine.run_state(key)
            solo = self._excl_pred.get(key)
            if solo is None:
                solo = predictor.gpu_predicted_total(key, now)
            if solo is None or solo <= 0:
                return None
            rows.append((key, now - run.arrival_time, rem, solo, run.spec))
        return rows

    @staticmethod
    def _gap(slowdowns: List[float]) -> float:
        return max(slowdowns) - min(slowdowns)

    def _project_exclusive(self, rows) -> List[float]:
        # rows arrive sorted by remaining time (the _reevaluate contract;
        # one sort serves both projections).
        slow, acc = [], 0.0
        for _, elapsed, rem, solo, _spec in rows:
            acc += rem
            slow.append((elapsed + acc) / solo)
        return slow

    def _project_sharing(self, rows) -> List[float]:
        winner_key, w_elapsed, w_rem, w_solo, w_spec = rows[0]
        cur_cap = max(1, min(self._cap_now(winner_key, w_spec),
                             w_spec.max_residency))
        shared_w = min(self.shared_residency, w_spec.max_residency)
        ts1 = w_rem * cur_cap / shared_w
        slow = [(w_elapsed + ts1) / w_solo]
        for key, elapsed, rem, solo, spec in rows[1:]:
            full = spec.max_residency
            shared_cap = self._loser_cap(spec, w_spec)
            cur = max(1, min(self._cap_now(key, spec), full))
            s_l = rem * cur / shared_cap      # time to finish at shared cap
            if s_l <= ts1:
                slow.append((elapsed + s_l) / solo)
            else:
                tail = (s_l - ts1) * shared_cap / full
                slow.append((elapsed + ts1 + tail) / solo)
        return slow

    def _cap_now(self, key: str, spec=None) -> int:
        cap = self._caps.get(key)
        if cap is not None:
            return cap
        if spec is None:
            spec = self._run(key).spec
        return spec.max_residency

    def _loser_cap(self, spec, winner_spec) -> int:
        shared_w = min(self.shared_residency, winner_spec.max_residency)
        free_frac = 1.0 - shared_w * winner_spec.resource_fraction
        return max(1, int(math.floor(free_frac * spec.max_residency)))

    def _reevaluate(self) -> None:
        if not self.sharing and len(self.machine.active_keys()) < 2:
            return   # < 2 active kernels can never enter sharing mode
        rows = self._predictions()
        if rows is None:
            if self.sharing:
                self.sharing = False
                self._caps = {}
                self.machine.sync_residency_caps()
            return
        # One stable sort by remaining time serves both projections and
        # the winner pick (stable => same winner as a min() over the
        # arrival-ordered rows).
        rows.sort(key=_BY_REMAINING)
        gap_excl = self._gap(self._project_exclusive(rows))
        gap_shared = self._gap(self._project_sharing(rows))
        want_sharing = (
            gap_excl > self.unfairness_threshold
            and gap_shared < gap_excl - self.hysteresis)
        new_caps: Dict[str, int] = {}
        if want_sharing:
            winner = rows[0][0]
            winner_spec = rows[0][4]
            for key, _elapsed, _rem, _solo, spec in rows:
                if key == winner:
                    new_caps[key] = min(self.shared_residency,
                                        spec.max_residency)
                else:
                    new_caps[key] = self._loser_cap(spec, winner_spec)
        if want_sharing != self.sharing or new_caps != self._caps:
            self.sharing = want_sharing
            self._caps = new_caps
            self.machine.sync_residency_caps()

    # ------------------------------------------------------------------ hooks
    def on_arrival(self, key: str) -> None:
        super().on_arrival(key)
        self._reevaluate()

    def on_block_end(self, key: str, sm: int) -> None:
        super().on_block_end(key, sm)
        machine = self.machine
        if not self.sharing:
            # Remember the exclusive-conditions prediction (Section 5.1.2:
            # "the prediction from the exclusive part of a run").  On a
            # terminally-solo machine — this kernel is the only active one
            # and no arrival can ever come — the stored value is provably
            # unreachable (only _predictions() reads it, and only with
            # >= 2 active kernels), so the Eq. 2 machine sweep is elided.
            if len(machine.active_keys()) > 1 or machine.arrivals_pending():
                pred = machine.predictor.gpu_predicted_total(
                    key, machine.now)
                if pred is not None:
                    self._excl_pred[key] = pred
        self._reevaluate()

    def on_kernel_end(self, key: str) -> None:
        super().on_kernel_end(key)
        self._excl_pred.pop(key, None)
        self._reevaluate()

    # -------------------------------------------------------------- decisions
    def residency_cap(self, key: str, sm: int) -> int:
        if self.sharing and key in self._caps:
            return self._caps[key]
        return self._run(key).spec.max_residency

    def decide(self, sm: int) -> Decision:
        if not self.sharing:
            return super().decide(sm)
        if self.sampling is not None and sm == self.sample_sm:
            key = self.sampling
            run = self.machine.run_state(key)
            if run.spec.num_blocks > run.issued \
                    and self.machine.can_fit(key, sm):
                return self._sample(key)
            return _HOLD_SAMPLING
        # Sharing mode: co-run, shortest first, up to the adaptive caps.
        for key in self._candidates(sm):
            if self._fits(key, sm):
                return self._grant(key)
        return _HOLD_ADAPTIVE


class CappedFIFO(FIFO):
    """FIFO with a fixed residency cap — used to reproduce the paper's
    residency studies (Figs. 7/8/10), where residency is controlled by
    inflating dynamic shared memory."""

    name = "fifo-cap"
    unlimited_caps = False

    def __init__(self, cap: int = MAX_RESIDENCY_DEFAULT):
        super().__init__()
        self.cap = cap

    def residency_cap(self, key: str, sm: int) -> int:
        return self.cap


class SRTFZeroSampling(SRTF):
    """SRTF with oracle-provided runtimes instead of online sampling
    (the paper's zero-sampling experiment, Section 6.2.2): isolates the
    cost of sampling from the cost of hand-off delay.  Unrealizable, like
    SJF, but diagnostic."""

    name = "srtf-zero"

    def __init__(self):
        super().__init__()
        self._oracle_cache: Dict[str, Optional[float]] = {}

    def on_arrival(self, key: str) -> None:
        self.eligible.add(key)              # no sampling phase

    def on_kernel_end(self, key: str) -> None:
        super().on_kernel_end(key)
        self._oracle_cache.pop(key, None)

    def _remaining(self, key: str, sm: int) -> float:
        # Oracle runtimes are fixed per run: memoize the lookup (this is
        # queried per candidate on every decision).
        try:
            rt = self._oracle_cache[key]
        except KeyError:
            rt = self._oracle_cache[key] = self.machine.oracle_runtime(key)
        if rt is None:
            return super()._remaining(key, sm)
        run = self._run(key)
        frac_left = 1.0 - run.done / max(1, run.spec.num_blocks)
        return rt * frac_left


POLICIES = {
    "fifo": FIFO,
    "fifo-cap": CappedFIFO,
    "sjf": SJF,
    "ljf": LJF,
    "mpmax": MPMax,
    "srtf": SRTF,
    "srtf-zero": SRTFZeroSampling,
    "srtf-adaptive": SRTFAdaptive,
}


def make_policy(name: str, **kwargs) -> Policy:
    try:
        return POLICIES[name](**kwargs)
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; choose from {sorted(POLICIES)}") from None
