"""Scenario registry: named, seeded arrival-process generators.

The paper's evaluation grid (Tables 5-6) is {two-program ERCBench
workloads} x {policies} x {arrival offsets}; the ROADMAP's production story
needs far more — open-loop Poisson kernel streams shared-cloud style
(Kernelet), bursty ON/OFF DL traffic, N-program mixes, and replayed
production traces.  This module makes every one of those a first-class,
*named* workload generator with a single contract::

    scenario = make_scenario("poisson-open", seed=0, n_arrivals=8)
    workloads = scenario.workloads()   # -> List[(name, List[Arrival])]

mirroring the policy/predictor registries (``POLICIES``/``PREDICTORS``):
``SCENARIOS`` maps public names to classes, :func:`register_scenario` adds
new ones, :func:`make_scenario` resolves names (or passes instances
through).  Scenarios are **deterministic**: the same (scenario params,
seed) produce bit-identical arrival lists in any process — RNG streams are
seeded from ``zlib.crc32`` of the scenario name (stable across processes;
Python's ``hash()`` is salted), exactly like the simulator's per-kernel
noise streams.  That determinism is what makes sweep results
content-addressable (:mod:`repro.core.sweep`).

Built-in scenarios:

* ``pair-stagger``  — the paper's 56 two-program ERCBench workloads
  (Section 6.1.3); byte-identical to
  :func:`repro.core.workload.two_program_workloads`.
* ``table6-offset`` — the second kernel arrives after a fraction of the
  first kernel's solo runtime (Table 6).
* ``poisson-open``  — open-loop Poisson arrivals over an
  ERCBench/Parboil2-like kernel mix (shared-cloud kernel streams).
* ``bursty``        — heavy-tail ON/OFF bursts (Pareto burst sizes,
  exponential gaps): the bursty many-kernel DL traffic shape.
* ``nprogram-mix``  — random closed N-program workloads (N > 2).
* ``trace-replay``  — arrivals replayed from a JSON trace (file or
  in-memory), for production traces and hermetic tests.
"""

from __future__ import annotations

import functools
import itertools
import json
import math
import zlib
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Type, Union

import numpy as np

from .executor import ExecutorJob
from .workload import (
    Arrival,
    ERCBENCH,
    KernelSpec,
    PARBOIL2_LIKE,
    TABLE3_RUNTIME,
    two_program_workloads,
)

#: The single scenario contract: named workloads, each a list of arrivals.
Workload = Tuple[str, List[Arrival]]

#: Default open-loop mix: every ERCBench kernel except SHA1 (whose 22M-cycle
#: solo runtime would dominate any stream) plus the short/medium
#: Parboil2-like kernels.
OPEN_LOOP_MIX: Tuple[str, ...] = (
    "AES-d", "AES-e", "JPEG-d", "JPEG-e", "RayTracing", "SAD",
    "ImageDenoising-nlm2", "SGEMM", "CUTCP", "HISTO",
)


def _spec_table(extra: Optional[Dict[str, KernelSpec]] = None
                ) -> Dict[str, KernelSpec]:
    table = dict(ERCBENCH)
    table.update(PARBOIL2_LIKE)
    if extra:
        table.update(extra)
    return table


class Scenario:
    """Base class: a seeded arrival-process generator.

    Subclasses implement :meth:`workloads`; all randomness must come from
    :meth:`rng` so that (params, seed) fully determine the output.
    """

    name = "base"

    def __init__(self, seed: int = 0):
        self.seed = seed

    def rng(self, *extra: int) -> np.random.Generator:
        """Process-stable RNG stream for this (scenario, seed[, extra])."""
        name_hash = zlib.crc32(self.name.encode()) % (2 ** 31)
        return np.random.default_rng(
            np.random.SeedSequence(entropy=(self.seed, name_hash, *extra)))

    def workloads(self) -> List[Workload]:
        raise NotImplementedError

    def reseeded(self, seed: int) -> "Scenario":
        """A copy of this scenario drawing from ``seed`` instead.

        Used by the sweep runner so one declarative spec can sweep arrival
        draws and simulation noise coherently across seeds.
        """
        import copy
        clone = copy.copy(self)
        clone.seed = seed
        return clone


#: Registry of scenario implementations, keyed by their public name.
SCENARIOS: Dict[str, Type[Scenario]] = {}


def register_scenario(name: str):
    """Class decorator registering a :class:`Scenario` under ``name``."""

    def decorate(cls: Type[Scenario]) -> Type[Scenario]:
        cls.name = name
        SCENARIOS[name] = cls
        return cls

    return decorate


def make_scenario(spec: Union[str, Scenario], **kwargs) -> Scenario:
    """Resolve ``spec`` into a scenario instance.

    ``spec`` may be an instance (returned as-is; kwargs then disallowed) or
    a registered name constructed with ``**kwargs``.
    """
    if isinstance(spec, Scenario):
        if kwargs:
            raise ValueError("kwargs are only valid with a scenario name")
        return spec
    try:
        cls = SCENARIOS[spec]
    except KeyError:
        raise ValueError(
            f"unknown scenario {spec!r}; choose from {sorted(SCENARIOS)}"
        ) from None
    return cls(**kwargs)


@register_scenario("pair-stagger")
class PairStagger(Scenario):
    """The paper's two-program ERCBench workloads (Section 6.1.3).

    Deterministic (no RNG): delegates to
    :func:`~repro.core.workload.two_program_workloads`, so the 56-pair
    sweep produced through the registry is byte-identical to the
    hard-coded one the golden traces were pinned against.
    """

    def __init__(self, seed: int = 0,
                 names: Optional[Sequence[str]] = None,
                 stagger_cycles: float = 100.0,
                 both_orders: bool = True):
        super().__init__(seed)
        self.names = list(names) if names is not None else None
        self.stagger_cycles = stagger_cycles
        self.both_orders = both_orders

    def workloads(self) -> List[Workload]:
        return two_program_workloads(
            names=self.names, stagger_cycles=self.stagger_cycles,
            both_orders=self.both_orders)


@register_scenario("table6-offset")
class Table6Offset(Scenario):
    """Table 6: second kernel arrives after ``offset_fraction`` of the first
    kernel's solo runtime.  ``solo`` maps kernel names to the solo runtimes
    the offsets are computed from (defaults to the paper's Table 3 values;
    the benchmarks pass the simulator-measured ones)."""

    def __init__(self, seed: int = 0,
                 offset_fraction: float = 0.25,
                 names: Optional[Sequence[str]] = None,
                 solo: Optional[Dict[str, float]] = None):
        super().__init__(seed)
        self.offset_fraction = offset_fraction
        self.names = sorted(names) if names is not None else sorted(ERCBENCH)
        self.solo = dict(solo) if solo is not None else dict(TABLE3_RUNTIME)

    @property
    def suffix(self) -> str:
        """Workload-name suffix — the one place the fraction is formatted
        (consumers filter cells with ``workload.endswith(scn.suffix)``)."""
        return f"@{int(round(self.offset_fraction * 100))}"

    def workloads(self) -> List[Workload]:
        out: List[Workload] = []
        for a, b in itertools.permutations(self.names, 2):
            offset = self.offset_fraction * self.solo[a]
            wl = [
                Arrival(ERCBENCH[a], 0.0, uid=f"{a}#0"),
                Arrival(ERCBENCH[b], offset, uid=f"{b}#1"),
            ]
            out.append((f"{a}+{b}{self.suffix}", wl))
        return out


class _MixScenario(Scenario):
    """Shared machinery for scenarios drawing kernels from a named mix."""

    def __init__(self, seed: int = 0,
                 names: Sequence[str] = OPEN_LOOP_MIX,
                 specs: Optional[Dict[str, KernelSpec]] = None):
        super().__init__(seed)
        self.names = list(names)
        self.specs = _spec_table(specs)
        missing = [n for n in self.names if n not in self.specs]
        if missing:
            raise ValueError(f"unknown kernels in mix: {missing}")

    def _pick(self, rng: np.random.Generator) -> KernelSpec:
        return self.specs[self.names[int(rng.integers(len(self.names)))]]

    @staticmethod
    def _build(arrivals: List[Tuple[KernelSpec, float]]) -> List[Arrival]:
        return [Arrival(spec, t, uid=f"{spec.name}#{i}")
                for i, (spec, t) in enumerate(arrivals)]


@register_scenario("poisson-open")
class PoissonOpen(Scenario):
    """Open-loop Poisson kernel stream over an ERCBench/Parboil2-like mix.

    Shared-cloud style (Kernelet): kernels arrive regardless of machine
    state with exponential inter-arrival times of mean
    ``mean_interarrival`` cycles.  With ``n_workloads`` > 1 each workload
    is an independent draw of the same process.
    """

    def __init__(self, seed: int = 0,
                 names: Sequence[str] = OPEN_LOOP_MIX,
                 specs: Optional[Dict[str, KernelSpec]] = None,
                 n_arrivals: int = 8,
                 mean_interarrival: float = 100_000.0,
                 n_workloads: int = 2):
        self._mix = _MixScenario(seed, names, specs)
        super().__init__(seed)
        self.n_arrivals = n_arrivals
        self.mean_interarrival = mean_interarrival
        self.n_workloads = n_workloads

    def workloads(self) -> List[Workload]:
        out: List[Workload] = []
        for w in range(self.n_workloads):
            rng = self.rng(w)
            t = 0.0
            draws: List[Tuple[KernelSpec, float]] = []
            for _ in range(self.n_arrivals):
                draws.append((self._mix._pick(rng), t))
                t += float(rng.exponential(self.mean_interarrival))
            out.append((f"poisson{w}", self._mix._build(draws)))
        return out


@register_scenario("bursty")
class Bursty(Scenario):
    """Heavy-tail ON/OFF arrival bursts (bursty DL inference traffic).

    Each burst holds ``1 + floor(Pareto(alpha))`` kernels (capped at
    ``max_burst``) spaced ``Exp(within_gap)`` apart; bursts are separated
    by ``Exp(idle_gap)`` quiet periods.
    """

    def __init__(self, seed: int = 0,
                 names: Sequence[str] = OPEN_LOOP_MIX,
                 specs: Optional[Dict[str, KernelSpec]] = None,
                 n_bursts: int = 3,
                 burst_alpha: float = 1.5,
                 max_burst: int = 6,
                 within_gap: float = 1_000.0,
                 idle_gap: float = 500_000.0,
                 n_workloads: int = 2):
        self._mix = _MixScenario(seed, names, specs)
        super().__init__(seed)
        self.n_bursts = n_bursts
        self.burst_alpha = burst_alpha
        self.max_burst = max_burst
        self.within_gap = within_gap
        self.idle_gap = idle_gap
        self.n_workloads = n_workloads

    def workloads(self) -> List[Workload]:
        out: List[Workload] = []
        for w in range(self.n_workloads):
            rng = self.rng(w)
            t = 0.0
            draws: List[Tuple[KernelSpec, float]] = []
            for _ in range(self.n_bursts):
                size = min(self.max_burst,
                           1 + int(rng.pareto(self.burst_alpha)))
                for _ in range(size):
                    draws.append((self._mix._pick(rng), t))
                    t += float(rng.exponential(self.within_gap))
                t += float(rng.exponential(self.idle_gap))
            out.append((f"bursty{w}", self._mix._build(draws)))
        return out


@register_scenario("nprogram-mix")
class NProgramMix(Scenario):
    """Random closed N-program workloads (N > 2): every kernel arrives
    within the first ``max_stagger`` cycles, generalizing the paper's
    two-program staggered launches to wider co-run sets."""

    def __init__(self, seed: int = 0,
                 names: Sequence[str] = OPEN_LOOP_MIX,
                 specs: Optional[Dict[str, KernelSpec]] = None,
                 n_programs: int = 4,
                 max_stagger: float = 100.0,
                 n_workloads: int = 4):
        if n_programs < 2:
            raise ValueError("nprogram-mix needs n_programs >= 2")
        self._mix = _MixScenario(seed, names, specs)
        super().__init__(seed)
        self.n_programs = n_programs
        self.max_stagger = max_stagger
        self.n_workloads = n_workloads

    def workloads(self) -> List[Workload]:
        out: List[Workload] = []
        for w in range(self.n_workloads):
            rng = self.rng(w)
            draws = [(self._mix._pick(rng),
                      0.0 if i == 0 else
                      float(rng.uniform(0.0, self.max_stagger)))
                     for i in range(self.n_programs)]
            draws.sort(key=lambda d: d[1])
            out.append((f"mix{w}x{self.n_programs}", self._mix._build(draws)))
        return out


@register_scenario("trace-replay")
class TraceReplay(Scenario):
    """Replay arrivals from a JSON trace (production traces, hermetic tests).

    Accepts either ``path`` to a JSON file or an in-memory ``trace``.
    Two shapes are understood::

        [{"kernel": "JPEG-d", "time": 0.0}, ...]                # one workload
        {"workloads": [{"name": "w0", "arrivals": [...]}, ...]} # several

    Kernel names resolve against ERCBench + Parboil2-like specs plus any
    caller-supplied ``specs``.  Deterministic by construction (no RNG).
    """

    def __init__(self, seed: int = 0,
                 path: Optional[Union[str, Path]] = None,
                 trace: Optional[Union[list, dict]] = None,
                 specs: Optional[Dict[str, KernelSpec]] = None,
                 name: str = "trace"):
        super().__init__(seed)
        if (path is None) == (trace is None):
            raise ValueError("trace-replay needs exactly one of path/trace")
        self.path = str(path) if path is not None else None
        self.trace = trace
        self.specs = _spec_table(specs)
        self.workload_name = name

    def _events(self) -> Union[list, dict]:
        if self.path is not None:
            return json.loads(Path(self.path).read_text())
        return self.trace

    def _arrivals(self, events: Sequence[dict]) -> List[Arrival]:
        out = []
        for i, ev in enumerate(events):
            kernel = ev["kernel"]
            try:
                spec = self.specs[kernel]
            except KeyError:
                raise ValueError(
                    f"trace kernel {kernel!r} not in spec table") from None
            out.append(Arrival(spec, float(ev.get("time", 0.0)),
                               uid=ev.get("uid", f"{kernel}#{i}")))
        return sorted(out, key=lambda a: a.time)

    def workloads(self) -> List[Workload]:
        data = self._events()
        if isinstance(data, dict):
            return [(wl.get("name", f"{self.workload_name}{i}"),
                     self._arrivals(wl["arrivals"]))
                    for i, wl in enumerate(data["workloads"])]
        return [(self.workload_name, self._arrivals(data))]


# ------------------------------------------------------- executor bridge
#: Seconds of executor (lane) time per scenario cycle.  Chosen so that the
#: cycle-scale arrival gaps the scenarios emit (hundreds to a few thousand
#: cycles) land in the same regime as real measured block durations
#: (fractions of a millisecond on this container).
DEFAULT_EXECUTOR_TIME_SCALE = 1e-6


def _synthetic_shape(spec: KernelSpec) -> Tuple[int, int]:
    """Deterministic (matrix dim, repeat count) for one kernel spec.

    The dim follows the grid's per-block parallelism (``threads_per_block``)
    and the repeat count the block-duration scale (``mean_t``), so distinct
    specs get distinct real costs and the SJF/SRTF orderings over synthetic
    jobs remain meaningful.
    """
    dim = max(16, min(128, int(spec.threads_per_block)))
    reps = max(1, min(6, int(math.log10(max(float(spec.mean_t), 10.0)))))
    return dim, reps


@functools.lru_cache(maxsize=None)
def _jitted_block(dim: int, reps: int):
    """One jit-compiled synthetic block body, shared by every job with the
    same shape (compiles once per process)."""
    import jax
    import jax.numpy as jnp

    x0 = jnp.linspace(-1.0, 1.0, dim * dim).reshape(dim, dim)

    @jax.jit
    def step(x):
        for _ in range(reps):
            x = jnp.tanh(x @ x) + 0.5 * x
        return x

    return step, x0


def executor_job(arrival: Arrival, *, n_lanes: int = 4,
                 time_scale: float = DEFAULT_EXECUTOR_TIME_SCALE
                 ) -> ExecutorJob:
    """Map one scenario :class:`~repro.core.workload.Arrival` to a
    schedulable :class:`~repro.core.executor.ExecutorJob`.

    The job keeps the scenario's declared grid (``num_blocks``, residency
    capped at the lane count) and arrival time (cycles scaled to seconds by
    ``time_scale``); each block is a REAL jit-compiled computation whose
    cost is a deterministic function of the spec
    (:func:`_synthetic_shape`), so executor sweeps measure actual JAX
    dispatch/compute behavior at scenario-declared sizes.
    """
    spec = arrival.spec
    dim, reps = _synthetic_shape(spec)

    def warmup():
        import jax
        step, x0 = _jitted_block(dim, reps)
        jax.block_until_ready(step(x0))   # compile only; discard result

    def make_block_fn(residency: int):
        import jax
        step, x0 = _jitted_block(dim, reps)

        def block():
            jax.block_until_ready(step(x0))

        return block

    return ExecutorJob(
        name=spec.name, num_blocks=spec.num_blocks,
        max_residency=min(spec.max_residency, n_lanes),
        make_block_fn=make_block_fn,
        arrival=arrival.time * time_scale,
        est_block_seconds=float(spec.mean_t),   # SJF fallback ordering only
        warmup_fn=warmup)


def executor_workload(arrivals: Sequence[Arrival], *, n_lanes: int = 4,
                      time_scale: float = DEFAULT_EXECUTOR_TIME_SCALE
                      ) -> List[Tuple[str, ExecutorJob]]:
    """Bridge one scenario workload to ``(key, job)`` pairs.

    Keys are the scenario's arrival uids (``{name}#{i}``) so executor cells
    carry the same kernel keys as DES cells of the same workload; pass each
    pair to :meth:`~repro.core.executor.LaneExecutor.add_job` as
    ``add_job(job, key=key)``.
    """
    return [(a.key, executor_job(a, n_lanes=n_lanes, time_scale=time_scale))
            for a in arrivals]


# --------------------------------------------------------------- utilities
def workload_digest(arrivals: Sequence[Arrival]) -> str:
    """Content digest of one arrival list (the sweep-cache workload key).

    Covers every :class:`KernelSpec` field plus arrival times and uids, so
    any change to the workload's content changes the digest.
    """
    import dataclasses
    import hashlib

    payload = [
        {"spec": dataclasses.asdict(a.spec), "time": a.time, "uid": a.uid}
        for a in arrivals
    ]
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def submission_offsets(scenario: Union[str, Scenario], n: int,
                       time_scale: float = 1.0, **kwargs) -> List[float]:
    """First-workload arrival times as ``n`` submission offsets.

    The serving/dryrun frontends use this to pace real job submissions from
    a scenario's arrival process: offsets are the scenario's first
    workload's arrival times scaled by ``time_scale`` (e.g. cycles ->
    seconds).  If the workload holds fewer than ``n`` arrivals the stream
    is extended at the mean observed gap.
    """
    scn = make_scenario(scenario, **kwargs)
    workloads = scn.workloads()
    if not workloads:
        raise ValueError(f"scenario {scn.name!r} produced no workloads")
    times = sorted(a.time for a in workloads[0][1])
    if not times:
        raise ValueError(f"scenario {scn.name!r} produced an empty workload")
    gaps = [b - a for a, b in zip(times, times[1:])]
    mean_gap = (sum(gaps) / len(gaps)) if gaps else 0.0
    while len(times) < n:
        times.append(times[-1] + mean_gap)
    return [t * time_scale for t in times[:n]]


__all__ = [
    "Bursty",
    "DEFAULT_EXECUTOR_TIME_SCALE",
    "NProgramMix",
    "OPEN_LOOP_MIX",
    "executor_job",
    "executor_workload",
    "PairStagger",
    "PoissonOpen",
    "SCENARIOS",
    "Scenario",
    "Table6Offset",
    "TraceReplay",
    "Workload",
    "make_scenario",
    "register_scenario",
    "submission_offsets",
    "workload_digest",
]
