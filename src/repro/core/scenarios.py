"""Scenario registry: named, seeded arrival-process generators.

The paper's evaluation grid (Tables 5-6) is {two-program ERCBench
workloads} x {policies} x {arrival offsets}; the ROADMAP's production story
needs far more — open-loop Poisson kernel streams shared-cloud style
(Kernelet), bursty ON/OFF DL traffic, N-program mixes, and replayed
production traces.  This module makes every one of those a first-class,
*named* workload generator with a single contract::

    scenario = make_scenario("poisson-open", seed=0, n_arrivals=8)
    workloads = scenario.workloads()   # -> List[(name, List[Arrival])]

mirroring the policy/predictor registries (``POLICIES``/``PREDICTORS``):
``SCENARIOS`` maps public names to classes, :func:`register_scenario` adds
new ones, :func:`make_scenario` resolves names (or passes instances
through).  Scenarios are **deterministic**: the same (scenario params,
seed) produce bit-identical arrival lists in any process — RNG streams are
seeded from ``zlib.crc32`` of the scenario name (stable across processes;
Python's ``hash()`` is salted), exactly like the simulator's per-kernel
noise streams.  That determinism is what makes sweep results
content-addressable (:mod:`repro.core.sweep`).

The contract is **two-tier** (DESIGN.md Section 7):

* **Open loop** (:class:`Scenario`): ``workloads()`` yields fixed, fully
  materialized arrival lists — arrivals do not react to machine state.
* **Closed loop** (:class:`ClosedLoopScenario`): ``make_process(name)``
  yields an **arrival process** — a seeded, stateful generator that is fed
  kernel completions by the machine (the
  :class:`~repro.core.events.ArrivalSource` feedback edge) and emits the
  next arrivals: offered load that reacts to how fast the scheduler
  drains it, the regime where preemptive SRTF is actually stress-tested.

Built-in open-loop scenarios:

* ``pair-stagger``  — the paper's 56 two-program ERCBench workloads
  (Section 6.1.3); byte-identical to
  :func:`repro.core.workload.two_program_workloads`.
* ``table6-offset`` — the second kernel arrives after a fraction of the
  first kernel's solo runtime (Table 6).
* ``poisson-open``  — open-loop Poisson arrivals over an
  ERCBench/Parboil2-like kernel mix (shared-cloud kernel streams).
* ``bursty``        — heavy-tail ON/OFF bursts (Pareto burst sizes,
  exponential gaps): the bursty many-kernel DL traffic shape.
* ``nprogram-mix``  — random closed N-program workloads (N > 2).
* ``trace-replay``  — arrivals replayed from a JSON trace (file or
  in-memory), for production traces and hermetic tests.
* ``diurnal``       — piecewise-rate (day/night) Poisson stream; the rate
  profile is calibratable from a ``trace-replay`` JSON
  (:func:`fit_diurnal_profile` / :meth:`Diurnal.from_trace`).

Built-in closed-loop scenarios:

* ``mgk-closed``    — M/G/k-style offered Poisson load with a bounded
  population: at most ``population`` kernels in the system; excess offered
  arrivals are deferred until a completion frees a slot (``admission=
  "defer"``) or rejected outright (``admission="drop"``).
* ``think-time``    — ``n_tenants`` independent tenants, each resubmitting
  a fresh kernel ``think ~ Exp(mean_think)`` after its previous one
  finishes (the interactive-user loop).
"""

from __future__ import annotations

import copy
import functools
import itertools
import json
import math
import zlib
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Type, Union

import numpy as np

from .executor import ExecutorJob
from .workload import (
    Arrival,
    ERCBENCH,
    KernelSpec,
    PARBOIL2_LIKE,
    TABLE3_RUNTIME,
    two_program_workloads,
)

#: The single scenario contract: named workloads, each a list of arrivals.
Workload = Tuple[str, List[Arrival]]

#: Default open-loop mix: every ERCBench kernel except SHA1 (whose 22M-cycle
#: solo runtime would dominate any stream) plus the short/medium
#: Parboil2-like kernels.
OPEN_LOOP_MIX: Tuple[str, ...] = (
    "AES-d", "AES-e", "JPEG-d", "JPEG-e", "RayTracing", "SAD",
    "ImageDenoising-nlm2", "SGEMM", "CUTCP", "HISTO",
)


def _spec_table(extra: Optional[Dict[str, KernelSpec]] = None
                ) -> Dict[str, KernelSpec]:
    table = dict(ERCBENCH)
    table.update(PARBOIL2_LIKE)
    if extra:
        table.update(extra)
    return table


class Scenario:
    """Base class: a seeded arrival-process generator.

    Subclasses implement :meth:`workloads`; all randomness must come from
    :meth:`rng` so that (params, seed) fully determine the output.
    """

    name = "base"

    def __init__(self, seed: int = 0):
        self.seed = seed

    def rng(self, *extra: int) -> np.random.Generator:
        """Process-stable RNG stream for this (scenario, seed[, extra])."""
        name_hash = zlib.crc32(self.name.encode()) % (2 ** 31)
        return np.random.default_rng(
            np.random.SeedSequence(entropy=(self.seed, name_hash, *extra)))

    def workloads(self) -> List[Workload]:
        raise NotImplementedError

    def reseeded(self, seed: int) -> "Scenario":
        """A copy of this scenario drawing from ``seed`` instead.

        Used by the sweep runner so one declarative spec can sweep arrival
        draws and simulation noise coherently across seeds.
        """
        import copy
        clone = copy.copy(self)
        clone.seed = seed
        return clone


#: Registry of scenario implementations, keyed by their public name.
SCENARIOS: Dict[str, Type[Scenario]] = {}


def register_scenario(name: str):
    """Class decorator registering a :class:`Scenario` under ``name``."""

    def decorate(cls: Type[Scenario]) -> Type[Scenario]:
        cls.name = name
        SCENARIOS[name] = cls
        return cls

    return decorate


def make_scenario(spec: Union[str, Scenario], **kwargs) -> Scenario:
    """Resolve ``spec`` into a scenario instance.

    ``spec`` may be an instance (returned as-is; kwargs then disallowed) or
    a registered name constructed with ``**kwargs``.
    """
    if isinstance(spec, Scenario):
        if kwargs:
            raise ValueError("kwargs are only valid with a scenario name")
        return spec
    try:
        cls = SCENARIOS[spec]
    except KeyError:
        raise ValueError(
            f"unknown scenario {spec!r}; choose from {sorted(SCENARIOS)}"
        ) from None
    return cls(**kwargs)


@register_scenario("pair-stagger")
class PairStagger(Scenario):
    """The paper's two-program ERCBench workloads (Section 6.1.3).

    Deterministic (no RNG): delegates to
    :func:`~repro.core.workload.two_program_workloads`, so the 56-pair
    sweep produced through the registry is byte-identical to the
    hard-coded one the golden traces were pinned against.
    """

    def __init__(self, seed: int = 0,
                 names: Optional[Sequence[str]] = None,
                 stagger_cycles: float = 100.0,
                 both_orders: bool = True):
        super().__init__(seed)
        self.names = list(names) if names is not None else None
        self.stagger_cycles = stagger_cycles
        self.both_orders = both_orders

    def workloads(self) -> List[Workload]:
        return two_program_workloads(
            names=self.names, stagger_cycles=self.stagger_cycles,
            both_orders=self.both_orders)


@register_scenario("table6-offset")
class Table6Offset(Scenario):
    """Table 6: second kernel arrives after ``offset_fraction`` of the first
    kernel's solo runtime.  ``solo`` maps kernel names to the solo runtimes
    the offsets are computed from (defaults to the paper's Table 3 values;
    the benchmarks pass the simulator-measured ones)."""

    def __init__(self, seed: int = 0,
                 offset_fraction: float = 0.25,
                 names: Optional[Sequence[str]] = None,
                 solo: Optional[Dict[str, float]] = None):
        super().__init__(seed)
        self.offset_fraction = offset_fraction
        self.names = sorted(names) if names is not None else sorted(ERCBENCH)
        self.solo = dict(solo) if solo is not None else dict(TABLE3_RUNTIME)

    @property
    def suffix(self) -> str:
        """Workload-name suffix — the one place the fraction is formatted
        (consumers filter cells with ``workload.endswith(scn.suffix)``)."""
        return f"@{int(round(self.offset_fraction * 100))}"

    def workloads(self) -> List[Workload]:
        out: List[Workload] = []
        for a, b in itertools.permutations(self.names, 2):
            offset = self.offset_fraction * self.solo[a]
            wl = [
                Arrival(ERCBENCH[a], 0.0, uid=f"{a}#0"),
                Arrival(ERCBENCH[b], offset, uid=f"{b}#1"),
            ]
            out.append((f"{a}+{b}{self.suffix}", wl))
        return out


class _MixScenario(Scenario):
    """Shared machinery for scenarios drawing kernels from a named mix."""

    def __init__(self, seed: int = 0,
                 names: Sequence[str] = OPEN_LOOP_MIX,
                 specs: Optional[Dict[str, KernelSpec]] = None):
        super().__init__(seed)
        self.names = list(names)
        self.specs = _spec_table(specs)
        missing = [n for n in self.names if n not in self.specs]
        if missing:
            raise ValueError(f"unknown kernels in mix: {missing}")

    def _pick(self, rng: np.random.Generator) -> KernelSpec:
        return self.specs[self.names[int(rng.integers(len(self.names)))]]

    @staticmethod
    def _build(arrivals: List[Tuple[KernelSpec, float]]) -> List[Arrival]:
        return [Arrival(spec, t, uid=f"{spec.name}#{i}")
                for i, (spec, t) in enumerate(arrivals)]


@register_scenario("poisson-open")
class PoissonOpen(Scenario):
    """Open-loop Poisson kernel stream over an ERCBench/Parboil2-like mix.

    Shared-cloud style (Kernelet): kernels arrive regardless of machine
    state with exponential inter-arrival times of mean
    ``mean_interarrival`` cycles.  With ``n_workloads`` > 1 each workload
    is an independent draw of the same process.
    """

    def __init__(self, seed: int = 0,
                 names: Sequence[str] = OPEN_LOOP_MIX,
                 specs: Optional[Dict[str, KernelSpec]] = None,
                 n_arrivals: int = 8,
                 mean_interarrival: float = 100_000.0,
                 n_workloads: int = 2):
        self._mix = _MixScenario(seed, names, specs)
        super().__init__(seed)
        self.n_arrivals = n_arrivals
        self.mean_interarrival = mean_interarrival
        self.n_workloads = n_workloads

    def workloads(self) -> List[Workload]:
        out: List[Workload] = []
        for w in range(self.n_workloads):
            rng = self.rng(w)
            t = 0.0
            draws: List[Tuple[KernelSpec, float]] = []
            for _ in range(self.n_arrivals):
                draws.append((self._mix._pick(rng), t))
                t += float(rng.exponential(self.mean_interarrival))
            out.append((f"poisson{w}", self._mix._build(draws)))
        return out


def fit_bursty_profile(times: Sequence[float],
                       threshold: Optional[float] = None) -> Dict[str, float]:
    """Fit :class:`Bursty` parameters from observed arrival times (the
    bursty counterpart of :func:`fit_diurnal_profile`).

    Arrivals are split into bursts at gaps larger than ``threshold``.
    With ``threshold=None`` the split point is found by Otsu's method on
    the log-gaps (the split maximizing between-class variance): the
    within-burst and idle gaps are exponentials separated by orders of
    magnitude, so they form two log-space clusters and the variance
    criterion finds the valley deterministically.  Fitted values:

    * ``n_bursts`` / ``max_burst`` — observed burst count and largest
      burst size;
    * ``within_gap`` — mean intra-burst gap (0.0 when every burst has one
      arrival — nothing to calibrate);
    * ``idle_gap`` — mean inter-burst gap *minus* ``within_gap``: the
      generator draws ``Exp(within_gap) + Exp(idle_gap)`` between bursts,
      so the observed separation over-counts by one within-draw (clamped
      at 0; 0.0 when there is a single burst);
    * ``burst_alpha`` — continuous-Pareto MLE on cell midpoints
      (``alpha = n / sum(ln(size + 0.5))``; the ``max_burst`` censoring
      is ignored — adequate for the loose shapes scenarios need);
    * ``threshold`` — the split actually used.

    Raises :class:`ValueError` on degenerate input (no arrivals, negative
    times, a non-positive explicit threshold).
    """
    times = sorted(float(t) for t in times)
    if not times:
        raise ValueError("cannot fit a bursty profile to zero arrivals")
    if times[0] < 0.0:
        raise ValueError("negative arrival time in trace")
    gaps = [b - a for a, b in zip(times, times[1:])]
    if threshold is None:
        positive = sorted(g for g in gaps if g > 0.0)
        if len(positive) >= 2:
            logs = [math.log(g) for g in positive]
            # Otsu in one pass over the sorted logs: split after index k
            # maximizing w0*w1*(mu0-mu1)^2 (between-class variance).
            total = sum(logs)
            n = len(logs)
            acc = 0.0
            best_score, best_k = -1.0, 0
            for k in range(n - 1):
                acc += logs[k]
                w0 = k + 1
                w1 = n - w0
                mu0 = acc / w0
                mu1 = (total - acc) / w1
                score = w0 * w1 * (mu0 - mu1) ** 2
                if score > best_score:
                    best_score, best_k = score, k
            threshold = math.sqrt(positive[best_k] * positive[best_k + 1])
        elif positive:
            threshold = positive[0]
        else:
            threshold = 0.0
    elif threshold <= 0.0:
        raise ValueError("threshold must be positive")
    sizes = [1]
    intra: List[float] = []
    inter: List[float] = []
    for g in gaps:
        if g <= threshold:
            sizes[-1] += 1
            intra.append(g)
        else:
            sizes.append(1)
            inter.append(g)
    within = sum(intra) / len(intra) if intra else 0.0
    idle = max(0.0, sum(inter) / len(inter) - within) if inter else 0.0
    alpha = len(sizes) / sum(math.log(s + 0.5) for s in sizes)
    return {
        "n_bursts": len(sizes),
        "burst_alpha": alpha,
        "max_burst": max(sizes),
        "within_gap": within,
        "idle_gap": idle,
        "threshold": threshold,
    }


@register_scenario("bursty")
class Bursty(Scenario):
    """Heavy-tail ON/OFF arrival bursts (bursty DL inference traffic).

    Each burst holds ``1 + floor(Pareto(alpha))`` kernels (capped at
    ``max_burst``) spaced ``Exp(within_gap)`` apart; bursts are separated
    by ``Exp(idle_gap)`` quiet periods.  Use :meth:`from_trace` /
    :func:`fit_bursty_profile` to calibrate the burst-size and gap
    parameters from a ``trace-replay`` JSON, the way ``diurnal`` fits its
    rate profile.
    """

    def __init__(self, seed: int = 0,
                 names: Sequence[str] = OPEN_LOOP_MIX,
                 specs: Optional[Dict[str, KernelSpec]] = None,
                 n_bursts: int = 3,
                 burst_alpha: float = 1.5,
                 max_burst: int = 6,
                 within_gap: float = 1_000.0,
                 idle_gap: float = 500_000.0,
                 n_workloads: int = 2):
        self._mix = _MixScenario(seed, names, specs)
        super().__init__(seed)
        self.n_bursts = n_bursts
        self.burst_alpha = burst_alpha
        self.max_burst = max_burst
        self.within_gap = within_gap
        self.idle_gap = idle_gap
        self.n_workloads = n_workloads

    @classmethod
    def from_trace(cls, path: Optional[Union[str, Path]] = None,
                   trace: Optional[Union[list, dict]] = None,
                   threshold: Optional[float] = None,
                   **kwargs) -> "Bursty":
        """Calibrate burst-size/gap parameters from a ``trace-replay``-
        shaped JSON (first workload's arrival times); see
        :func:`fit_bursty_profile` for the fit itself."""
        replay = TraceReplay(path=path, trace=trace,
                             specs=kwargs.get("specs"))
        workloads = replay.workloads()
        if not workloads or not workloads[0][1]:
            raise ValueError("trace holds no arrivals to calibrate from")
        profile = fit_bursty_profile(
            [a.time for a in workloads[0][1]], threshold=threshold)
        return cls(n_bursts=profile["n_bursts"],
                   burst_alpha=profile["burst_alpha"],
                   max_burst=profile["max_burst"],
                   within_gap=profile["within_gap"],
                   idle_gap=profile["idle_gap"], **kwargs)

    def workloads(self) -> List[Workload]:
        out: List[Workload] = []
        for w in range(self.n_workloads):
            rng = self.rng(w)
            t = 0.0
            draws: List[Tuple[KernelSpec, float]] = []
            for _ in range(self.n_bursts):
                size = min(self.max_burst,
                           1 + int(rng.pareto(self.burst_alpha)))
                for _ in range(size):
                    draws.append((self._mix._pick(rng), t))
                    t += float(rng.exponential(self.within_gap))
                t += float(rng.exponential(self.idle_gap))
            out.append((f"bursty{w}", self._mix._build(draws)))
        return out


@register_scenario("nprogram-mix")
class NProgramMix(Scenario):
    """Random closed N-program workloads (N > 2): every kernel arrives
    within the first ``max_stagger`` cycles, generalizing the paper's
    two-program staggered launches to wider co-run sets."""

    def __init__(self, seed: int = 0,
                 names: Sequence[str] = OPEN_LOOP_MIX,
                 specs: Optional[Dict[str, KernelSpec]] = None,
                 n_programs: int = 4,
                 max_stagger: float = 100.0,
                 n_workloads: int = 4):
        if n_programs < 2:
            raise ValueError("nprogram-mix needs n_programs >= 2")
        self._mix = _MixScenario(seed, names, specs)
        super().__init__(seed)
        self.n_programs = n_programs
        self.max_stagger = max_stagger
        self.n_workloads = n_workloads

    def workloads(self) -> List[Workload]:
        out: List[Workload] = []
        for w in range(self.n_workloads):
            rng = self.rng(w)
            draws = [(self._mix._pick(rng),
                      0.0 if i == 0 else
                      float(rng.uniform(0.0, self.max_stagger)))
                     for i in range(self.n_programs)]
            draws.sort(key=lambda d: d[1])
            out.append((f"mix{w}x{self.n_programs}", self._mix._build(draws)))
        return out


@register_scenario("trace-replay")
class TraceReplay(Scenario):
    """Replay arrivals from a JSON trace (production traces, hermetic tests).

    Accepts either ``path`` to a JSON file or an in-memory ``trace``.
    Two shapes are understood::

        [{"kernel": "JPEG-d", "time": 0.0}, ...]                # one workload
        {"workloads": [{"name": "w0", "arrivals": [...]}, ...]} # several

    Kernel names resolve against ERCBench + Parboil2-like specs plus any
    caller-supplied ``specs``.  Deterministic by construction (no RNG).
    """

    def __init__(self, seed: int = 0,
                 path: Optional[Union[str, Path]] = None,
                 trace: Optional[Union[list, dict]] = None,
                 specs: Optional[Dict[str, KernelSpec]] = None,
                 name: str = "trace"):
        super().__init__(seed)
        if (path is None) == (trace is None):
            raise ValueError("trace-replay needs exactly one of path/trace")
        self.path = str(path) if path is not None else None
        self.trace = trace
        self.specs = _spec_table(specs)
        self.workload_name = name

    def _events(self) -> Union[list, dict]:
        if self.path is not None:
            return json.loads(Path(self.path).read_text())
        return self.trace

    def _arrivals(self, events: Sequence[dict]) -> List[Arrival]:
        out = []
        for i, ev in enumerate(events):
            kernel = ev["kernel"]
            try:
                spec = self.specs[kernel]
            except KeyError:
                raise ValueError(
                    f"trace kernel {kernel!r} not in spec table") from None
            out.append(Arrival(spec, float(ev.get("time", 0.0)),
                               uid=ev.get("uid", f"{kernel}#{i}")))
        return sorted(out, key=lambda a: a.time)

    def workloads(self) -> List[Workload]:
        data = self._events()
        if isinstance(data, dict):
            return [(wl.get("name", f"{self.workload_name}{i}"),
                     self._arrivals(wl["arrivals"]))
                    for i, wl in enumerate(data["workloads"])]
        return [(self.workload_name, self._arrivals(data))]


# ----------------------------------------------------------------- diurnal
#: Named day/night rate profile: relative arrival rate per segment of the
#: repeating day (trough -> ramp -> sustained peak -> evening falloff).
DAY_NIGHT_PROFILE: Tuple[float, ...] = (
    0.15, 0.3, 0.7, 1.0, 1.0, 0.8, 0.5, 0.25)


def fit_diurnal_profile(times: Sequence[float], n_segments: int,
                        period: float) -> Tuple[Tuple[float, ...], float]:
    """Fit a :class:`Diurnal` ``(profile, peak_interarrival)`` from
    observed arrival times (e.g. a production ``trace-replay`` JSON).

    Arrival times are binned by ``time mod period`` into ``n_segments``
    equal segments over an observation span rounded up to whole periods;
    per-segment rates are normalized so the peak segment has relative rate
    1.0, and ``peak_interarrival`` is the peak segment's mean interarrival
    gap.  Raises :class:`ValueError` on degenerate input (no arrivals,
    non-positive period, fewer than one segment).
    """
    times = sorted(float(t) for t in times)
    if not times:
        raise ValueError("cannot fit a diurnal profile to zero arrivals")
    if times[0] < 0.0:
        raise ValueError("negative arrival time in trace")
    if period <= 0.0 or n_segments < 1:
        raise ValueError("need period > 0 and n_segments >= 1")
    # Observation span rounded up to whole periods; the epsilon keeps a
    # span that is an exact multiple of the period (e.g. from_trace's
    # default period == max(times)) from counting a phantom extra period,
    # which would halve every fitted rate.
    n_periods = max(1, math.ceil(times[-1] / period - 1e-9))
    segment = period / n_segments
    counts = [0] * n_segments
    for t in times:
        rem = t % period
        if rem == 0.0 and t > 0.0:
            # An arrival at an exact period multiple closes the previous
            # period (from_trace's default period == max(times) puts the
            # last arrival here); binning it into segment 0 would inflate
            # the first segment's rate.
            counts[n_segments - 1] += 1
        else:
            counts[min(n_segments - 1, int(rem / segment))] += 1
    observed_per_segment = n_periods * segment
    rates = [c / observed_per_segment for c in counts]
    peak = max(rates)
    # times is non-empty, so at least one bin counted and peak > 0
    return tuple(r / peak for r in rates), 1.0 / peak


@register_scenario("diurnal")
class Diurnal(Scenario):
    """Piecewise-rate (non-homogeneous) Poisson stream: the day/night load
    shape real clusters see.

    The rate over a repeating day of ``len(profile)`` segments of
    ``segment`` cycles each is ``profile[j] / peak_interarrival`` —
    ``profile`` holds *relative* rates (peak 1.0), ``peak_interarrival``
    the mean gap at peak.  Arrivals are drawn by cumulative-hazard
    inversion (unit-rate exponentials mapped through the piecewise-linear
    integrated rate), so zero-rate segments are skipped exactly.  Use
    :meth:`from_trace` / :func:`fit_diurnal_profile` to calibrate the
    profile from a ``trace-replay`` JSON.
    """

    def __init__(self, seed: int = 0,
                 names: Sequence[str] = OPEN_LOOP_MIX,
                 specs: Optional[Dict[str, KernelSpec]] = None,
                 n_arrivals: int = 12,
                 peak_interarrival: float = 40_000.0,
                 profile: Sequence[float] = DAY_NIGHT_PROFILE,
                 segment: float = 150_000.0,
                 n_workloads: int = 2):
        self._mix = _MixScenario(seed, names, specs)
        super().__init__(seed)
        self.profile = tuple(float(r) for r in profile)
        if not self.profile or min(self.profile) < 0.0 \
                or max(self.profile) <= 0.0:
            raise ValueError(
                "profile needs >= 1 non-negative relative rates, peak > 0")
        if peak_interarrival <= 0.0 or segment <= 0.0:
            raise ValueError("peak_interarrival and segment must be > 0")
        self.n_arrivals = n_arrivals
        self.peak_interarrival = peak_interarrival
        self.segment = segment
        self.n_workloads = n_workloads

    @classmethod
    def from_trace(cls, path: Optional[Union[str, Path]] = None,
                   trace: Optional[Union[list, dict]] = None,
                   n_segments: int = 8, period: Optional[float] = None,
                   **kwargs) -> "Diurnal":
        """Calibrate ``profile``/``peak_interarrival``/``segment`` from a
        ``trace-replay``-shaped JSON (first workload's arrival times).
        ``period`` defaults to the trace's observed span."""
        replay = TraceReplay(path=path, trace=trace,
                             specs=kwargs.get("specs"))
        workloads = replay.workloads()
        if not workloads or not workloads[0][1]:
            raise ValueError("trace holds no arrivals to calibrate from")
        times = [a.time for a in workloads[0][1]]
        if period is None:
            period = max(times) if max(times) > 0.0 else 1.0
        profile, peak = fit_diurnal_profile(times, n_segments, period)
        return cls(profile=profile, peak_interarrival=peak,
                   segment=period / n_segments, **kwargs)

    def _hazard_per_segment(self) -> List[float]:
        """Integrated rate (expected arrivals) of each segment."""
        return [r * self.segment / self.peak_interarrival
                for r in self.profile]

    def _invert(self, cum_hazard: float) -> float:
        """Arrival time whose integrated rate equals ``cum_hazard``."""
        seg_hazard = self._hazard_per_segment()
        per_period = sum(seg_hazard)
        period = self.segment * len(self.profile)
        k, rem = divmod(cum_hazard, per_period)
        t = k * period
        for j, h in enumerate(seg_hazard):
            if rem < h:  # lands inside segment j (rate > 0 since h > rem >= 0)
                return t + j * self.segment \
                    + rem * self.peak_interarrival / self.profile[j]
            rem -= h
        # rem == per_period boundary rounding: start of the next period
        return t + period

    def workloads(self) -> List[Workload]:
        out: List[Workload] = []
        for w in range(self.n_workloads):
            rng = self.rng(w)
            hazard = 0.0
            draws: List[Tuple[KernelSpec, float]] = []
            for _ in range(self.n_arrivals):
                draws.append((self._mix._pick(rng), self._invert(hazard)))
                hazard += float(rng.exponential(1.0))
            out.append((f"diurnal{w}", self._mix._build(draws)))
        return out


# ------------------------------------------------------- closed-loop tier
class ArrivalProcess:
    """Base class for completion-driven arrival generators.

    Implements the :class:`repro.core.events.ArrivalSource` machine
    contract: :meth:`initial` is called once at attach time,
    :meth:`on_completion` once per natural kernel completion.  A process
    is **stateful and single-use** — one machine run consumes one process;
    build a fresh one per run via
    :meth:`ClosedLoopScenario.make_process`.  Times are in scenario cycles
    (machines with other clocks convert — see
    :meth:`repro.core.machine.MachineBase.attach_arrival_source`).
    """

    def initial(self) -> List[Arrival]:
        raise NotImplementedError

    def on_completion(self, key: str, now: float) -> List[Arrival]:
        raise NotImplementedError


class ClosedLoopScenario(Scenario):
    """Tier-2 scenario contract: named, seeded arrival *processes*.

    Closed-loop scenarios cannot materialize ``workloads()`` — the arrival
    sequence depends on the machine's completions, which depend on the
    policy under test (that coupling is the point).  Instead they expose:

    * :meth:`process_names` — the workload names of the sweep grid,
    * :meth:`make_process`  — a fresh single-use :class:`ArrivalProcess`
      per (workload, run), seeded from (scenario seed, workload index),
    * :meth:`mix_specs`     — every kernel spec the process may emit
      (the sweep runner measures solo oracles from it up front),
    * :meth:`process_params` — the canonical parameter payload the sweep
      cache digests in place of a materialized arrival list.
    """

    def workloads(self) -> List[Workload]:
        raise TypeError(
            f"{self.name!r} is a closed-loop scenario: arrivals are "
            "completion-driven and cannot be materialized up front; use "
            "process_names()/make_process() (or run it through "
            "repro.core.sweep.run_sweep)")

    def process_names(self) -> List[str]:
        raise NotImplementedError

    def make_process(self, name: str) -> ArrivalProcess:
        raise NotImplementedError

    def mix_specs(self) -> Dict[str, KernelSpec]:
        raise NotImplementedError

    def process_params(self) -> dict:
        """Canonical cache-key payload: class + every draw-determining
        parameter + the full content of every spec the process may emit.
        The sweep seed is *not* included — the cell key carries it."""
        import dataclasses
        return {
            "scenario": self.name,
            "class": type(self).__name__,
            "params": self._params(),
            "specs": {n: dataclasses.asdict(s)
                      for n, s in sorted(self.mix_specs().items())},
        }

    def _params(self) -> dict:
        """Draw-determining parameters (primitives only); subclass hook
        for :meth:`process_params`."""
        raise NotImplementedError

    def _process_rng(self, name: str) -> np.random.Generator:
        """Per-(scenario, seed, workload) RNG stream for a fresh process."""
        names = self.process_names()
        try:
            index = names.index(name)
        except ValueError:
            raise ValueError(
                f"unknown workload {name!r}; choose from {names}") from None
        return self.rng(index)


class _MGkProcess(ArrivalProcess):
    """Bounded-population window over a pre-drawn offered Poisson stream.

    The offered stream (arrival gaps + kernel picks) is drawn up front, so
    the *demand* is identical across policies — only admission timing
    reacts to completions.  At most ``population`` released-but-unfinished
    kernels exist at any time; on each completion the next offered arrival
    is released at ``max(offered time, now)`` (``admission="defer"``) or
    offered arrivals whose time passed while the system was full are
    rejected and counted in :attr:`dropped` (``admission="drop"``).
    """

    def __init__(self, offered: List[Tuple[KernelSpec, float]],
                 population: int, admission: str):
        self._offered = offered
        self._population = population
        self._admission = admission
        self._next = 0
        self._in_system = 0
        self._live: set = set()   # uids this process emitted, unfinished
        #: Offered arrivals rejected by the admission cap (drop mode).
        self.dropped = 0

    def _release(self, at: Optional[float] = None) -> Arrival:
        spec, time = self._offered[self._next]
        uid = f"{spec.name}#{self._next}"
        self._next += 1
        self._in_system += 1
        self._live.add(uid)
        return Arrival(spec, time if at is None else max(time, at), uid=uid)

    def initial(self) -> List[Arrival]:
        out = []
        while self._next < len(self._offered) \
                and self._in_system < self._population:
            out.append(self._release())
        return out

    def on_completion(self, key: str, now: float) -> List[Arrival]:
        if key not in self._live:
            # The machine reports every natural completion; static
            # arrivals it was constructed with are not ours and must not
            # corrupt the population accounting.
            return []
        self._live.discard(key)
        self._in_system -= 1
        if self._admission == "drop":
            # Loss system: offered arrivals whose time passed while the
            # system was full found it full — reject them.
            while self._next < len(self._offered) \
                    and self._offered[self._next][1] < now:
                self._next += 1
                self.dropped += 1
        out = []
        while self._next < len(self._offered) \
                and self._in_system < self._population:
            out.append(self._release(at=now))
        return out

    # In-engine lowering (consumed by FastSimulator).  The offered stream
    # is pre-drawn, so "defer" admission is a pure function of completion
    # order: the j-th in-engine release is offered arrival _next + j.
    # "drop" admission depends on wall-clock `now` vs the offered times in
    # a way the engine doesn't model (dropped counting) — not lowered.
    def engine_stage(self, limit: int) -> Optional[dict]:
        if self._admission != "defer":
            return None
        end = min(len(self._offered), self._next + limit)
        specs = []
        times = []
        uids = []
        for j in range(self._next, end):
            spec, time = self._offered[j]
            specs.append(spec)
            times.append(time)
            uids.append(f"{spec.name}#{j}")
        return {
            "mode": "mgk", "specs": specs, "times": times, "uids": uids,
            "more": end < len(self._offered),
            "in_system": self._in_system,
            "population": self._population,
            "live": frozenset(self._live),
        }

    def engine_commit(self, consumed: int, in_system: int,
                      live: Sequence[str]) -> None:
        self._next += consumed
        self._in_system = in_system
        self._live = set(live)


@register_scenario("mgk-closed")
class MGkClosed(ClosedLoopScenario):
    """M/G/k-style offered load with a bounded population (closed loop).

    ``n_total`` offered arrivals per workload with mean gap
    ``mean_interarrival`` (the offered load), drawn from the kernel mix; at
    most ``population`` kernels in the system.  ``admission="defer"``
    queues excess offered arrivals until a completion frees a slot —
    sustained backpressure; ``admission="drop"`` is the admission-capped
    variant: arrivals that find the system full are rejected (the process
    counts them in ``dropped``).  Each of ``n_workloads`` workloads is an
    independent draw of the same offered process.
    """

    def __init__(self, seed: int = 0,
                 names: Sequence[str] = OPEN_LOOP_MIX,
                 specs: Optional[Dict[str, KernelSpec]] = None,
                 n_total: int = 12,
                 mean_interarrival: float = 50_000.0,
                 population: int = 4,
                 admission: str = "defer",
                 n_workloads: int = 1,
                 tag: str = ""):
        self._mix = _MixScenario(seed, names, specs)
        super().__init__(seed)
        if population < 1:
            raise ValueError("mgk-closed needs population >= 1")
        if admission not in ("defer", "drop"):
            raise ValueError(
                f"unknown admission {admission!r}; choose defer or drop")
        self.n_total = n_total
        self.mean_interarrival = mean_interarrival
        self.population = population
        self.admission = admission
        self.n_workloads = n_workloads
        #: Optional label folded into workload names (e.g. one tag per
        #: offered-load point, so load-sweep cells stay distinguishable).
        self.tag = tag

    def _params(self) -> dict:
        return {
            "names": list(self._mix.names), "n_total": self.n_total,
            "mean_interarrival": self.mean_interarrival,
            "population": self.population, "admission": self.admission,
            "n_workloads": self.n_workloads, "tag": self.tag,
        }

    def process_names(self) -> List[str]:
        prefix = f"mgk{self.tag}" if self.tag else "mgk"
        return [f"{prefix}.{w}" for w in range(self.n_workloads)]

    def mix_specs(self) -> Dict[str, KernelSpec]:
        return {n: self._mix.specs[n] for n in self._mix.names}

    def make_process(self, name: str) -> _MGkProcess:
        rng = self._process_rng(name)
        t = 0.0
        offered: List[Tuple[KernelSpec, float]] = []
        for _ in range(self.n_total):
            offered.append((self._mix._pick(rng), t))
            t += float(rng.exponential(self.mean_interarrival))
        return _MGkProcess(offered, self.population, self.admission)


class _ThinkTimeProcess(ArrivalProcess):
    """N tenants, each looping submit -> await completion -> think."""

    def __init__(self, rng: np.random.Generator, picks, mean_think: float,
                 n_tenants: int, n_rounds: int):
        self._rng = rng
        self._pick = picks
        self._mean_think = mean_think
        self._n_tenants = n_tenants
        self._n_rounds = n_rounds
        self._tenant_of: Dict[str, int] = {}
        self._rounds_done = [0] * n_tenants
        self._seq = 0

    def _submit(self, tenant: int, at: float) -> Arrival:
        spec = self._pick(self._rng)
        uid = f"{spec.name}#{self._seq}"
        self._seq += 1
        self._tenant_of[uid] = tenant
        self._rounds_done[tenant] += 1
        return Arrival(spec, at, uid=uid)

    def initial(self) -> List[Arrival]:
        # Each tenant thinks once before its first submission, so tenants
        # de-synchronize exactly like they do between rounds.
        return [
            self._submit(i, float(self._rng.exponential(self._mean_think)))
            for i in range(self._n_tenants)
        ]

    def on_completion(self, key: str, now: float) -> List[Arrival]:
        tenant = self._tenant_of.pop(key, None)
        if tenant is None or self._rounds_done[tenant] >= self._n_rounds:
            return []
        think = float(self._rng.exponential(self._mean_think))
        return [self._submit(tenant, now + think)]

    # In-engine lowering (consumed by FastSimulator).  Each resubmission
    # consumes one (think draw, spec pick) pair from the shared RNG in
    # completion order regardless of WHICH tenant completed, so the k-th
    # future pair is pre-drawable on a copy of the RNG; only its tenant
    # binding is decided in-engine.  `engine_commit` replays the consumed
    # draws on the real RNG so python and engine streams stay aligned.
    def engine_stage(self, limit: int) -> Optional[dict]:
        total = 0
        for done in self._rounds_done:
            if done < self._n_rounds:
                total += self._n_rounds - done
        n = min(total, limit)
        rng = copy.deepcopy(self._rng)
        specs = []
        delays = []
        uids = []
        for k in range(n):
            # Draw order matches on_completion -> _submit exactly.
            think = float(rng.exponential(self._mean_think))
            spec = self._pick(rng)
            specs.append(spec)
            delays.append(think)
            uids.append(f"{spec.name}#{self._seq + k}")
        return {
            "mode": "think", "specs": specs, "delays": delays,
            "uids": uids, "more": total > n,
            "n_rounds": self._n_rounds,
            "rounds_done": list(self._rounds_done),
            "tenants": dict(self._tenant_of),
        }

    def engine_commit(self, consumed: int, rounds_done: Sequence[int],
                      tenants: Dict[str, int]) -> None:
        for _ in range(consumed):
            self._rng.exponential(self._mean_think)
            self._pick(self._rng)
        self._seq += consumed
        self._rounds_done = list(rounds_done)
        self._tenant_of = dict(tenants)


@register_scenario("think-time")
class ThinkTime(ClosedLoopScenario):
    """Interactive-tenant loop (closed loop): each of ``n_tenants``
    tenants resubmits a fresh kernel from the mix ``think ~
    Exp(mean_think)`` cycles after its previous kernel finishes, for
    ``n_rounds`` rounds.  Offered load tracks service capacity by
    construction — the canonical closed queueing loop."""

    def __init__(self, seed: int = 0,
                 names: Sequence[str] = OPEN_LOOP_MIX,
                 specs: Optional[Dict[str, KernelSpec]] = None,
                 n_tenants: int = 3,
                 mean_think: float = 20_000.0,
                 n_rounds: int = 4,
                 n_workloads: int = 1):
        self._mix = _MixScenario(seed, names, specs)
        super().__init__(seed)
        if n_tenants < 1 or n_rounds < 1:
            raise ValueError("think-time needs n_tenants, n_rounds >= 1")
        self.n_tenants = n_tenants
        self.mean_think = mean_think
        self.n_rounds = n_rounds
        self.n_workloads = n_workloads

    def _params(self) -> dict:
        return {
            "names": list(self._mix.names), "n_tenants": self.n_tenants,
            "mean_think": self.mean_think, "n_rounds": self.n_rounds,
            "n_workloads": self.n_workloads,
        }

    def process_names(self) -> List[str]:
        return [f"think.{w}" for w in range(self.n_workloads)]

    def mix_specs(self) -> Dict[str, KernelSpec]:
        return {n: self._mix.specs[n] for n in self._mix.names}

    def make_process(self, name: str) -> _ThinkTimeProcess:
        return _ThinkTimeProcess(
            self._process_rng(name), self._mix._pick,
            self.mean_think, self.n_tenants, self.n_rounds)


def open_loop_names() -> Tuple[str, ...]:
    """Registered scenario names whose ``workloads()`` materializes (the
    CLI frontends that pace fixed submission streams filter on this)."""
    return tuple(sorted(
        name for name, cls in SCENARIOS.items()
        if not issubclass(cls, ClosedLoopScenario)))


# ------------------------------------------------------- executor bridge
#: Seconds of executor (lane) time per scenario cycle.  Chosen so that the
#: cycle-scale arrival gaps the scenarios emit (hundreds to a few thousand
#: cycles) land in the same regime as real measured block durations
#: (fractions of a millisecond on this container).
DEFAULT_EXECUTOR_TIME_SCALE = 1e-6


def _synthetic_shape(spec: KernelSpec) -> Tuple[int, int]:
    """Deterministic (matrix dim, repeat count) for one kernel spec.

    The dim follows the grid's per-block parallelism (``threads_per_block``)
    and the repeat count the block-duration scale (``mean_t``), so distinct
    specs get distinct real costs and the SJF/SRTF orderings over synthetic
    jobs remain meaningful.
    """
    dim = max(16, min(128, int(spec.threads_per_block)))
    reps = max(1, min(6, int(math.log10(max(float(spec.mean_t), 10.0)))))
    return dim, reps


@functools.lru_cache(maxsize=None)
def _jitted_block(dim: int, reps: int):
    """One jit-compiled synthetic block body, shared by every job with the
    same shape (compiles once per process)."""
    import jax
    import jax.numpy as jnp

    x0 = jnp.linspace(-1.0, 1.0, dim * dim).reshape(dim, dim)

    @jax.jit
    def step(x):
        for _ in range(reps):
            x = jnp.tanh(x @ x) + 0.5 * x
        return x

    return step, x0


def executor_job(arrival: Arrival, *, n_lanes: int = 4,
                 time_scale: float = DEFAULT_EXECUTOR_TIME_SCALE
                 ) -> ExecutorJob:
    """Map one scenario :class:`~repro.core.workload.Arrival` to a
    schedulable :class:`~repro.core.executor.ExecutorJob`.

    The job keeps the scenario's declared grid (``num_blocks``, residency
    capped at the lane count) and arrival time (cycles scaled to seconds by
    ``time_scale``); each block is a REAL jit-compiled computation whose
    cost is a deterministic function of the spec
    (:func:`_synthetic_shape`), so executor sweeps measure actual JAX
    dispatch/compute behavior at scenario-declared sizes.
    """
    spec = arrival.spec
    dim, reps = _synthetic_shape(spec)

    def warmup():
        import jax
        step, x0 = _jitted_block(dim, reps)
        jax.block_until_ready(step(x0))   # compile only; discard result

    def make_block_fn(residency: int):
        import jax
        step, x0 = _jitted_block(dim, reps)

        def block():
            jax.block_until_ready(step(x0))

        return block

    return ExecutorJob(
        name=spec.name, num_blocks=spec.num_blocks,
        max_residency=min(spec.max_residency, n_lanes),
        make_block_fn=make_block_fn,
        arrival=arrival.time * time_scale,
        est_block_seconds=float(spec.mean_t),   # SJF fallback ordering only
        warmup_fn=warmup)


def executor_workload(arrivals: Sequence[Arrival], *, n_lanes: int = 4,
                      time_scale: float = DEFAULT_EXECUTOR_TIME_SCALE
                      ) -> List[Tuple[str, ExecutorJob]]:
    """Bridge one scenario workload to ``(key, job)`` pairs.

    Keys are the scenario's arrival uids (``{name}#{i}``) so executor cells
    carry the same kernel keys as DES cells of the same workload; pass each
    pair to :meth:`~repro.core.executor.LaneExecutor.add_job` as
    ``add_job(job, key=key)``.
    """
    return [(a.key, executor_job(a, n_lanes=n_lanes, time_scale=time_scale))
            for a in arrivals]


# --------------------------------------------------------------- utilities
def workload_digest(arrivals: Sequence[Arrival]) -> str:
    """Content digest of one arrival list (the sweep-cache workload key).

    Covers every :class:`KernelSpec` field plus arrival times and uids, so
    any change to the workload's content changes the digest.
    """
    import dataclasses
    import hashlib

    payload = [
        {"spec": dataclasses.asdict(a.spec), "time": a.time, "uid": a.uid}
        for a in arrivals
    ]
    # allow_nan=False: a NaN spec field would otherwise serialize as the
    # non-standard NaN token — and NaN != NaN, so two identical workloads
    # could digest differently.  Loud failure beats a poisoned cache key.
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)
    return hashlib.sha256(blob.encode()).hexdigest()


def submission_offsets(scenario: Union[str, Scenario], n: int,
                       time_scale: float = 1.0, **kwargs) -> List[float]:
    """First-workload arrival times as ``n`` submission offsets.

    The serving/dryrun frontends use this to pace real job submissions from
    a scenario's arrival process: offsets are the scenario's first
    workload's arrival times scaled by ``time_scale`` (e.g. cycles ->
    seconds).  If the workload holds fewer than ``n`` arrivals the stream
    is extended at the mean observed gap.
    """
    scn = make_scenario(scenario, **kwargs)
    workloads = scn.workloads()
    if not workloads:
        raise ValueError(f"scenario {scn.name!r} produced no workloads")
    times = sorted(a.time for a in workloads[0][1])
    if not times:
        raise ValueError(f"scenario {scn.name!r} produced an empty workload")
    gaps = [b - a for a, b in zip(times, times[1:])]
    mean_gap = (sum(gaps) / len(gaps)) if gaps else 0.0
    while len(times) < n:
        times.append(times[-1] + mean_gap)
    return [t * time_scale for t in times[:n]]


__all__ = [
    "ArrivalProcess",
    "Bursty",
    "ClosedLoopScenario",
    "DAY_NIGHT_PROFILE",
    "DEFAULT_EXECUTOR_TIME_SCALE",
    "Diurnal",
    "MGkClosed",
    "NProgramMix",
    "OPEN_LOOP_MIX",
    "executor_job",
    "executor_workload",
    "fit_bursty_profile",
    "fit_diurnal_profile",
    "open_loop_names",
    "PairStagger",
    "PoissonOpen",
    "SCENARIOS",
    "Scenario",
    "Table6Offset",
    "ThinkTime",
    "TraceReplay",
    "Workload",
    "make_scenario",
    "register_scenario",
    "submission_offsets",
    "workload_digest",
]
