"""The paper's primary contribution: structural runtime prediction and
preemptive thread-block-style scheduling for concurrent workloads.

Backend-independent core:

* :mod:`repro.core.machine`   — the formal ``Machine`` protocol (the read
  surface policies/predictors may touch) and the ``SchedulerCore`` (one
  policy + one predictor) that drives any machine implementing it.
* :mod:`repro.core.events`    — typed machine events (``KernelArrived`` /
  ``BlockStarted`` / ``BlockEnded`` / ``KernelEnded``) and scheduling
  decisions (``IssueGrant`` / ``SampleOnSM`` / ``Hold`` /
  ``PreemptAtBoundary``).
* :mod:`repro.core.predictor` — Staircase model (Eq. 1), the ``Predictor``
  interface + registry, Simple Slicing (Table 1 / Algorithm 1 / Eq. 2) and
  the EWMA baseline.
* :mod:`repro.core.policies`  — FIFO, SJF, LJF, JIT-MPMax, SRTF,
  SRTF/Adaptive, all written against the ``Machine`` protocol.
* :mod:`repro.core.simulator` — discrete-event multi-SM GPU simulator
  (the GPGPU-Sim analogue used to reproduce the paper's evaluation).
* :mod:`repro.core.executor`  — real-JAX lane executor: the same scheduler
  driving actual ``train_step`` / ``serve_step`` computations (TPU pod
  adaptation; see DESIGN.md Section 2).
* :mod:`repro.core.scheduler_service` — async multi-tenant submission API
  (``submit(job) -> handle``, late arrivals, cancellation, per-tenant
  metrics) over the lane executor.
* :mod:`repro.core.metrics`   — STP / ANTT / StrictF.
"""

from .events import (
    BlockEnded,
    BlockStarted,
    Decision,
    Hold,
    IssueGrant,
    KernelArrived,
    KernelEnded,
    MachineEvent,
    PreemptAtBoundary,
    SampleOnSM,
    grants_issue,
)
from .machine import KernelRun, Machine, MachineBase, SchedulerCore
from .metrics import WorkloadMetrics, evaluate, geomean, summarize
from .policies import (
    FIFO,
    LJF,
    MPMax,
    POLICIES,
    Policy,
    SJF,
    SRTF,
    SRTFAdaptive,
    make_policy,
)
from .predictor import (
    EWMAPredictor,
    PREDICTORS,
    Predictor,
    SimpleSlicingPredictor,
    make_predictor,
    register_predictor,
    staircase_blocks_in,
    staircase_runtime,
)
from .simulator import Simulator, SimResult, simulate, solo_runtime
from .workload import (
    Arrival,
    ERCBENCH,
    KernelSpec,
    N_SM,
    TABLE3_RUNTIME,
    two_program_workloads,
)

__all__ = [
    "Arrival",
    "BlockEnded",
    "BlockStarted",
    "Decision",
    "ERCBENCH",
    "EWMAPredictor",
    "FIFO",
    "Hold",
    "IssueGrant",
    "KernelArrived",
    "KernelEnded",
    "KernelRun",
    "KernelSpec",
    "LJF",
    "MPMax",
    "Machine",
    "MachineBase",
    "MachineEvent",
    "N_SM",
    "POLICIES",
    "PREDICTORS",
    "Policy",
    "PreemptAtBoundary",
    "Predictor",
    "SJF",
    "SRTF",
    "SRTFAdaptive",
    "SampleOnSM",
    "SchedulerCore",
    "SimResult",
    "SimpleSlicingPredictor",
    "Simulator",
    "TABLE3_RUNTIME",
    "WorkloadMetrics",
    "evaluate",
    "geomean",
    "grants_issue",
    "make_policy",
    "make_predictor",
    "register_predictor",
    "simulate",
    "solo_runtime",
    "staircase_blocks_in",
    "staircase_runtime",
    "summarize",
    "two_program_workloads",
]
