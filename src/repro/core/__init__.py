"""The paper's primary contribution: structural runtime prediction and
preemptive thread-block-style scheduling for concurrent workloads.

Backend-independent core:

* :mod:`repro.core.predictor` — Staircase model (Eq. 1) + Simple Slicing
  online predictor (Table 1 / Algorithm 1 / Eq. 2).
* :mod:`repro.core.policies`  — FIFO, SJF, LJF, JIT-MPMax, SRTF,
  SRTF/Adaptive.
* :mod:`repro.core.simulator` — discrete-event multi-SM GPU simulator
  (the GPGPU-Sim analogue used to reproduce the paper's evaluation).
* :mod:`repro.core.executor`  — real-JAX lane executor: the same scheduler
  driving actual ``train_step`` / ``serve_step`` computations (TPU pod
  adaptation; see DESIGN.md Section 2).
* :mod:`repro.core.metrics`   — STP / ANTT / StrictF.
"""

from .metrics import WorkloadMetrics, evaluate, geomean, summarize
from .policies import (
    FIFO,
    LJF,
    MPMax,
    POLICIES,
    SJF,
    SRTF,
    SRTFAdaptive,
    make_policy,
)
from .predictor import (
    SimpleSlicingPredictor,
    staircase_blocks_in,
    staircase_runtime,
)
from .simulator import Simulator, SimResult, simulate, solo_runtime
from .workload import (
    Arrival,
    ERCBENCH,
    KernelSpec,
    N_SM,
    TABLE3_RUNTIME,
    two_program_workloads,
)

__all__ = [
    "Arrival",
    "ERCBENCH",
    "FIFO",
    "KernelSpec",
    "LJF",
    "MPMax",
    "N_SM",
    "POLICIES",
    "SJF",
    "SRTF",
    "SRTFAdaptive",
    "SimResult",
    "SimpleSlicingPredictor",
    "Simulator",
    "TABLE3_RUNTIME",
    "WorkloadMetrics",
    "evaluate",
    "geomean",
    "make_policy",
    "simulate",
    "solo_runtime",
    "staircase_blocks_in",
    "staircase_runtime",
    "summarize",
    "two_program_workloads",
]
