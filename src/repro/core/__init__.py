"""The paper's primary contribution: structural runtime prediction and
preemptive thread-block-style scheduling for concurrent workloads.

Backend-independent core:

* :mod:`repro.core.machine`   — the formal ``Machine`` protocol (the read
  surface policies/predictors may touch) and the ``SchedulerCore`` (one
  policy + one predictor) that drives any machine implementing it.
* :mod:`repro.core.events`    — typed machine events (``KernelArrived`` /
  ``BlockStarted`` / ``BlockEnded`` / ``KernelEnded``) and scheduling
  decisions (``IssueGrant`` / ``SampleOnSM`` / ``Hold`` /
  ``PreemptAtBoundary``).
* :mod:`repro.core.predictor` — Staircase model (Eq. 1), the ``Predictor``
  interface + registry, Simple Slicing (Table 1 / Algorithm 1 / Eq. 2) and
  the EWMA baseline.
* :mod:`repro.core.policies`  — FIFO, SJF, LJF, JIT-MPMax, SRTF,
  SRTF/Adaptive, all written against the ``Machine`` protocol.
* :mod:`repro.core.simulator` — discrete-event multi-SM GPU simulator
  (the GPGPU-Sim analogue used to reproduce the paper's evaluation).
* :mod:`repro.core.executor`  — real-JAX lane executor: the same scheduler
  driving actual ``train_step`` / ``serve_step`` computations (TPU pod
  adaptation; see DESIGN.md Section 2).
* :mod:`repro.core.scheduler_service` — async multi-tenant submission API
  (``submit(job) -> handle``, late arrivals, cancellation, per-tenant
  metrics) over the lane executor.
* :mod:`repro.core.metrics`   — STP / ANTT / StrictF, completion-window
  metrics for open-loop/truncated runs, and steady-state queueing metrics
  (mean/p95 response, number in system, throughput) for closed-loop runs.
* :mod:`repro.core.scenarios` — two-tier registry of named, seeded
  workload generators: open-loop arrival lists (the paper's pair
  workloads, Table-6 offsets, Poisson/bursty/diurnal streams, N-program
  mixes, trace replay) and closed-loop arrival *processes* fed by machine
  completions (M/G/k bounded-population load, think-time tenant loops).
* :mod:`repro.core.sweep`     — declarative (scenario x policy x predictor
  x seed) sweeps on either machine with multiprocess fan-out and a
  content-addressed on-disk result cache.
"""

from .events import (
    ArrivalSource,
    BlockEnded,
    BlockStarted,
    Decision,
    Hold,
    IssueGrant,
    KernelArrived,
    KernelEnded,
    MachineEvent,
    PreemptAtBoundary,
    SampleOnSM,
    grants_issue,
)
from .machine import KernelRun, Machine, MachineBase, SchedulerCore
from .metrics import (
    MetricsError,
    QueueingMetrics,
    WindowMetrics,
    WorkloadMetrics,
    evaluate,
    evaluate_queueing,
    evaluate_window,
    geomean,
    summarize,
)
from .policies import (
    FIFO,
    LJF,
    MPMax,
    POLICIES,
    Policy,
    SJF,
    SRTF,
    SRTFAdaptive,
    make_policy,
)
from .predictor import (
    EWMAPredictor,
    PREDICTORS,
    Predictor,
    SimpleSlicingPredictor,
    make_predictor,
    register_predictor,
    staircase_blocks_in,
    staircase_runtime,
)
from .scenarios import (
    ArrivalProcess,
    ClosedLoopScenario,
    Diurnal,
    MGkClosed,
    SCENARIOS,
    Scenario,
    ThinkTime,
    executor_job,
    executor_workload,
    fit_diurnal_profile,
    make_scenario,
    open_loop_names,
    register_scenario,
    submission_offsets,
    workload_digest,
)
from .simulator import SimResult, Simulator, simulate, solo_runtime
from .sweep import (
    CellResult,
    MACHINES,
    MetricsCI,
    SweepResult,
    SweepSpec,
    run_sweep,
    solo_runtime_cached,
    solo_runtime_executor_cached,
)
from .workload import (
    Arrival,
    ERCBENCH,
    KernelSpec,
    N_SM,
    PARBOIL2_LIKE,
    TABLE3_RUNTIME,
    two_program_workloads,
)

__all__ = [
    "Arrival",
    "ArrivalProcess",
    "ArrivalSource",
    "BlockEnded",
    "BlockStarted",
    "CellResult",
    "ClosedLoopScenario",
    "Decision",
    "Diurnal",
    "ERCBENCH",
    "EWMAPredictor",
    "FIFO",
    "Hold",
    "IssueGrant",
    "KernelArrived",
    "KernelEnded",
    "KernelRun",
    "KernelSpec",
    "LJF",
    "MACHINES",
    "MGkClosed",
    "MPMax",
    "Machine",
    "MetricsCI",
    "MachineBase",
    "MachineEvent",
    "MetricsError",
    "N_SM",
    "PARBOIL2_LIKE",
    "POLICIES",
    "PREDICTORS",
    "Policy",
    "PreemptAtBoundary",
    "Predictor",
    "QueueingMetrics",
    "SCENARIOS",
    "SJF",
    "SRTF",
    "SRTFAdaptive",
    "SampleOnSM",
    "Scenario",
    "SchedulerCore",
    "SimResult",
    "SimpleSlicingPredictor",
    "Simulator",
    "SweepResult",
    "SweepSpec",
    "TABLE3_RUNTIME",
    "ThinkTime",
    "WindowMetrics",
    "WorkloadMetrics",
    "evaluate",
    "evaluate_queueing",
    "evaluate_window",
    "executor_job",
    "executor_workload",
    "fit_diurnal_profile",
    "geomean",
    "grants_issue",
    "make_policy",
    "make_predictor",
    "make_scenario",
    "open_loop_names",
    "register_predictor",
    "register_scenario",
    "run_sweep",
    "simulate",
    "solo_runtime",
    "solo_runtime_cached",
    "solo_runtime_executor_cached",
    "staircase_blocks_in",
    "staircase_runtime",
    "submission_offsets",
    "summarize",
    "two_program_workloads",
    "workload_digest",
]
