"""Structural Runtime Prediction (paper Sections 3-4).

Implements:

* the Staircase model (Eq. 1):          ``T = ceil(N / R) * t``
* the Simple Slicing (SS) predictor     (Table 1 state, Algorithm 1 handlers,
  Eq. 2 prediction), maintained per execution unit ("SM" on the GPU, "lane"
  on a TPU pod) and per kernel/job.

The predictor is backend-independent: the discrete-event simulator
(:mod:`repro.core.simulator`) and the real-JAX lane executor
(:mod:`repro.core.executor`) both drive it through the four events of
Algorithm 1 (``on_launch`` / ``on_block_start`` / ``on_block_end`` /
``on_kernel_end``) plus the residency-change reslice of Section 3.4.3.

Terminology note: we keep the paper's names (SM, thread block, kernel,
residency).  In the TPU adaptation SM=lane, block=step, kernel=job; the math
is identical (see DESIGN.md Section 2).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional


def staircase_runtime(num_blocks: int, residency: int, t: float) -> float:
    """Eq. 1: total time for ``num_blocks`` at residency ``residency``.

    ``T = ceil(N / R) * t``.
    """
    if num_blocks <= 0:
        return 0.0
    residency = max(1, int(residency))
    return math.ceil(num_blocks / residency) * float(t)


def staircase_blocks_in(time: float, residency: int, t: float) -> int:
    """Inverse of Eq. 1 (used by SRTF/Adaptive, Section 5.1.2).

    Number of blocks completed within ``time`` at residency ``residency``:
    ``N = T * R / t`` (paper's closed form, non-staircase for tractability).
    """
    if t <= 0 or time <= 0:
        return 0
    return int((time * max(1, residency)) / t)


@dataclass
class PerSMState:
    """Table 1: per-kernel state maintained on each SM/lane."""

    total_blocks: int = 0          # Total_Blocks: blocks expected on this SM
    done_blocks: int = 0           # Done_Blocks: blocks completed on this SM
    resident_blocks: int = 1       # Resident_Blocks: residency used in Eq. 2
    t: Optional[float] = None      # duration of a thread block (sampled)
    pred_cycles: Optional[float] = None  # Pred_Cycles: Eq. 2 output
    reslice: bool = True           # Reslice: new slice has started
    # --- bookkeeping for Active_Kernel_Cycles -------------------------------
    active_cycles: float = 0.0     # accumulated cycles with >=1 running block
    running_count: int = 0
    running_since: float = 0.0
    # --- bookkeeping for Block_Start[] --------------------------------------
    block_start: Dict[int, float] = field(default_factory=dict)
    blocks_started: int = 0

    def active_at(self, now: float) -> float:
        if self.running_count > 0:
            return self.active_cycles + (now - self.running_since)
        return self.active_cycles


class SimpleSlicingPredictor:
    """The Simple Slicing (SS) online runtime predictor (Section 4).

    One instance serves a whole machine: state is per ``(kernel, sm)``.
    Predictions estimate *total* runtime under current conditions (Eq. 2):

        Pred = Active_Kernel_Cycles
               + (Total_Blocks - Done_Blocks) / Resident_Blocks * t

    ``t`` is resampled at slice boundaries: kernel launch/end (Algorithm 1)
    and residency changes (Section 3.4.3 / 3.4.4).  Per the paper's text
    ("Equation 2 is not [a] step function"), the remaining-work term uses a
    plain division, not the Eq. 1 ceiling.
    """

    def __init__(self, n_sm: int):
        self.n_sm = n_sm
        self._state: Dict[str, Dict[int, PerSMState]] = {}

    # ------------------------------------------------------------------ state
    def state(self, kernel: str, sm: int) -> PerSMState:
        return self._state[kernel][sm]

    def has_kernel(self, kernel: str) -> bool:
        return kernel in self._state

    def drop_kernel(self, kernel: str) -> None:
        self._state.pop(kernel, None)

    def kernels(self):
        return list(self._state)

    # ------------------------------------------------------- Algorithm 1 ----
    def on_launch(self, kernel: str, total_blocks: int, residency: int) -> None:
        """ONLAUNCH: initialise per-SM counters for a newly launched kernel."""
        per_sm = {}
        expected = math.ceil(total_blocks / self.n_sm)
        for sm in range(self.n_sm):
            per_sm[sm] = PerSMState(
                total_blocks=expected,
                resident_blocks=max(1, residency),
                reslice=True,
            )
        self._state[kernel] = per_sm
        # A launch starts a new slice for every *other* running kernel too
        # (slice boundaries are kernel launches and endings, Section 4).
        for other, states in self._state.items():
            if other == kernel:
                continue
            for st in states.values():
                st.reslice = True

    def on_kernel_end(self, kernel: str) -> None:
        """ONKERNELEND: mark a new slice for all still-running kernels."""
        for other, states in self._state.items():
            if other == kernel:
                continue
            for st in states.values():
                st.reslice = True

    def on_block_start(self, kernel: str, sm: int, blkindex: int, now: float) -> None:
        st = self.state(kernel, sm)
        st.block_start[blkindex] = now
        st.blocks_started += 1
        if st.running_count == 0:
            st.running_since = now
        st.running_count += 1

    def on_block_end(self, kernel: str, sm: int, blkindex: int, now: float) -> float:
        """ONBLOCKEND + Eq. 2.  Returns the new Pred_Cycles for (kernel, sm)."""
        st = self.state(kernel, sm)
        st.done_blocks += 1
        if st.reslice or st.t is None:
            start = st.block_start.get(blkindex)
            if start is not None:
                st.t = now - start
            st.reslice = False
        st.block_start.pop(blkindex, None)
        st.running_count = max(0, st.running_count - 1)
        if st.running_count == 0:
            st.active_cycles += now - st.running_since
        return self.predict(kernel, sm, now)

    # --------------------------------------------------------- reslicing ----
    def on_residency_change(self, kernel: str, sm: int, new_residency: int) -> None:
        """Section 3.4.3: resample ``t`` whenever residency changes."""
        st = self.state(kernel, sm)
        new_residency = max(1, int(new_residency))
        if st.resident_blocks != new_residency:
            st.resident_blocks = new_residency
            st.reslice = True

    def reslice_all(self, kernel: Optional[str] = None) -> None:
        """Force a new slice (e.g. co-runner set changed, Section 3.4.4)."""
        targets = [kernel] if kernel is not None else list(self._state)
        for k in targets:
            for st in self._state.get(k, {}).values():
                st.reslice = True

    def broadcast_t(self, kernel: str, t: float, from_sm: int) -> None:
        """SRTF sampling (Section 5.1.1): copy the sample SM's ``t`` to the
        other SMs as their initial estimate."""
        for sm, st in self._state.get(kernel, {}).items():
            if sm == from_sm:
                continue
            if st.t is None:
                st.t = t
                st.reslice = False

    # ------------------------------------------------------- predictions ----
    def predict(self, kernel: str, sm: int, now: float) -> Optional[float]:
        """Eq. 2 prediction of *total* runtime for (kernel, sm)."""
        st = self.state(kernel, sm)
        if st.t is None:
            return None
        remaining_blocks = max(0, st.total_blocks - st.done_blocks)
        remaining = (remaining_blocks / max(1, st.resident_blocks)) * st.t
        st.pred_cycles = st.active_at(now) + remaining
        return st.pred_cycles

    def remaining(self, kernel: str, sm: int) -> Optional[float]:
        """Predicted remaining cycles for (kernel, sm) — the SRTF ranking key."""
        if kernel not in self._state:
            return None
        st = self._state[kernel][sm]
        if st.t is None:
            return None
        remaining_blocks = max(0, st.total_blocks - st.done_blocks)
        return (remaining_blocks / max(1, st.resident_blocks)) * st.t

    def gpu_remaining(self, kernel: str) -> Optional[float]:
        """Machine-level remaining-time estimate: mean over SMs with samples.

        Used by SRTF/Adaptive's slowdown projection and for logging; per-SM
        scheduling decisions use :meth:`remaining` directly.
        """
        if kernel not in self._state:
            return None
        vals = []
        for sm in self._state[kernel]:
            r = self.remaining(kernel, sm)
            if r is not None:
                vals.append(r)
        if not vals:
            return None
        return sum(vals) / len(vals)

    def gpu_predicted_total(self, kernel: str, now: float) -> Optional[float]:
        if kernel not in self._state:
            return None
        vals = []
        for sm in self._state[kernel]:
            p = self.predict(kernel, sm, now)
            if p is not None:
                vals.append(p)
        if not vals:
            return None
        return sum(vals) / len(vals)
