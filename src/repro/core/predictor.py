"""Structural Runtime Prediction (paper Sections 3-4).

Implements:

* the Staircase model (Eq. 1):          ``T = ceil(N / R) * t``
* the :class:`Predictor` interface      (Algorithm 1 event handlers plus the
  query surface policies consume), with a registry of pluggable
  implementations (``register_predictor`` / ``make_predictor``),
* the Simple Slicing (SS) predictor     (Table 1 state, Algorithm 1 handlers,
  Eq. 2 prediction) — the paper's predictor and the registry default,
* an EWMA baseline predictor            (same interface, blends every block
  duration instead of resampling at slice boundaries) proving the seam.

Predictors are backend-independent: any :class:`repro.core.machine.Machine`
(the discrete-event simulator, the real-JAX lane executor, future cluster
backends) drives them through the four events of Algorithm 1 (``on_launch``
/ ``on_block_start`` / ``on_block_end`` / ``on_kernel_end``) plus the
residency-change reslice of Section 3.4.3.

Terminology note: we keep the paper's names (SM, thread block, kernel,
residency).  In the TPU adaptation SM=lane, block=step, kernel=job; the math
is identical (see DESIGN.md Section 2).
"""

from __future__ import annotations

import math
from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Type, Union


def staircase_runtime(num_blocks: int, residency: int, t: float) -> float:
    """Eq. 1: total time for ``num_blocks`` at residency ``residency``.

    ``T = ceil(N / R) * t``.
    """
    if num_blocks <= 0:
        return 0.0
    residency = max(1, int(residency))
    return math.ceil(num_blocks / residency) * float(t)


def staircase_blocks_in(time: float, residency: int, t: float) -> int:
    """Inverse of Eq. 1 (used by SRTF/Adaptive, Section 5.1.2).

    Number of blocks completed within ``time`` at residency ``residency``:
    ``N = T * R / t`` (paper's closed form, non-staircase for tractability).
    """
    if t <= 0 or time <= 0:
        return 0
    return int((time * max(1, residency)) / t)


# ---------------------------------------------------------------- interface


class Predictor(ABC):
    """Online runtime predictor driven by Algorithm-1 events.

    One instance serves a whole machine; state is per ``(kernel, sm)``.
    Machines post events through :class:`repro.core.machine.SchedulerCore`;
    policies query predictions through the read methods.  Implementations
    register with :func:`register_predictor` and are instantiated by name
    via :func:`make_predictor` (machines accept either a name or an
    instance).
    """

    #: Registry name, set by :func:`register_predictor`.
    name: str = "base"

    def __init__(self, n_sm: int):
        self.n_sm = n_sm

    # -- Algorithm 1 event handlers ----------------------------------------
    @abstractmethod
    def on_launch(self, kernel: str, total_blocks: int, residency: int) -> None:
        """ONLAUNCH: a kernel with ``total_blocks`` blocks became visible."""

    @abstractmethod
    def on_block_start(self, kernel: str, sm: int, blkindex: int,
                       now: float) -> None:
        """ONBLOCKSTART: one block of ``kernel`` started on ``sm``."""

    @abstractmethod
    def on_block_end(self, kernel: str, sm: int, blkindex: int,
                     now: float) -> Optional[float]:
        """ONBLOCKEND: returns the updated total-runtime prediction."""

    @abstractmethod
    def on_kernel_end(self, kernel: str) -> None:
        """ONKERNELEND: every block of ``kernel`` completed."""

    @abstractmethod
    def on_residency_change(self, kernel: str, sm: int,
                            new_residency: int) -> None:
        """Section 3.4.3: the residency cap for ``(kernel, sm)`` changed."""

    # -- slice management ---------------------------------------------------
    @abstractmethod
    def reslice_all(self, kernel: Optional[str] = None) -> None:
        """Force a new slice (e.g. co-runner set changed, Section 3.4.4)."""

    @abstractmethod
    def broadcast_t(self, kernel: str, t: float, from_sm: int) -> None:
        """SRTF sampling (Section 5.1.1): seed other units with a sample."""

    # -- queries ------------------------------------------------------------
    @abstractmethod
    def has_kernel(self, kernel: str) -> bool:
        """Whether ``kernel`` has been launched and not dropped."""

    @abstractmethod
    def sampled_t(self, kernel: str, sm: int) -> Optional[float]:
        """Current per-block duration estimate for ``(kernel, sm)``."""

    @abstractmethod
    def done_blocks(self, kernel: str, sm: int) -> int:
        """Blocks of ``kernel`` completed on ``sm`` so far."""

    @abstractmethod
    def remaining(self, kernel: str, sm: int) -> Optional[float]:
        """Predicted remaining cycles for ``(kernel, sm)`` — SRTF's key."""

    @abstractmethod
    def gpu_remaining(self, kernel: str) -> Optional[float]:
        """Machine-level remaining-time estimate across units."""

    @abstractmethod
    def gpu_predicted_total(self, kernel: str, now: float) -> Optional[float]:
        """Machine-level Eq. 2 total-runtime prediction."""


#: Registry of predictor implementations, keyed by their public name.
PREDICTORS: Dict[str, Type[Predictor]] = {}

DEFAULT_PREDICTOR = "simple-slicing"


def register_predictor(name: str):
    """Class decorator registering a :class:`Predictor` under ``name``."""

    def decorate(cls: Type[Predictor]) -> Type[Predictor]:
        cls.name = name
        PREDICTORS[name] = cls
        return cls

    return decorate


def make_predictor(spec: Union[str, Predictor, None], n_sm: int,
                   **kwargs) -> Predictor:
    """Resolve ``spec`` into a predictor instance bound to ``n_sm`` units.

    ``spec`` may be an instance (returned as-is), a registered name, or
    ``None`` for the default (``simple-slicing``, the paper's predictor).
    """
    if isinstance(spec, Predictor):
        return spec
    name = DEFAULT_PREDICTOR if spec is None else spec
    try:
        cls = PREDICTORS[name]
    except KeyError:
        raise ValueError(
            f"unknown predictor {name!r}; choose from {sorted(PREDICTORS)}"
        ) from None
    return cls(n_sm, **kwargs)


# ------------------------------------------------------------ simple slicing


@dataclass(slots=True)
class PerSMState:
    """Table 1: per-kernel state maintained on each SM/lane."""

    total_blocks: int = 0          # Total_Blocks: blocks expected on this SM
    done_blocks: int = 0           # Done_Blocks: blocks completed on this SM
    resident_blocks: int = 1       # Resident_Blocks: residency used in Eq. 2
    t: Optional[float] = None      # duration of a thread block (sampled)
    pred_cycles: Optional[float] = None  # Pred_Cycles: Eq. 2 output
    reslice: bool = True           # Reslice: new slice has started
    # --- bookkeeping for Active_Kernel_Cycles -------------------------------
    active_cycles: float = 0.0     # accumulated cycles with >=1 running block
    running_count: int = 0
    running_since: float = 0.0
    # --- bookkeeping for Block_Start[] --------------------------------------
    block_start: Dict[int, float] = field(default_factory=dict)
    blocks_started: int = 0

    def active_at(self, now: float) -> float:
        if self.running_count > 0:
            return self.active_cycles + (now - self.running_since)
        return self.active_cycles


@register_predictor("simple-slicing")
class SimpleSlicingPredictor(Predictor):
    """The Simple Slicing (SS) online runtime predictor (Section 4).

    One instance serves a whole machine: state is per ``(kernel, sm)``.
    Predictions estimate *total* runtime under current conditions (Eq. 2):

        Pred = Active_Kernel_Cycles
               + (Total_Blocks - Done_Blocks) / Resident_Blocks * t

    ``t`` is resampled at slice boundaries: kernel launch/end (Algorithm 1)
    and residency changes (Section 3.4.3 / 3.4.4).  Per the paper's text
    ("Equation 2 is not [a] step function"), the remaining-work term uses a
    plain division, not the Eq. 1 ceiling.
    """

    def __init__(self, n_sm: int):
        super().__init__(n_sm)
        # Whether _observe must see every measured duration.  Simple
        # Slicing only consumes the first duration of a new slice, so the
        # per-block handler skips the call mid-slice — but ONLY when
        # _observe is the base implementation: any subclass overriding the
        # seam (EWMA, future estimators) is detected here and fed every
        # block, so the optimization can never starve a custom estimator.
        self._observe_every_block = (
            type(self)._observe is not SimpleSlicingPredictor._observe)
        # Per-kernel per-SM Table-1 state, index-addressed: SM ids are
        # dense 0..n_sm-1 on every machine, so a flat list beats a dict in
        # the per-block handlers (state() keeps the lookup API).
        self._state: Dict[str, List[PerSMState]] = {}
        # Version-counter memo for the machine-level remaining estimate:
        # ``gpu_remaining(k)`` is pure over per-(k, sm) state, and that
        # state only changes through the handlers below — each bumps the
        # kernel's version, so an unchanged version returns the memoized
        # float (bit-identical by definition).  SRTF/Adaptive call
        # ``gpu_remaining`` for every active kernel on every block end;
        # most of those calls land between mutations of *other* kernels.
        self._rem_version: Dict[str, int] = {}
        self._rem_memo: Dict[str, tuple] = {}

    def _touch(self, kernel: str) -> None:
        """Invalidate memoized estimates for ``kernel`` (state changed)."""
        self._rem_version[kernel] = self._rem_version.get(kernel, 0) + 1

    # ------------------------------------------------------------------ state
    def state(self, kernel: str, sm: int) -> PerSMState:
        return self._state[kernel][sm]

    def has_kernel(self, kernel: str) -> bool:
        return kernel in self._state

    def drop_kernel(self, kernel: str) -> None:
        self._state.pop(kernel, None)
        self._rem_version.pop(kernel, None)
        self._rem_memo.pop(kernel, None)

    def kernels(self) -> List[str]:
        return list(self._state)

    def sampled_t(self, kernel: str, sm: int) -> Optional[float]:
        if kernel not in self._state:
            return None
        return self._state[kernel][sm].t

    def done_blocks(self, kernel: str, sm: int) -> int:
        if kernel not in self._state:
            return 0
        return self._state[kernel][sm].done_blocks

    # ------------------------------------------------------- Algorithm 1 ----
    def on_launch(self, kernel: str, total_blocks: int, residency: int) -> None:
        """ONLAUNCH: initialise per-SM counters for a newly launched kernel."""
        expected = math.ceil(total_blocks / self.n_sm)
        residency = max(1, residency)
        per_sm = [
            PerSMState(total_blocks=expected, resident_blocks=residency,
                       reslice=True)
            for _ in range(self.n_sm)
        ]
        self._state[kernel] = per_sm
        self._touch(kernel)
        # A launch starts a new slice for every *other* running kernel too
        # (slice boundaries are kernel launches and endings, Section 4).
        # (Reslicing alone does not move any ``t``/``done`` state, so the
        # other kernels' remaining-estimate memos stay valid.)
        for other, states in self._state.items():
            if other == kernel:
                continue
            for st in states:
                st.reslice = True

    def on_kernel_end(self, kernel: str) -> None:
        """ONKERNELEND: mark a new slice for all still-running kernels."""
        for other, states in self._state.items():
            if other == kernel:
                continue
            for st in states:
                st.reslice = True

    def on_block_start(self, kernel: str, sm: int, blkindex: int, now: float) -> None:
        st = self._state[kernel][sm]
        st.block_start[blkindex] = now
        st.blocks_started += 1
        if st.running_count == 0:
            st.running_since = now
        st.running_count += 1

    def on_block_end(self, kernel: str, sm: int, blkindex: int, now: float) -> Optional[float]:
        """ONBLOCKEND + Eq. 2.  Returns the new Pred_Cycles for (kernel, sm).

        The Eq. 2 projection is inlined (same arithmetic as
        :meth:`predict`): this handler runs once per executed block on the
        whole machine.
        """
        st = self._state[kernel][sm]
        st.done_blocks += 1
        start = st.block_start.pop(blkindex, None)
        if st.reslice or st.t is None or self._observe_every_block:
            # Mid-slice Simple Slicing ignores the duration entirely (the
            # `_observe` precondition) — skip the call; estimators that
            # fold every duration set `_observe_every_block`.
            self._observe(st, None if start is None else now - start)
        rc = st.running_count - 1
        st.running_count = rc if rc > 0 else 0
        if rc <= 0:
            st.active_cycles += now - st.running_since
        rv = self._rem_version                     # inlined _touch()
        rv[kernel] = rv.get(kernel, 0) + 1
        t = st.t
        if t is None:
            return None
        remaining_blocks = st.total_blocks - st.done_blocks
        if remaining_blocks < 0:
            remaining_blocks = 0
        res = st.resident_blocks
        remaining = (remaining_blocks / (res if res > 1 else 1)) * t
        active = st.active_cycles
        if st.running_count > 0:
            active += now - st.running_since
        st.pred_cycles = active + remaining
        return st.pred_cycles

    def _observe(self, st: PerSMState, duration: Optional[float]) -> None:
        """Fold one measured block duration into the ``t`` estimate.

        Simple Slicing resamples ``t`` only at slice boundaries (Section 4):
        the first completed block of a new slice sets ``t``; later blocks of
        the same slice are ignored.  Subclasses override this to implement
        other estimators against identical bookkeeping.
        """
        if st.reslice or st.t is None:
            if duration is not None:
                st.t = duration
            st.reslice = False

    # --------------------------------------------------------- reslicing ----
    def on_residency_change(self, kernel: str, sm: int, new_residency: int) -> None:
        """Section 3.4.3: resample ``t`` whenever residency changes."""
        st = self.state(kernel, sm)
        new_residency = max(1, int(new_residency))
        if st.resident_blocks != new_residency:
            st.resident_blocks = new_residency
            st.reslice = True
            self._touch(kernel)

    def reslice_all(self, kernel: Optional[str] = None) -> None:
        """Force a new slice (e.g. co-runner set changed, Section 3.4.4)."""
        targets = [kernel] if kernel is not None else list(self._state)
        for k in targets:
            for st in self._state.get(k, ()):
                st.reslice = True

    def broadcast_t(self, kernel: str, t: float, from_sm: int) -> None:
        """SRTF sampling (Section 5.1.1): copy the sample SM's ``t`` to the
        other SMs as their initial estimate."""
        for sm, st in enumerate(self._state.get(kernel, ())):
            if sm == from_sm:
                continue
            if st.t is None:
                st.t = t
                st.reslice = False
        self._touch(kernel)

    # ------------------------------------------------------- predictions ----
    def predict(self, kernel: str, sm: int, now: float) -> Optional[float]:
        """Eq. 2 prediction of *total* runtime for (kernel, sm)."""
        st = self.state(kernel, sm)
        if st.t is None:
            return None
        remaining_blocks = max(0, st.total_blocks - st.done_blocks)
        remaining = (remaining_blocks / max(1, st.resident_blocks)) * st.t
        st.pred_cycles = st.active_at(now) + remaining
        return st.pred_cycles

    def remaining(self, kernel: str, sm: int) -> Optional[float]:
        """Predicted remaining cycles for (kernel, sm) — the SRTF ranking key."""
        states = self._state.get(kernel)
        if states is None:
            return None
        st = states[sm]
        if st.t is None:
            return None
        remaining_blocks = st.total_blocks - st.done_blocks
        if remaining_blocks < 0:
            remaining_blocks = 0
        res = st.resident_blocks
        return (remaining_blocks / (res if res > 1 else 1)) * st.t

    def gpu_remaining(self, kernel: str) -> Optional[float]:
        """Machine-level remaining-time estimate: mean over SMs with samples.

        Used by SRTF/Adaptive's slowdown projection and for logging; per-SM
        scheduling decisions use :meth:`remaining` directly.  (Inlined
        per-SM arithmetic — this runs for every active kernel on every
        block end under SRTF/Adaptive.)
        """
        states = self._state.get(kernel)
        if states is None:
            return None
        version = self._rem_version.get(kernel, 0)
        memo = self._rem_memo.get(kernel)
        if memo is not None and memo[0] == version:
            return memo[1]
        vals = []
        for st in states:
            if st.t is None:
                continue
            remaining_blocks = st.total_blocks - st.done_blocks
            if remaining_blocks < 0:
                remaining_blocks = 0
            res = st.resident_blocks
            vals.append((remaining_blocks / (res if res > 1 else 1)) * st.t)
        out = (sum(vals) / len(vals)) if vals else None
        self._rem_memo[kernel] = (version, out)
        return out

    def gpu_predicted_total(self, kernel: str, now: float) -> Optional[float]:
        states = self._state.get(kernel)
        if states is None:
            return None
        total = 0.0
        n = 0
        for st in states:
            t = st.t
            if t is None:
                continue
            remaining_blocks = st.total_blocks - st.done_blocks
            if remaining_blocks < 0:
                remaining_blocks = 0
            res = st.resident_blocks
            remaining = (remaining_blocks / (res if res > 1 else 1)) * t
            active = st.active_cycles
            if st.running_count > 0:
                active += now - st.running_since
            st.pred_cycles = active + remaining
            total += st.pred_cycles
            n += 1
        if n == 0:
            return None
        return total / n


# ------------------------------------------------------------ EWMA baseline


@register_predictor("ewma")
class EWMAPredictor(SimpleSlicingPredictor):
    """Exponentially-weighted moving-average baseline predictor.

    Shares Simple Slicing's Table-1 bookkeeping and Eq. 2 projection but
    replaces the slice-boundary resampling of ``t`` with a continuous EWMA
    over *every* measured block duration.  It has no notion of slices, so it
    adapts slowly after residency changes (exactly the failure mode
    Section 3.4.3 motivates) — a useful control to quantify what Simple
    Slicing's reslicing buys, and the proof that the predictor seam is real.
    """

    def __init__(self, n_sm: int, alpha: float = 0.3):
        super().__init__(n_sm)
        self.alpha = alpha

    def _observe(self, st: PerSMState, duration: Optional[float]) -> None:
        st.reslice = False
        if duration is None:
            return
        if st.t is None:
            st.t = duration
        else:
            st.t = self.alpha * duration + (1.0 - self.alpha) * st.t
