"""Workload definitions and ERCBench calibration (paper Tables 2-4).

A :class:`KernelSpec` describes one GPU kernel (or, in the TPU adaptation,
one job) as the scheduler sees it: a grid of ``num_blocks`` homogeneous
blocks, a maximum residency ``max_residency`` per SM, and a block-duration
model.  The duration model reproduces the systematic effects the paper
measures in Section 3.4:

* residency-dependent duration (Fig. 7/8): ``t`` grows with residency while
  per-SM throughput saturates,
* co-runner interference (Fig. 9/10): ``t`` grows with co-resident warps of
  other kernels,
* per-block noise (Fig. 6): lognormal with the kernel's %RSD (Table 3),
* startup effects (Section 3.4.1): longer first-wave blocks,
* staggered starts (Section 3.3, Fig. 5): serialized first-wave issue.

Calibration: ``mean_t`` is the *simulator* mean block duration at maximum
solo residency (paper Table 3), so solo runtimes reproduce Table 3 via
Eq. 1 with N_SM = 15 (Table 4).
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: Table 4 — simulated GPU configuration (GTX 480 / Fermi-class).
N_SM = 15
MAX_BLOCK_SLOTS = 8
MAX_THREADS_PER_SM = 1536
MAX_WARPS_PER_SM = 48
THREADS_PER_WARP = 32


@dataclass(frozen=True)
class KernelSpec:
    """Static description of one kernel/grid (Tables 2-3)."""

    name: str
    num_blocks: int            # Table 2 "Blocks"
    max_residency: int         # Table 2 "R"
    threads_per_block: int     # Table 2 "TPB"
    mean_t: float              # Table 3 "Mean t" (cycles, at max residency)
    rsd: float = 0.0           # Table 3 "%RSD" / 100
    # --- systematic-effect knobs (Section 3.4) ------------------------------
    residency_beta: float = 0.08   # slope of t vs residency (Fig. 7)
    corunner_sens: float = 0.45    # sensitivity of t to co-resident warps (Fig. 9/10)
    corunner_pressure: float = 1.0 # pressure this kernel exerts on co-runners
    startup_factor: float = 0.0    # first-wave blocks run (1+f) longer (Sec. 3.4.1)
    stagger_frac: float = 0.0      # first-wave issue stagger, as fraction of t (Fig. 5)
    stagger_sm_prob: float = 0.0   # probability a given SM staggers (hardware-like)

    # cached_property (not property): both are read on every block issue /
    # free in the DES hot loop, and a frozen dataclass can still cache into
    # its __dict__.  Not dataclass fields, so asdict()/eq are unaffected.
    @functools.cached_property
    def warps_per_block(self) -> int:
        return math.ceil(self.threads_per_block / THREADS_PER_WARP)

    @functools.cached_property
    def base_t_table(self) -> Tuple[float, ...]:
        """``base_t(r)`` for every legal residency, indexed by ``r``.

        The DES issue loop reads the mean block duration once per executed
        block; the table replaces the clamp-and-normalise arithmetic of
        :meth:`base_t` with one tuple index (entry 0 aliases residency 1,
        matching ``base_t``'s clamp) and is bit-identical by construction.
        """
        return tuple(self.base_t(r) for r in range(self.max_residency + 1))

    @functools.cached_property
    def resource_fraction(self) -> float:
        """Fraction of one SM consumed by one resident block.

        Normalised-resource model: at max residency the kernel exactly fills
        whatever resource binds it (threads for AES, registers for render,
        block slots otherwise), so one block consumes ``1/R`` of an SM.  This
        makes mixed-kernel packing and MPMax-style reservations well-defined:
        a set of resident blocks fits iff the fractions sum to <= 1.
        """
        return 1.0 / self.max_residency

    # ------------------------------------------------------------- duration
    def base_t(self, residency: int) -> float:
        """Mean block duration at ``residency`` resident blocks (Fig. 7).

        Linear-in-residency contention normalised so that
        ``base_t(max_residency) == mean_t``:
        ``t(r) = mean_t * (1 + beta (r-1)) / (1 + beta (R-1))``.
        Per-SM throughput ``r / t(r)`` then saturates like Fig. 8.
        """
        r = max(1, min(int(residency), self.max_residency))
        num = 1.0 + self.residency_beta * (r - 1)
        den = 1.0 + self.residency_beta * (self.max_residency - 1)
        return self.mean_t * num / den

    def duration(
        self,
        rng: Optional[np.random.Generator],
        residency: int,
        corunner_warps: float = 0.0,
        first_wave: bool = False,
    ) -> float:
        """Sample one block duration under the current SM conditions.

        ``rng=None`` skips the per-block noise factor (the simulator
        applies its own precomputed per-block noise stream instead).
        """
        t = self.base_t(residency)
        if corunner_warps > 0.0:
            t *= 1.0 + self.corunner_sens * (corunner_warps / MAX_WARPS_PER_SM)
        if first_wave and self.startup_factor > 0.0:
            t *= 1.0 + self.startup_factor
        if rng is not None and self.rsd > 0.0:
            sigma = math.sqrt(math.log(1.0 + self.rsd * self.rsd))
            t *= rng.lognormal(mean=-0.5 * sigma * sigma, sigma=sigma)
        return max(t, 1.0)

    def solo_staircase_runtime(self) -> float:
        """Eq. 1 estimate of solo runtime on the Table 4 machine."""
        per_sm = math.ceil(self.num_blocks / N_SM)
        return math.ceil(per_sm / self.max_residency) * self.mean_t


#: ERCBench kernels: Tables 2 and 3, with Section 3.3/3.4 effect knobs chosen
#: to reproduce the paper's qualitative observations:
#:   - AES-d / SHA1 show staggered execution on some SMs (Section 3.3),
#:   - JPEG-d / SAD / SHA1 show startup overestimates (Section 3.4.1),
#:   - render has strongly value-dependent work (Fig. 6, max 4x),
#:   - SHA1 is the most intrusive co-runner (Fig. 9).
ERCBENCH: Dict[str, KernelSpec] = {
    spec.name: spec
    for spec in [
        KernelSpec("AES-d", 1429, 6, 256, 14529.0, 0.1252,
                   stagger_frac=0.30, stagger_sm_prob=0.4),
        KernelSpec("AES-e", 1429, 6, 256, 14031.0, 0.1210),
        KernelSpec("ImageDenoising-nlm2", 4096, 8, 64, 19873.0, 0.0287,
                   corunner_pressure=1.2),
        KernelSpec("JPEG-d", 512, 8, 64, 5238.0, 0.2958, startup_factor=0.25),
        KernelSpec("JPEG-e", 512, 8, 64, 5367.0, 0.3295, startup_factor=0.25),
        KernelSpec("RayTracing", 2048, 5, 128, 15167.0, 0.6571),
        KernelSpec("SAD", 1584, 8, 61, 32332.0, 0.0657, startup_factor=0.15,
                   corunner_sens=2.5),
        KernelSpec("SHA1", 1539, 8, 64, 1708531.0, 0.0798,
                   startup_factor=0.15, stagger_frac=0.30, stagger_sm_prob=0.4,
                   corunner_pressure=1.6),
    ]
}

#: Synthetic "Parboil2-like" kernels used where the paper also evaluates
#: Parboil2 (Figs. 3/4) and by the open-loop scenario mixes.  Grid shapes
#: chosen to mimic the named kernels' published structure; durations are
#: arbitrary but the *structure* (many uniform blocks / staggered /
#: value-dependent) is what is tested.
PARBOIL2_LIKE: Dict[str, KernelSpec] = {
    spec.name: spec
    for spec in [
        KernelSpec("SGEMM", 528, 6, 128, 80_000.0, 0.03),
        KernelSpec("LBM", 18_000, 6, 120, 12_000.0, 0.05,
                   stagger_frac=0.4, stagger_sm_prob=1.0),
        KernelSpec("CUTCP", 121, 8, 128, 150_000.0, 0.30),
        KernelSpec("HISTO", 2_042, 8, 192, 25_000.0, 0.08,
                   startup_factor=0.2),
    ]
}

#: Table 3 solo runtimes on the simulator (cycles) — calibration targets.
TABLE3_RUNTIME: Dict[str, float] = {
    "AES-d": 234154.0,
    "AES-e": 226335.0,
    "ImageDenoising-nlm2": 692686.0,
    "JPEG-d": 24853.0,
    "JPEG-e": 25383.0,
    "RayTracing": 416563.0,
    "SAD": 441297.0,
    "SHA1": 22224223.0,
}


@dataclass(frozen=True)
class Arrival:
    """One kernel instance arriving at ``time`` (cycles)."""

    spec: KernelSpec
    time: float = 0.0
    uid: Optional[str] = None

    @property
    def key(self) -> str:
        return self.uid if self.uid is not None else self.spec.name


def two_program_workloads(
    names: Optional[Sequence[str]] = None,
    stagger_cycles: float = 100.0,
    both_orders: bool = True,
) -> List[Tuple[str, List[Arrival]]]:
    """All 2-program workloads from ERCBench (Section 6.1.3).

    28 unordered pairs; with ``both_orders`` both arrival orders are emitted
    (56 workloads).  The second kernel arrives ``stagger_cycles`` after the
    first ("staggered by upto 100 cycles").
    """
    names = list(names) if names is not None else sorted(ERCBENCH)
    out: List[Tuple[str, List[Arrival]]] = []
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            orders = [(a, b), (b, a)] if both_orders else [(a, b)]
            for first, second in orders:
                wl = [
                    Arrival(ERCBENCH[first], 0.0, uid=f"{first}#0"),
                    Arrival(ERCBENCH[second], stagger_cycles, uid=f"{second}#1"),
                ]
                out.append((f"{first}+{second}", wl))
    return out


def offset_workload(
    first: str,
    second: str,
    offset_fraction: float,
    solo_runtime_first: float,
) -> List[Arrival]:
    """Workload where the second kernel arrives after ``offset_fraction`` of
    the first kernel's solo runtime has elapsed (Table 6)."""
    return [
        Arrival(ERCBENCH[first], 0.0, uid=f"{first}#0"),
        Arrival(ERCBENCH[second], offset_fraction * solo_runtime_first,
                uid=f"{second}#1"),
    ]


def scaled_spec(spec: KernelSpec, **overrides) -> KernelSpec:
    """Convenience for tests/benchmarks: tweak fields of a frozen spec."""
    return replace(spec, **overrides)


def reorder_for_oracle(
    arrivals: Sequence[Arrival],
    solo_runtimes: Dict[str, float],
    longest_first: bool = False,
) -> List[Arrival]:
    """Permute which kernel occupies which arrival slot, by solo runtime.

    This is how the paper realizes SJF/LJF (Section 2): "FIFO's schedule is
    the same as either of Shortest Job First (SJF) or Longest Job First (LJF)
    depending on the order of arrival of the kernels" — the oracle policies
    are FIFO runs with the oracle-chosen arrival order.
    """
    times = sorted(a.time for a in arrivals)
    by_runtime = sorted(
        arrivals,
        key=lambda a: solo_runtimes[a.spec.name],
        reverse=longest_first,
    )
    return [
        Arrival(spec=a.spec, time=t, uid=f"{a.spec.name}#{i}")
        for i, (t, a) in enumerate(zip(times, by_runtime))
    ]
