"""Generated-C backend for the flat-array DES engine.

The C source below is a line-for-line translation of
:mod:`repro.core.fastsim_twin` (the ONE algorithm — see that module's
docstring and DESIGN.md Section 10).  The layout ``#define`` block is
generated from the twin's constants at build time, so the two can never
drift apart silently; the build is content-addressed (source hash in the
file name) and cached under ``REPRO_FASTSIM_CACHE`` or
``src/repro/core/_fastsim_build/`` (gitignored).

Bit-identity notes:

* compiled with ``-ffp-contract=off`` — gcc at ``-O2`` defaults to
  contracting ``a*b+c`` into FMA, which changes results in the last ulp;
  CPython never fuses, so neither may the C.  No ``-ffast-math`` ever.
* every ``int / int`` from the Python side becomes an explicit
  ``(double)x / (double)y`` — C integer division truncates, Python's
  ``/`` is true division.
* None is NaN, tested with ``x != x`` (safe without fast-math).

The only export is :func:`native_advance`, returning an ``advance(S)``
callable over the twin's 29-array state tuple, or raising when no C
compiler is available (callers treat any failure as "backend absent").
"""

from __future__ import annotations

import ctypes
import hashlib
import os
import subprocess
from pathlib import Path

from . import fastsim_twin as tw


def _c_defines() -> str:
    """#define block generated from the twin's layout constants."""
    lines = []
    for name in sorted(dir(tw)):
        if not name[:1].isupper() or not name.replace("_", "").isalnum():
            continue
        value = getattr(tw, name)
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            continue
        lines.append(f"#define {name} {value!r}")
    lines.append(f"#define FS_EPS {tw._EPS!r}")
    return "\n".join(lines)


_C_BODY = r"""
#include <stdint.h>
#include <math.h>

typedef struct {
    int64_t *si; double *sd; int64_t *ci; double *cf;
    int64_t *ri; double *rf; int64_t *psi; double *psf;
    double *bs; int64_t *sl; int64_t *smi; double *smf;
    int64_t *hi; double *hf; int64_t *tri; double *trf;
    int64_t *dci; double *dcf; int64_t *pri; double *prf;
    int64_t *act; int64_t *q; int64_t *rwi; double *rwf;
    int64_t *newc; int64_t *cand; double *crem;
    double *np_pool; double *bt_pool;
    int64_t *srci; double *srcf;
    int64_t nsm;
} St;

typedef struct {
    double t; int64_t kind, seq, a, b, c; double start;
} Ev;

#define RI(r, c)      (S->ri[(r) * RI_LEN + (c)])
#define RF(r, c)      (S->rf[(r) * RF_LEN + (c)])
#define PSI(r, s, c)  (S->psi[((r) * S->nsm + (s)) * PI_LEN + (c)])
#define PSF(r, s, c)  (S->psf[((r) * S->nsm + (s)) * PF_LEN + (c)])
#define BS(r, s, k)   (S->bs[((r) * S->nsm + (s)) * MAX_BLOCK_SLOTS + (k)])
#define SL(s, k)      (S->sl[(s) * MAX_BLOCK_SLOTS + (k)])
#define SMI(s, c)     (S->smi[(s) * SMI_LEN + (c)])
#define SMF(s)        (S->smf[(s)])
#define HI(i, c)      (S->hi[(i) * HI_LEN + (c)])
#define HF(i, c)      (S->hf[(i) * HF_LEN + (c)])
#define TRI(i, c)     (S->tri[(i) * 3 + (c)])
#define TRF(i, c)     (S->trf[(i) * 2 + (c)])
#define DCI(i, c)     (S->dci[(i) * 3 + (c)])
#define DCF(i)        (S->dcf[(i)])
#define PRI(i, c)     (S->pri[(i) * 3 + (c)])
#define PRF(i, c)     (S->prf[(i) * 2 + (c)])
#define RWF(i, c)     (S->rwf[(i) * 3 + (c)])

/* ------------------------------------------------------------------ heap */
static int heap_lt(const St *S, int64_t i, int64_t j) {
    double ti = HF(i, HF_TIME), tj = HF(j, HF_TIME);
    if (ti != tj) return ti < tj;
    {
        int64_t ki = HI(i, HI_KIND), kj = HI(j, HI_KIND);
        if (ki != kj) return ki < kj;
    }
    return HI(i, HI_SEQ) < HI(j, HI_SEQ);
}

static int lt_item(const St *S, double t, int64_t kind, int64_t seq,
                   int64_t j) {
    double tj = HF(j, HF_TIME);
    if (t != tj) return t < tj;
    {
        int64_t kj = HI(j, HI_KIND);
        if (kind != kj) return kind < kj;
    }
    return seq < HI(j, HI_SEQ);
}

static void copy_row(St *S, int64_t dst, int64_t src) {
    HI(dst, 0) = HI(src, 0);
    HI(dst, 1) = HI(src, 1);
    HI(dst, 2) = HI(src, 2);
    HI(dst, 3) = HI(src, 3);
    HI(dst, 4) = HI(src, 4);
    HF(dst, 0) = HF(src, 0);
    HF(dst, 1) = HF(src, 1);
}

static void heap_push(St *S, double t, int64_t kind, int64_t seq,
                      int64_t a, int64_t b, int64_t c, double start) {
    int64_t pos = S->si[SI_HEAP_LEN];
    S->si[SI_HEAP_LEN] = pos + 1;
    while (pos > 0) {
        int64_t parent = (pos - 1) >> 1;
        if (lt_item(S, t, kind, seq, parent)) {
            copy_row(S, pos, parent);
            pos = parent;
        } else {
            break;
        }
    }
    HI(pos, HI_KIND) = kind;
    HI(pos, HI_SEQ) = seq;
    HI(pos, HI_A) = a;
    HI(pos, HI_B) = b;
    HI(pos, HI_C) = c;
    HF(pos, HF_TIME) = t;
    HF(pos, HF_START) = start;
}

static Ev heap_pop(St *S) {
    int64_t n = S->si[SI_HEAP_LEN] - 1;
    Ev last, root;
    int64_t pos, childpos;
    S->si[SI_HEAP_LEN] = n;
    last.t = HF(n, HF_TIME);
    last.kind = HI(n, HI_KIND);
    last.seq = HI(n, HI_SEQ);
    last.a = HI(n, HI_A);
    last.b = HI(n, HI_B);
    last.c = HI(n, HI_C);
    last.start = HF(n, HF_START);
    if (n == 0) return last;
    root.t = HF(0, HF_TIME);
    root.kind = HI(0, HI_KIND);
    root.seq = HI(0, HI_SEQ);
    root.a = HI(0, HI_A);
    root.b = HI(0, HI_B);
    root.c = HI(0, HI_C);
    root.start = HF(0, HF_START);
    pos = 0;
    childpos = 1;
    while (childpos < n) {
        int64_t rightpos = childpos + 1;
        if (rightpos < n && !heap_lt(S, childpos, rightpos))
            childpos = rightpos;
        copy_row(S, pos, childpos);
        pos = childpos;
        childpos = 2 * pos + 1;
    }
    while (pos > 0) {
        int64_t parent = (pos - 1) >> 1;
        if (lt_item(S, last.t, last.kind, last.seq, parent)) {
            copy_row(S, pos, parent);
            pos = parent;
        } else {
            break;
        }
    }
    HI(pos, HI_KIND) = last.kind;
    HI(pos, HI_SEQ) = last.seq;
    HI(pos, HI_A) = last.a;
    HI(pos, HI_B) = last.b;
    HI(pos, HI_C) = last.c;
    HF(pos, HF_TIME) = last.t;
    HF(pos, HF_START) = last.start;
    return root;
}

/* ---------------------------------------------------- machine primitives */
static void refresh_active(St *S) {
    int64_t n, r;
    if (S->si[SI_ACTIVE_DIRTY] == 0) return;
    n = 0;
    for (r = 0; r < S->ci[CI_NRUNS]; r++) {
        double fin = RF(r, RF_FIN);
        if (RI(r, RI_LAUNCHED) != 0 && fin != fin) {
            S->act[n] = r;
            n += 1;
        }
    }
    S->si[SI_ACTIVE_N] = n;
    S->si[SI_ACTIVE_DIRTY] = 0;
}

static int64_t pol_residency_cap(St *S, int64_t r) {
    int64_t pol = S->ci[CI_POLICY];
    if (pol == POL_FIFO_CAP) return S->ci[CI_FIXED_CAP];
    if (pol == POL_MPMAX) {
        int64_t cap = RI(r, RI_MPCAP);
        if (cap >= 0) return cap;
        return RI(r, RI_MAXR);
    }
    if (pol == POL_SRTF_ADAPTIVE) {
        int64_t cap = RI(r, RI_ADPCAP);
        if (S->si[SI_SHARING] != 0 && cap >= 0) return cap;
        return RI(r, RI_MAXR);
    }
    return RI(r, RI_MAXR);
}

static int can_fit(St *S, int64_t r, int64_t sm) {
    int64_t cap;
    if (RI(r, RI_NUMB) - RI(r, RI_ISSUED) <= 0) return 0;
    cap = RI(r, RI_MAXR);
    if (S->ci[CI_UNLIMITED] == 0) {
        int64_t pcap = pol_residency_cap(S, r);
        if (pcap < cap) cap = pcap;
    }
    if (PSI(r, sm, PI_RES) >= cap) return 0;
    if (SMI(sm, SMI_FREETOP) <= 0) return 0;
    if (SMI(sm, SMI_THR) + RI(r, RI_TPB) > MAX_THREADS_PER_SM) return 0;
    return SMF(sm) + RF(r, RF_FRAC) <= 1.0 + FS_EPS;
}

/* ---------------------------------------------------- predictor queries */
static double pred_remaining(St *S, int64_t r, int64_t sm) {
    double t;
    int64_t rb, res;
    if (RI(r, RI_PKNOWN) == 0) return NAN;
    t = PSF(r, sm, PF_PT);
    if (t != t) return NAN;
    rb = RI(r, RI_EXPECTED) - PSI(r, sm, PI_PDONE);
    if (rb < 0) rb = 0;
    res = PSI(r, sm, PI_PRESID);
    if (res <= 1) res = 1;
    return ((double)rb / (double)res) * t;
}

static double gpu_remaining(St *S, int64_t r) {
    double total = 0.0;
    int64_t count = 0, sm;
    if (RI(r, RI_PKNOWN) == 0) return NAN;
    for (sm = 0; sm < S->ci[CI_NSM]; sm++) {
        double t = PSF(r, sm, PF_PT);
        int64_t rb, res;
        if (t != t) continue;
        rb = RI(r, RI_EXPECTED) - PSI(r, sm, PI_PDONE);
        if (rb < 0) rb = 0;
        res = PSI(r, sm, PI_PRESID);
        if (res <= 1) res = 1;
        total = total + ((double)rb / (double)res) * t;
        count += 1;
    }
    if (count == 0) return NAN;
    return total / (double)count;
}

static double gpu_predicted_total(St *S, int64_t r, double now) {
    double total = 0.0;
    int64_t count = 0, sm;
    if (RI(r, RI_PKNOWN) == 0) return NAN;
    for (sm = 0; sm < S->ci[CI_NSM]; sm++) {
        double t = PSF(r, sm, PF_PT);
        double remaining, active;
        int64_t rb, res;
        if (t != t) continue;
        rb = RI(r, RI_EXPECTED) - PSI(r, sm, PI_PDONE);
        if (rb < 0) rb = 0;
        res = PSI(r, sm, PI_PRESID);
        if (res <= 1) res = 1;
        remaining = ((double)rb / (double)res) * t;
        active = PSF(r, sm, PF_PACT);
        if (PSI(r, sm, PI_PRUN) > 0)
            active = active + (now - PSF(r, sm, PF_PSINCE));
        total = total + (active + remaining);
        count += 1;
    }
    if (count == 0) return NAN;
    return total / (double)count;
}

/* --------------------------------------------------- predictor handlers */
static void observe(St *S, int64_t r, int64_t sm, double duration) {
    if (S->ci[CI_PRED_KIND] == 1) {
        double t;
        PSI(r, sm, PI_PRESLICE) = 0;
        if (duration != duration) return;
        t = PSF(r, sm, PF_PT);
        if (t != t) {
            PSF(r, sm, PF_PT) = duration;
        } else {
            double alpha = S->cf[CF_ALPHA];
            PSF(r, sm, PF_PT) = alpha * duration + (1.0 - alpha) * t;
        }
    } else {
        double t = PSF(r, sm, PF_PT);
        if (PSI(r, sm, PI_PRESLICE) != 0 || t != t) {
            if (duration == duration) PSF(r, sm, PF_PT) = duration;
            PSI(r, sm, PI_PRESLICE) = 0;
        }
    }
}

static void pred_on_launch(St *S, int64_t r) {
    int64_t nsm = S->ci[CI_NSM], sm, slot, other;
    int64_t residency = RI(r, RI_MAXR);
    if (residency < 1) residency = 1;
    for (sm = 0; sm < nsm; sm++) {
        PSI(r, sm, PI_PDONE) = 0;
        PSI(r, sm, PI_PRESID) = residency;
        PSI(r, sm, PI_PRESLICE) = 1;
        PSI(r, sm, PI_PRUN) = 0;
        PSF(r, sm, PF_PT) = NAN;
        PSF(r, sm, PF_PACT) = 0.0;
        PSF(r, sm, PF_PSINCE) = 0.0;
        for (slot = 0; slot < MAX_BLOCK_SLOTS; slot++)
            BS(r, sm, slot) = NAN;
    }
    RI(r, RI_PKNOWN) = 1;
    for (other = 0; other < S->ci[CI_NRUNS]; other++) {
        if (other == r || RI(other, RI_PKNOWN) == 0) continue;
        for (sm = 0; sm < nsm; sm++)
            PSI(other, sm, PI_PRESLICE) = 1;
    }
}

static void pred_on_kernel_end(St *S, int64_t r) {
    int64_t other, sm;
    for (other = 0; other < S->ci[CI_NRUNS]; other++) {
        if (other == r || RI(other, RI_PKNOWN) == 0) continue;
        for (sm = 0; sm < S->ci[CI_NSM]; sm++)
            PSI(other, sm, PI_PRESLICE) = 1;
    }
}

static void pred_on_block_start(St *S, int64_t r, int64_t sm, int64_t slot,
                                double now) {
    BS(r, sm, slot) = now;
    if (PSI(r, sm, PI_PRUN) == 0) PSF(r, sm, PF_PSINCE) = now;
    PSI(r, sm, PI_PRUN) += 1;
}

static double pred_on_block_end(St *S, int64_t r, int64_t sm, int64_t slot,
                                double now) {
    double start, t, remaining, active;
    int64_t rc, rb, res;
    PSI(r, sm, PI_PDONE) += 1;
    start = BS(r, sm, slot);
    BS(r, sm, slot) = NAN;
    {
        double pt = PSF(r, sm, PF_PT);
        if (PSI(r, sm, PI_PRESLICE) != 0 || pt != pt
                || S->ci[CI_PRED_KIND] == 1) {
            if (start != start)
                observe(S, r, sm, NAN);
            else
                observe(S, r, sm, now - start);
        }
    }
    rc = PSI(r, sm, PI_PRUN) - 1;
    PSI(r, sm, PI_PRUN) = rc > 0 ? rc : 0;
    if (rc <= 0)
        PSF(r, sm, PF_PACT) = PSF(r, sm, PF_PACT)
            + (now - PSF(r, sm, PF_PSINCE));
    t = PSF(r, sm, PF_PT);
    if (t != t) return NAN;
    rb = RI(r, RI_EXPECTED) - PSI(r, sm, PI_PDONE);
    if (rb < 0) rb = 0;
    res = PSI(r, sm, PI_PRESID);
    if (res <= 1) res = 1;
    remaining = ((double)rb / (double)res) * t;
    active = PSF(r, sm, PF_PACT);
    if (PSI(r, sm, PI_PRUN) > 0)
        active = active + (now - PSF(r, sm, PF_PSINCE));
    return active + remaining;
}

static void pred_on_residency_change(St *S, int64_t r, int64_t sm,
                                     int64_t new_residency) {
    if (new_residency < 1) new_residency = 1;
    if (PSI(r, sm, PI_PRESID) != new_residency) {
        PSI(r, sm, PI_PRESID) = new_residency;
        PSI(r, sm, PI_PRESLICE) = 1;
    }
}

static void broadcast_t(St *S, int64_t r, double t, int64_t from_sm) {
    int64_t sm;
    for (sm = 0; sm < S->ci[CI_NSM]; sm++) {
        double pt;
        if (sm == from_sm) continue;
        pt = PSF(r, sm, PF_PT);
        if (pt != pt) {
            PSF(r, sm, PF_PT) = t;
            PSI(r, sm, PI_PRESLICE) = 0;
        }
    }
}

static void sync_residency_caps(St *S) {
    int64_t i;
    refresh_active(S);
    for (i = 0; i < S->si[SI_ACTIVE_N]; i++) {
        int64_t r = S->act[i], cap, sm;
        if (RI(r, RI_PKNOWN) == 0) continue;
        cap = RI(r, RI_MAXR);
        if (S->ci[CI_UNLIMITED] == 0) {
            int64_t pcap = pol_residency_cap(S, r);
            if (pcap < cap) cap = pcap;
        }
        if (RI(r, RI_SYNCED) == cap) continue;
        for (sm = 0; sm < S->ci[CI_NSM]; sm++)
            pred_on_residency_change(S, r, sm, cap);
        RI(r, RI_SYNCED) = cap;
    }
}

/* ---------------------------------------------------------- policy layer */
static void mpmax_recompute(St *S) {
    int64_t r, i, n;
    refresh_active(S);
    for (r = 0; r < S->ci[CI_NRUNS]; r++)
        RI(r, RI_MPCAP) = -1;
    n = S->si[SI_ACTIVE_N];
    for (i = 0; i < n; i++) {
        int64_t rr = S->act[i], j, cap;
        double reserved = 0.0;
        for (j = 0; j < n; j++) {
            int64_t other = S->act[j];
            if (other != rr) reserved = reserved + RF(other, RF_FRAC);
        }
        cap = (int64_t)floor((double)RI(rr, RI_MAXR) * (1.0 - reserved));
        if (cap < 1) cap = 1;
        RI(rr, RI_MPCAP) = cap;
    }
}

static void start_next_sample(St *S) {
    while (S->si[SI_SAMPLING] < 0 && S->si[SI_QHEAD] < S->si[SI_QTAIL]) {
        int64_t r = S->q[S->si[SI_QHEAD]];
        double fin;
        S->si[SI_QHEAD] += 1;
        if (RI(r, RI_ELIG) != 0) continue;
        fin = RF(r, RF_FIN);
        if (fin == fin) continue;
        S->si[SI_SAMPLING] = r;
    }
}

static void queue_remove(St *S, int64_t r) {
    int64_t head = S->si[SI_QHEAD], tail = S->si[SI_QTAIL], i, j;
    for (i = head; i < tail; i++) {
        if (S->q[i] == r) {
            for (j = i; j < tail - 1; j++)
                S->q[j] = S->q[j + 1];
            S->si[SI_QTAIL] = tail - 1;
            return;
        }
    }
}

static double srtf_remaining(St *S, int64_t r, int64_t sm) {
    double rem;
    if (S->ci[CI_POLICY] == POL_SRTF_ZERO) {
        double rt = RF(r, RF_ORACLE);
        if (rt == rt) {
            int64_t numb = RI(r, RI_NUMB);
            double frac_left;
            if (numb < 1) numb = 1;
            frac_left = 1.0 - (double)RI(r, RI_DONE) / (double)numb;
            return rt * frac_left;
        }
    }
    rem = pred_remaining(S, r, sm);
    if (rem == rem) return rem;
    rem = gpu_remaining(S, r);
    if (rem == rem) return rem;
    return INFINITY;
}

static int64_t best_candidate(St *S, int64_t sm) {
    int64_t n, sole = -1, count = 0, i, best = -1;
    double best_rem = 0.0;
    refresh_active(S);
    n = S->si[SI_ACTIVE_N];
    for (i = 0; i < n; i++) {
        int64_t r = S->act[i];
        if (RI(r, RI_ELIG) == 0) continue;
        if (RI(r, RI_NUMB) > RI(r, RI_ISSUED)) {
            count += 1;
            if (count > 1) break;
            sole = r;
        }
    }
    if (count == 0) return -1;
    if (count == 1) return sole;
    for (i = 0; i < n; i++) {
        int64_t r = S->act[i];
        double rem;
        if (RI(r, RI_ELIG) == 0) continue;
        if (RI(r, RI_NUMB) <= RI(r, RI_ISSUED)) continue;
        rem = srtf_remaining(S, r, sm);
        if (best < 0 || rem < best_rem) {
            best = r;
            best_rem = rem;
        }
    }
    return best;
}

static int64_t adaptive_candidates(St *S, int64_t sm) {
    int64_t m = 0, i;
    refresh_active(S);
    for (i = 0; i < S->si[SI_ACTIVE_N]; i++) {
        int64_t r = S->act[i];
        if (RI(r, RI_ELIG) != 0 && RI(r, RI_NUMB) > RI(r, RI_ISSUED)) {
            S->cand[m] = r;
            S->crem[m] = srtf_remaining(S, r, sm);
            m += 1;
        }
    }
    for (i = 1; i < m; i++) {
        int64_t kr = S->cand[i], j = i - 1;
        double kv = S->crem[i];
        while (j >= 0 && S->crem[j] > kv) {
            S->cand[j + 1] = S->cand[j];
            S->crem[j + 1] = S->crem[j];
            j -= 1;
        }
        S->cand[j + 1] = kr;
        S->crem[j + 1] = kv;
    }
    return m;
}

static int64_t adaptive_loser_cap(St *S, int64_t r, int64_t winner) {
    int64_t shared_w = S->ci[CI_SHARED_RES];
    int64_t wmax = RI(winner, RI_MAXR), cap;
    double free_frac;
    if (wmax < shared_w) shared_w = wmax;
    free_frac = 1.0 - (double)shared_w * RF(winner, RF_FRAC);
    cap = (int64_t)floor(free_frac * (double)RI(r, RI_MAXR));
    if (cap < 1) cap = 1;
    return cap;
}

static int64_t adaptive_cap_now(St *S, int64_t r) {
    int64_t cap = RI(r, RI_ADPCAP);
    if (cap >= 0) return cap;
    return RI(r, RI_MAXR);
}

static void adaptive_reevaluate(St *S, double now) {
    int sharing, ok = 1, want, changed;
    int64_t nrows = 0, i, winner, w_cap_now, wmax, cur_cap, shared_w;
    double acc, ex_max = 0.0, ex_min = 0.0, gap_excl;
    double ts1, s0, sh_max, sh_min, gap_shared;
    refresh_active(S);
    sharing = S->si[SI_SHARING] != 0;
    if (!sharing && S->si[SI_ACTIVE_N] < 2) return;
    for (i = 0; i < S->si[SI_ACTIVE_N]; i++) {
        int64_t r = S->act[i];
        if (RI(r, RI_ELIG) == 0) continue;
        S->rwi[nrows] = r;
        nrows += 1;
    }
    if (nrows < 2) ok = 0;
    if (ok) {
        for (i = 0; i < nrows; i++) {
            int64_t r = S->rwi[i];
            double rem = gpu_remaining(S, r), solo;
            if (rem != rem) { ok = 0; break; }
            solo = RF(r, RF_EXCL);
            if (solo != solo) solo = gpu_predicted_total(S, r, now);
            if (solo != solo || solo <= 0.0) { ok = 0; break; }
            RWF(i, RW_REM) = rem;
            RWF(i, RW_ELAPSED) = now - RF(r, RF_ARRT);
            RWF(i, RW_SOLO) = solo;
        }
    }
    if (!ok) {
        if (sharing) {
            int64_t r;
            S->si[SI_SHARING] = 0;
            for (r = 0; r < S->ci[CI_NRUNS]; r++)
                RI(r, RI_ADPCAP) = -1;
            sync_residency_caps(S);
        }
        return;
    }
    for (i = 1; i < nrows; i++) {
        int64_t kr = S->rwi[i], j = i - 1;
        double v0 = RWF(i, RW_REM);
        double v1 = RWF(i, RW_ELAPSED);
        double v2 = RWF(i, RW_SOLO);
        while (j >= 0 && RWF(j, RW_REM) > v0) {
            S->rwi[j + 1] = S->rwi[j];
            RWF(j + 1, RW_REM) = RWF(j, RW_REM);
            RWF(j + 1, RW_ELAPSED) = RWF(j, RW_ELAPSED);
            RWF(j + 1, RW_SOLO) = RWF(j, RW_SOLO);
            j -= 1;
        }
        S->rwi[j + 1] = kr;
        RWF(j + 1, RW_REM) = v0;
        RWF(j + 1, RW_ELAPSED) = v1;
        RWF(j + 1, RW_SOLO) = v2;
    }
    acc = 0.0;
    for (i = 0; i < nrows; i++) {
        double s;
        acc = acc + RWF(i, RW_REM);
        s = (RWF(i, RW_ELAPSED) + acc) / RWF(i, RW_SOLO);
        if (i == 0) {
            ex_max = s;
            ex_min = s;
        } else {
            if (s > ex_max) ex_max = s;
            if (s < ex_min) ex_min = s;
        }
    }
    gap_excl = ex_max - ex_min;
    winner = S->rwi[0];
    w_cap_now = adaptive_cap_now(S, winner);
    wmax = RI(winner, RI_MAXR);
    cur_cap = w_cap_now < wmax ? w_cap_now : wmax;
    if (cur_cap < 1) cur_cap = 1;
    shared_w = S->ci[CI_SHARED_RES];
    if (wmax < shared_w) shared_w = wmax;
    ts1 = RWF(0, RW_REM) * (double)cur_cap / (double)shared_w;
    s0 = (RWF(0, RW_ELAPSED) + ts1) / RWF(0, RW_SOLO);
    sh_max = s0;
    sh_min = s0;
    for (i = 1; i < nrows; i++) {
        int64_t r = S->rwi[i];
        int64_t full = RI(r, RI_MAXR);
        int64_t shared_cap = adaptive_loser_cap(S, r, winner);
        int64_t cur = adaptive_cap_now(S, r);
        double s_l, s;
        if (cur > full) cur = full;
        if (cur < 1) cur = 1;
        s_l = RWF(i, RW_REM) * (double)cur / (double)shared_cap;
        if (s_l <= ts1) {
            s = (RWF(i, RW_ELAPSED) + s_l) / RWF(i, RW_SOLO);
        } else {
            double tail = (s_l - ts1) * (double)shared_cap / (double)full;
            s = (RWF(i, RW_ELAPSED) + ts1 + tail) / RWF(i, RW_SOLO);
        }
        if (s > sh_max) sh_max = s;
        if (s < sh_min) sh_min = s;
    }
    gap_shared = sh_max - sh_min;
    want = (gap_excl > S->cf[CF_THRESHOLD]
            && gap_shared < gap_excl - S->cf[CF_HYSTERESIS]);
    if (want) {
        for (i = 0; i < nrows; i++) {
            int64_t r = S->rwi[i], cap;
            if (r == winner) {
                cap = S->ci[CI_SHARED_RES];
                if (RI(r, RI_MAXR) < cap) cap = RI(r, RI_MAXR);
            } else {
                cap = adaptive_loser_cap(S, r, winner);
            }
            S->newc[i] = cap;
        }
    }
    changed = want != sharing;
    if (!changed) {
        int64_t old_n = 0, r;
        for (r = 0; r < S->ci[CI_NRUNS]; r++)
            if (RI(r, RI_ADPCAP) >= 0) old_n += 1;
        if (want) {
            if (old_n != nrows) {
                changed = 1;
            } else {
                for (i = 0; i < nrows; i++) {
                    if (RI(S->rwi[i], RI_ADPCAP) != S->newc[i]) {
                        changed = 1;
                        break;
                    }
                }
            }
        } else {
            changed = old_n != 0;
        }
    }
    if (changed) {
        int64_t r;
        S->si[SI_SHARING] = want ? 1 : 0;
        for (r = 0; r < S->ci[CI_NRUNS]; r++)
            RI(r, RI_ADPCAP) = -1;
        if (want) {
            for (i = 0; i < nrows; i++)
                RI(S->rwi[i], RI_ADPCAP) = S->newc[i];
        }
        sync_residency_caps(S);
    }
}

static int64_t fs_decide(St *S, int64_t sm, int64_t *out_r) {
    int64_t pol = S->ci[CI_POLICY], i, k;
    *out_r = -1;
    if (pol == POL_FIFO || pol == POL_FIFO_CAP) {
        refresh_active(S);
        for (i = 0; i < S->si[SI_ACTIVE_N]; i++) {
            int64_t r = S->act[i];
            if (RI(r, RI_NUMB) > RI(r, RI_ISSUED)) {
                if (can_fit(S, r, sm)) {
                    *out_r = r;
                    return DEC_GRANT;
                }
                return DEC_HOLD_HEAD;
            }
        }
        return DEC_HOLD_NO_UNDISP;
    }
    if (pol == POL_SJF || pol == POL_LJF) {
        int64_t best = -1;
        double best_key = 0.0;
        refresh_active(S);
        for (i = 0; i < S->si[SI_ACTIVE_N]; i++) {
            int64_t r = S->act[i];
            double kk;
            if (RI(r, RI_NUMB) <= RI(r, RI_ISSUED)) continue;
            kk = RF(r, RF_SJFKEY);
            if (best < 0 || kk < best_key) {
                best = r;
                best_key = kk;
            }
        }
        if (best < 0) return DEC_HOLD_NO_UNDISP;
        if (can_fit(S, best, sm)) {
            *out_r = best;
            return DEC_GRANT;
        }
        return DEC_HOLD_HEAD;
    }
    if (pol == POL_MPMAX) {
        refresh_active(S);
        for (i = 0; i < S->si[SI_ACTIVE_N]; i++) {
            int64_t r = S->act[i];
            if (RI(r, RI_NUMB) > RI(r, RI_ISSUED) && can_fit(S, r, sm)) {
                *out_r = r;
                return DEC_GRANT;
            }
        }
        return DEC_HOLD_MPMAX;
    }
    if (pol == POL_SRTF_ADAPTIVE && S->si[SI_SHARING] != 0) {
        int64_t m;
        if (S->si[SI_SAMPLING] >= 0 && sm == S->ci[CI_SAMPLE_SM]) {
            k = S->si[SI_SAMPLING];
            if (RI(k, RI_NUMB) > RI(k, RI_ISSUED) && can_fit(S, k, sm)) {
                *out_r = k;
                return DEC_SAMPLE;
            }
            return DEC_HOLD_SAMPLING;
        }
        m = adaptive_candidates(S, sm);
        for (i = 0; i < m; i++) {
            if (can_fit(S, S->cand[i], sm)) {
                *out_r = S->cand[i];
                return DEC_GRANT;
            }
        }
        return DEC_HOLD_ADAPTIVE;
    }
    if (S->si[SI_SAMPLING] >= 0 && sm == S->ci[CI_SAMPLE_SM]) {
        k = S->si[SI_SAMPLING];
        if (RI(k, RI_NUMB) > RI(k, RI_ISSUED) && can_fit(S, k, sm)) {
            *out_r = k;
            return DEC_SAMPLE;
        }
        return DEC_HOLD_SAMPLING;
    }
    k = best_candidate(S, sm);
    if (k < 0) return DEC_HOLD_NO_ELIG;
    if (can_fit(S, k, sm)) {
        *out_r = k;
        return DEC_GRANT;
    }
    *out_r = k;
    return DEC_PREEMPT;
}

static void pol_on_arrival(St *S, int64_t r, double now) {
    int64_t pol = S->ci[CI_POLICY];
    if (pol == POL_MPMAX) {
        mpmax_recompute(S);
        return;
    }
    if (pol == POL_SRTF_ZERO) {
        RI(r, RI_ELIG) = 1;
        return;
    }
    if (pol == POL_SRTF || pol == POL_SRTF_ADAPTIVE) {
        refresh_active(S);
        if (S->si[SI_ACTIVE_N] == 1) {
            RI(r, RI_ELIG) = 1;
        } else {
            S->q[S->si[SI_QTAIL]] = r;
            S->si[SI_QTAIL] += 1;
            start_next_sample(S);
        }
        if (pol == POL_SRTF_ADAPTIVE)
            adaptive_reevaluate(S, now);
    }
}

static void pol_on_block_end(St *S, int64_t r, int64_t sm, double now) {
    int64_t pol = S->ci[CI_POLICY];
    if (pol < POL_SRTF) return;
    if (r == S->si[SI_SAMPLING] && sm == S->ci[CI_SAMPLE_SM]) {
        double t = PSF(r, sm, PF_PT);
        if (t == t) {
            broadcast_t(S, r, t, sm);
            RI(r, RI_ELIG) = 1;
            S->si[SI_SAMPLING] = -1;
            start_next_sample(S);
        }
    }
    if (pol == POL_SRTF_ADAPTIVE) {
        if (S->si[SI_SHARING] == 0) {
            refresh_active(S);
            if (S->si[SI_ACTIVE_N] > 1 || S->si[SI_PENDING] > 0
                    || S->ci[CI_HAS_SOURCE] != 0) {
                double pred = gpu_predicted_total(S, r, now);
                if (pred == pred) RF(r, RF_EXCL) = pred;
            }
        }
        adaptive_reevaluate(S, now);
    }
}

static void pol_on_kernel_end(St *S, int64_t r, double now) {
    int64_t pol = S->ci[CI_POLICY];
    if (pol == POL_MPMAX) {
        mpmax_recompute(S);
        return;
    }
    if (pol < POL_SRTF) return;
    RI(r, RI_ELIG) = 0;
    if (S->si[SI_SAMPLING] == r) S->si[SI_SAMPLING] = -1;
    queue_remove(S, r);
    start_next_sample(S);
    refresh_active(S);
    if (S->si[SI_ACTIVE_N] == 1)
        RI(S->act[0], RI_ELIG) = 1;
    if (pol == POL_SRTF_ADAPTIVE) {
        RF(r, RF_EXCL) = NAN;
        adaptive_reevaluate(S, now);
    }
}

/* ------------------------------------------------------------ issue loop */
static void finalize_block(St *S, int64_t r, int64_t sm, int64_t slot,
                           int64_t noise_idx, int64_t first_wave,
                           double now) {
    int64_t residency = PSI(r, sm, PI_RES), maxr, idx, i, seq;
    double corunner_warps = 0.0, t, base, duration, end;
    refresh_active(S);
    for (i = 0; i < S->si[SI_ACTIVE_N]; i++) {
        int64_t other = S->act[i], cnt;
        if (other == r) continue;
        cnt = PSI(other, sm, PI_RES);
        if (cnt != 0)
            corunner_warps = corunner_warps
                + ((RF(other, RF_CPRESS) * (double)cnt)
                   * (double)RI(other, RI_WARPS));
    }
    maxr = RI(r, RI_MAXR);
    idx = residency < maxr ? residency : maxr;
    t = S->bt_pool[RI(r, RI_BT_OFF) + idx];
    if (corunner_warps > 0.0)
        t = t * (1.0 + RF(r, RF_CSENS) * (corunner_warps
                                          / MAX_WARPS_PER_SM));
    if (first_wave != 0 && RF(r, RF_STARTUP) > 0.0)
        t = t * (1.0 + RF(r, RF_STARTUP));
    base = t > 1.0 ? t : 1.0;
    duration = base * S->np_pool[RI(r, RI_NOISE_OFF) + noise_idx];
    if (S->ci[CI_DRIVE_PRED] != 0)
        pred_on_block_start(S, r, sm, slot, now);
    end = now + duration;
    seq = S->si[SI_SEQ];
    S->si[SI_SEQ] = seq + 1;
    heap_push(S, end, EV_BLOCK_END, seq, r, sm, slot, now);
    if (S->ci[CI_REC_TRACE] != 0) {
        int64_t n = S->si[SI_TRACE_N];
        TRI(n, 0) = r;
        TRI(n, 1) = sm;
        TRI(n, 2) = slot;
        TRF(n, 0) = now;
        TRF(n, 1) = end;
        S->si[SI_TRACE_N] = n + 1;
    }
}

static void try_issue(St *S, int64_t sm, double now) {
    int64_t batch[MAX_BLOCK_SLOTS][4];
    int64_t nb = 0, i;
    for (;;) {
        int64_t r, code, top, slot, issued_on_sm, noise_idx, first_wave;
        double gate;
        code = fs_decide(S, sm, &r);
        if (S->ci[CI_REC_DEC] != 0) {
            int64_t n = S->si[SI_DEC_N];
            DCI(n, 0) = sm;
            DCI(n, 1) = code;
            DCI(n, 2) = r;
            DCF(n) = now;
            S->si[SI_DEC_N] = n + 1;
        }
        if (code > DEC_SAMPLE) break;
        gate = PSF(r, sm, PF_GATE);
        if (gate > now + FS_EPS) {
            int64_t seq = S->si[SI_SEQ];
            S->si[SI_SEQ] = seq + 1;
            heap_push(S, gate, EV_TRY_ISSUE, seq, sm, 0, 0, 0.0);
            break;
        }
        top = SMI(sm, SMI_FREETOP) - 1;
        SMI(sm, SMI_FREETOP) = top;
        slot = SMI(sm, SMI_FS0 + top);
        SL(sm, slot) = r;
        SMI(sm, SMI_THR) = SMI(sm, SMI_THR) + RI(r, RI_TPB);
        SMF(sm) = SMF(sm) + RF(r, RF_FRAC);
        PSI(r, sm, PI_RES) += 1;
        issued_on_sm = PSI(r, sm, PI_ISSD);
        PSI(r, sm, PI_ISSD) = issued_on_sm + 1;
        {
            double first = RF(r, RF_FIRST);
            if (first != first) RF(r, RF_FIRST) = now;
        }
        first_wave = issued_on_sm < RI(r, RI_MAXR) ? 1 : 0;
        noise_idx = RI(r, RI_ISSUED);
        RI(r, RI_ISSUED) = noise_idx + 1;
        if (first_wave != 0 && PSI(r, sm, PI_STAG) != 0)
            PSF(r, sm, PF_GATE) = now + RF(r, RF_STAGF) * RF(r, RF_MEANT);
        batch[nb][0] = r;
        batch[nb][1] = slot;
        batch[nb][2] = noise_idx;
        batch[nb][3] = first_wave;
        nb += 1;
    }
    for (i = 0; i < nb; i++)
        finalize_block(S, batch[i][0], sm, batch[i][1], batch[i][2],
                       batch[i][3], now);
}

static void fan_out(St *S, double now) {
    int64_t sm;
    for (sm = 0; sm < S->ci[CI_NSM]; sm++)
        try_issue(S, sm, now);
}

static void src_inject(St *S, int64_t r2, double t, double now) {
    int64_t seq;
    if (t < now)
        t = now;
    RI(r2, RI_STAGED) = 0;
    RF(r2, RF_ARRT) = t;
    S->si[SI_PENDING] += 1;
    seq = S->si[SI_SEQ];
    S->si[SI_SEQ] = seq + 1;
    heap_push(S, t, EV_ARRIVAL, seq, r2, 0, 0, 0.0);
    S->si[SI_ACTIVE_DIRTY] = 1;
}

static int64_t src_release_mgk(St *S, double now) {
    while (S->srci[SRC_INSYS] < S->srci[SRC_POP]) {
        int64_t k = S->srci[SRC_NEXT];
        if (k >= S->srci[SRC_NSTAGED]) {
            if (S->srci[SRC_MORE] != 0)
                return 7;
            return 0;
        }
        S->srci[SRC_NEXT] = k + 1;
        S->srci[SRC_INSYS] += 1;
        src_inject(S, S->srci[SRC_BASE] + k, S->srcf[k], now);
    }
    return 0;
}

static int64_t src_feed_think(St *S, int64_t r, double now) {
    int64_t ten = RI(r, RI_TENANT), k, r2;
    if (ten < 0)
        return 0;
    if (S->srci[SRC_RD0 + ten] >= S->srci[SRC_NROUNDS])
        return 0;
    k = S->srci[SRC_NEXT];
    if (k >= S->srci[SRC_NSTAGED]) {
        S->srci[SRC_PEND] = ten;
        return 7;
    }
    S->srci[SRC_NEXT] = k + 1;
    S->srci[SRC_RD0 + ten] += 1;
    r2 = S->srci[SRC_BASE] + k;
    RI(r2, RI_TENANT) = ten;
    src_inject(S, r2, now + S->srcf[k], now);
    return 0;
}

static int64_t src_on_completion(St *S, int64_t r, double now) {
    int64_t mode = S->ci[CI_SRC_MODE];
    if (mode == SRCMODE_MGK) {
        if (RI(r, RI_SRC) == 0)
            return 0;
        S->srci[SRC_INSYS] -= 1;
        return src_release_mgk(S, now);
    }
    if (mode == SRCMODE_THINK)
        return src_feed_think(S, r, now);
    return 2;
}

static int64_t src_resume(St *S, double now) {
    int64_t mode = S->ci[CI_SRC_MODE];
    if (mode == SRCMODE_MGK)
        return src_release_mgk(S, now);
    if (mode == SRCMODE_THINK) {
        int64_t ten = S->srci[SRC_PEND], k, r2;
        if (ten < 0)
            return 0;
        k = S->srci[SRC_NEXT];
        if (k >= S->srci[SRC_NSTAGED])
            return 7;
        S->srci[SRC_PEND] = -1;
        S->srci[SRC_NEXT] = k + 1;
        S->srci[SRC_RD0 + ten] += 1;
        r2 = S->srci[SRC_BASE] + k;
        RI(r2, RI_TENANT) = ten;
        src_inject(S, r2, now + S->srcf[k], now);
    }
    return 0;
}

static int64_t handle_block_end(St *S, int64_t r, int64_t sm, int64_t slot,
                                double start, double now) {
    double frac = RF(r, RF_FRAC), pred = NAN, uf;
    int64_t top, ut;
    S->sd[SD_BUSY] = S->sd[SD_BUSY] + (now - start) * frac;
    SL(sm, slot) = -1;
    top = SMI(sm, SMI_FREETOP);
    SMI(sm, SMI_FS0 + top) = slot;
    SMI(sm, SMI_FREETOP) = top + 1;
    ut = SMI(sm, SMI_THR) - RI(r, RI_TPB);
    SMI(sm, SMI_THR) = ut > 0 ? ut : 0;
    uf = SMF(sm) - frac;
    SMF(sm) = uf > 0.0 ? uf : 0.0;
    PSI(r, sm, PI_RES) -= 1;
    RI(r, RI_DONE) += 1;
    if (S->ci[CI_DRIVE_PRED] != 0) {
        pred = pred_on_block_end(S, r, sm, slot, now);
        pol_on_block_end(S, r, sm, now);
    } else {
        pol_on_block_end(S, r, sm, now);
    }
    if (S->ci[CI_REC_PRED] != 0 && pred == pred) {
        int64_t n = S->si[SI_PRED_N];
        PRI(n, 0) = r;
        PRI(n, 1) = sm;
        PRI(n, 2) = PSI(r, sm, PI_PDONE);
        PRF(n, 0) = now;
        PRF(n, 1) = pred;
        S->si[SI_PRED_N] = n + 1;
    }
    if (RI(r, RI_DONE) == RI(r, RI_NUMB)) {
        RF(r, RF_FIN) = now;
        S->si[SI_ACTIVE_DIRTY] = 1;
        RI(r, RI_SYNCED) = -1;
        pred_on_kernel_end(S, r);
        pol_on_kernel_end(S, r, now);
        sync_residency_caps(S);
        if (S->ci[CI_HAS_SOURCE] != 0) {
            int64_t rc;
            S->si[SI_EXIT_RUN] = r;
            rc = src_on_completion(S, r, now);
            if (rc != 0)
                return rc;
        }
        fan_out(S, now);
    } else {
        try_issue(S, sm, now);
    }
    return -1;
}

static void handle_arrival(St *S, int64_t r, double now) {
    S->si[SI_PENDING] -= 1;
    RI(r, RI_LAUNCHED) = 1;
    S->si[SI_ACTIVE_DIRTY] = 1;
    pred_on_launch(S, r);
    pol_on_arrival(S, r, now);
    sync_residency_caps(S);
    fan_out(S, now);
}

int64_t fs_advance(
    int64_t *si, double *sd, int64_t *ci, double *cf,
    int64_t *ri, double *rf, int64_t *psi, double *psf,
    double *bs, int64_t *sl, int64_t *smi, double *smf,
    int64_t *hi, double *hf, int64_t *tri, double *trf,
    int64_t *dci, double *dcf, int64_t *pri, double *prf,
    int64_t *act, int64_t *q, int64_t *rwi, double *rwf,
    int64_t *newc, int64_t *cand, double *crem,
    double *np_pool, double *bt_pool,
    int64_t *srci, double *srcf) {
    St state;
    St *S = &state;
    int64_t nsm;
    state.si = si; state.sd = sd; state.ci = ci; state.cf = cf;
    state.ri = ri; state.rf = rf; state.psi = psi; state.psf = psf;
    state.bs = bs; state.sl = sl; state.smi = smi; state.smf = smf;
    state.hi = hi; state.hf = hf; state.tri = tri; state.trf = trf;
    state.dci = dci; state.dcf = dcf; state.pri = pri; state.prf = prf;
    state.act = act; state.q = q; state.rwi = rwi; state.rwf = rwf;
    state.newc = newc; state.cand = cand; state.crem = crem;
    state.np_pool = np_pool; state.bt_pool = bt_pool;
    state.srci = srci; state.srcf = srcf;
    state.nsm = ci[CI_NSM];
    nsm = state.nsm;
    if (si[SI_RESUME] != 0) {
        int64_t rc;
        si[SI_RESUME] = 0;
        rc = src_resume(S, sd[SD_NOW]);
        if (rc != 0)
            return rc;
        fan_out(S, sd[SD_NOW]);
    }
    for (;;) {
        Ev ev;
        if (si[SI_HEAP_LEN] + 9 * nsm + 8 + ci[CI_SRC_RESERVE]
                > ci[CI_HEAP_CAP])
            return 3;
        if (ci[CI_REC_TRACE] != 0
                && si[SI_TRACE_N] + 8 * nsm + 8 > ci[CI_TRACE_CAP])
            return 4;
        if (ci[CI_REC_DEC] != 0
                && si[SI_DEC_N] + 9 * nsm + 8 > ci[CI_DEC_CAP])
            return 5;
        if (ci[CI_REC_PRED] != 0 && si[SI_PRED_N] + 4 > ci[CI_PRED_CAP])
            return 6;
        if (si[SI_HEAP_LEN] == 0) return 0;
        ev = heap_pop(S);
        if (ev.t > sd[SD_HORIZON]) {
            double now = sd[SD_NOW];
            int64_t i;
            for (i = 0; i < si[SI_HEAP_LEN]; i++) {
                if (HI(i, HI_KIND) == EV_BLOCK_END) {
                    double frac = RF(HI(i, HI_A), RF_FRAC);
                    double d = now - HF(i, HF_START);
                    sd[SD_BUSY] = sd[SD_BUSY]
                        + (d > 0.0 ? d : 0.0) * frac;
                }
            }
            if (ev.kind == EV_BLOCK_END) {
                double frac = RF(ev.a, RF_FRAC);
                double d = now - ev.start;
                sd[SD_BUSY] = sd[SD_BUSY] + (d > 0.0 ? d : 0.0) * frac;
            }
            return 1;
        }
        sd[SD_NOW] = ev.t;
        if (ev.kind == EV_BLOCK_END) {
            int64_t rc = handle_block_end(S, ev.a, ev.b, ev.c, ev.start,
                                          ev.t);
            if (rc >= 0)
                return rc;
        } else if (ev.kind == EV_ARRIVAL) {
            handle_arrival(S, ev.a, ev.t);
        } else {
            try_issue(S, ev.a, ev.t);
        }
    }
}
"""


def c_source() -> str:
    return (
        "/* GENERATED from repro.core.fastsim_twin — do not edit the build\n"
        "   artifact; edit the twin and fastsim_c.py. */\n"
        + _c_defines() + "\n" + _C_BODY)


def _build_dir() -> Path:
    override = os.environ.get("REPRO_FASTSIM_CACHE")
    if override:
        return Path(override)
    return Path(__file__).resolve().parent / "_fastsim_build"


def _build_library() -> ctypes.CDLL:
    src = c_source()
    digest = hashlib.sha256(src.encode()).hexdigest()[:16]
    build = _build_dir()
    build.mkdir(parents=True, exist_ok=True)
    lib_path = build / f"fastsim_{digest}.so"
    if not lib_path.exists():
        c_path = build / f"fastsim_{digest}.c"
        c_path.write_text(src)
        # Unique temp then atomic replace: concurrent builders (parallel
        # sweep workers) race benignly to the same content-addressed name.
        tmp = build / f".fastsim_{digest}.{os.getpid()}.so"
        cc = os.environ.get("CC", "cc")
        subprocess.run(
            [cc, "-O2", "-fPIC", "-shared", "-ffp-contract=off",
             "-o", str(tmp), str(c_path), "-lm"],
            check=True, capture_output=True)
        os.replace(tmp, lib_path)
    return ctypes.CDLL(str(lib_path))


def native_advance():
    """Build (or load) the C engine; return ``advance(S) -> exit code``.

    Raises on any failure (no compiler, sandboxed tmp, bad toolchain);
    :mod:`repro.core.fastsim` treats that as "native backend absent" and
    falls back to the twin.
    """
    lib = _build_library()
    fn = lib.fs_advance
    fn.restype = ctypes.c_int64
    fn.argtypes = [ctypes.c_void_p] * 31

    _addressof = ctypes.addressof
    _from_buffer = ctypes.c_char.from_buffer
    # Pointer cache keyed by state-tuple identity: a numpy array's data
    # pointer is fixed for its lifetime, and an identical tuple object
    # means identical arrays — the chunk runner's reused scratch state
    # (fastsim staging prototype) hits this on every sibling cell.  The
    # entry holds the tuple itself, so a recycled id can never alias.
    cache: dict = {}

    def adv(S):
        entry = cache.get(id(S))
        if entry is not None and entry[0] is S:
            return fn(*entry[1])
        # addressof(c_char.from_buffer(a)) is the cheapest stable route to
        # a.ctypes.data (~4x less overhead: no per-array ctypes interface
        # object, no __array_interface__ dict) — 31 arrays, once per
        # simulation, so this is on the per-cell floor of tiny sweeps.
        ptrs = [_addressof(_from_buffer(arr)) for arr in S]
        if len(cache) >= 8:
            cache.clear()
        cache[id(S)] = (S, ptrs)
        return fn(*ptrs)

    return adv
