"""Job builders for the real-JAX lane executor: wrap the model zoo's train
and serve steps as schedulable grids of blocks.

A training job's block is one fixed-size microbatch optimizer step; a
serving job's block is one k-token decode chunk for a request batch.  Both
are homogeneous, which is exactly the structural property the paper's
predictor exploits.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ArchConfig
from repro.models import lm
from repro.optim import adamw

from .executor import ExecutorJob


def make_train_job(
    cfg: ArchConfig,
    name: str,
    *,
    blocks: int,
    batch: int = 4,
    seq: int = 64,
    max_residency: int = 4,
    arrival: float = 0.0,
    seed: int = 0,
    opt_cfg: adamw.OptConfig = adamw.OptConfig(lr=1e-3, warmup_steps=5,
                                               total_steps=1000),
    checkpointer: Optional[Checkpointer] = None,
    checkpoint_every: int = 0,
    resume: bool = False,
    tenant: Optional[str] = None,
) -> ExecutorJob:
    """A training job: ``blocks`` microbatch steps of a reduced model.

    Blocks mutate the job's (params, opt_state) held in a closure; because
    preemption happens only at block boundaries, the state is always
    consistent — checkpoint (if configured) and hand-off need no extra
    coordination.
    """
    key = jax.random.PRNGKey(seed)
    state = {"params": lm.init(cfg, key),
             "opt": None, "block": 0}
    state["opt"] = adamw.init(state["params"])
    if resume and checkpointer is not None and checkpointer.latest_step() is not None:
        step, restored, _ = checkpointer.restore(
            {"params": state["params"], "opt": state["opt"]})
        state["params"], state["opt"] = restored["params"], restored["opt"]
        state["block"] = step

    @jax.jit
    def train_step(params, opt_state, tokens):
        def loss(p):
            return lm.loss_fn(cfg, p, {"tokens": tokens})[0]
        loss_val, grads = jax.value_and_grad(loss)(params)
        new_p, new_s, _ = adamw.update(grads, opt_state, params, opt_cfg)
        return new_p, new_s, loss_val

    data_key = jax.random.PRNGKey(seed + 1)

    def warmup():
        tokens = jax.random.randint(jax.random.fold_in(data_key, 0),
                                    (batch, seq), 0, cfg.vocab_size)
        out = train_step(state["params"], state["opt"], tokens)
        jax.block_until_ready(out[2])   # compile only; discard results

    def make_block_fn(residency: int) -> Callable[[], None]:
        def block():
            i = state["block"]
            tokens = jax.random.randint(
                jax.random.fold_in(data_key, i), (batch, seq), 0,
                cfg.vocab_size)
            p, o, loss_val = train_step(state["params"], state["opt"], tokens)
            jax.block_until_ready(loss_val)
            state["params"], state["opt"] = p, o
            state["block"] = i + 1
            if (checkpointer is not None and checkpoint_every
                    and (i + 1) % checkpoint_every == 0):
                checkpointer.save(i + 1, {"params": p, "opt": o},
                                  {"job": name})
        return block

    return ExecutorJob(name=name, num_blocks=blocks - state["block"],
                       max_residency=max_residency,
                       make_block_fn=make_block_fn, arrival=arrival,
                       warmup_fn=warmup, tenant=tenant)


def make_serve_job(
    cfg: ArchConfig,
    name: str,
    *,
    blocks: int,
    tokens_per_block: int = 8,
    batch: int = 2,
    prompt_len: int = 16,
    max_residency: int = 4,
    arrival: float = 0.0,
    seed: int = 0,
    tenant: Optional[str] = None,
) -> ExecutorJob:
    """A serving job: ``blocks`` decode chunks of ``tokens_per_block`` each
    against a live KV cache (prefill happens in the first block)."""
    key = jax.random.PRNGKey(seed)
    max_seq = prompt_len + blocks * tokens_per_block + 8
    state: Dict = {"params": lm.init(cfg, key), "caches": None,
                   "lengths": None, "token": None}

    @jax.jit
    def do_prefill(params, tokens):
        return lm.prefill(cfg, params, tokens, max_seq=max_seq)

    @jax.jit
    def do_decode(params, token, caches, lengths):
        logits, caches = lm.decode_step(cfg, params, token, caches, lengths)
        return jnp.argmax(logits, -1), caches

    prompt = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)

    def warmup():
        logits, caches = do_prefill(state["params"], prompt)
        tok = jnp.argmax(logits, -1)
        lengths = jnp.full((batch,), prompt_len, jnp.int32)
        out = do_decode(state["params"], tok, caches, lengths)
        jax.block_until_ready(out[0])   # compile only; discard results

    def make_block_fn(residency: int) -> Callable[[], None]:
        def block():
            if state["caches"] is None:
                logits, caches = do_prefill(state["params"], prompt)
                state["caches"] = caches
                state["lengths"] = jnp.full((batch,), prompt_len, jnp.int32)
                state["token"] = jnp.argmax(logits, -1)
            for _ in range(tokens_per_block):
                tok, caches = do_decode(state["params"], state["token"],
                                        state["caches"], state["lengths"])
                state["token"] = tok
                state["caches"] = caches
                state["lengths"] = state["lengths"] + 1
            jax.block_until_ready(state["token"])
        return block

    return ExecutorJob(name=name, num_blocks=blocks,
                       max_residency=max_residency,
                       make_block_fn=make_block_fn, arrival=arrival,
                       warmup_fn=warmup, tenant=tenant)
