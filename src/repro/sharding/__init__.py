from .annotate import NULL_SHARDER, NullSharder, Sharder, profile_for

__all__ = ["NULL_SHARDER", "NullSharder", "Sharder", "profile_for"]
