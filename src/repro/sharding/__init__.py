from .annotate import NULL_SHARDER, NullSharder, Sharder, profile_for
