"""Parameter / optimizer / batch / cache PartitionSpec rules.

Role-aware 2D sharding: for every weight the "wide" structural dim (d_ff,
heads, experts, vocab, d_inner, lru width) shards over ``model`` and the
d_model-ish dim shards over ``data`` (FSDP).  Any dim that does not divide
its mesh axis stays unsharded — the rules are total, so every architecture
lowers on the same mesh.  Stacked-layer leaves get a leading ``None`` for
the repeats axis.

These rules are the *baseline*; EXPERIMENTS.md §Perf iterates on them for
the three hillclimb cells.
"""

from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def _div(n: int, mesh: Mesh, axis) -> Optional[str]:
    if axis is None or axis not in mesh.axis_names:
        return None
    return axis if n % mesh.shape[axis] == 0 else None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


#: (regex on path, spec builder taking (shape, mesh) -> tuple of axis names)
_RULES = [
    # embeddings / head
    (r"embed/table$", lambda s, m: (_div(s[0], m, "model"),
                                    _div(s[1], m, "data"))),
    (r"lm_head/w$", lambda s, m: (_div(s[0], m, "data"),
                                  _div(s[1], m, "model"))),
    # MoE experts: E over model (EP), d_model over data
    (r"ffn/(gate_w|up_w)$", lambda s, m: (_div(s[0], m, "model"),
                                          _div(s[1], m, "data"), None)),
    (r"ffn/down_w$", lambda s, m: (_div(s[0], m, "model"), None,
                                   _div(s[2], m, "data"))),
    (r"ffn/router$", lambda s, m: (_div(s[0], m, "data"), None)),
    # dense FFN (and MoE shared experts)
    (r"(ffn|shared)(/shared)?/(gate|up)/w$",
     lambda s, m: (_div(s[0], m, "data"), _div(s[1], m, "model"))),
    (r"(ffn|shared)(/shared)?/down/w$",
     lambda s, m: (_div(s[0], m, "model"), _div(s[1], m, "data"))),
    # attention projections [D, H, hd] / [H, hd, D]: shard heads over model
    # when divisible, else fall back to the head_dim axis (128 % 16 == 0
    # across the zoo) so the weights still shard 256-way at rest
    (r"(mixer|cross)/w[qkv]$",
     lambda s, m: (_div(s[0], m, "data"), _div(s[1], m, "model"),
                   None if s[1] % m.shape.get("model", 1) == 0
                   else _div(s[2], m, "model"))),
    (r"(mixer|cross)/wo$",
     lambda s, m: (_div(s[0], m, "model"),
                   None if s[0] % m.shape.get("model", 1) == 0
                   else _div(s[1], m, "model"),
                   _div(s[2], m, "data"))),
    # MLA
    (r"mixer/wdq$", lambda s, m: (_div(s[0], m, "data"), None)),
    (r"mixer/wuq$", lambda s, m: (None, _div(s[1], m, "model"), None)),
    (r"mixer/wdkv$", lambda s, m: (_div(s[0], m, "data"), None)),
    (r"mixer/wkr$", lambda s, m: (_div(s[0], m, "data"), None)),
    (r"mixer/w(uk|uv)$", lambda s, m: (None, _div(s[1], m, "model"), None)),
    # mamba2 (separate per-component projections; B/C/dt stay replicated-out)
    (r"mixer/(w_gate|w_x|w_dt)$", lambda s, m: (_div(s[0], m, "data"),
                                                _div(s[1], m, "model"))),
    (r"mixer/w_[bc]$", lambda s, m: (_div(s[0], m, "data"), None)),
    (r"mixer/out_proj$", lambda s, m: (_div(s[0], m, "model"),
                                       _div(s[1], m, "data"))),
    (r"mixer/conv_x_w$", lambda s, m: (None, _div(s[1], m, "model"))),
    (r"mixer/conv_x_b$", lambda s, m: (_div(s[0], m, "model"),)),
    (r"mixer/conv_[bc]_[wb]$", lambda s, m: tuple(None for _ in s)),
    (r"mixer/(dt_bias|a_log|d_skip)$",
     lambda s, m: (_div(s[0], m, "model"),)),
    (r"mixer/gate_norm/scale$", lambda s, m: (_div(s[0], m, "model"),)),
    # RG-LRU
    (r"mixer/w[xy]$", lambda s, m: (_div(s[0], m, "data"),
                                    _div(s[1], m, "model"))),
    (r"mixer/out$", lambda s, m: (_div(s[0], m, "model"),
                                  _div(s[1], m, "data"))),
    (r"mixer/gate_[ai]$", lambda s, m: (_div(s[0], m, "model"), None, None)),
    (r"mixer/(gate_[ai]_b|a_param)$",
     lambda s, m: (_div(s[0], m, "model"),)),
]

_COMPILED = [(re.compile(pat), fn) for pat, fn in _RULES]


def param_spec(path, shape: Tuple[int, ...], mesh: Mesh) -> P:
    s = _path_str(path)
    stacked = 0
    if re.search(r"(stage\d+|encoder/layers)", s):
        stacked = 1
    core = shape[stacked:]
    for pat, fn in _COMPILED:
        if pat.search(s):
            spec = tuple(fn(core, mesh))
            if len(spec) < len(core):           # rank-robust fallback
                spec = spec + (None,) * (len(core) - len(spec))
            return P(*((None,) * stacked + spec[: len(core)]))
    return P(*((None,) * len(shape)))           # replicate (norms, biases)


def param_shardings(param_shapes, mesh: Mesh):
    """Map a pytree of ShapeDtypeStructs to NamedShardings."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf.shape, mesh)),
        param_shapes)


def unit_shardings(param_shardings_tree, stage_key: str):
    """Shardings for one repeat of a stage's unit: take the stage subtree and
    drop the leading (stacked-layers) spec entry of every leaf."""
    sub = param_shardings_tree[stage_key]

    def strip(ns: NamedSharding) -> NamedSharding:
        return NamedSharding(ns.mesh, P(*ns.spec[1:]))

    return jax.tree.map(strip, sub)


def unit_struct(param_struct_tree, stage_key: str):
    """ShapeDtypeStructs for one repeat (drop the stacked axis)."""
    sub = param_struct_tree[stage_key]
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), sub)


# ------------------------------------------------------------------ batches
def batch_shardings(batch_spec_tree, mesh: Mesh, cfg: ArchConfig,
                    profile: str):
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    full_axes = batch_axes + (("model",) if "model" in mesh.axis_names
                              else ())

    def spec(path, leaf):
        shape = leaf.shape
        rest = [None] * (len(shape) - 1)
        full_ok = profile == "tp" or cfg.moe is None
        if full_ok and shape[0] % _size(mesh, full_axes) == 0:
            # recurrent-arch training: batch over the whole mesh
            return NamedSharding(mesh, P(full_axes, *rest))
        b = batch_axes if (batch_axes and shape[0] % _size(mesh, batch_axes) == 0) else None
        if profile == "cp" and len(shape) >= 2:
            rest[0] = _div(shape[1], mesh, "model")
        return NamedSharding(mesh, P(b, *rest))

    return jax.tree_util.tree_map_with_path(spec, batch_spec_tree)


def _size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ------------------------------------------------------------------- caches
def cache_shardings(cache_shapes, mesh: Mesh, cfg: ArchConfig):
    """Decode-cache shardings: batch over data axes; the long axis (cache
    sequence, SSD heads, RG-LRU channels) over ``model``."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def spec(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        # leading stacked-layers axis, then batch
        b = batch_axes if (len(shape) > 1 and
                           shape[1] % _size(mesh, batch_axes) == 0) else None
        dims = [None, b] + [None] * (len(shape) - 2)
        if re.search(r"(^|/)(k|v|c_kv|k_rope)$", s) and len(shape) >= 3:
            dims[2] = _div(shape[2], mesh, "model")     # cache sequence
        elif s.endswith("ssm") and len(shape) == 5:
            dims[2] = _div(shape[2], mesh, "model")     # SSD heads
        elif s.endswith("/h") and len(shape) == 3:
            dims[2] = _div(shape[2], mesh, "model")     # RG-LRU channels
        elif (s.endswith("conv") or s.endswith("conv_x")) and len(shape) == 4:
            dims[3] = _div(shape[3], mesh, "model")     # conv channels
        # cross-attention caches stay replicated on Se (small)
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)
