"""Activation sharding annotations (with_sharding_constraint helpers).

Two profiles (see DESIGN.md "Distribution design"):

* ``cp``  — context parallelism: activations [B, S, D] sharded batch over the
  data axes and sequence over ``model``; attention replicates (all-gathers)
  the small GQA/MLA KV across ``model`` and computes with sequence-sharded
  queries.  Used by every attention-family architecture (works for any head
  count).
* ``tp``  — Megatron tensor parallelism over channels/heads: activations
  sharded batch-only; mixer-internal tensors shard their channel/head axis
  over ``model``.  Used by the recurrent architectures (mamba2,
  recurrentgemma) whose sequential scans must keep the sequence axis local.

All constraints degrade gracefully: any axis whose size does not divide the
mesh axis is left unsharded, so the same model code runs on 1 CPU device
(NULL_SHARDER) and on the 512-way production mesh.
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class NullSharder:
    """No-op sharder for single-device runs and unit tests."""

    profile = "null"

    def activations(self, x):
        return x

    def logits(self, x):
        return x

    def replicate_seq(self, kv):
        return kv

    def channels(self, x):
        return x

    def weight_for_batch(self, w, batch_size):
        return w

    def decode_activations(self, x):
        return x

    def constraint(self, x, *spec):
        return x


NULL_SHARDER = NullSharder()


class Sharder:
    def __init__(self, mesh: Mesh, profile: str,
                 batch_axes: Tuple[str, ...] = ("data",),
                 model_axis: str = "model", full_dp: bool = False):
        assert profile in ("cp", "tp"), profile
        self.mesh = mesh
        self.profile = profile
        self.batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
        self.model_axis = model_axis if model_axis in mesh.axis_names else None
        # cp-profile archs without MoE may fall into pure DP+FSDP when the
        # global batch divides the whole mesh: attention then runs fully
        # local (no per-layer KV gather), which beat CP by 2-6x on the
        # collective roofline term for the train cells (EXPERIMENTS §Perf).
        self.full_dp = full_dp

    # ------------------------------------------------------------- helpers
    def _axis_size(self, axes) -> int:
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def _batch_spec(self, b: int):
        return self.batch_axes if (self.batch_axes
                                   and b % self._axis_size(self.batch_axes) == 0) else None

    def _model_spec(self, dim: int):
        if self.model_axis and dim % self._axis_size(self.model_axis) == 0:
            return self.model_axis
        return None

    def _plan(self, b: int):
        """(batch axes, model_axis_free) for a tensor with batch size ``b``.

        tp profile: recurrent scans keep the sequence local, so when the
        global batch divides the WHOLE mesh we shard batch over
        (pod, data, model) — per-layer activation checkpoints then scale as
        B/n_devices (pure FSDP+DP), which measured ~40 GiB/device cheaper
        than channel-TP on the mamba2/recurrentgemma train cells
        (EXPERIMENTS.md §Perf).  Otherwise batch uses the data axes and the
        model axis is free for channel sharding.
        """
        if (self.profile == "tp" or self.full_dp) and self.model_axis:
            full = self.batch_axes + (self.model_axis,)
            if b % self._axis_size(full) == 0:
                return full, False
        return self._batch_spec(b), True

    def constraint(self, x, *spec):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    # --------------------------------------------------------------- hooks
    def activations(self, x):
        """[B, S, D] between layers."""
        b_spec, model_free = self._plan(x.shape[0])
        s_spec = (self._model_spec(x.shape[1])
                  if (self.profile == "cp" and model_free) else None)
        return self.constraint(x, b_spec, s_spec, None)

    def logits(self, x):
        return self.activations(x)

    def replicate_seq(self, kv):
        """KV tensors gathered across ``model`` before streaming attention
        (cp profile).  Under the tp profile the sequence is already local:
        keep whatever batch plan is active — re-constraining to data-only
        batch would replicate attention across the model axis (measured
        ~13 GiB/device of gathers per local-attention layer on
        recurrentgemma — EXPERIMENTS.md §Perf)."""
        b_spec, model_free = self._plan(kv.shape[0])
        if self.profile == "cp" and model_free:
            b_spec = self._batch_spec(kv.shape[0])
        return self.constraint(kv, b_spec, *([None] * (kv.ndim - 1)))

    def channels(self, x):
        """[B, S, C] with the channel axis model-sharded (recurrent blocks);
        when the batch already occupies the model axis, C stays local."""
        b_spec, model_free = self._plan(x.shape[0])
        c_spec = self._model_spec(x.shape[2]) if model_free else None
        return self.constraint(x, b_spec, None, c_spec)

    def weight_for_batch(self, w, batch_size: int):
        """Under the full-mesh batch plan, force the (small) weight to be
        gathered instead of letting SPMD re-gather the activations per op —
        measured ~15 GiB/device of activation all-gather per scanned unit
        on recurrentgemma otherwise (EXPERIMENTS.md §Perf)."""
        if self.profile != "tp":
            return w
        _, model_free = self._plan(batch_size)
        if model_free:
            return w
        return self.constraint(w, *([None] * w.ndim))

    def decode_activations(self, x):
        """[B, D] single-token activations."""
        b_spec = self._batch_spec(x.shape[0])
        return self.constraint(x, b_spec, None)


def profile_for(cfg) -> str:
    """Sharding profile for an architecture (see module docstring)."""
    return "tp" if (cfg.ssm is not None or cfg.rglru is not None) else "cp"
