"""Mini C front end for the generated-engine body (``fastsim_c._C_BODY``).

The translation validator (:mod:`repro.analysis.translate`) needs a
*structural* view of the hand-written C translation — functions, control
flow, array accesses, operators — not a compiler.  The body is written in
a deliberately tiny C89 dialect (see DESIGN.md Section 11), so a small
tokenizer + recursive-descent parser covers it exactly:

* preprocessor: ``#include`` (ignored), object-like ``#define NAME val``
  (recorded — the drift check compares them against the twin's
  constants), function-like ``#define M(a, b) (...)`` accessor macros
  (recorded and expanded at call sites);
* ``typedef struct { ... } Name;`` (field order recorded — the ``Ev``
  struct defines the 7-tuple return convention, ``St`` the state-array
  order);
* ``static`` functions over ``int64_t``/``double``/``int``/``void``/
  struct types, C89 multi-declarator declarations, ``if``/``else``,
  ``while``, ``for`` (including ``for (;;)``), ``return``, ``break``,
  ``continue``, bare blocks;
* expressions: ``?:``, ``||``/``&&``/``!``, comparisons, ``+ - * /``,
  ``>>``/``<<``, casts, unary ``- & *``, postfix calls / ``[i]`` /
  ``.f`` / ``->f`` / ``++``/``--``, parentheses, int/float literals.

Anything outside the dialect raises :class:`CParseError` with a line
number; the validator turns that into a blocking finding (an engine edit
that the validator cannot read must not ship silently).

Expression nodes are plain tuples (first element is the tag)::

    ("num", value)            ("name", ident)
    ("call", name, [args])    ("idx", base, index)
    ("mem", base, field)      ("un", op, e)        op in {"-", "!", "&", "*"}
    ("bin", op, a, b)         ("cmp", op, a, b)
    ("bool", op, [parts])     op in {"&&", "||"}
    ("tern", cond, a, b)      ("cast", ctype, e)

``base->field`` is normalized to ``("mem", base, field)`` (the dialect
has no pointer-vs-value distinction worth keeping).  Statements are
small dataclasses (:class:`CIf`, :class:`CWhile`, ...).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "CParseError",
    "CMacro",
    "CStruct",
    "CDecl",
    "CAssign",
    "CIf",
    "CWhile",
    "CFor",
    "CReturn",
    "CBreak",
    "CContinue",
    "CExprStmt",
    "CFunc",
    "CUnit",
    "parse_c",
]


class CParseError(SyntaxError):
    """The C body stepped outside the dialect the validator can read."""

    def __init__(self, message: str, line: int):
        super().__init__(f"line {line}: {message}")
        self.line = line


# --------------------------------------------------------------- tokenizer
_TOKEN_RE = re.compile(
    r"""
      (?P<ws>\s+)
    | (?P<comment>/\*.*?\*/)
    | (?P<num>(?:\d+\.\d*(?:[eE][+-]?\d+)?)|(?:\.\d+(?:[eE][+-]?\d+)?)
             |(?:\d+[eE][+-]?\d+)|(?:\d+))
    | (?P<name>[A-Za-z_]\w*)
    | (?P<op>\+\+|--|\+=|-=|\*=|/=|<<|>>|<=|>=|==|!=|&&|\|\||->
            |[-+*/%<>=!&|?:;,.(){}\[\]])
    """,
    re.VERBOSE | re.DOTALL,
)


@dataclass
class _Tok:
    kind: str        # "num" | "name" | "op"
    text: str
    line: int


def _tokenize(src: str, start_line: int = 1) -> List[_Tok]:
    toks: List[_Tok] = []
    pos = 0
    line = start_line
    while pos < len(src):
        m = _TOKEN_RE.match(src, pos)
        if m is None:
            raise CParseError(f"unreadable character {src[pos]!r}", line)
        kind = m.lastgroup
        text = m.group()
        if kind not in ("ws", "comment"):
            toks.append(_Tok(kind, text, line))
        line += text.count("\n")
        pos = m.end()
    return toks


def _parse_num(text: str):
    if re.fullmatch(r"\d+", text):
        return int(text)
    return float(text)


# ------------------------------------------------------------ declarations
@dataclass
class CMacro:
    """A ``#define``; ``params is None`` means object-like."""

    name: str
    params: Optional[List[str]]
    body: List[_Tok]
    line: int


@dataclass
class CStruct:
    name: str
    # (ctype, is_pointer, field_name) in declaration order.
    fields: List[Tuple[str, bool, str]]
    line: int


@dataclass
class CDecl:
    """One declarator of a declaration statement (``int64_t a = e, b;``
    yields two CDecls)."""

    ctype: str
    is_pointer: bool
    name: str
    init: Optional[tuple]
    array_dims: List[tuple] = field(default_factory=list)
    line: int = 0


@dataclass
class CAssign:
    target: tuple
    op: str            # "=", "+=", "-=", "*=", "/="
    value: tuple
    line: int = 0


@dataclass
class CIf:
    cond: tuple
    then: List[object]
    orelse: List[object]
    line: int = 0


@dataclass
class CWhile:
    cond: tuple
    body: List[object]
    line: int = 0


@dataclass
class CFor:
    """``for (init; cond; step)``; all three may be None (``for (;;)``)."""

    init: Optional[object]
    cond: Optional[tuple]
    step: Optional[object]
    body: List[object]
    line: int = 0


@dataclass
class CReturn:
    value: Optional[tuple]
    line: int = 0


@dataclass
class CBreak:
    line: int = 0


@dataclass
class CContinue:
    line: int = 0


@dataclass
class CExprStmt:
    expr: tuple
    line: int = 0


@dataclass
class CFunc:
    name: str
    rtype: str
    rtype_pointer: bool
    static: bool
    # (ctype, is_pointer, name) in order.
    params: List[Tuple[str, bool, str]]
    body: List[object]
    line: int = 0


@dataclass
class CUnit:
    macros: Dict[str, CMacro]
    object_defines: List[CMacro]
    structs: Dict[str, CStruct]
    functions: List[CFunc]


_TYPE_WORDS = {
    "int64_t", "int32_t", "int16_t", "int8_t", "uint64_t", "uint32_t",
    "double", "float", "int", "long", "short", "char", "void",
    "unsigned", "signed", "const", "static",
}

_ASSIGN_OPS = {"=", "+=", "-=", "*=", "/="}

_CMP_OPS = {"==", "!=", "<", "<=", ">", ">="}


# ----------------------------------------------------------------- parser
class _Parser:
    def __init__(self, toks: List[_Tok], macros: Dict[str, CMacro],
                 struct_names: Sequence[str]):
        self.toks = toks
        self.i = 0
        self.macros = macros
        self.struct_names = set(struct_names)

    # -- token helpers
    def _peek(self, ahead: int = 0) -> Optional[_Tok]:
        j = self.i + ahead
        return self.toks[j] if j < len(self.toks) else None

    def _line(self) -> int:
        t = self._peek()
        return t.line if t else (self.toks[-1].line if self.toks else 0)

    def _next(self) -> _Tok:
        t = self._peek()
        if t is None:
            raise CParseError("unexpected end of input",
                              self.toks[-1].line if self.toks else 0)
        self.i += 1
        return t

    def _expect(self, text: str) -> _Tok:
        t = self._next()
        if t.text != text:
            raise CParseError(f"expected {text!r}, found {t.text!r}", t.line)
        return t

    def _at(self, text: str, ahead: int = 0) -> bool:
        t = self._peek(ahead)
        return t is not None and t.text == text

    # -- types
    def _looks_like_type(self) -> bool:
        t = self._peek()
        if t is None or t.kind != "name":
            return False
        return t.text in _TYPE_WORDS or t.text in self.struct_names

    def _parse_type(self) -> Tuple[str, bool]:
        words = []
        while self._looks_like_type():
            w = self._next().text
            if w not in ("const", "static"):
                words.append(w)
        if not words:
            raise CParseError("expected a type", self._line())
        is_ptr = False
        while self._at("*"):
            self._next()
            is_ptr = True
        return " ".join(words), is_ptr

    # -- expressions (precedence climbing)
    def parse_expr(self) -> tuple:
        return self._ternary()

    def _ternary(self) -> tuple:
        cond = self._or()
        if self._at("?"):
            self._next()
            a = self._ternary()
            self._expect(":")
            b = self._ternary()
            return ("tern", cond, a, b)
        return cond

    def _or(self) -> tuple:
        parts = [self._and()]
        while self._at("||"):
            self._next()
            parts.append(self._and())
        return parts[0] if len(parts) == 1 else ("bool", "||", parts)

    def _and(self) -> tuple:
        parts = [self._cmp()]
        while self._at("&&"):
            self._next()
            parts.append(self._cmp())
        return parts[0] if len(parts) == 1 else ("bool", "&&", parts)

    def _cmp(self) -> tuple:
        e = self._shift()
        while (t := self._peek()) is not None and t.text in _CMP_OPS:
            op = self._next().text
            e = ("cmp", op, e, self._shift())
        return e

    def _shift(self) -> tuple:
        e = self._add()
        while (t := self._peek()) is not None and t.text in ("<<", ">>"):
            op = self._next().text
            e = ("bin", op, e, self._add())
        return e

    def _add(self) -> tuple:
        e = self._mul()
        while (t := self._peek()) is not None and t.text in ("+", "-"):
            op = self._next().text
            e = ("bin", op, e, self._mul())
        return e

    def _mul(self) -> tuple:
        e = self._unary()
        while (t := self._peek()) is not None and t.text in ("*", "/", "%"):
            op = self._next().text
            e = ("bin", op, e, self._unary())
        return e

    def _unary(self) -> tuple:
        t = self._peek()
        if t is None:
            raise CParseError("unexpected end of expression", self._line())
        if t.text in ("-", "!", "&", "*"):
            self._next()
            return ("un", t.text, self._unary())
        if t.text == "(":
            # Cast or parenthesized expression.
            save = self.i
            self._next()
            if self._looks_like_type():
                ctype, is_ptr = self._parse_type()
                if self._at(")"):
                    self._next()
                    e = self._unary()
                    return ("cast", ctype + ("*" if is_ptr else ""), e)
            self.i = save
        return self._postfix()

    def _postfix(self) -> tuple:
        e = self._primary()
        while True:
            if self._at("("):
                if e[0] != "name":
                    raise CParseError("call of a non-identifier",
                                      self._line())
                self._next()
                args = []
                if not self._at(")"):
                    args.append(self.parse_expr())
                    while self._at(","):
                        self._next()
                        args.append(self.parse_expr())
                self._expect(")")
                e = ("call", e[1], args)
            elif self._at("["):
                self._next()
                idx = self.parse_expr()
                self._expect("]")
                e = ("idx", e, idx)
            elif self._at(".") or self._at("->"):
                self._next()
                f = self._next()
                if f.kind != "name":
                    raise CParseError("expected field name", f.line)
                e = ("mem", e, f.text)
            else:
                return e

    def _primary(self) -> tuple:
        t = self._next()
        if t.kind == "num":
            return ("num", _parse_num(t.text))
        if t.kind == "name":
            if t.text in self.macros and self.macros[t.text].params is not None \
                    and self._at("("):
                self._next()
                args: List[tuple] = []
                if not self._at(")"):
                    args.append(self.parse_expr())
                    while self._at(","):
                        self._next()
                        args.append(self.parse_expr())
                self._expect(")")
                macro = self.macros[t.text]
                if len(args) != len(macro.params or ()):
                    raise CParseError(
                        f"macro {t.text} called with {len(args)} arg(s), "
                        f"defined with {macro.params}", t.line)
                return ("mcall", t.text, args)
            return ("name", t.text)
        if t.text == "(":
            e = self.parse_expr()
            self._expect(")")
            return e
        raise CParseError(f"unexpected token {t.text!r}", t.line)

    # -- statements
    def _parse_block(self) -> List[object]:
        self._expect("{")
        stmts: List[object] = []
        while not self._at("}"):
            stmts.extend(self._parse_stmt())
        self._expect("}")
        return stmts

    def _parse_stmt_or_block(self) -> List[object]:
        if self._at("{"):
            return self._parse_block()
        return self._parse_stmt()

    def _parse_decl_stmt(self) -> List[CDecl]:
        line = self._line()
        ctype, first_ptr = self._parse_type()
        decls: List[CDecl] = []
        while True:
            is_ptr = first_ptr
            while self._at("*"):
                self._next()
                is_ptr = True
            name_tok = self._next()
            if name_tok.kind != "name":
                raise CParseError("expected declarator name", name_tok.line)
            dims: List[tuple] = []
            while self._at("["):
                self._next()
                dims.append(self.parse_expr())
                self._expect("]")
            init = None
            if self._at("="):
                self._next()
                init = self.parse_expr()
            decls.append(CDecl(ctype, is_ptr, name_tok.text, init, dims,
                               line))
            if self._at(","):
                self._next()
                first_ptr = False
                continue
            self._expect(";")
            return decls

    def _parse_simple_stmt(self, terminator: str) -> Optional[object]:
        """Assignment / call / ++ / -- up to ``terminator`` (not eaten)."""
        if self._at(terminator):
            return None
        line = self._line()
        e = self.parse_expr()
        t = self._peek()
        if t is not None and t.text in _ASSIGN_OPS:
            op = self._next().text
            value = self.parse_expr()
            return CAssign(e, op, value, line)
        if t is not None and t.text in ("++", "--"):
            self._next()
            one = ("num", 1)
            return CAssign(e, "+=" if t.text == "++" else "-=", one, line)
        return CExprStmt(e, line)

    def _parse_stmt(self) -> List[object]:
        t = self._peek()
        if t is None:
            raise CParseError("unexpected end of function body", self._line())
        line = t.line
        if t.text == "{":
            # Bare block: flatten (scopes carry no meaning in the IR).
            return self._parse_block()
        if t.text == ";":
            self._next()
            return []
        if t.kind == "name" and (t.text in _TYPE_WORDS
                                 or t.text in self.struct_names):
            return list(self._parse_decl_stmt())
        if t.text == "if":
            self._next()
            self._expect("(")
            cond = self.parse_expr()
            self._expect(")")
            then = self._parse_stmt_or_block()
            orelse: List[object] = []
            if self._at("else"):
                self._next()
                orelse = self._parse_stmt_or_block()
            return [CIf(cond, then, orelse, line)]
        if t.text == "while":
            self._next()
            self._expect("(")
            cond = self.parse_expr()
            self._expect(")")
            return [CWhile(cond, self._parse_stmt_or_block(), line)]
        if t.text == "for":
            self._next()
            self._expect("(")
            init: Optional[object] = None
            if not self._at(";"):
                if self._looks_like_type():
                    raise CParseError(
                        "C89 dialect: no declarations in for-init", line)
                init = self._parse_simple_stmt(";")
            self._expect(";")
            cond = None if self._at(";") else self.parse_expr()
            self._expect(";")
            step = self._parse_simple_stmt(")")
            self._expect(")")
            return [CFor(init, cond, step, self._parse_stmt_or_block(), line)]
        if t.text == "return":
            self._next()
            value = None if self._at(";") else self.parse_expr()
            self._expect(";")
            return [CReturn(value, line)]
        if t.text == "break":
            self._next()
            self._expect(";")
            return [CBreak(line)]
        if t.text == "continue":
            self._next()
            self._expect(";")
            return [CContinue(line)]
        stmt = self._parse_simple_stmt(";")
        self._expect(";")
        return [stmt] if stmt is not None else []


# ------------------------------------------------------- top-level parsing
_DEFINE_RE = re.compile(r"^[ \t]*#[ \t]*define[ \t]+(\w+)(\(([^)]*)\))?"
                        r"[ \t]*(.*?)[ \t]*$")


def _strip_preprocessor(src: str) -> Tuple[str, Dict[str, CMacro],
                                           List[CMacro]]:
    """Collect #defines; blank out all # lines (preserving line count)."""
    macros: Dict[str, CMacro] = {}
    object_defines: List[CMacro] = []
    out_lines: List[str] = []
    for lineno, raw in enumerate(src.split("\n"), start=1):
        stripped = raw.lstrip()
        if not stripped.startswith("#"):
            out_lines.append(raw)
            continue
        out_lines.append("")
        m = _DEFINE_RE.match(raw)
        if m is None:
            continue            # include etc.
        name, has_params, params_text, body_text = (
            m.group(1), m.group(2), m.group(3), m.group(4))
        params = None
        if has_params is not None:
            params = [p.strip() for p in params_text.split(",") if p.strip()]
        body = _tokenize(body_text, lineno)
        macro = CMacro(name, params, body, lineno)
        if params is None:
            object_defines.append(macro)
        else:
            macros[name] = macro
    return "\n".join(out_lines), macros, object_defines


_STRUCT_RE = re.compile(
    r"typedef\s+struct\s*\{(?P<body>[^}]*)\}\s*(?P<name>\w+)\s*;",
    re.DOTALL,
)


def _parse_structs(src: str) -> Tuple[str, Dict[str, CStruct]]:
    structs: Dict[str, CStruct] = {}

    def grab(m: re.Match) -> str:
        body = m.group("body")
        name = m.group("name")
        line = src[:m.start()].count("\n") + 1
        fields: List[Tuple[str, bool, str]] = []
        for decl in body.split(";"):
            decl = decl.strip()
            if not decl:
                continue
            toks = _tokenize(decl, line)
            words = [t.text for t in toks]
            type_words = []
            k = 0
            while k < len(words) and words[k] in _TYPE_WORDS:
                type_words.append(words[k])
                k += 1
            ctype = " ".join(type_words)
            is_ptr = False
            cur_name = None
            for w in words[k:]:
                if w == "*":
                    is_ptr = True
                elif w == ",":
                    fields.append((ctype, is_ptr, cur_name))
                    is_ptr = False
                    cur_name = None
                else:
                    cur_name = w
            if cur_name is not None:
                fields.append((ctype, is_ptr, cur_name))
        structs[name] = CStruct(name, fields, line)
        # Blank out, preserving newlines so later line numbers survive.
        return "\n" * m.group(0).count("\n")

    return _STRUCT_RE.sub(grab, src), structs


def parse_c(src: str) -> CUnit:
    """Parse the engine's C dialect into a :class:`CUnit`."""
    src, macros, object_defines = _strip_preprocessor(src)
    src, structs = _parse_structs(src)
    toks = _tokenize(src)
    parser = _Parser(toks, macros, list(structs))
    functions: List[CFunc] = []
    while parser._peek() is not None:
        line = parser._line()
        static = False
        if parser._at("static"):
            parser._next()
            static = True
        rtype, rptr = parser._parse_type()
        name_tok = parser._next()
        if name_tok.kind != "name":
            raise CParseError("expected function name", name_tok.line)
        parser._expect("(")
        params: List[Tuple[str, bool, str]] = []
        if not parser._at(")"):
            while True:
                ptype, pptr = parser._parse_type()
                ptok = parser._next()
                if ptok.kind != "name":
                    raise CParseError("expected parameter name", ptok.line)
                params.append((ptype, pptr, ptok.text))
                if parser._at(","):
                    parser._next()
                    continue
                break
        parser._expect(")")
        body = parser._parse_block()
        functions.append(CFunc(name_tok.text, rtype, rptr, static, params,
                               body, line))
    return CUnit(macros=macros, object_defines=object_defines,
                 structs=structs, functions=functions)
