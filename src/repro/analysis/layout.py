"""Flat-layout / bounds cross-check across the engine trio.

The twin's ``*_LEN`` field tables are THE layout contract: ``fastsim``
allocates arrays from them, the twin indexes with the ``<FAM>_<FIELD>``
constants, and the C accessor macros hard-code the same strides.  A
drifted width, a column constant from the wrong family, or a record
buffer whose growth exit was dropped all corrupt state silently — the
runtime equivalence matrix only catches them when a sampled cell
happens to trip the bad index.  This pass checks the contract shape by
shape:

* ``family-gap`` — each ``<FAM>_*`` constant family with a ``_LEN``
  must enumerate distinct in-range column indices (full 0..LEN-1
  coverage except the documented SMI free-slot tail, which must satisfy
  ``SMI_LEN == SMI_FS0 + MAX_BLOCK_SLOTS``).
* ``state-order`` — the ``S_*`` position constants, the 29-tuple built
  by ``fastsim._build_state``, and the C ``St`` struct must all list
  the arrays in canonical order with the right element dtypes, and the
  ctypes interface must pass exactly that many pointers.
* ``alloc-width`` / ``stride-mismatch`` — the trailing dimension of
  every ``_build_state`` allocation must match the twin's ``_LEN`` and
  the stride baked into the corresponding C accessor macro.
* ``col-bounds`` / ``wrong-family`` — every constant column index in
  the twin must fold below its array's width and come from that array's
  own field family.
* ``missing-growth-exit`` / ``cap-unassigned`` — every ``CI_*_CAP``
  capacity must be guarded in ``advance`` by a headroom check returning
  a distinct exit code, and assigned a value by ``fastsim``; a growable
  buffer without a wired exit would overflow instead of re-entering.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from .cparse import CParseError
from .enginesrc import (ARRAY_DTYPES, CANONICAL_ARRAYS, _fold_expr, c_path,
                        fold_twin_constants, load_module_ast, load_twin_ast,
                        sim_path, twin_jit_functions, twin_path)
from .report import Finding
from .translate import macro_shapes

PASS = "layout"

_TWIN = "fastsim_twin"
_SIM = "fastsim"
_C = "fastsim_c"

#: Families whose members must cover 0..LEN-1 exactly.
_FULL_FAMILIES = ("SI", "SD", "CI", "CF", "RI", "RF", "PI", "PF",
                  "HI", "HF", "S")

#: Column family expected per state array (None: variable columns only).
_COL_FAMILY: Dict[str, Optional[str]] = {
    "si": "SI", "sd": "SD", "ci": "CI", "cf": "CF", "ri": "RI",
    "rf": "RF", "psi": "PI", "psf": "PF", "smi": "SMI", "hi": "HI",
    "hf": "HF", "rwf": "RW", "srci": "SRC",
}

#: ``S_*`` abbreviation per canonical array.
_S_ABBREV = {"np_pool": "NP", "bt_pool": "BT"}

#: capacity constant -> counter guarding it in ``advance``.
_CAP_COUNTERS = {
    "CI_HEAP_CAP": "SI_HEAP_LEN",
    "CI_TRACE_CAP": "SI_TRACE_N",
    "CI_DEC_CAP": "SI_DEC_N",
    "CI_PRED_CAP": "SI_PRED_N",
}


def _family_members(consts: Dict[str, object],
                    prefix: str) -> Dict[str, int]:
    out = {}
    for name, value in consts.items():
        if name.startswith(prefix + "_") and isinstance(value, int) \
                and not isinstance(value, bool) and name != prefix + "_LEN":
            out[name] = value
    return out


def _check_families(consts: Dict[str, object]) -> List[Finding]:
    findings: List[Finding] = []

    def flag(context: str, message: str) -> None:
        findings.append(Finding(PASS, "family-gap", _TWIN, context, 0,
                                message))

    for fam in _FULL_FAMILIES:
        length = consts.get(fam + "_LEN")
        if not isinstance(length, int):
            flag(fam, f"{fam}_LEN is missing or non-integer")
            continue
        members = _family_members(consts, fam)
        values = sorted(members.values())
        if values != list(range(length)):
            dupes = {v for v in values if values.count(v) > 1}
            missing = sorted(set(range(length)) - set(values))
            extra = sorted(v for v in values if not 0 <= v < length)
            parts = []
            if dupes:
                parts.append(f"duplicate indices {sorted(dupes)}")
            if missing:
                parts.append(f"unused indices {missing}")
            if extra:
                parts.append(f"out-of-range indices {extra}")
            flag(fam, f"{fam}_* must cover 0..{length - 1} exactly: "
                      + "; ".join(parts))
    smi_len = consts.get("SMI_LEN")
    smi_fs0 = consts.get("SMI_FS0")
    slots = consts.get("MAX_BLOCK_SLOTS")
    if not (isinstance(smi_len, int) and isinstance(smi_fs0, int)
            and isinstance(slots, int)
            and smi_len == smi_fs0 + slots):
        flag("SMI", "SMI_LEN must equal SMI_FS0 + MAX_BLOCK_SLOTS "
                    "(free-slot stack tail)")
    for name, value in _family_members(consts, "SMI").items():
        if isinstance(smi_len, int) and not 0 <= value < smi_len:
            flag("SMI", f"{name} = {value} outside [0, SMI_LEN)")
    return findings


def _check_s_constants(consts: Dict[str, object]) -> List[Finding]:
    findings: List[Finding] = []
    for i, arr in enumerate(CANONICAL_ARRAYS):
        name = "S_" + _S_ABBREV.get(arr, arr.upper())
        if consts.get(name) != i:
            findings.append(Finding(
                PASS, "state-order", _TWIN, name, 0,
                f"{name} must be {i} (position of {arr!r} in the state "
                f"tuple), found {consts.get(name)!r}"))
    if consts.get("S_LEN") != len(CANONICAL_ARRAYS):
        findings.append(Finding(
            PASS, "state-order", _TWIN, "S_LEN", 0,
            f"S_LEN must be {len(CANONICAL_ARRAYS)}, found "
            f"{consts.get('S_LEN')!r}"))
    return findings


# ------------------------------------------------------ fastsim.py side
class _AllocSpec:
    """Trailing width + dtype of one ``_build_state`` allocation."""

    def __init__(self, width: Optional[int], dtype: Optional[str],
                 line: int):
        self.width = width      # None for 1-D arrays
        self.dtype = dtype      # "i" / "f" / None (unknown)
        self.line = line


def _np_attr(e: ast.expr) -> Optional[str]:
    if isinstance(e, ast.Attribute) and isinstance(e.value, ast.Name) \
            and e.value.id == "np":
        return e.attr
    return None


def _fold_sim_expr(e: ast.expr, consts: Dict[str, object]):
    """Fold ``tw.<CONST>``-style expressions in fastsim.py."""
    if isinstance(e, ast.Attribute) and isinstance(e.value, ast.Name) \
            and e.value.id == "tw":
        return consts.get(e.attr)
    if isinstance(e, ast.Constant) and isinstance(e.value, int) \
            and not isinstance(e.value, bool):
        return e.value
    return None


def _alloc_spec(call: ast.Call,
                consts: Dict[str, object]) -> Optional[_AllocSpec]:
    fn = _np_attr(call.func)
    if fn not in ("zeros", "empty", "full") or not call.args:
        return None
    shape = call.args[0]
    dtype_arg = call.args[-1] if len(call.args) >= 2 else None
    dtype = None
    attr = _np_attr(dtype_arg) if dtype_arg is not None else None
    if attr == "int64":
        dtype = "i"
    elif attr == "float64":
        dtype = "f"
    if isinstance(shape, ast.Tuple) and shape.elts:
        width = _fold_sim_expr(shape.elts[-1], consts)
        return _AllocSpec(width if isinstance(width, int) else None,
                          dtype, call.lineno)
    return _AllocSpec(None, dtype, call.lineno)


def _build_state_specs(sim_tree: ast.Module, consts: Dict[str, object],
                       findings: List[Finding],
                       ) -> Dict[str, _AllocSpec]:
    """canonical array -> allocation spec, via the 29-tuple's positions."""
    build = None
    for node in ast.walk(sim_tree):
        if isinstance(node, ast.FunctionDef) and node.name == "_build_state":
            build = node
            break
    if build is None:
        findings.append(Finding(
            PASS, "state-order", _SIM, "_build_state", 0,
            "fastsim._build_state not found; cannot cross-check the "
            "allocation layout"))
        return {}
    allocs: Dict[str, _AllocSpec] = {}
    state_tuple: Optional[ast.Tuple] = None
    for node in ast.walk(build):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if isinstance(node.value, ast.Call):
                spec = _alloc_spec(node.value, consts)
                if spec is not None:
                    allocs[name] = spec
            if name == "state" and isinstance(node.value, ast.Tuple):
                state_tuple = node.value
    if state_tuple is None:
        findings.append(Finding(
            PASS, "state-order", _SIM, "_build_state", build.lineno,
            "state tuple literal not found in _build_state"))
        return {}
    if len(state_tuple.elts) != len(CANONICAL_ARRAYS):
        findings.append(Finding(
            PASS, "state-order", _SIM, "_build_state", state_tuple.lineno,
            f"state tuple has {len(state_tuple.elts)} element(s); the "
            f"engine contract is {len(CANONICAL_ARRAYS)}"))
        return {}
    specs: Dict[str, _AllocSpec] = {}
    for i, el in enumerate(state_tuple.elts):
        if not isinstance(el, ast.Name):
            findings.append(Finding(
                PASS, "state-order", _SIM, "_build_state", el.lineno,
                f"state tuple position {i} is not a plain local name"))
            continue
        spec = allocs.get(el.id)
        if spec is not None:
            specs[CANONICAL_ARRAYS[i]] = spec
    return specs


def _expected_width(arr: str, consts: Dict[str, object],
                    specs: Dict[str, _AllocSpec]) -> Optional[int]:
    spec = specs.get(arr)
    return spec.width if spec is not None else None


def _check_alloc_dtypes(specs: Dict[str, _AllocSpec]) -> List[Finding]:
    findings = []
    for arr, spec in specs.items():
        want = ARRAY_DTYPES[arr]
        if spec.dtype is not None and spec.dtype != want:
            label = "float64" if want == "f" else "int64"
            findings.append(Finding(
                PASS, "alloc-width", _SIM, "_build_state", spec.line,
                f"{arr} allocated with the wrong dtype; the engine "
                f"contract is {label}"))
    return findings


def _check_alloc_widths(specs: Dict[str, _AllocSpec],
                        consts: Dict[str, object]) -> List[Finding]:
    findings = []
    expected = {
        "ri": "RI_LEN", "rf": "RF_LEN", "psi": "PI_LEN", "psf": "PF_LEN",
        "bs": "MAX_BLOCK_SLOTS", "sl": "MAX_BLOCK_SLOTS",
        "smi": "SMI_LEN", "hi": "HI_LEN", "hf": "HF_LEN",
    }
    for arr, const in expected.items():
        spec = specs.get(arr)
        want = consts.get(const)
        if spec is None or not isinstance(want, int):
            continue
        if spec.width != want:
            findings.append(Finding(
                PASS, "alloc-width", _SIM, "_build_state", spec.line,
                f"{arr} trailing dimension {spec.width} != {const} "
                f"({want})"))
    return findings


# -------------------------------------------------------- twin subscripts
def _check_twin_columns(twin_tree: ast.Module, consts: Dict[str, object],
                        specs: Dict[str, _AllocSpec]) -> List[Finding]:
    findings: List[Finding] = []
    for fn in twin_jit_functions(twin_tree):
        aliases: Dict[str, str] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Subscript) \
                    and isinstance(node.value.value, ast.Name) \
                    and node.value.value.id == "S" \
                    and isinstance(node.value.slice, ast.Constant) \
                    and isinstance(node.value.slice.value, int):
                idx = node.value.slice.value
                if 0 <= idx < len(CANONICAL_ARRAYS):
                    aliases[node.targets[0].id] = CANONICAL_ARRAYS[idx]
        for p in fn.args.args:
            if p.arg in CANONICAL_ARRAYS:
                aliases[p.arg] = p.arg
        for node in ast.walk(fn):
            if not isinstance(node, ast.Subscript):
                continue
            if not isinstance(node.value, ast.Name):
                continue
            arr = aliases.get(node.value.id)
            if arr is None:
                continue
            idx = node.slice
            dims = idx.elts if isinstance(idx, ast.Tuple) else [idx]
            col = dims[-1]
            width = _expected_width(arr, consts, specs)
            family = _COL_FAMILY.get(arr)
            if family is not None and isinstance(col, ast.Name) \
                    and col.id in consts:
                col_fam = col.id.split("_", 1)[0]
                if col_fam != family:
                    findings.append(Finding(
                        PASS, "wrong-family", _TWIN, fn.name, node.lineno,
                        f"{arr}[..] indexed with {col.id} from the "
                        f"{col_fam}_* family; {arr} columns are "
                        f"{family}_*"))
            value = _fold_expr(col, consts) if not isinstance(
                col, ast.Name) else consts.get(col.id)
            if width is not None and isinstance(value, int) \
                    and not isinstance(value, bool) and len(dims) > 1:
                if not -0 <= value < width:
                    findings.append(Finding(
                        PASS, "col-bounds", _TWIN, fn.name, node.lineno,
                        f"{arr}[.., {value}] exceeds the allocated "
                        f"width {width}"))
    return findings


# ----------------------------------------------------------- C-side shape
def _check_c_layout(core_dir: Path, consts: Dict[str, object],
                    specs: Dict[str, _AllocSpec]) -> List[Finding]:
    findings: List[Finding] = []
    from .enginesrc import parse_c_unit
    try:
        unit, c_module, _line = parse_c_unit(core_dir)
    except CParseError:
        return []       # translate reports the parse failure
    if unit is None:
        return []

    # St struct: canonical order, per-array pointer dtypes, nsm tail.
    st = unit.structs.get("St")
    if st is None:
        findings.append(Finding(
            PASS, "state-order", _C, "St", 0,
            "St struct not found in _C_BODY"))
    else:
        want_fields = [
            ("double *" if ARRAY_DTYPES[a] == "f" else "int64_t *", a)
            for a in CANONICAL_ARRAYS] + [("int64_t", "nsm")]
        got_fields = [(f"{ctype} *" if is_ptr else ctype, name)
                      for ctype, is_ptr, name in st.fields]
        if got_fields != want_fields:
            for i, (want, got) in enumerate(zip(want_fields, got_fields)):
                if want != got:
                    findings.append(Finding(
                        PASS, "state-order", _C, "St", st.line,
                        f"St field {i} is {got[0]} {got[1]!r}; the state "
                        f"contract requires {want[0]} {want[1]!r}"))
            if len(got_fields) != len(want_fields):
                findings.append(Finding(
                    PASS, "state-order", _C, "St", st.line,
                    f"St has {len(got_fields)} fields; the state "
                    f"contract requires {len(want_fields)}"))
    ev = unit.structs.get("Ev")
    ev_want = [("double", "t"), ("int64_t", "kind"), ("int64_t", "seq"),
               ("int64_t", "a"), ("int64_t", "b"), ("int64_t", "c"),
               ("double", "start")]
    if ev is not None:
        got = [(ctype, name) for ctype, _p, name in ev.fields]
        if got != ev_want:
            findings.append(Finding(
                PASS, "state-order", _C, "Ev", ev.line,
                f"Ev fields {got} diverge from the heap row contract "
                f"{ev_want}"))

    # Accessor macro strides vs the fastsim allocation widths.
    shapes, _bad = macro_shapes(unit)
    for name, shape in sorted(shapes.items()):
        width = _expected_width(shape.array, consts, specs)
        if width is None:
            if shape.ndim != 1:
                continue
            spec = specs.get(shape.array)
            if spec is not None and spec.width not in (None, 1):
                findings.append(Finding(
                    PASS, "stride-mismatch", _C, name, shape.line,
                    f"{name} indexes {shape.array} as 1-D but the "
                    f"allocation is {spec.width} wide"))
            continue
        if shape.ndim == 1:
            if width != 1:
                findings.append(Finding(
                    PASS, "stride-mismatch", _C, name, shape.line,
                    f"{name} indexes {shape.array} as 1-D but the "
                    f"allocation is {width} wide"))
            continue
        stride = shape.strides[-1]
        stride_v = stride if isinstance(stride, int) else consts.get(
            str(stride))
        if stride_v != width:
            findings.append(Finding(
                PASS, "stride-mismatch", _C, name, shape.line,
                f"{name} stride {stride!r} ({stride_v}) != {shape.array} "
                f"allocation width {width}"))
        if shape.ndim == 3 and not shape.uses_nsm:
            findings.append(Finding(
                PASS, "stride-mismatch", _C, name, shape.line,
                f"{name} middle stride must be S->nsm"))

    # ctypes interface: exactly one pointer per state array.
    n_args = None
    for node in ast.walk(c_module):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Attribute) \
                and node.targets[0].attr == "argtypes" \
                and isinstance(node.value, ast.BinOp) \
                and isinstance(node.value.op, ast.Mult) \
                and isinstance(node.value.right, ast.Constant):
            n_args = (node.value.right.value, node.lineno)
    if n_args is not None and n_args[0] != len(CANONICAL_ARRAYS):
        findings.append(Finding(
            PASS, "state-order", _C, "argtypes", n_args[1],
            f"fs_advance takes {n_args[0]} pointers; the state contract "
            f"is {len(CANONICAL_ARRAYS)}"))
    return findings


# -------------------------------------------------- buffer-growth wiring
def _check_growth_exits(twin_tree: ast.Module, sim_tree: ast.Module,
                        consts: Dict[str, object]) -> List[Finding]:
    findings: List[Finding] = []
    caps = sorted(n for n in consts
                  if n.startswith("CI_") and n.endswith("_CAP")
                  and n in _CAP_COUNTERS)
    for cap in sorted(set(_CAP_COUNTERS) - set(caps)):
        findings.append(Finding(
            PASS, "missing-growth-exit", _TWIN, "advance", 0,
            f"growable-buffer capacity constant {cap} is missing"))

    advance = None
    for fn in twin_jit_functions(twin_tree):
        if fn.name == "advance":
            advance = fn
            break
    guarded: Dict[str, Tuple[int, int]] = {}
    if advance is None:
        findings.append(Finding(
            PASS, "missing-growth-exit", _TWIN, "advance", 0,
            "twin advance() not found"))
    else:
        for node in ast.walk(advance):
            if not (isinstance(node, ast.If) and len(node.body) == 1
                    and isinstance(node.body[0], ast.Return)
                    and isinstance(node.body[0].value, ast.Constant)
                    and isinstance(node.body[0].value.value, int)):
                continue
            code = node.body[0].value.value
            for sub in ast.walk(node.test):
                if isinstance(sub, ast.Subscript) \
                        and isinstance(sub.value, ast.Name) \
                        and isinstance(sub.slice, ast.Name) \
                        and sub.slice.id in _CAP_COUNTERS:
                    guarded[sub.slice.id] = (code, node.lineno)
        for cap in caps:
            if cap not in guarded:
                findings.append(Finding(
                    PASS, "missing-growth-exit", _TWIN, "advance",
                    advance.lineno,
                    f"advance() has no headroom guard on {cap}; the "
                    f"buffer would overflow instead of exiting for a "
                    f"rebuild"))
            else:
                code, line = guarded[cap]
                counter = _CAP_COUNTERS[cap]
                test_ok = False
                for node in ast.walk(advance):
                    if isinstance(node, ast.If) and node.lineno == line:
                        for sub in ast.walk(node.test):
                            if isinstance(sub, ast.Subscript) \
                                    and isinstance(sub.slice, ast.Name) \
                                    and sub.slice.id == counter:
                                test_ok = True
                if not test_ok:
                    findings.append(Finding(
                        PASS, "missing-growth-exit", _TWIN, "advance",
                        line,
                        f"the {cap} guard does not test the {counter} "
                        f"counter"))
        codes = [c for c, _l in guarded.values()]
        if len(set(codes)) != len(codes):
            findings.append(Finding(
                PASS, "missing-growth-exit", _TWIN, "advance",
                advance.lineno if advance else 0,
                f"growth-exit codes {sorted(codes)} are not distinct"))

    # fastsim must assign every capacity before entering the engine.
    assigned = set()
    for node in ast.walk(sim_tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Subscript) \
                        and isinstance(t.slice, ast.Attribute) \
                        and isinstance(t.slice.value, ast.Name) \
                        and t.slice.value.id == "tw":
                    assigned.add(t.slice.attr)
    for cap in caps:
        if cap not in assigned:
            findings.append(Finding(
                PASS, "cap-unassigned", _SIM, "_build_state", 0,
                f"fastsim never assigns ci[tw.{cap}]; the engine would "
                f"see a zero capacity and exit-loop forever"))
    return findings


# ------------------------------------------------------------- the pass
def scan_layout(core_dir: Path) -> List[Finding]:
    core_dir = Path(core_dir)
    if not twin_path(core_dir).exists():
        return []
    twin_tree = load_twin_ast(core_dir)
    consts = fold_twin_constants(twin_tree)

    findings: List[Finding] = []
    findings.extend(_check_families(consts))
    findings.extend(_check_s_constants(consts))

    specs: Dict[str, _AllocSpec] = {}
    sim_tree: Optional[ast.Module] = None
    if sim_path(core_dir).exists():
        sim_tree = load_module_ast(sim_path(core_dir))
        specs = _build_state_specs(sim_tree, consts, findings)
        findings.extend(_check_alloc_dtypes(specs))
        findings.extend(_check_alloc_widths(specs, consts))
    findings.extend(_check_twin_columns(twin_tree, consts, specs))
    if c_path(core_dir).exists():
        findings.extend(_check_c_layout(core_dir, consts, specs))
    if sim_tree is not None:
        findings.extend(_check_growth_exits(twin_tree, sim_tree, consts))
    return findings
