"""Entry point for ``python -m repro.analysis``."""

from __future__ import annotations

import sys

from .cli import main

sys.exit(main())
