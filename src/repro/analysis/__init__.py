"""Static determinism & cache-integrity analysis for ``repro.core``.

Three AST passes guard the invariants every reported number rests on
(DESIGN.md Section 9):

* :mod:`repro.analysis.importgraph` — the sweep-cache code fingerprint
  (``sweep._FINGERPRINT_SOURCES``) must equal the transitive
  import-closure of each machine's result-determining entry points;
* :mod:`repro.analysis.determinism` — nondeterminism lints (unseeded
  RNGs, set-iteration order, wall-clock reads, NaN-capable JSON, …) over
  the schedule-determining modules, with a checked-in justification
  baseline (:mod:`repro.analysis.report`);
* :mod:`repro.analysis.protocol` — declared contracts vs. actual ASTs:
  Policy hint flags, the fused/typed ``SchedulerCore`` dispatch pair, and
  full Machine-protocol signatures.

Run it as ``python -m repro.analysis`` (CI does, via ``make analyze``).
The package never imports ``repro.core`` — everything is file-level AST,
so it can analyze mutated copies of the tree (and the heavy simulator
stack never loads just to lint).
"""

from __future__ import annotations

from .cli import PASSES, main, run_passes
from .determinism import (
    default_scan_modules,
    scan_determinism,
    scan_source,
)
from .importgraph import (
    ENTRY_POINTS,
    NON_RESULT_MODULES,
    build_import_graph,
    check_fingerprint_coverage,
    expected_fingerprint_sources,
    load_fingerprint_table,
    transitive_closure,
)
from .protocol import (
    check_fused_paths,
    check_machine_signatures,
    check_policy_hints,
    check_protocols,
)
from .report import (
    Baseline,
    Finding,
    Report,
    apply_baseline,
    format_report,
)

__all__ = [
    "Baseline",
    "ENTRY_POINTS",
    "Finding",
    "NON_RESULT_MODULES",
    "PASSES",
    "Report",
    "apply_baseline",
    "build_import_graph",
    "check_fingerprint_coverage",
    "check_fused_paths",
    "check_machine_signatures",
    "check_policy_hints",
    "check_protocols",
    "default_scan_modules",
    "expected_fingerprint_sources",
    "format_report",
    "load_fingerprint_table",
    "main",
    "run_passes",
    "scan_determinism",
    "scan_source",
    "transitive_closure",
]
