"""Static determinism & cache-integrity analysis for ``repro.core``.

Three AST passes guard the invariants every reported number rests on
(DESIGN.md Section 9):

* :mod:`repro.analysis.importgraph` — the sweep-cache code fingerprint
  (``sweep._FINGERPRINT_SOURCES``) must equal the transitive
  import-closure of each machine's result-determining entry points;
* :mod:`repro.analysis.determinism` — nondeterminism lints (unseeded
  RNGs, set-iteration order, wall-clock reads, NaN-capable JSON, …) over
  the schedule-determining modules, with a checked-in justification
  baseline (:mod:`repro.analysis.report`);
* :mod:`repro.analysis.protocol` — declared contracts vs. actual ASTs:
  Policy hint flags, the fused/typed ``SchedulerCore`` dispatch pair, and
  full Machine-protocol signatures.

Three more passes verify the compiled DES engine trio (DESIGN.md
Section 11):

* :mod:`repro.analysis.conformance` — ``fastsim_twin`` stays inside the
  nopython subset all three backends execute identically;
* :mod:`repro.analysis.translate` — twin and generated-C functions lower
  to the same normalized IR (control-flow skeleton + operation bags),
  plus constant-drift / FMA-contraction / int-division / narrowed-dtype
  lints on the C side;
* :mod:`repro.analysis.layout` — field tables, allocation widths, C
  accessor strides, the 29-array state order, and the buffer-growth
  exit wiring all agree.

Run it as ``python -m repro.analysis`` (CI does, via ``make analyze``).
The package never imports ``repro.core`` — everything is file-level AST,
so it can analyze mutated copies of the tree (and the heavy simulator
stack never loads just to lint).
"""

from __future__ import annotations

from .cli import PASSES, main, run_passes
from .conformance import scan_conformance
from .determinism import (
    default_scan_modules,
    scan_determinism,
    scan_source,
)
from .layout import scan_layout
from .importgraph import (
    ENTRY_POINTS,
    NON_RESULT_MODULES,
    build_import_graph,
    check_fingerprint_coverage,
    expected_fingerprint_sources,
    load_fingerprint_table,
    transitive_closure,
)
from .protocol import (
    check_fused_paths,
    check_machine_signatures,
    check_policy_hints,
    check_protocols,
)
from .report import (
    Baseline,
    Finding,
    Report,
    apply_baseline,
    format_report,
)
from .translate import FuncSummary, scan_translation

__all__ = [
    "Baseline",
    "ENTRY_POINTS",
    "Finding",
    "FuncSummary",
    "NON_RESULT_MODULES",
    "PASSES",
    "Report",
    "apply_baseline",
    "build_import_graph",
    "check_fingerprint_coverage",
    "check_fused_paths",
    "check_machine_signatures",
    "check_policy_hints",
    "check_protocols",
    "default_scan_modules",
    "expected_fingerprint_sources",
    "format_report",
    "load_fingerprint_table",
    "main",
    "run_passes",
    "scan_conformance",
    "scan_determinism",
    "scan_layout",
    "scan_source",
    "scan_translation",
    "transitive_closure",
]
