"""Determinism lints over the schedule-determining modules.

Every cached sweep record and every golden trace assumes that re-running
the same cell under the same seed reproduces the same bytes.  The lints
below flag the constructs that historically break that property.  They are
deliberately *syntactic* (no type inference): a hazard that cannot be
recognized locally is a hazard a reviewer cannot recognize either, and a
false positive is one baseline entry with a written-down justification
(see :mod:`repro.analysis.report`).

Rules
-----
``unseeded-random``
    Calls into process-global RNG state: ``random.<fn>()`` from the stdlib
    module, or the legacy ``numpy.random.<fn>()`` module-level API.  All
    sanctioned randomness flows through explicitly seeded
    ``np.random.Generator`` objects (``default_rng(SeedSequence(...))``).
``set-iteration``
    Iterating a ``set``/``frozenset`` expression (literal, constructor
    call, or set comprehension) in a ``for``, a comprehension, or an
    order-sensitive/accumulating call (``list``/``tuple``/``sum``/…).
    Set iteration order is salted-hash order and varies across processes —
    exactly the cross-worker poison for a multiprocessing sweep.
    ``sorted(set(...), key=...)`` is flagged too — ties in the sort key
    fall back to set order (Python sorts are stable in *input* order) —
    but key-less ``sorted`` over a set totally orders its distinct
    elements and is allowed.
``dict-popitem``
    ``d.popitem()`` — LIFO over insertion order; almost never the order
    the caller means, and a refactor away from nondeterminism.
``id-in-key``
    ``id(...)`` anywhere in a result path: object identity is an address,
    different every process, so any ordering or keying through it is
    nondeterministic across runs.
``wallclock``
    Reads of real time (``time.time``/``perf_counter``/``monotonic``…,
    ``datetime.now``/``utcnow``/``today``) — fine for logging/stats, fatal
    in anything that feeds a schedule or a cache record.
``uuid``
    ``uuid.uuid1()``/``uuid.uuid4()`` — fresh entropy per call.
``nan-json``
    ``json.dumps``/``json.dump`` without an explicit ``allow_nan=False``:
    NaN-capable floats flowing into cache JSON would serialize as the
    non-standard ``NaN`` token (and NaN != NaN breaks record comparison);
    cache writers must route NaN through an explicit encoding
    (``sweep._nan_to_null``) and keep strict JSON on.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set

from .importgraph import (
    CORE_DIR,
    expected_fingerprint_sources,
    list_modules,
)
from .report import Finding

#: numpy.random module-level functions that mutate/read the process-global
#: legacy RandomState (np.random.default_rng / Generator / SeedSequence are
#: the sanctioned, explicitly-seeded API and are not listed).
_NP_RANDOM_LEGACY = frozenset({
    "seed", "random", "random_sample", "ranf", "sample", "rand", "randn",
    "randint", "random_integers", "choice", "shuffle", "permutation",
    "bytes", "uniform", "normal", "lognormal", "exponential", "poisson",
    "binomial", "beta", "gamma", "standard_normal", "get_state",
    "set_state",
})

_WALLCLOCK_TIME = frozenset({
    "time", "time_ns", "monotonic", "monotonic_ns", "perf_counter",
    "perf_counter_ns", "process_time", "process_time_ns",
})
_WALLCLOCK_DATETIME = frozenset({"now", "utcnow", "today"})

_ORDER_SENSITIVE_CALLS = frozenset({
    "list", "tuple", "sum", "min", "max", "sorted", "any", "all",
    "enumerate", "map", "filter", "reversed",
})

_UUID_FRESH = frozenset({"uuid1", "uuid4"})


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    return False


class _Scanner(ast.NodeVisitor):
    def __init__(self, module: str):
        self.module = module
        self.findings: List[Finding] = []
        self._ctx: List[str] = []
        # alias -> canonical module name, for the modules the rules watch.
        self.mod_alias: Dict[str, str] = {}
        # names imported via "from X import y": name -> "X.y"
        self.from_alias: Dict[str, str] = {}

    # ------------------------------------------------------------- helpers
    @property
    def context(self) -> str:
        return ".".join(self._ctx)

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            "determinism", rule, self.module, self.context,
            getattr(node, "lineno", 1), message))

    def _call_target(self, func: ast.AST) -> Optional[str]:
        """Dotted name of a call target, de-aliased, or None.

        ``np.random.rand`` -> "numpy.random.rand" (given ``import numpy
        as np``); ``perf_counter`` -> "time.perf_counter" (given ``from
        time import perf_counter``).
        """
        parts: List[str] = []
        while isinstance(func, ast.Attribute):
            parts.append(func.attr)
            func = func.value
        if isinstance(func, ast.Name):
            root = func.id
            if root in self.mod_alias:
                parts.append(self.mod_alias[root])
            elif root in self.from_alias and not parts:
                return self.from_alias[root]
            elif root in self.from_alias:
                parts.append(self.from_alias[root])
            else:
                parts.append(root)
            return ".".join(reversed(parts))
        return None

    # ------------------------------------------------------------- imports
    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            name = alias.name
            self.mod_alias[alias.asname or name] = name
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module and node.level == 0:
            for alias in node.names:
                self.from_alias[alias.asname or alias.name] = \
                    f"{node.module}.{alias.name}"
        self.generic_visit(node)

    # ------------------------------------------------------------- scoping
    def _scoped(self, node) -> None:
        self._ctx.append(node.name)
        self.generic_visit(node)
        self._ctx.pop()

    visit_FunctionDef = _scoped
    visit_AsyncFunctionDef = _scoped
    visit_ClassDef = _scoped

    @staticmethod
    def _is_total_sort(node: ast.Call) -> bool:
        """``sorted(<set>)`` with no ``key=`` totally orders the distinct
        elements — deterministic by construction, so not a finding.  With
        a ``key=``, equal keys tie and stable sort falls back to the
        set's salted-hash order."""
        return (isinstance(node.func, ast.Name)
                and node.func.id == "sorted"
                and not any(k.arg == "key" for k in node.keywords))

    # ------------------------------------------------------------ the rules
    def visit_For(self, node: ast.For) -> None:
        if _is_set_expr(node.iter):
            self._emit("set-iteration", node.iter,
                       "for-loop over a set: iteration order is "
                       "salted-hash order, different across processes")
        self.generic_visit(node)

    def visit_comprehension_iter(self, node) -> None:
        for gen in node.generators:
            if _is_set_expr(gen.iter):
                self._emit("set-iteration", gen.iter,
                           "comprehension over a set: iteration order is "
                           "salted-hash order, different across processes")
        self.generic_visit(node)

    visit_ListComp = visit_comprehension_iter
    visit_GeneratorExp = visit_comprehension_iter
    visit_DictComp = visit_comprehension_iter
    # SetComp iterating a set stays unordered -> not flagged.

    def visit_Call(self, node: ast.Call) -> None:
        target = self._call_target(node.func)
        if target is not None:
            head, _, tail = target.rpartition(".")
            if head == "random":
                # random.Random(seed) / random.SeedSequence-style
                # explicitly-seeded construction is deterministic;
                # only the argless form seeds from OS entropy.
                seeded_ctor = (tail == "Random"
                               and bool(node.args or node.keywords))
                if not seeded_ctor:
                    self._emit("unseeded-random", node,
                               f"random.{tail}() uses the process-global "
                               "stdlib RNG; use an explicitly seeded "
                               "np.random.Generator stream")
            elif head in ("numpy.random", "np.random") \
                    and tail in _NP_RANDOM_LEGACY:
                self._emit("unseeded-random", node,
                           f"numpy.random.{tail}() uses the legacy "
                           "process-global RandomState; use "
                           "default_rng(SeedSequence(...))")
            elif head == "time" and tail in _WALLCLOCK_TIME:
                self._emit("wallclock", node,
                           f"time.{tail}() reads the real clock; results "
                           "must be functions of machine time, not wall "
                           "time")
            elif (head in ("datetime", "datetime.datetime", "datetime.date")
                    and tail in _WALLCLOCK_DATETIME):
                self._emit("wallclock", node,
                           f"{target}() reads the real clock; results "
                           "must be functions of machine time, not wall "
                           "time")
            elif head == "uuid" and tail in _UUID_FRESH:
                self._emit("uuid", node,
                           f"uuid.{tail}() draws fresh entropy per call")
            elif target in ("json.dumps", "json.dump"):
                kw = {k.arg for k in node.keywords}
                if "allow_nan" not in kw:
                    self._emit("nan-json", node,
                               f"{target}() without allow_nan=False: a "
                               "NaN reaching this payload would emit the "
                               "non-standard NaN token into cache/digest "
                               "JSON; encode NaN explicitly and pass "
                               "allow_nan=False")
        if isinstance(node.func, ast.Name):
            if node.func.id in _ORDER_SENSITIVE_CALLS and node.args \
                    and _is_set_expr(node.args[0]) \
                    and not self._is_total_sort(node):
                self._emit("set-iteration", node,
                           f"{node.func.id}() over a set feeds "
                           "order-sensitive output from salted-hash "
                           "iteration order (sorted() ties fall back to "
                           "set order)")
            elif node.func.id == "id" and len(node.args) == 1:
                self._emit("id-in-key", node,
                           "id() is an object address — different every "
                           "process; never let identity feed an order, a "
                           "key, or a record")
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "popitem" and not node.args:
            self._emit("dict-popitem", node,
                       "dict.popitem() pops in LIFO insertion order; "
                       "spell the intended order explicitly")
        self.generic_visit(node)


def default_scan_modules(core_dir: Optional[Path] = None) -> List[str]:
    """Modules the determinism pass scans by default: the union of every
    machine's result-determining closure, plus ``sweep`` itself (cache
    keys and records are built there — a nondeterministic key is as stale
    as a nondeterministic record)."""
    mods: Set[str] = {"sweep"}
    for closure in expected_fingerprint_sources(core_dir).values():
        mods |= closure
    return sorted(mods)


#: Repo-level directories the pass also lints (benchmarks drive cached
#: sweeps; tests pin golden bytes — nondeterminism there corrupts both).
REPO_SCAN_DIRS = ("benchmarks", "tests")


def repo_scan_files(core_dir: Path) -> List[tuple]:
    """``(module-label, path)`` for repo-level scan targets.

    Only resolves when ``core_dir`` sits at the canonical
    ``<root>/src/repro/core`` location; ``--core-dir`` scratch trees have
    no surrounding repo and are silently scanned core-only.
    """
    core_dir = Path(core_dir).resolve()
    if core_dir.name != "core" or core_dir.parent.name != "repro" \
            or core_dir.parent.parent.name != "src":
        return []
    root = core_dir.parent.parent.parent
    out = []
    for dirname in REPO_SCAN_DIRS:
        d = root / dirname
        if not d.is_dir():
            continue
        for path in sorted(d.glob("*.py")):
            out.append((f"{dirname}/{path.stem}", path))
    return out


def scan_determinism(core_dir: Optional[Path] = None,
                     modules: Optional[Sequence[str]] = None
                     ) -> List[Finding]:
    """Run the determinism lints; returns raw (un-baselined) findings."""
    core_dir = Path(core_dir) if core_dir is not None else CORE_DIR
    available = list_modules(core_dir)
    targets: List[tuple] = []
    if modules is None:
        targets = [(m, available[m])
                   for m in default_scan_modules(core_dir)
                   if m in available]
        targets.extend(repo_scan_files(core_dir))
    else:
        targets = [(m, available[m]) for m in modules if m in available]
    findings: List[Finding] = []
    for label, path in targets:
        scanner = _Scanner(label)
        scanner.visit(ast.parse(path.read_text(), filename=str(path)))
        findings.extend(scanner.findings)
    findings.sort(key=lambda f: (f.module, f.line, f.rule))
    return findings


def scan_source(source: str, module: str = "<fixture>") -> List[Finding]:
    """Lint one source string (test fixtures use this)."""
    scanner = _Scanner(module)
    scanner.visit(ast.parse(source))
    return scanner.findings
