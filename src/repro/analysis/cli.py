"""``python -m repro.analysis`` — run the determinism & cache-integrity
analyzer and the engine-verification passes.

Exit status: 0 when every pass is clean (modulo the checked-in
baseline), 1 when any non-baselined finding blocks, 2 when the analyzer
itself crashed or was misused.  CI runs this (via ``make analyze``)
before the test tiers; ``--json`` emits stable-sorted machine-readable
records for tooling.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback
from pathlib import Path
from typing import List, Optional, Sequence

from .conformance import scan_conformance
from .determinism import scan_determinism
from .importgraph import CORE_DIR, check_fingerprint_coverage
from .layout import scan_layout
from .protocol import check_protocols
from .report import (
    BASELINABLE_PASSES,
    Baseline,
    Finding,
    apply_baseline,
    format_report,
)
from .translate import scan_translation

PASSES = ("fingerprint", "determinism", "protocol", "conformance",
          "translate", "layout")


def run_passes(core_dir: Optional[Path] = None,
               passes: Sequence[str] = PASSES) -> List[Finding]:
    findings: List[Finding] = []
    if "fingerprint" in passes:
        findings.extend(check_fingerprint_coverage(core_dir))
    if "determinism" in passes:
        findings.extend(scan_determinism(core_dir))
    if "protocol" in passes:
        findings.extend(check_protocols(core_dir))
    resolved = Path(core_dir) if core_dir is not None else CORE_DIR
    if "conformance" in passes:
        findings.extend(scan_conformance(resolved))
    if "translate" in passes:
        findings.extend(scan_translation(resolved))
    if "layout" in passes:
        findings.extend(scan_layout(resolved))
    return findings


def _json_records(report) -> str:
    """Stable-sorted machine-readable findings (blocking + suppressed)."""
    records = []
    for f, suppressed in ([(f, False) for f in report.blocking]
                          + [(f, True) for f in report.suppressed]):
        records.append({
            "pass": f.pass_name,
            "rule": f.rule,
            "file": f"{f.module}.py",
            "line": f.line,
            "location": f"{f.module}.py:{f.line}",
            "context": f.context,
            "message": f.message,
            "suppressed": suppressed,
        })
    records.sort(key=lambda r: (r["file"], r["line"], r["pass"], r["rule"],
                                r["context"], r["message"]))
    return json.dumps({"ok": report.ok, "findings": records},
                      indent=2, sort_keys=True, allow_nan=False)


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static determinism & cache-integrity analysis of "
                    "repro.core (DESIGN.md Section 9).")
    parser.add_argument(
        "--core-dir", type=Path, default=None,
        help="analyze this copy of the repro/core sources instead of the "
             "installed package (mutation tests use this)")
    parser.add_argument(
        "--baseline", type=Path, default=None,
        help="baseline file (default: the checked-in "
             "src/repro/analysis/baseline.json)")
    parser.add_argument(
        "--passes", default=",".join(PASSES),
        help=f"comma-separated subset of {PASSES}")
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="rewrite the baseline to accept all current determinism "
             "findings (preserving reasons of kept entries); new entries "
             "still need a hand-written reason before the run goes green")
    parser.add_argument(
        "--json", action="store_true", dest="as_json",
        help="emit machine-readable findings (stable-sorted records with "
             "file:line, rule id and pass name) instead of the text "
             "report")
    parser.add_argument(
        "-v", "--verbose", action="store_true",
        help="also list baseline-suppressed findings")
    args = parser.parse_args(argv)

    passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())
    unknown = [p for p in passes if p not in PASSES]
    if unknown:
        parser.error(f"unknown pass(es) {unknown}; choose from {PASSES}")

    core_dir = args.core_dir if args.core_dir is not None else CORE_DIR
    if not Path(core_dir, "sweep.py").exists():
        parser.error(f"{core_dir} does not look like repro/core "
                     "(no sweep.py)")

    try:
        findings = run_passes(core_dir, passes)
        baseline = Baseline.load(args.baseline)

        if args.write_baseline:
            old_reasons = {k: r for k, (_, r) in baseline.entries.items()}
            new = Baseline.from_findings(findings, reasons=old_reasons)
            new.dump(args.baseline if args.baseline is not None
                     else baseline.path)
            print(f"baseline rewritten with {len(new.entries)} "
                  "entr(y/ies); fill in empty \"reason\" fields before "
                  "committing")
            baseline = new

        all_baselinable_ran = all(p in passes for p in BASELINABLE_PASSES)
        report = apply_baseline(findings, baseline,
                                check_stale=all_baselinable_ran)
    except Exception:
        # A crash must not be mistakable for "no findings": exit 2, not 0/1.
        traceback.print_exc()
        print("analyzer crashed; this is an analyzer bug, not a finding",
              file=sys.stderr)
        return 2
    if args.as_json:
        print(_json_records(report))
    else:
        out = format_report(report, verbose=args.verbose)
        if out:
            print(out)
    return 0 if report.ok else 1


if __name__ == "__main__":          # pragma: no cover - exercised via -m
    sys.exit(main())
