"""``python -m repro.analysis`` — run the determinism & cache-integrity
analyzer.

Exit status: 0 when every pass is clean (modulo the checked-in baseline),
1 when any non-baselined finding blocks, 2 on usage errors.  CI runs this
(via ``make analyze``) before the test tiers.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from .determinism import scan_determinism
from .importgraph import CORE_DIR, check_fingerprint_coverage
from .protocol import check_protocols
from .report import (
    Baseline,
    Finding,
    apply_baseline,
    format_report,
)

PASSES = ("fingerprint", "determinism", "protocol")


def run_passes(core_dir: Optional[Path] = None,
               passes: Sequence[str] = PASSES) -> List[Finding]:
    findings: List[Finding] = []
    if "fingerprint" in passes:
        findings.extend(check_fingerprint_coverage(core_dir))
    if "determinism" in passes:
        findings.extend(scan_determinism(core_dir))
    if "protocol" in passes:
        findings.extend(check_protocols(core_dir))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static determinism & cache-integrity analysis of "
                    "repro.core (DESIGN.md Section 9).")
    parser.add_argument(
        "--core-dir", type=Path, default=None,
        help="analyze this copy of the repro/core sources instead of the "
             "installed package (mutation tests use this)")
    parser.add_argument(
        "--baseline", type=Path, default=None,
        help="baseline file (default: the checked-in "
             "src/repro/analysis/baseline.json)")
    parser.add_argument(
        "--passes", default=",".join(PASSES),
        help=f"comma-separated subset of {PASSES}")
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="rewrite the baseline to accept all current determinism "
             "findings (preserving reasons of kept entries); new entries "
             "still need a hand-written reason before the run goes green")
    parser.add_argument(
        "-v", "--verbose", action="store_true",
        help="also list baseline-suppressed findings")
    args = parser.parse_args(argv)

    passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())
    unknown = [p for p in passes if p not in PASSES]
    if unknown:
        parser.error(f"unknown pass(es) {unknown}; choose from {PASSES}")

    core_dir = args.core_dir if args.core_dir is not None else CORE_DIR
    if not Path(core_dir, "sweep.py").exists():
        parser.error(f"{core_dir} does not look like repro/core "
                     "(no sweep.py)")

    findings = run_passes(core_dir, passes)
    baseline = Baseline.load(args.baseline)

    if args.write_baseline:
        old_reasons = {k: r for k, (_, r) in baseline.entries.items()}
        new = Baseline.from_findings(findings, reasons=old_reasons)
        new.dump(args.baseline if args.baseline is not None
                 else baseline.path)
        print(f"baseline rewritten with {len(new.entries)} entr(y/ies); "
              "fill in empty \"reason\" fields before committing")
        baseline = new

    report = apply_baseline(findings, baseline)
    out = format_report(report, verbose=args.verbose)
    if out:
        print(out)
    return 0 if report.ok else 1


if __name__ == "__main__":          # pragma: no cover - exercised via -m
    sys.exit(main())
