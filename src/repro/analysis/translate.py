"""Translation validator: the Python twin vs. the generated C backend.

``fastsim_twin.py`` and ``fastsim_c.py`` are maintained as a
function-for-function pair; the runtime equivalence matrix (DESIGN.md
Section 10) samples their agreement, but a *sampled* gate can miss an
unmirrored edit.  This pass lowers both sides into a shared normalized
summary per function and fails on any structural disagreement.

Normalized IR (per function) — deliberately *bag-based* rather than a
lockstep tree diff, so that C idioms the translation legitimately uses
(declaration hoisting, one-sided temporaries for repeated reads, block
scoping) do not produce noise:

* ``params``   — parameter count after dropping the state arrays / ``S``.
* ``skeleton`` — *ordered* control-flow string: counted loops ``L{..}``,
  ``while (1)`` / ``while True`` loops ``F{..}``, other whiles
  ``W{..}``, conditionals ``I{..}E{..}``, ``return`` ``R<arity>``,
  ``break``/``continue`` ``B``/``C``.  Straight-line assignments and
  calls are invisible.
* ``compares`` / ``binops`` / ``selects`` / ``loops`` / ``calls`` /
  ``writes`` — *multisets* of operation signatures where operands
  collapse to a constant value or the wildcard ``x``.
* ``reads`` — a *set* (not multiset) of array-read signatures with the
  index rendered symbolically; set semantics make C-side caching of a
  repeated read into a temporary invisible.
* ``local_arrays`` — shapes/dtypes of function-local scratch arrays.

Scalar assignments are not recorded at all: a temporary only matters
through the reads/ops/writes it feeds, which the bags already capture.
Constants are folded through the twin's module constants, so renaming a
``#define`` or drifting its value surfaces as a bag or constant-drift
mismatch rather than hiding behind a name.

On top of the pair diff, C-side-only lints cover the places where a
structurally identical translation could still diverge numerically:
``-ffp-contract=off`` must stay in the build line while FMA-able
``a*b+c`` float shapes exist (rule ``fma-contract``), C ``/`` must never
see two int operands since Python ``/`` is true division and ``//``
floors while C truncates (rule ``int-division``), and every declared
scalar must be ``int64_t``/``double`` so no implicit narrowing can bite
(rule ``narrowed-dtype``).
"""

from __future__ import annotations

import ast
import math
from collections import Counter
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from . import cparse
from .cparse import (CAssign, CBreak, CContinue, CDecl, CExprStmt, CFor,
                     CFunc, CIf, CParseError, CReturn, CUnit, CWhile)
from .enginesrc import (ARRAY_DTYPES, C_CONST_ALIASES, CANONICAL_ARRAYS,
                        c_path, fold_twin_constants, load_twin_ast,
                        pair_name, parse_c_unit, twin_jit_functions,
                        twin_path)
from .report import Finding

PASS = "translate"

_TWIN_MODULE = "fastsim_twin"
_C_MODULE = "fastsim_c"

#: C scalar declaration types that match the twin's int64/float64 world.
_WIDE_TYPES = {"int64_t", "double"}

#: ``int`` is tolerated for pure boolean/flag locals (values in {0,1},
#: never fed into arithmetic); anything else narrows.
_BOOL_OK_TYPE = "int"

_ARITH_OPS = {"+", "-", "*", "/", "%", "<<", ">>"}


# ------------------------------------------------------------- summaries
@dataclass
class FuncSummary:
    name: str
    line: int = 0
    params: int = 0
    skeleton: str = ""
    loops: Counter = field(default_factory=Counter)
    compares: Counter = field(default_factory=Counter)
    binops: Counter = field(default_factory=Counter)
    selects: Counter = field(default_factory=Counter)
    calls: Counter = field(default_factory=Counter)
    writes: Counter = field(default_factory=Counter)
    returns: Counter = field(default_factory=Counter)
    reads: set = field(default_factory=set)
    local_arrays: Counter = field(default_factory=Counter)

    _BAGS = ("loops", "compares", "binops", "selects", "calls", "writes",
             "returns", "local_arrays")

    def diff(self, other: "FuncSummary") -> List[str]:
        """Human-readable mismatch descriptions (empty = equivalent)."""
        out: List[str] = []
        if self.params != other.params:
            out.append(f"parameter count {self.params} vs {other.params}")
        if self.skeleton != other.skeleton:
            out.append(f"control-flow skeleton {self.skeleton!r} vs "
                       f"{other.skeleton!r}")
        for bag in self._BAGS:
            a: Counter = getattr(self, bag)
            b: Counter = getattr(other, bag)
            if a != b:
                only_a = sorted((a - b).elements())
                only_b = sorted((b - a).elements())
                parts = []
                if only_a:
                    parts.append("twin-only " + ", ".join(only_a[:4]))
                if only_b:
                    parts.append("c-only " + ", ".join(only_b[:4]))
                out.append(f"{bag} bag: " + "; ".join(parts))
        if self.reads != other.reads:
            only_a = sorted(self.reads - other.reads)
            only_b = sorted(other.reads - self.reads)
            parts = []
            if only_a:
                parts.append("twin-only " + ", ".join(only_a[:4]))
            if only_b:
                parts.append("c-only " + ", ".join(only_b[:4]))
            out.append("reads set: " + "; ".join(parts))
        return out


def _const_repr(value) -> str:
    if isinstance(value, float):
        if math.isnan(value):
            return "NAN"
        if math.isinf(value):
            return "INF" if value > 0 else "-INF"
        if value == int(value) and abs(value) < 1e15:
            # 1.0 and 1 must not depend on which side spelled the literal
            # with a dot; the engine is all-float64/int64 anyway.
            return str(int(value))
        return repr(value)
    return str(value)


_CMP_MIRROR = {"<": ">", "<=": ">=", ">": "<", ">=": "<=",
               "==": "==", "!=": "!="}


def _cmp_sig(op: str, left: str, right: str) -> str:
    """Orientation-normalized comparison signature.

    ``a < b`` and ``b > a`` are the same comparison; pick the
    lexicographically smaller rendering so both sides agree regardless
    of how the translation oriented it.
    """
    a = f"({op},{left},{right})"
    b = f"({_CMP_MIRROR[op]},{right},{left})"
    return min(a, b)


_COMMUTATIVE = {"+", "*"}


def _bin_sig(op: str, left: str, right: str) -> str:
    if op in _COMMUTATIVE and right < left:
        left, right = right, left
    return f"({op},{left},{right})"


# ------------------------------------------------------- twin normalizer
_PY_BINOPS = {ast.Add: "+", ast.Sub: "-", ast.Mult: "*", ast.Div: "/",
              ast.FloorDiv: "//", ast.Mod: "%", ast.LShift: "<<",
              ast.RShift: ">>"}
_PY_CMPOPS = {ast.Eq: "==", ast.NotEq: "!=", ast.Lt: "<", ast.LtE: "<=",
              ast.Gt: ">", ast.GtE: ">="}


class TwinNormalizeError(Exception):
    def __init__(self, message: str, line: int = 0):
        super().__init__(message)
        self.line = line


class _TwinNormalizer:
    """Lower one ``@_jit`` twin function into a :class:`FuncSummary`."""

    def __init__(self, fn: ast.FunctionDef, consts: Dict[str, object]):
        self.fn = fn
        self.consts = consts
        self.summary = FuncSummary(name=fn.name, line=fn.lineno)
        self.aliases: Dict[str, str] = {}   # local name -> canonical array
        self.local_arrays: Dict[str, str] = {}
        params = [a.arg for a in fn.args.args]
        self.state_param = "S" if "S" in params else None
        for p in params:
            if p in CANONICAL_ARRAYS:
                self.aliases[p] = p
        self.summary.params = len([
            p for p in params if p != "S" and p not in CANONICAL_ARRAYS])

    def run(self) -> FuncSummary:
        self.summary.skeleton = self._block(self.fn.body)
        return self.summary

    # -- statements -> skeleton fragments
    def _block(self, stmts: Sequence[ast.stmt]) -> str:
        return "".join(self._stmt(s) for s in stmts)

    def _stmt(self, s: ast.stmt) -> str:
        if isinstance(s, ast.Expr):
            if isinstance(s.value, ast.Constant) and isinstance(
                    s.value.value, str):
                return ""      # docstring
            self._expr(s.value)
            return ""
        if isinstance(s, ast.Assign):
            return self._assign(s)
        if isinstance(s, ast.AugAssign):
            op = _PY_BINOPS.get(type(s.op))
            if op is None:
                raise TwinNormalizeError(
                    f"unsupported augmented op {type(s.op).__name__}",
                    s.lineno)
            target_kind = self._expr(s.target, write=True)
            value_kind = self._expr(s.value)
            self.summary.binops[_bin_sig(op, target_kind, value_kind)] += 1
            return ""
        if isinstance(s, ast.If):
            self._expr(s.test)
            frag = "I{" + self._block(s.body) + "}"
            if s.orelse:
                frag += "E{" + self._block(s.orelse) + "}"
            return frag
        if isinstance(s, ast.While):
            if isinstance(s.test, ast.Constant) and s.test.value is True:
                return "F{" + self._block(s.body) + "}"
            self._expr(s.test)
            return "W{" + self._block(s.body) + "}"
        if isinstance(s, ast.For):
            return self._for(s)
        if isinstance(s, ast.Return):
            return self._return(s)
        if isinstance(s, ast.Break):
            return "B"
        if isinstance(s, ast.Continue):
            return "C"
        if isinstance(s, ast.Pass):
            return ""
        raise TwinNormalizeError(
            f"unsupported statement {type(s).__name__}", s.lineno)

    def _assign(self, s: ast.Assign) -> str:
        if len(s.targets) != 1:
            raise TwinNormalizeError("chained assignment", s.lineno)
        target = s.targets[0]
        if isinstance(target, ast.Name):
            # State-unpack prologue: ``si = S[0]`` binds an alias.
            if (self.state_param and isinstance(s.value, ast.Subscript)
                    and isinstance(s.value.value, ast.Name)
                    and s.value.value.id == self.state_param
                    and isinstance(s.value.slice, ast.Constant)):
                idx = s.value.slice.value
                if isinstance(idx, int) and 0 <= idx < len(CANONICAL_ARRAYS):
                    self.aliases[target.id] = CANONICAL_ARRAYS[idx]
                    return ""
            arr = self._np_empty(s.value)
            if arr is not None:
                label = f"local{len(self.local_arrays)}"
                self.local_arrays[target.id] = label
                self.summary.local_arrays[f"{label}{arr}"] += 1
                return ""
            self._expr(s.value)
            return ""
        if isinstance(target, ast.Tuple):
            if not all(isinstance(e, ast.Name) for e in target.elts):
                raise TwinNormalizeError("complex tuple target", s.lineno)
            self._expr(s.value)
            return ""
        if isinstance(target, (ast.Subscript,)):
            self._expr(target, write=True)
            self._expr(s.value)
            return ""
        raise TwinNormalizeError(
            f"unsupported assignment target {type(target).__name__}",
            s.lineno)

    def _np_empty(self, e: ast.expr) -> Optional[str]:
        """``np.empty((d0, d1), np.int64)`` -> ``(d0,d1):i`` signature."""
        if not (isinstance(e, ast.Call) and isinstance(e.func, ast.Attribute)
                and isinstance(e.func.value, ast.Name)
                and e.func.value.id == "np"
                and e.func.attr in ("empty", "zeros")):
            return None
        if not e.args:
            return None
        shape = e.args[0]
        dims = shape.elts if isinstance(shape, ast.Tuple) else [shape]
        rendered = []
        for d in dims:
            from .enginesrc import _fold_expr
            v = _fold_expr(d, self.consts)
            rendered.append(_const_repr(v) if v is not None else "x")
        dtype = "i"
        if len(e.args) > 1 and isinstance(e.args[1], ast.Attribute):
            dtype = "f" if "float" in e.args[1].attr else "i"
        return "(" + ",".join(rendered) + "):" + dtype

    def _for(self, s: ast.For) -> str:
        if not (isinstance(s.iter, ast.Call)
                and isinstance(s.iter.func, ast.Name)
                and s.iter.func.id == "range"
                and isinstance(s.target, ast.Name)):
            raise TwinNormalizeError("non-range for loop", s.lineno)
        args = s.iter.args
        if len(args) == 1:
            lo: Optional[ast.expr] = None
            hi = args[0]
        elif len(args) == 2:
            lo, hi = args
        else:
            raise TwinNormalizeError("stepped range loop", s.lineno)
        lo_kind = "0" if lo is None else self._expr(lo)
        hi_kind = self._expr(hi)
        self.summary.loops[f"({lo_kind},{hi_kind})"] += 1
        return "L{" + self._block(s.body) + "}"

    def _return(self, s: ast.Return) -> str:
        if s.value is None:
            self.summary.returns["R0"] += 1
            return "R0"
        if isinstance(s.value, ast.Tuple):
            arity = len(s.value.elts)
            for e in s.value.elts:
                self._expr(e)
        else:
            arity = 1
            self._expr(s.value)
        self.summary.returns[f"R{arity}"] += 1
        return f"R{arity}"

    # -- expressions -> kinds
    def _expr(self, e: ast.expr, write: bool = False) -> str:
        if isinstance(e, ast.Constant):
            if isinstance(e.value, bool):
                return _const_repr(int(e.value))
            if isinstance(e.value, (int, float)):
                return _const_repr(e.value)
            raise TwinNormalizeError(
                f"unsupported constant {e.value!r}", e.lineno)
        if isinstance(e, ast.Name):
            if e.id in self.consts:
                return _const_repr(self.consts[e.id])
            return "x"
        if isinstance(e, ast.Attribute):
            if isinstance(e.value, ast.Name) and e.value.id == "math":
                if e.attr == "nan":
                    return "NAN"
                if e.attr == "inf":
                    return "INF"
            return "x"
        if isinstance(e, ast.Subscript):
            return self._arrayref(e, write)
        if isinstance(e, ast.BinOp):
            op = _PY_BINOPS.get(type(e.op))
            if op is None:
                raise TwinNormalizeError(
                    f"unsupported operator {type(e.op).__name__}", e.lineno)
            lk = self._expr(e.left)
            rk = self._expr(e.right)
            self.summary.binops[_bin_sig(op, lk, rk)] += 1
            return "x"
        if isinstance(e, ast.BoolOp):
            op = "and" if isinstance(e.op, ast.And) else "or"
            for v in e.values:
                self._expr(v)
            self.summary.binops[f"({op},{len(e.values)})"] += 1
            return "x"
        if isinstance(e, ast.UnaryOp):
            if isinstance(e.op, ast.USub):
                inner = self._expr(e.operand)
                if inner not in ("x",) and not inner.startswith("-"):
                    # Folded constant negation: -1, -INF ...
                    if inner == "INF":
                        return "-INF"
                    try:
                        return _const_repr(-float(inner)
                                           if "." in inner or "e" in inner
                                           else -int(inner))
                    except ValueError:
                        pass
                self.summary.binops[f"(neg,{inner})"] += 1
                return "x"
            if isinstance(e.op, ast.Not):
                self._expr(e.operand)
                self.summary.binops["(not)"] += 1
                return "x"
            raise TwinNormalizeError(
                f"unsupported unary op {type(e.op).__name__}", e.lineno)
        if isinstance(e, ast.Compare):
            if len(e.ops) != 1:
                raise TwinNormalizeError("chained comparison", e.lineno)
            op = _PY_CMPOPS.get(type(e.ops[0]))
            if op is None:
                raise TwinNormalizeError(
                    f"unsupported comparison {type(e.ops[0]).__name__}",
                    e.lineno)
            lk = self._expr(e.left)
            rk = self._expr(e.comparators[0])
            self.summary.compares[_cmp_sig(op, lk, rk)] += 1
            return "x"
        if isinstance(e, ast.IfExp):
            self._expr(e.test)
            a = self._expr(e.body)
            b = self._expr(e.orelse)
            self.summary.selects[f"({a},{b})"] += 1
            return "x"
        if isinstance(e, ast.Call):
            return self._call(e)
        raise TwinNormalizeError(
            f"unsupported expression {type(e).__name__}", e.lineno)

    def _call(self, e: ast.Call) -> str:
        func = e.func
        if isinstance(func, ast.Name):
            name = func.id
            if name == "int" and len(e.args) == 1:
                self._expr(e.args[0])   # cast: erased in the IR
                return "x"
            callee = name.lstrip("_") if name.startswith("_") else name
        elif (isinstance(func, ast.Attribute)
              and isinstance(func.value, ast.Name)
              and func.value.id == "math"):
            callee = func.attr
        else:
            raise TwinNormalizeError("unsupported call target", e.lineno)
        kinds = []
        for a in e.args:
            if isinstance(a, ast.Name) and (
                    a.id == self.state_param or a.id in self.aliases):
                continue    # state plumbing: dropped on both sides
            kinds.append(self._expr(a))
        self.summary.calls[f"{callee}({','.join(kinds)})"] += 1
        return "x"

    def _arrayref(self, e: ast.Subscript, write: bool) -> str:
        if not isinstance(e.value, ast.Name):
            raise TwinNormalizeError("nested subscript base", e.lineno)
        base = e.value.id
        if base == self.state_param:
            raise TwinNormalizeError(
                "raw state-tuple subscript outside prologue", e.lineno)
        if base in self.aliases:
            arr = self.aliases[base]
        elif base in self.local_arrays:
            arr = self.local_arrays[base]
        else:
            raise TwinNormalizeError(
                f"subscript of unknown array {base!r}", e.lineno)
        idx = e.slice
        dims = idx.elts if isinstance(idx, ast.Tuple) else [idx]
        rendered = [self._index(d) for d in dims]
        if arr in ("smf", "dcf") and len(rendered) == 1:
            rendered.append("0")
        sig = f"{arr}[{','.join(rendered)}]"
        if write:
            self.summary.writes[sig] += 1
        else:
            self.summary.reads.add(sig)
        return "x"

    def _index(self, e: ast.expr) -> str:
        """Symbolic index rendering (richer than kinds: keeps + shapes)."""
        if isinstance(e, ast.Constant) and isinstance(e.value, (int, float)):
            return _const_repr(e.value)
        if isinstance(e, ast.Name):
            if e.id in self.consts:
                return _const_repr(self.consts[e.id])
            return "x"
        if isinstance(e, ast.BinOp):
            op = _PY_BINOPS.get(type(e.op))
            if op is None:
                raise TwinNormalizeError("unsupported index op", e.lineno)
            # Index arithmetic lands in the read/write signature itself,
            # not in the binop bag (the C side mirrors this).
            return _bin_sig(op, self._index(e.left), self._index(e.right))
        if isinstance(e, ast.UnaryOp) and isinstance(e.op, ast.USub):
            inner = self._index(e.operand)
            if inner != "x":
                return "-" + inner
            return "x"
        if isinstance(e, ast.Call):
            self._call(e)
            return "x"
        if isinstance(e, ast.Subscript):
            self._arrayref(e, write=False)
            return "x"
        return "x"


# --------------------------------------------------------- C macro table
@dataclass
class MacroShape:
    """A flat-accessor macro, e.g. ``RI(r,c) -> S->ri[(r)*RI_LEN+(c)]``."""
    name: str
    array: str
    ndim: int
    strides: Tuple[object, ...]     # per-dim multiplier names/values
    uses_nsm: bool = False
    line: int = 0


def _macro_shape(macro: cparse.CMacro,
                 struct_names: Sequence[str]) -> Optional[MacroShape]:
    """Recognize a macro body as a flat array accessor; None otherwise."""
    try:
        sub = cparse._Parser(list(macro.body), {}, struct_names)
        e = sub.parse_expr()
        if sub._peek() is not None:
            return None
    except CParseError:
        return None
    if not (isinstance(e, tuple) and e[0] == "idx"):
        return None
    base, idx = e[1], e[2]
    if not (base[0] == "mem" and base[1] == ("name", "S")):
        return None
    array = base[2]
    params = macro.params or []

    def is_param(x, i):
        return x == ("name", params[i])

    # 1-dim: BODY = S->arr[(p0)]
    if len(params) == 1 and is_param(idx, 0):
        return MacroShape(macro.name, array, 1, (), False, macro.line)
    # 2-dim: S->arr[(p0) * STRIDE + (p1)]
    if (len(params) == 2 and idx[0] == "bin" and idx[1] == "+"
            and idx[2][0] == "bin" and idx[2][1] == "*"
            and is_param(idx[2][2], 0) and is_param(idx[3], 1)):
        stride = idx[2][3]
        if stride[0] in ("name", "num"):
            return MacroShape(macro.name, array, 2, (stride[1],), False,
                             macro.line)
    # 3-dim: S->arr[((p0) * S->nsm + (p1)) * K + (p2)]
    if (len(params) == 3 and idx[0] == "bin" and idx[1] == "+"
            and is_param(idx[3], 2)
            and idx[2][0] == "bin" and idx[2][1] == "*"):
        outer, k = idx[2][2], idx[2][3]
        if (k[0] in ("name", "num") and outer[0] == "bin"
                and outer[1] == "+" and is_param(outer[3], 1)
                and outer[2][0] == "bin" and outer[2][1] == "*"
                and is_param(outer[2][2], 0)
                and outer[2][3] == ("mem", ("name", "S"), "nsm")):
            return MacroShape(macro.name, array, 3, ("nsm", k[1]), True,
                             macro.line)
    return None


def macro_shapes(unit: CUnit) -> Tuple[Dict[str, MacroShape], List[str]]:
    """(name -> shape) for every accessor macro, plus unrecognized names."""
    shapes: Dict[str, MacroShape] = {}
    bad: List[str] = []
    for name, macro in unit.macros.items():
        shape = _macro_shape(macro, unit.structs.keys())
        if shape is None:
            bad.append(name)
        else:
            shapes[name] = shape
    return shapes, bad


# ---------------------------------------------------------- C normalizer
class CNormalizeError(Exception):
    def __init__(self, message: str, line: int = 0):
        super().__init__(message)
        self.line = line


#: C callee -> twin-canonical callee.
_C_CALLEE_MAP = {"fs_decide": "decide", "fs_advance": "advance"}

#: Ev struct fields — member reads of event variables are plain scalars.
_EV_RETURN_ARITY = 7


class _CNormalizer:
    """Lower one C function into the shared :class:`FuncSummary`."""

    def __init__(self, fn: CFunc, unit: CUnit,
                 shapes: Dict[str, MacroShape],
                 consts: Dict[str, object]):
        self.fn = fn
        self.unit = unit
        self.shapes = shapes
        self.consts = consts
        self.summary = FuncSummary(name=fn.name, line=fn.line)
        self.struct_vars: Dict[str, str] = {}   # var -> struct type
        self.local_arrays: Dict[str, str] = {}
        self.out_params: set = set()
        n = 0
        for ctype, is_ptr, name in fn.params:
            if ctype in unit.structs:
                self.struct_vars[name] = ctype
                continue
            if name in CANONICAL_ARRAYS:
                continue    # fs_advance raw-pointer interface
            if is_ptr:
                self.out_params.add(name)
                continue
            n += 1
        self.summary.params = n
        self.return_arity = 1 if fn.rtype != "void" else 0
        self.return_arity += len(self.out_params)

    def run(self) -> FuncSummary:
        self.summary.skeleton = self._block(self.fn.body)
        return self.summary

    # -- statements
    def _block(self, stmts: Sequence[object]) -> str:
        return "".join(self._stmt(s) for s in stmts)

    def _stmt(self, s) -> str:
        if isinstance(s, CDecl):
            return self._decl(s)
        if isinstance(s, CAssign):
            return self._assign(s)
        if isinstance(s, CExprStmt):
            self._expr(s.expr)
            return ""
        if isinstance(s, CIf):
            self._expr(s.cond)
            frag = "I{" + self._block(s.then) + "}"
            if s.orelse:
                frag += "E{" + self._block(s.orelse) + "}"
            return frag
        if isinstance(s, CWhile):
            if s.cond == ("num", 1):
                return "F{" + self._block(s.body) + "}"
            self._expr(s.cond)
            return "W{" + self._block(s.body) + "}"
        if isinstance(s, CFor):
            return self._for(s)
        if isinstance(s, CReturn):
            return self._return(s)
        if isinstance(s, CBreak):
            return "B"
        if isinstance(s, CContinue):
            return "C"
        raise CNormalizeError(f"unsupported statement {type(s).__name__}",
                              getattr(s, "line", 0))

    def _decl(self, s: CDecl) -> str:
        if s.ctype in self.unit.structs:
            if not s.is_pointer:
                self.struct_vars[s.name] = s.ctype
                if s.init is not None:
                    self._expr(s.init)
                return ""
            # ``St *S = &state;`` — alias plumbing, invisible.
            self.struct_vars[s.name] = s.ctype
            return ""
        if s.array_dims:
            dims = [self._fold_c(d) for d in s.array_dims]
            rendered = [_const_repr(v) if v is not None else "x"
                        for v in dims]
            dtype = "f" if s.ctype in ("double", "float") else "i"
            label = f"local{len(self.local_arrays)}"
            self.local_arrays[s.name] = label
            self.summary.local_arrays[
                f"{label}({','.join(rendered)}):{dtype}"] += 1
            return ""
        if s.init is not None:
            self._expr(s.init)      # scalar init: like an assignment
        return ""

    def _assign(self, s: CAssign) -> str:
        target = s.target
        if s.op == "=":
            if target[0] in ("name", "mem") or (
                    target[0] == "un" and target[1] == "*"):
                self._expr(s.value)     # scalar store: invisible
                return ""
            self._expr(target, write=True)
            self._expr(s.value)
            return ""
        op = s.op[0]    # "+=" -> "+"
        target_kind = self._expr(
            target, write=target[0] not in ("name", "mem"))
        value_kind = self._expr(s.value)
        if target[0] in ("name", "mem"):
            target_kind = "x"
        self.summary.binops[_bin_sig(op, target_kind, value_kind)] += 1
        return ""

    def _for(self, s: CFor) -> str:
        if s.init is None and s.cond is None and s.step is None:
            return "F{" + self._block(s.body) + "}"
        # Counted loop: for (v = lo; v < hi; v++)
        if (isinstance(s.init, CAssign) and s.init.op == "="
                and s.init.target[0] == "name"
                and isinstance(s.step, CAssign) and s.step.op == "+="
                and s.step.value == ("num", 1)
                and s.step.target == s.init.target
                and s.cond is not None and s.cond[0] == "cmp"
                and s.cond[1] == "<" and s.cond[2] == s.init.target):
            lo_kind = self._expr(s.init.value)
            hi_kind = self._expr(s.cond[3])
            self.summary.loops[f"({lo_kind},{hi_kind})"] += 1
            return "L{" + self._block(s.body) + "}"
        raise CNormalizeError("unrecognized for-loop shape",
                              getattr(s, "line", 0))

    def _return(self, s: CReturn) -> str:
        if s.value is None:
            arity = self.return_arity
            tag = f"R{arity}" if arity else "R0"
            self.summary.returns[tag] += 1
            return tag
        if (s.value[0] == "name"
                and self.struct_vars.get(s.value[1]) == "Ev"):
            arity = _EV_RETURN_ARITY
        else:
            arity = self.return_arity
            self._expr(s.value)
        self.summary.returns[f"R{arity}"] += 1
        return f"R{arity}"

    # -- expressions
    def _fold_c(self, e) -> Optional[object]:
        if e[0] == "num":
            return e[1]
        if e[0] == "name":
            name = e[1]
            twin_name = C_CONST_ALIASES.get(name, name)
            return self.consts.get(twin_name)
        if e[0] == "un" and e[1] == "-":
            v = self._fold_c(e[2])
            return None if v is None else -v
        if e[0] == "bin" and e[1] in ("+", "-", "*"):
            a, b = self._fold_c(e[2]), self._fold_c(e[3])
            if a is None or b is None:
                return None
            return a + b if e[1] == "+" else (
                a - b if e[1] == "-" else a * b)
        return None

    def _expr(self, e, write: bool = False) -> str:
        tag = e[0]
        if tag == "num":
            return _const_repr(e[1])
        if tag == "name":
            name = e[1]
            if name == "NAN":
                return "NAN"
            if name == "INFINITY":
                return "INF"
            twin_name = C_CONST_ALIASES.get(name, name)
            if twin_name in self.consts:
                return _const_repr(self.consts[twin_name])
            return "x"
        if tag == "mem":
            return "x"      # Ev fields, state.X, S->nsm: scalars
        if tag == "mcall":
            return self._macro_ref(e, write)
        if tag == "idx":
            return self._idx_ref(e, write)
        if tag == "cast":
            return self._expr(e[2])     # casts erased in the IR
        if tag == "un":
            op = e[1]
            if op == "-":
                v = self._fold_c(e)
                if v is not None:
                    return _const_repr(v)
                inner = self._expr(e[2])
                if inner == "INF":
                    return "-INF"
                self.summary.binops[f"(neg,{inner})"] += 1
                return "x"
            if op == "!":
                self._expr(e[2])
                self.summary.binops["(not)"] += 1
                return "x"
            if op == "&":
                return self._expr(e[2])     # &out_r address-of: transparent
            if op == "*":
                return self._expr(e[2])     # *out_r deref: transparent
            raise CNormalizeError(f"unsupported unary {op}")
        if tag == "bin":
            op = e[1]
            lk = self._expr(e[2])
            rk = self._expr(e[3])
            self.summary.binops[_bin_sig(op, lk, rk)] += 1
            return "x"
        if tag == "cmp":
            op = e[1]
            lk = self._expr(e[2])
            rk = self._expr(e[3])
            self.summary.compares[_cmp_sig(op, lk, rk)] += 1
            return "x"
        if tag == "bool":
            op = "and" if e[1] == "&&" else "or"
            for part in e[2]:
                self._expr(part)
            self.summary.binops[f"({op},{len(e[2])})"] += 1
            return "x"
        if tag == "tern":
            self._expr(e[1])
            a = self._expr(e[2])
            b = self._expr(e[3])
            self.summary.selects[f"({a},{b})"] += 1
            return "x"
        if tag == "call":
            return self._call(e)
        raise CNormalizeError(f"unsupported expression tag {tag}")

    def _call(self, e) -> str:
        name = e[1]
        callee = _C_CALLEE_MAP.get(name, name)
        kinds = []
        for a in e[2]:
            if a[0] == "name" and (a[1] in self.struct_vars
                                   or a[1] in CANONICAL_ARRAYS):
                continue    # state plumbing
            if a[0] == "un" and a[1] == "&":
                inner = a[2]
                if inner[0] == "name" and inner[1] not in CANONICAL_ARRAYS:
                    continue    # &out_r out-param: folded into return arity
                if inner[0] == "name":
                    continue
            kinds.append(self._expr(a))
        self.summary.calls[f"{callee}({','.join(kinds)})"] += 1
        return "x"

    def _macro_ref(self, e, write: bool) -> str:
        name, args = e[1], e[2]
        shape = self.shapes.get(name)
        if shape is None:
            raise CNormalizeError(f"unrecognized accessor macro {name}")
        rendered = [self._index(a) for a in args]
        if shape.array in ("smf", "dcf") and len(rendered) == 1:
            rendered.append("0")
        sig = f"{shape.array}[{','.join(rendered)}]"
        if write:
            self.summary.writes[sig] += 1
        else:
            self.summary.reads.add(sig)
        return "x"

    def _idx_ref(self, e, write: bool) -> str:
        # Flatten idx chains: batch[nb][0], S->act[i], bare param arr[i].
        dims = []
        base = e
        while base[0] == "idx":
            dims.append(base[2])
            base = base[1]
        dims.reverse()
        if base[0] == "mem" and base[1] == ("name", "S") \
                and base[2] in CANONICAL_ARRAYS:
            arr = base[2]
        elif base[0] == "name" and base[1] in CANONICAL_ARRAYS:
            arr = base[1]
        elif base[0] == "name" and base[1] in self.local_arrays:
            arr = self.local_arrays[base[1]]
        else:
            raise CNormalizeError(f"subscript of unknown base {base!r}")
        rendered = [self._index(d) for d in dims]
        sig = f"{arr}[{','.join(rendered)}]"
        if write:
            self.summary.writes[sig] += 1
        else:
            self.summary.reads.add(sig)
        return "x"

    def _index(self, e) -> str:
        tag = e[0]
        if tag == "num":
            return _const_repr(e[1])
        if tag == "name":
            twin_name = C_CONST_ALIASES.get(e[1], e[1])
            if twin_name in self.consts:
                return _const_repr(self.consts[twin_name])
            return "x"
        if tag == "bin":
            lk = self._index(e[2])
            rk = self._index(e[3])
            return _bin_sig(e[1], lk, rk)
        if tag == "un" and e[1] == "-":
            v = self._fold_c(e)
            if v is not None:
                return _const_repr(v)
            return "x"
        if tag == "cast":
            return self._index(e[2])
        if tag in ("mcall", "idx"):
            self._expr(e)
            return "x"
        if tag in ("call", "cmp", "tern", "bool", "mem"):
            self._expr(e)
            return "x"
        return "x"


# --------------------------------------------------------- C-side lints
def _walk_c_exprs(stmts):
    """Yield (expr, line) for every expression in a statement list."""
    for s in stmts:
        line = getattr(s, "line", 0)
        if isinstance(s, CDecl):
            if s.init is not None:
                yield s.init, line
            for d in s.array_dims:
                yield d, line
        elif isinstance(s, CAssign):
            yield s.target, line
            yield s.value, line
        elif isinstance(s, CExprStmt):
            yield s.expr, line
        elif isinstance(s, CIf):
            yield s.cond, line
            yield from _walk_c_exprs(s.then)
            yield from _walk_c_exprs(s.orelse)
        elif isinstance(s, CWhile):
            yield s.cond, line
            yield from _walk_c_exprs(s.body)
        elif isinstance(s, CFor):
            if s.init is not None:
                yield from _walk_c_exprs([s.init])
            if s.cond is not None:
                yield s.cond, line
            if s.step is not None:
                yield from _walk_c_exprs([s.step])
            yield from _walk_c_exprs(s.body)
        elif isinstance(s, CReturn):
            if s.value is not None:
                yield s.value, line


def _subexprs(e):
    yield e
    tag = e[0]
    if tag in ("num", "name"):
        return
    if tag == "mem":
        yield from _subexprs(e[1])
    elif tag == "un":
        yield from _subexprs(e[2])
    elif tag == "cast":
        yield from _subexprs(e[2])
    elif tag in ("bin", "cmp"):
        yield from _subexprs(e[2])
        yield from _subexprs(e[3])
    elif tag == "idx":
        yield from _subexprs(e[1])
        yield from _subexprs(e[2])
    elif tag == "tern":
        yield from _subexprs(e[1])
        yield from _subexprs(e[2])
        yield from _subexprs(e[3])
    elif tag == "bool":
        for p in e[2]:
            yield from _subexprs(p)
    elif tag in ("call", "mcall"):
        for a in e[2]:
            yield from _subexprs(a)


class _CTypeEnv:
    """Scalar floatness environment for one C function."""

    _FLOAT_FIELDS = {"t", "start"}      # Ev float members

    def __init__(self, fn: CFunc, unit: CUnit,
                 shapes: Dict[str, MacroShape],
                 consts: Dict[str, object]):
        self.consts = consts
        self.shapes = shapes
        self.var_types: Dict[str, str] = {}
        for ctype, is_ptr, name in fn.params:
            self.var_types[name] = ctype

        def collect(stmts):
            for s in stmts:
                if isinstance(s, CDecl):
                    self.var_types[s.name] = s.ctype
                elif isinstance(s, CIf):
                    collect(s.then)
                    collect(s.orelse)
                elif isinstance(s, (CWhile, CFor)):
                    collect(s.body)
        collect(fn.body)

    def is_float(self, e) -> bool:
        tag = e[0]
        if tag == "num":
            return isinstance(e[1], float)
        if tag == "name":
            name = e[1]
            if name in ("NAN", "INFINITY"):
                return True
            twin_name = C_CONST_ALIASES.get(name, name)
            if twin_name in self.consts:
                return isinstance(self.consts[twin_name], float)
            return self.var_types.get(name) in ("double", "float")
        if tag == "cast":
            return e[1] in ("double", "float")
        if tag == "un":
            if e[1] in ("-",):
                return self.is_float(e[2])
            return False
        if tag == "bin":
            return self.is_float(e[2]) or self.is_float(e[3])
        if tag == "tern":
            return self.is_float(e[2]) or self.is_float(e[3])
        if tag == "mem":
            return e[2] in self._FLOAT_FIELDS
        if tag == "mcall":
            shape = self.shapes.get(e[1])
            return bool(shape and ARRAY_DTYPES.get(shape.array) == "f")
        if tag == "idx":
            base = e
            while base[0] == "idx":
                base = base[1]
            if base[0] == "mem" and base[2] in ARRAY_DTYPES:
                return ARRAY_DTYPES[base[2]] == "f"
            if base[0] == "name":
                if base[1] in ARRAY_DTYPES:
                    return ARRAY_DTYPES[base[1]] == "f"
                return self.var_types.get(base[1]) in ("double", "float")
            return False
        if tag == "call":
            return e[1] in ("floor", "fabs", "fmin", "fmax")
        return False


def _lint_c_function(fn: CFunc, unit: CUnit,
                     shapes: Dict[str, MacroShape],
                     consts: Dict[str, object],
                     module: str) -> List[Finding]:
    findings: List[Finding] = []
    env = _CTypeEnv(fn, unit, shapes, consts)

    # narrowed-dtype: every scalar decl must be int64_t/double (plain
    # ``int`` tolerated only for 0/1 flags never used arithmetically).
    int_vars: Dict[str, int] = {}

    def scan_decls(stmts):
        for s in stmts:
            if isinstance(s, CDecl):
                if s.ctype in unit.structs or s.is_pointer:
                    continue
                if s.ctype in _WIDE_TYPES:
                    continue
                if s.ctype == _BOOL_OK_TYPE:
                    int_vars[s.name] = s.line
                    continue
                findings.append(Finding(
                    PASS, "narrowed-dtype", module, fn.name, s.line,
                    f"declaration '{s.ctype} {s.name}' narrows the engine's "
                    f"int64/float64 value domain"))
            elif isinstance(s, CIf):
                scan_decls(s.then)
                scan_decls(s.orelse)
            elif isinstance(s, (CWhile, CFor)):
                scan_decls(s.body)
    scan_decls(fn.body)
    for ctype, is_ptr, name in fn.params:
        if ctype in unit.structs or ctype in _WIDE_TYPES:
            continue
        findings.append(Finding(
            PASS, "narrowed-dtype", module, fn.name, fn.line,
            f"parameter '{ctype}{'*' if is_ptr else ''} {name}' narrows "
            f"the engine's int64/float64 value domain"))

    # ``int`` flags: flag arithmetic use or value-bearing assignment.
    if int_vars:
        def rhs_is_flaggy(e) -> bool:
            tag = e[0]
            if tag in ("num", "cmp", "bool"):
                return False
            if tag == "name":
                return e[1] not in int_vars and not (
                    C_CONST_ALIASES.get(e[1], e[1]) in consts)
            if tag == "un" and e[1] in ("-", "!"):
                return rhs_is_flaggy(e[2])
            if tag == "tern":
                return rhs_is_flaggy(e[2]) or rhs_is_flaggy(e[3])
            return True     # arithmetic, array reads, calls, casts ...

        def scan_stmts(stmts):
            for s in stmts:
                if isinstance(s, CAssign) and s.target[0] == "name" \
                        and s.target[1] in int_vars:
                    if s.op != "=" or rhs_is_flaggy(s.value):
                        findings.append(Finding(
                            PASS, "narrowed-dtype", module, fn.name, s.line,
                            f"'int {s.target[1]}' receives a non-flag "
                            f"value; widen to int64_t"))
                if isinstance(s, CIf):
                    scan_stmts(s.then)
                    scan_stmts(s.orelse)
                elif isinstance(s, (CWhile, CFor)):
                    scan_stmts(s.body)
        scan_stmts(fn.body)
        for e, line in _walk_c_exprs(fn.body):
            for sub in _subexprs(e):
                if sub[0] == "bin" and sub[1] in _ARITH_OPS:
                    for opnd in (sub[2], sub[3]):
                        if opnd[0] == "name" and opnd[1] in int_vars:
                            findings.append(Finding(
                                PASS, "narrowed-dtype", module, fn.name,
                                line,
                                f"'int {opnd[1]}' used in arithmetic; "
                                f"widen to int64_t"))

    # int-division: C ``/`` truncates toward zero, Python ``//`` floors;
    # any all-int division is a semantic trap on negative operands.
    for e, line in _walk_c_exprs(fn.body):
        for sub in _subexprs(e):
            if sub[0] == "bin" and sub[1] == "/":
                if not (env.is_float(sub[2]) or env.is_float(sub[3])):
                    findings.append(Finding(
                        PASS, "int-division", module, fn.name, line,
                        "all-integer '/' truncates in C but floors in "
                        "Python; cast an operand to double or restructure"))
            if sub[0] == "bin" and sub[1] == "%":
                if not (env.is_float(sub[2]) or env.is_float(sub[3])):
                    findings.append(Finding(
                        PASS, "int-division", module, fn.name, line,
                        "all-integer '%' differs from Python on negative "
                        "operands; restructure"))
    return findings


def _count_fma_shapes(unit: CUnit, shapes, consts) -> int:
    n = 0
    for fn in unit.functions:
        env = _CTypeEnv(fn, unit, shapes, consts)
        for e, _line in _walk_c_exprs(fn.body):
            for sub in _subexprs(e):
                if sub[0] == "bin" and sub[1] in ("+", "-"):
                    for opnd in (sub[2], sub[3]):
                        if (opnd[0] == "bin" and opnd[1] == "*"
                                and env.is_float(opnd)):
                            n += 1
                            break
    return n


def _build_flags(c_module: ast.Module) -> Tuple[set, int]:
    """String constants inside the compile ``subprocess.run`` argv."""
    flags: set = set()
    line = 0
    for node in ast.walk(c_module):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "run" and node.args):
            argv = node.args[0]
            if isinstance(argv, ast.List):
                line = node.lineno
                for el in argv.elts:
                    if isinstance(el, ast.Constant) and isinstance(
                            el.value, str):
                        flags.add(el.value)
    return flags, line


# ------------------------------------------------------------- the pass
def scan_translation(core_dir: Path) -> List[Finding]:
    core_dir = Path(core_dir)
    findings: List[Finding] = []

    if not twin_path(core_dir).exists() or not c_path(core_dir).exists():
        return findings     # nothing to validate in this tree

    twin_tree = load_twin_ast(core_dir)
    consts = fold_twin_constants(twin_tree)

    try:
        unit, c_module, body_line = parse_c_unit(core_dir)
    except CParseError as exc:
        return [Finding(PASS, "c-parse-error", _C_MODULE, "_C_BODY",
                        getattr(exc, "line", 0) or 0,
                        f"cannot parse _C_BODY: {exc}")]
    if unit is None:
        return [Finding(PASS, "c-parse-error", _C_MODULE, "_C_BODY", 0,
                        "_C_BODY string literal not found")]

    shapes, bad_macros = macro_shapes(unit)
    for name in sorted(bad_macros):
        macro = unit.macros[name]
        findings.append(Finding(
            PASS, "macro-shape", _C_MODULE, name, macro.line,
            f"accessor macro {name} does not match a recognized flat-"
            f"array pattern; the validator cannot check its uses"))

    # Constant drift: a hand-written object-like #define in _C_BODY either
    # shadows a generated twin constant (drift risk) or invents a C-only
    # constant the twin cannot see.  The clean translation has neither —
    # all numeric constants flow through the generated block.
    for macro in unit.object_defines:
        twin_name = C_CONST_ALIASES.get(macro.name, macro.name)
        value = _parse_define_value(macro)
        if twin_name in consts:
            twin_value = consts[twin_name]
            if value is None or not _values_equal(value, twin_value):
                findings.append(Finding(
                    PASS, "constant-drift", _C_MODULE, macro.name,
                    macro.line,
                    f"#define {macro.name} {_fmt(value)} shadows the twin "
                    f"constant {twin_name} = {_fmt(twin_value)}"))
            else:
                findings.append(Finding(
                    PASS, "constant-drift", _C_MODULE, macro.name,
                    macro.line,
                    f"#define {macro.name} duplicates the generated "
                    f"constants block; delete it"))
        else:
            findings.append(Finding(
                PASS, "constant-drift", _C_MODULE, macro.name, macro.line,
                f"#define {macro.name} has no twin counterpart; numeric "
                f"constants must live in fastsim_twin"))

    # Function pairing.
    twin_fns = twin_jit_functions(twin_tree)
    c_fns = {fn.name: fn for fn in unit.functions}
    paired: set = set()
    for twin_fn in twin_fns:
        cname = pair_name(twin_fn.name)
        c_fn = c_fns.get(cname)
        if c_fn is None:
            findings.append(Finding(
                PASS, "missing-function", _C_MODULE, cname, body_line,
                f"twin function {twin_fn.name} has no C counterpart "
                f"{cname}"))
            continue
        paired.add(cname)
        try:
            twin_sum = _TwinNormalizer(twin_fn, consts).run()
        except TwinNormalizeError as exc:
            findings.append(Finding(
                PASS, "twin-normalize", _TWIN_MODULE, twin_fn.name,
                exc.line or twin_fn.lineno, str(exc)))
            continue
        try:
            c_sum = _CNormalizer(c_fn, unit, shapes, consts).run()
        except CNormalizeError as exc:
            findings.append(Finding(
                PASS, "c-normalize", _C_MODULE, cname,
                exc.line or c_fn.line, str(exc)))
            continue
        for desc in twin_sum.diff(c_sum):
            findings.append(Finding(
                PASS, "pair-mismatch", _TWIN_MODULE, twin_fn.name,
                twin_fn.lineno,
                f"{twin_fn.name} vs C {cname}: {desc}"))
    for cname in sorted(set(c_fns) - paired):
        findings.append(Finding(
            PASS, "extra-function", _C_MODULE, cname, c_fns[cname].line,
            f"C function {cname} has no @_jit twin counterpart"))

    # C-side numeric lints.
    for fn in unit.functions:
        findings.extend(_lint_c_function(fn, unit, shapes, consts,
                                         _C_MODULE))

    # FMA contraction: the build line must pin -ffp-contract=off while
    # FMA-able float shapes exist (and -ffast-math is never acceptable).
    flags, flags_line = _build_flags(c_module)
    if "-ffast-math" in flags:
        findings.append(Finding(
            PASS, "fma-contract", _C_MODULE, "build", flags_line,
            "-ffast-math breaks IEEE semantics and bit-identity with the "
            "twin; remove it"))
    if "-ffp-contract=off" not in flags:
        n = _count_fma_shapes(unit, shapes, consts)
        if n:
            findings.append(Finding(
                PASS, "fma-contract", _C_MODULE, "build", flags_line,
                f"build line lacks -ffp-contract=off while _C_BODY has "
                f"{n} FMA-able float a*b+c shape(s); contraction would "
                f"break bit-identity with the twin"))
    return findings


def _parse_define_value(macro: cparse.CMacro) -> Optional[object]:
    try:
        sub = cparse._Parser(list(macro.body), {}, ())
        e = sub.parse_expr()
        if sub._peek() is not None:
            return None
    except CParseError:
        return None
    if e[0] == "num":
        return e[1]
    if e[0] == "un" and e[1] == "-" and e[2][0] == "num":
        return -e[2][1]
    return None


def _values_equal(a, b) -> bool:
    if isinstance(a, float) and isinstance(b, float):
        if math.isnan(a) and math.isnan(b):
            return True
    return type(a) is type(b) and a == b or (
        isinstance(a, (int, float)) and isinstance(b, (int, float))
        and float(a) == float(b))


def _fmt(v) -> str:
    return "?" if v is None else repr(v)
