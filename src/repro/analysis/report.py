"""Findings, the suppression baseline, and report formatting.

A :class:`Finding` is one analyzer complaint, keyed for suppression by
``rule::module::context`` — deliberately *not* by line number, so a
baselined finding survives unrelated edits to the same file but a second
occurrence of the same hazard in the same function does not slip through
(the baseline stores an occurrence *count* per key).

Only **determinism** findings are baselinable: a nondeterminism hazard can
be a deliberate, justified design choice (the lane executor measures real
wall time; the sweep nonce is a deliberate uniquifier).  Fingerprint
coverage and protocol drift are structural invariants — there is no
justified way to under-cover the cache fingerprint — so those passes
ignore the baseline and always block.

Baseline workflow (DESIGN.md Section 9): fix the finding, or add an inline
justification comment at the site *and* an entry here via
``python -m repro.analysis --write-baseline`` (then fill in the
``reason`` field by hand; empty reasons are themselves findings).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

#: Passes whose findings may be suppressed by the baseline.
BASELINABLE_PASSES = ("determinism", "conformance")

DEFAULT_BASELINE_PATH = Path(__file__).with_name("baseline.json")


@dataclass(frozen=True)
class Finding:
    """One analyzer complaint."""

    pass_name: str          # "fingerprint" | "determinism" | "protocol"
    rule: str               # short rule id, e.g. "wallclock"
    module: str             # repro.core module stem, e.g. "executor"
    context: str            # dotted qualname inside the module ("" = top)
    line: int               # 1-based line in the module source
    message: str

    @property
    def key(self) -> str:
        """Line-independent suppression key."""
        return f"{self.rule}::{self.module}::{self.context}"

    def format(self) -> str:
        where = f"{self.module}.py:{self.line}"
        ctx = f" in {self.context}" if self.context else ""
        return f"[{self.pass_name}/{self.rule}] {where}{ctx}: {self.message}"


@dataclass
class Baseline:
    """Checked-in accepted findings: key -> (count, reason)."""

    entries: Dict[str, Tuple[int, str]] = field(default_factory=dict)
    path: Optional[Path] = None

    @classmethod
    def load(cls, path: Optional[Path] = None) -> "Baseline":
        path = Path(path) if path is not None else DEFAULT_BASELINE_PATH
        if not path.exists():
            return cls(path=path)
        payload = json.loads(path.read_text())
        entries = {
            e["key"]: (int(e.get("count", 1)), e.get("reason", ""))
            for e in payload.get("entries", [])
        }
        return cls(entries=entries, path=path)

    @classmethod
    def from_findings(cls, findings: Sequence[Finding],
                      reasons: Optional[Dict[str, str]] = None) -> "Baseline":
        counts: Dict[str, int] = {}
        for f in findings:
            if f.pass_name in BASELINABLE_PASSES:
                counts[f.key] = counts.get(f.key, 0) + 1
        reasons = reasons or {}
        return cls(entries={k: (n, reasons.get(k, ""))
                            for k, n in counts.items()})

    def dump(self, path: Optional[Path] = None) -> str:
        path = Path(path) if path is not None else self.path
        blob = json.dumps(
            {
                "version": 1,
                "entries": [
                    {"key": k, "count": n, "reason": r}
                    for k, (n, r) in sorted(self.entries.items())
                ],
            },
            indent=2, sort_keys=False, allow_nan=False,
        ) + "\n"
        if path is not None:
            path.write_text(blob)
        return blob


@dataclass
class Report:
    """Outcome of applying the baseline to a batch of findings."""

    blocking: List[Finding]
    suppressed: List[Finding]
    stale_keys: List[str]        # baseline entries that matched nothing
    empty_reasons: List[str]     # baseline entries with no justification

    @property
    def ok(self) -> bool:
        return not self.blocking and not self.empty_reasons


def apply_baseline(findings: Sequence[Finding],
                   baseline: Baseline,
                   check_stale: bool = True) -> Report:
    """Split findings into blocking vs. baseline-suppressed.

    Per key the first ``count`` occurrences are suppressed and any excess
    blocks — so adding a *second* wall-clock read to an already-baselined
    function is a new finding, not a free ride.

    ``check_stale=False`` skips the stale-entry warning: staleness is only
    decidable when every baselinable pass actually ran (a ``--passes``
    subset would otherwise flag entries of the skipped passes).
    """
    budget = {k: n for k, (n, _) in baseline.entries.items()}
    seen = set()
    blocking: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        if f.pass_name not in BASELINABLE_PASSES:
            blocking.append(f)
            continue
        seen.add(f.key)
        if budget.get(f.key, 0) > 0:
            budget[f.key] -= 1
            suppressed.append(f)
        else:
            blocking.append(f)
    stale = [k for k, (n, _) in sorted(baseline.entries.items())
             if k not in seen] if check_stale else []
    empty = [k for k, (n, r) in sorted(baseline.entries.items())
             if k in seen and not r.strip()]
    return Report(blocking=blocking, suppressed=suppressed,
                  stale_keys=stale, empty_reasons=empty)


def format_report(report: Report, verbose: bool = False) -> str:
    lines: List[str] = []
    for f in report.blocking:
        lines.append(f.format())
    for key in report.empty_reasons:
        lines.append(f"[baseline] entry {key!r} has no justification "
                     "(fill in its \"reason\" field)")
    if verbose:
        for f in report.suppressed:
            lines.append(f"(baselined) {f.format()}")
    for key in report.stale_keys:
        lines.append(f"warning: stale baseline entry {key!r} matched "
                     "nothing (remove it)")
    n_block = len(report.blocking) + len(report.empty_reasons)
    lines.append(
        f"{n_block} blocking finding(s), "
        f"{len(report.suppressed)} baselined, "
        f"{len(report.stale_keys)} stale baseline entr(y/ies)")
    return "\n".join(lines)
