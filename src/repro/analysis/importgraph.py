"""Fingerprint-coverage pass: import graph vs. ``_FINGERPRINT_SOURCES``.

The sweep cache is content-addressed and every key embeds a *code
fingerprint* — a digest of the source files whose behavior the cached
record depends on (``repro.core.sweep._FINGERPRINT_SOURCES``).  The table
is hand-maintained, and its failure mode is silent: forget to list a
module that affects schedules and the cache happily serves records
computed by old code.

This pass closes that hole statically.  For each machine it computes the
transitive closure of ``repro.core``-internal imports from the machine's
*result-determining entry points* and demands that the fingerprint table
equals the closure exactly:

* a closure module missing from the table is **under-coverage** (stale
  cache served — the dangerous direction),
* a table module outside the closure is a **stale entry** (pointless
  invalidation — the annoying direction),
* a ``repro.core`` module in neither any closure nor the explicit
  :data:`NON_RESULT_MODULES` allowlist is **unclassified** — every new
  module must declare which side it is on before CI passes.

The closure is an over-approximation by construction (a module-level
import counts even if the imported code cannot run on that machine's
path); that is the right direction for a cache key — over-invalidation
merely recomputes.

Everything here is pure AST over file contents: nothing from
``repro.core`` is imported, so the pass can run against a mutated copy of
the tree (the mutation tests do exactly that).
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .report import Finding

#: The real package this analyzer guards.
CORE_DIR = Path(__file__).resolve().parents[1] / "core"

CORE_PACKAGE = "repro.core"

#: Result-determining entry points per machine (module stems).  The
#: machine's own driver module plus everything a sweep cell's *record*
#: content is computed from: the policy and predictor implementations the
#: cell names, the metrics evaluated into the record, and — for scenario
#: cells — the arrival-process code.  Since PR 9, distrib.py — the cell
#: runners + record store every dispatcher executes through — is an entry
#: point of every machine: a record's bytes are shaped there (window
#: evaluation, NaN encoding, serialization), whichever dispatcher and
#: whichever host produced it.
ENTRY_POINTS: Dict[str, Tuple[str, ...]] = {
    "des": ("simulator", "policies", "predictor", "metrics", "distrib"),
    "des-closed": ("simulator", "policies", "predictor", "metrics",
                   "scenarios", "distrib"),
    "executor": ("executor", "policies", "predictor", "metrics",
                 "scenarios", "distrib"),
}

#: Modules that are deliberately *not* result-determining, with the reason
#: each is safe to leave out of every fingerprint.  A module missing from
#: both this table and every closure fails the pass (see module docstring).
NON_RESULT_MODULES: Dict[str, str] = {
    "__init__": "re-export surface only; importing it runs no cell logic",
    "sweep": "cache-key construction and orchestration; record-shaping "
             "edits here must bump CACHE_VERSION instead (DESIGN.md "
             "Section 9)",
    "jobs": "launch-tier job builders; consumed by benchmarks and the "
            "service frontend, never imported by a sweep cell",
    "scheduler_service": "async frontend over the executor; wraps "
                         "machines, does not alter what they compute",
}

FINGERPRINT_TABLE_NAME = "_FINGERPRINT_SOURCES"


def list_modules(core_dir: Optional[Path] = None) -> Dict[str, Path]:
    """Map module stem -> path for every ``repro.core`` source file."""
    core_dir = Path(core_dir) if core_dir is not None else CORE_DIR
    return {p.stem: p for p in sorted(core_dir.glob("*.py"))}


def module_imports(path: Path, known: FrozenSet[str]) -> Set[str]:
    """Stems of ``repro.core`` modules imported anywhere in ``path``.

    Function-local and conditional imports count: they execute on some
    path, and the closure must over- rather than under-approximate.
    """
    tree = ast.parse(path.read_text(), filename=str(path))
    edges: Set[str] = set()
    prefix = CORE_PACKAGE + "."
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.startswith(prefix):
                    stem = alias.name[len(prefix):].split(".")[0]
                    if stem in known:
                        edges.add(stem)
        elif isinstance(node, ast.ImportFrom):
            if node.level == 1 and node.module:
                stem = node.module.split(".")[0]
                if stem in known:
                    edges.add(stem)
            elif node.level == 1 and node.module is None:
                for alias in node.names:        # from . import simulator
                    if alias.name in known:
                        edges.add(alias.name)
            elif node.level == 0 and node.module:
                if node.module == CORE_PACKAGE:
                    for alias in node.names:
                        if alias.name in known:
                            edges.add(alias.name)
                elif node.module.startswith(prefix):
                    stem = node.module[len(prefix):].split(".")[0]
                    if stem in known:
                        edges.add(stem)
    return edges


def build_import_graph(core_dir: Optional[Path] = None
                       ) -> Dict[str, Set[str]]:
    """Intra-package import graph: module stem -> imported stems."""
    modules = list_modules(core_dir)
    known = frozenset(modules)
    return {stem: module_imports(path, known)
            for stem, path in modules.items()}


def transitive_closure(graph: Dict[str, Set[str]],
                       roots: Tuple[str, ...]) -> Set[str]:
    closure: Set[str] = set()
    stack: List[str] = [r for r in roots if r in graph]
    while stack:
        mod = stack.pop()
        if mod in closure:
            continue
        closure.add(mod)
        stack.extend(graph.get(mod, ()))
    return closure


def expected_fingerprint_sources(core_dir: Optional[Path] = None
                                 ) -> Dict[str, Set[str]]:
    """The closure each machine's fingerprint tuple must equal."""
    graph = build_import_graph(core_dir)
    return {machine: transitive_closure(graph, roots)
            for machine, roots in ENTRY_POINTS.items()}


def load_fingerprint_table(core_dir: Optional[Path] = None
                           ) -> Optional[Dict[str, Tuple[str, ...]]]:
    """Statically read ``_FINGERPRINT_SOURCES`` from ``sweep.py``.

    Returns None when the assignment is missing or not a literal dict —
    both are coverage findings, reported by :func:`check_fingerprint_coverage`.
    """
    core_dir = Path(core_dir) if core_dir is not None else CORE_DIR
    sweep_path = core_dir / "sweep.py"
    if not sweep_path.exists():
        return None
    tree = ast.parse(sweep_path.read_text(), filename=str(sweep_path))
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        for target in targets:
            if (isinstance(target, ast.Name)
                    and target.id == FINGERPRINT_TABLE_NAME):
                try:
                    value = ast.literal_eval(node.value)
                except ValueError:
                    return None
                if not isinstance(value, dict):
                    return None
                return {str(k): tuple(v) for k, v in value.items()}
    return None


def check_fingerprint_coverage(core_dir: Optional[Path] = None
                               ) -> List[Finding]:
    """The fingerprint-coverage pass (see module docstring)."""
    core_dir = Path(core_dir) if core_dir is not None else CORE_DIR
    findings: List[Finding] = []

    def finding(rule: str, module: str, message: str) -> None:
        findings.append(Finding("fingerprint", rule, module, "", 1, message))

    modules = list_modules(core_dir)
    table = load_fingerprint_table(core_dir)
    if table is None:
        finding("table-unreadable", "sweep",
                f"{FINGERPRINT_TABLE_NAME} is missing from sweep.py or is "
                "not a literal dict; the coverage pass cannot verify it")
        return findings

    expected = expected_fingerprint_sources(core_dir)

    for machine in sorted(set(expected) | set(table)):
        if machine not in table:
            finding("machine-missing", "sweep",
                    f"machine {machine!r} has analyzer entry points but no "
                    f"{FINGERPRINT_TABLE_NAME} entry")
            continue
        if machine not in expected:
            finding("machine-unknown", "sweep",
                    f"{FINGERPRINT_TABLE_NAME} lists machine {machine!r} "
                    "unknown to the analyzer; add its entry points to "
                    "repro.analysis.importgraph.ENTRY_POINTS")
            continue
        declared = set(table[machine])
        closure = expected[machine]
        for mod in sorted(closure - declared):
            finding("under-coverage", mod,
                    f"{mod}.py is reachable from {machine!r} entry points "
                    f"{ENTRY_POINTS[machine]} but absent from "
                    f"{FINGERPRINT_TABLE_NAME}[{machine!r}]: edits to it "
                    "would silently serve stale cached results")
        for mod in sorted(declared - closure):
            finding("stale-entry", mod,
                    f"{FINGERPRINT_TABLE_NAME}[{machine!r}] lists {mod}.py "
                    "which is not reachable from that machine's entry "
                    "points; remove it or add the missing import edge")
        for mod in sorted(declared - set(modules)):
            finding("missing-file", mod,
                    f"{FINGERPRINT_TABLE_NAME}[{machine!r}] lists {mod}.py "
                    "which does not exist in repro/core")

    classified: Set[str] = set(NON_RESULT_MODULES)
    for closure in expected.values():
        classified |= closure
    for mod in sorted(set(modules) - classified):
        finding("unclassified-module", mod,
                f"{mod}.py is neither reachable from any machine's entry "
                "points nor declared in NON_RESULT_MODULES; classify it "
                "(result-determining modules must be imported by an entry "
                "point; others need an allowlist entry with a reason)")
    for mod in sorted(set(NON_RESULT_MODULES) - set(modules) - {"__init__"}):
        finding("stale-allowlist", mod,
                f"NON_RESULT_MODULES lists {mod}.py which does not exist")
    return findings
