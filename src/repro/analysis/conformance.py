"""Nopython-subset conformance for the engine twin.

``fastsim_twin.py`` must stay inside the language subset that all three
backends execute identically: numba's nopython mode, and — stricter —
the C89-ish dialect :mod:`repro.core.fastsim_c` mirrors function for
function.  Anything outside the subset is a finding, so a convenient
Python-ism (a dict, a slice, a generator, an f-string) cannot creep into
the twin and silently diverge the interpreted backend from the other
two.

The subset, by construction from what the C translation can express:

* statements — plain/augmented assignment, ``if``/``elif``/``else``,
  ``while``, ``for .. in range(..)``, ``return``, ``break``,
  ``continue``, ``pass``, expression-statement calls;
* expressions — int/float/bool constants, scalar names, single
  comparisons, ``+ - * / // % << >>``, ``and``/``or``/``not``, unary
  minus, conditional expressions, flat array subscripts (no slices),
  tuples only for multi-assignment/return/indexing;
* calls — ``range``, ``int``, ``math.floor``, other ``@_jit`` functions,
  and ``np.empty``/``np.zeros`` with an explicit ``np.int64`` /
  ``np.float64`` dtype;
* signatures — plain positional parameters only (no defaults, ``*``,
  ``**``, keyword-only);
* module level — every function is ``@_jit`` except the documented
  dispatch shims.

The pass is baselinable (``conformance`` is in
:data:`repro.analysis.report.BASELINABLE_PASSES`): a deliberate,
justified exception can be suppressed in ``baseline.json``, but it must
carry a reason the reviewer can audit.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Set

from .enginesrc import load_twin_ast, twin_jit_functions, twin_path
from .report import Finding

PASS = "conformance"

_MODULE = "fastsim_twin"

#: Module-level functions that are dispatch plumbing, not kernel code.
_UNJITTED_ALLOWED = {"_identity"}

_ALLOWED_BINOPS = (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv,
                   ast.Mod, ast.LShift, ast.RShift)
_ALLOWED_CMPOPS = (ast.Eq, ast.NotEq, ast.Lt, ast.LtE, ast.Gt, ast.GtE)
_ALLOWED_UNARY = (ast.USub, ast.Not)

_NAME_CALLS = {"range", "int"}
_MATH_CALLS = {"floor"}
_NP_ALLOC_CALLS = {"empty", "zeros"}
_NP_DTYPES = {"int64", "float64"}


class _SubsetChecker:
    def __init__(self, fn: ast.FunctionDef, jit_names: Set[str]):
        self.fn = fn
        self.jit_names = jit_names
        self.findings: List[Finding] = []

    def _flag(self, rule: str, line: int, message: str) -> None:
        self.findings.append(
            Finding(PASS, rule, _MODULE, self.fn.name, line, message))

    def run(self) -> List[Finding]:
        args = self.fn.args
        if (args.defaults or args.kw_defaults or args.vararg
                or args.kwarg or args.kwonlyargs or args.posonlyargs):
            self._flag("subset-signature", self.fn.lineno,
                       "only plain positional parameters are portable "
                       "across the numba and C backends")
        self._block(self.fn.body, top=True)
        return self.findings

    # -- statements
    def _block(self, stmts, top: bool = False) -> None:
        for i, s in enumerate(stmts):
            self._stmt(s, docstring_ok=top and i == 0)

    def _stmt(self, s: ast.stmt, docstring_ok: bool = False) -> None:
        if isinstance(s, ast.Expr):
            if (docstring_ok and isinstance(s.value, ast.Constant)
                    and isinstance(s.value.value, str)):
                return
            if isinstance(s.value, ast.Call):
                self._expr(s.value)
                return
            self._flag("subset-node", s.lineno,
                       "bare non-call expression statement")
            return
        if isinstance(s, ast.Assign):
            for t in s.targets:
                self._target(t)
            self._expr(s.value)
            return
        if isinstance(s, ast.AugAssign):
            if not isinstance(s.op, _ALLOWED_BINOPS):
                self._flag("subset-node", s.lineno,
                           f"augmented operator {type(s.op).__name__} "
                           f"outside the portable subset")
            self._target(s.target)
            self._expr(s.value)
            return
        if isinstance(s, ast.If):
            self._expr(s.test)
            self._block(s.body)
            self._block(s.orelse)
            return
        if isinstance(s, ast.While):
            if s.orelse:
                self._flag("subset-node", s.lineno, "while-else clause")
            self._expr(s.test)
            self._block(s.body)
            return
        if isinstance(s, ast.For):
            if s.orelse:
                self._flag("subset-node", s.lineno, "for-else clause")
            if not (isinstance(s.iter, ast.Call)
                    and isinstance(s.iter.func, ast.Name)
                    and s.iter.func.id == "range"
                    and 1 <= len(s.iter.args) <= 2
                    and not s.iter.keywords):
                self._flag("subset-node", s.lineno,
                           "for loops must iterate a 1- or 2-argument "
                           "range() so the C translation is a counted for")
            else:
                for a in s.iter.args:
                    self._expr(a)
            if not isinstance(s.target, ast.Name):
                self._flag("subset-node", s.lineno,
                           "loop target must be a plain name")
            self._block(s.body)
            return
        if isinstance(s, ast.Return):
            if s.value is not None:
                if isinstance(s.value, ast.Tuple):
                    for e in s.value.elts:
                        self._expr(e)
                else:
                    self._expr(s.value)
            return
        if isinstance(s, (ast.Break, ast.Continue, ast.Pass)):
            return
        self._flag("subset-node", s.lineno,
                   f"statement {type(s).__name__} outside the portable "
                   f"subset")

    def _target(self, t: ast.expr) -> None:
        if isinstance(t, ast.Name):
            return
        if isinstance(t, ast.Tuple):
            for e in t.elts:
                if not isinstance(e, ast.Name):
                    self._flag("subset-node", t.lineno,
                               "tuple-assignment elements must be names")
            return
        if isinstance(t, ast.Subscript):
            self._subscript(t)
            return
        self._flag("subset-node", t.lineno,
                   f"assignment target {type(t).__name__} outside the "
                   f"portable subset")

    # -- expressions
    def _expr(self, e: ast.expr) -> None:
        if isinstance(e, ast.Constant):
            if not isinstance(e.value, (int, float, bool)):
                self._flag("subset-node", e.lineno,
                           f"constant {e.value!r} is not a portable "
                           f"scalar")
            return
        if isinstance(e, ast.Name):
            return
        if isinstance(e, ast.Attribute):
            if isinstance(e.value, ast.Name):
                if e.value.id == "math" and e.attr in ("nan", "inf"):
                    return
                if e.value.id == "np" and e.attr in _NP_DTYPES:
                    return
            self._flag("subset-node", e.lineno,
                       f"attribute access {ast.unparse(e)} outside the "
                       f"portable subset")
            return
        if isinstance(e, ast.Subscript):
            self._subscript(e)
            return
        if isinstance(e, ast.BinOp):
            if not isinstance(e.op, _ALLOWED_BINOPS):
                self._flag("subset-node", e.lineno,
                           f"operator {type(e.op).__name__} outside the "
                           f"portable subset")
            self._expr(e.left)
            self._expr(e.right)
            return
        if isinstance(e, ast.BoolOp):
            for v in e.values:
                self._expr(v)
            return
        if isinstance(e, ast.UnaryOp):
            if not isinstance(e.op, _ALLOWED_UNARY):
                self._flag("subset-node", e.lineno,
                           f"unary {type(e.op).__name__} outside the "
                           f"portable subset")
            self._expr(e.operand)
            return
        if isinstance(e, ast.Compare):
            if len(e.ops) != 1:
                self._flag("subset-node", e.lineno,
                           "chained comparisons have no C counterpart; "
                           "split into and-ed comparisons")
            for op in e.ops:
                if not isinstance(op, _ALLOWED_CMPOPS):
                    self._flag("subset-node", e.lineno,
                               f"comparison {type(op).__name__} outside "
                               f"the portable subset")
            self._expr(e.left)
            for c in e.comparators:
                self._expr(c)
            return
        if isinstance(e, ast.IfExp):
            self._expr(e.test)
            self._expr(e.body)
            self._expr(e.orelse)
            return
        if isinstance(e, ast.Call):
            self._call(e)
            return
        self._flag("subset-node", e.lineno,
                   f"expression {type(e).__name__} outside the portable "
                   f"subset")

    def _subscript(self, e: ast.Subscript) -> None:
        if not isinstance(e.value, ast.Name):
            self._flag("subset-node", e.lineno,
                       "subscript base must be a plain array name")
        idx = e.slice
        dims = idx.elts if isinstance(idx, ast.Tuple) else [idx]
        for d in dims:
            if isinstance(d, (ast.Slice,)):
                self._flag("subset-node", e.lineno,
                           "slicing has no flat-array C counterpart; "
                           "index elementwise")
            else:
                self._expr(d)

    def _call(self, e: ast.Call) -> None:
        if e.keywords:
            self._flag("subset-call", e.lineno,
                       "keyword arguments are not portable; pass "
                       "positionally")
        func = e.func
        if isinstance(func, ast.Name):
            if func.id in _NAME_CALLS or func.id in self.jit_names:
                for a in e.args:
                    self._expr(a)
                return
            self._flag("subset-call", e.lineno,
                       f"call to {func.id}() — only range/int, math.floor, "
                       f"np.empty/np.zeros and other @_jit functions are "
                       f"portable")
            return
        if isinstance(func, ast.Attribute) and isinstance(
                func.value, ast.Name):
            base, attr = func.value.id, func.attr
            if base == "math" and attr in _MATH_CALLS:
                for a in e.args:
                    self._expr(a)
                return
            if base == "np" and attr in _NP_ALLOC_CALLS:
                self._np_alloc(e)
                return
            self._flag("subset-call", e.lineno,
                       f"call to {base}.{attr}() outside the portable "
                       f"subset")
            return
        self._flag("subset-call", e.lineno,
                   "computed call target outside the portable subset")

    def _np_alloc(self, e: ast.Call) -> None:
        if len(e.args) != 2:
            self._flag("subset-dtype", e.lineno,
                       "np.empty/np.zeros in kernel code must pass an "
                       "explicit dtype (np.int64 or np.float64)")
            return
        shape, dtype = e.args
        dims = shape.elts if isinstance(shape, ast.Tuple) else [shape]
        for d in dims:
            self._expr(d)
        if not (isinstance(dtype, ast.Attribute)
                and isinstance(dtype.value, ast.Name)
                and dtype.value.id == "np" and dtype.attr in _NP_DTYPES):
            self._flag("subset-dtype", e.lineno,
                       "kernel allocations must use np.int64 or "
                       "np.float64 — anything else diverges from the "
                       "int64/float64 C world")


def scan_conformance(core_dir: Path) -> List[Finding]:
    core_dir = Path(core_dir)
    if not twin_path(core_dir).exists():
        return []
    tree = load_twin_ast(core_dir)
    jit_fns = twin_jit_functions(tree)
    jit_names: Set[str] = set()
    for fn in jit_fns:
        jit_names.add(fn.name)
        jit_names.add(fn.name.lstrip("_"))

    findings: List[Finding] = []
    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            if node in jit_fns or node.name in _UNJITTED_ALLOWED:
                continue
            findings.append(Finding(
                PASS, "unjitted-function", _MODULE, node.name, node.lineno,
                f"module-level function {node.name} lacks @_jit; kernel "
                f"code outside the jit set runs interpreted-only and "
                f"cannot be mirrored to C"))
        elif isinstance(node, ast.ClassDef):
            findings.append(Finding(
                PASS, "subset-node", _MODULE, node.name, node.lineno,
                "classes are outside the nopython kernel subset"))
    for fn in jit_fns:
        findings.extend(_SubsetChecker(fn, jit_names).run())
    return findings
