"""Shared AST-level access to the three engine sources.

The engine-verification passes (:mod:`repro.analysis.conformance`,
:mod:`repro.analysis.translate`, :mod:`repro.analysis.layout`) all need
the same raw material: the twin's module AST and folded layout
constants, and the C backend's ``_C_BODY`` parsed through
:mod:`repro.analysis.cparse`.  Everything here is file-level — the
analyzer never imports ``repro.core`` — so the passes run unchanged
against ``--core-dir`` scratch trees.
"""

from __future__ import annotations

import ast
import math
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from .cparse import CUnit, parse_c

#: State-tuple array names in S-order — THE cross-backend contract
#: (twin ``S_*`` constants, ``fastsim._build_state`` tuple, C ``St``
#: struct fields, ``fs_advance`` parameters all follow this order).
CANONICAL_ARRAYS: Tuple[str, ...] = (
    "si", "sd", "ci", "cf", "ri", "rf", "psi", "psf", "bs", "sl",
    "smi", "smf", "hi", "hf", "tri", "trf", "dci", "dcf", "pri", "prf",
    "act", "q", "rwi", "rwf", "newc", "cand", "crem",
    "np_pool", "bt_pool", "srci", "srcf",
)

#: dtype kind per state array: "i" = int64, "f" = float64.
ARRAY_DTYPES: Dict[str, str] = {
    "si": "i", "sd": "f", "ci": "i", "cf": "f", "ri": "i", "rf": "f",
    "psi": "i", "psf": "f", "bs": "f", "sl": "i", "smi": "i", "smf": "f",
    "hi": "i", "hf": "f", "tri": "i", "trf": "f", "dci": "i", "dcf": "f",
    "pri": "i", "prf": "f", "act": "i", "q": "i", "rwi": "i", "rwf": "f",
    "newc": "i", "cand": "i", "crem": "f", "np_pool": "f", "bt_pool": "f",
    "srci": "i", "srcf": "f",
}

#: twin function -> C function where stripping the underscore isn't it.
PAIR_OVERRIDES: Dict[str, str] = {
    "_decide": "fs_decide",
    "advance": "fs_advance",
}

#: Float-constant names the generated ``#define`` block maps specially.
C_CONST_ALIASES: Dict[str, str] = {
    "FS_EPS": "_EPS",
    "NAN": "_NAN",
    "INFINITY": "_INF",
}


def twin_path(core_dir: Path) -> Path:
    return Path(core_dir) / "fastsim_twin.py"


def c_path(core_dir: Path) -> Path:
    return Path(core_dir) / "fastsim_c.py"


def sim_path(core_dir: Path) -> Path:
    return Path(core_dir) / "fastsim.py"


def load_twin_ast(core_dir: Path) -> ast.Module:
    path = twin_path(core_dir)
    return ast.parse(path.read_text(), filename=str(path))


def load_module_ast(path: Path) -> ast.Module:
    return ast.parse(path.read_text(), filename=str(path))


def extract_c_body(c_module: ast.Module) -> Tuple[Optional[str], int]:
    """The ``_C_BODY`` string literal and its line number."""
    for node in c_module.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "_C_BODY"
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, str)):
            return node.value.value, node.lineno
    return None, 0


def parse_c_unit(core_dir: Path) -> Tuple[Optional[CUnit], ast.Module, int]:
    """(parsed C body or None, fastsim_c module AST, _C_BODY line)."""
    module = load_module_ast(c_path(core_dir))
    body, line = extract_c_body(module)
    if body is None:
        return None, module, 0
    return parse_c(body), module, line


# ------------------------------------------------------- constant folding
def _fold_expr(node: ast.expr, consts: Dict[str, object]):
    """Fold a module-level constant expression; None when unfoldable."""
    if isinstance(node, ast.Constant):
        if isinstance(node.value, bool):
            return None
        if isinstance(node.value, (int, float)):
            return node.value
        return None
    if isinstance(node, ast.Name):
        return consts.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        inner = _fold_expr(node.operand, consts)
        return None if inner is None else -inner
    if isinstance(node, ast.BinOp):
        left = _fold_expr(node.left, consts)
        right = _fold_expr(node.right, consts)
        if left is None or right is None:
            return None
        if isinstance(node.op, ast.Add):
            return left + right
        if isinstance(node.op, ast.Sub):
            return left - right
        if isinstance(node.op, ast.Mult):
            return left * right
        if isinstance(node.op, ast.Div):
            return left / right
        return None
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "float" and len(node.args) == 1
            and isinstance(node.args[0], ast.Constant)
            and isinstance(node.args[0].value, str)):
        try:
            return float(node.args[0].value)
        except ValueError:
            return None
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name):
        if node.value.id == "math" and node.attr in ("nan", "inf", "pi"):
            return getattr(math, node.attr)
    return None


def fold_twin_constants(tree: ast.Module) -> Dict[str, object]:
    """Module-level numeric constants (the generated-#define universe).

    Covers plain ``NAME = <literal/expr>`` and tuple assignments like the
    ``S_*`` block; bools are excluded exactly as ``_c_defines`` excludes
    them.
    """
    consts: Dict[str, object] = {}
    for node in tree.body:
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        target = node.targets[0]
        if isinstance(target, ast.Name):
            value = _fold_expr(node.value, consts)
            if value is not None:
                consts[target.id] = value
        elif (isinstance(target, ast.Tuple)
              and isinstance(node.value, ast.Tuple)
              and len(target.elts) == len(node.value.elts)
              and all(isinstance(e, ast.Name) for e in target.elts)):
            for name_node, val_node in zip(target.elts, node.value.elts):
                value = _fold_expr(val_node, consts)
                if value is not None:
                    consts[name_node.id] = value
    return consts


def twin_jit_functions(tree: ast.Module) -> List[ast.FunctionDef]:
    """Module-level functions decorated ``@_jit`` (the engine kernel)."""
    out = []
    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Name) and dec.id == "_jit":
                    out.append(node)
                    break
    return out


def pair_name(twin_name: str) -> str:
    """Expected C counterpart name for a twin function."""
    if twin_name in PAIR_OVERRIDES:
        return PAIR_OVERRIDES[twin_name]
    return twin_name.lstrip("_")
