"""Protocol-drift pass: declared contracts vs. what the AST actually does.

Three structural invariants that ``runtime_checkable`` cannot see:

1. **Policy hints** (``policies.py``).  ``Policy.uses_predictor`` /
   ``unlimited_caps`` / ``uniform_caps`` let machines skip per-block
   predictor bookkeeping, cap queries and per-SM cap fan-outs.  A wrong
   hint is not a crash — it is a silently different (or slower) schedule.
   For every registry policy the pass checks the hint against the class's
   own code (its AST-MRO chain): predictor reads require
   ``uses_predictor=True`` and vice versa; a ``residency_cap`` override
   requires ``unlimited_caps=False`` and vice versa; a cap body that uses
   its ``sm`` parameter requires ``uniform_caps=False`` and vice versa.

2. **Fused fast paths** (``machine.py``).  ``SchedulerCore`` dispatches
   the two per-block events through fused methods
   (``post_block_start``/``post_block_end``) that must perform exactly the
   dispatch of the corresponding typed branches of ``post()`` (PR 5's
   bit-identical guarantee).  The pass extracts the (receiver, method,
   argument) call sequence from both sides — resolving the bound-method
   aliases ``bind()`` installs — and requires them identical.

3. **Machine signatures** (``machine.py`` vs. the concrete machines).
   ``isinstance(sim, Machine)`` only checks member *names*; here every
   protocol method is resolved through each implementation's class chain
   and its positional parameter names must match the protocol exactly,
   and each protocol attribute must be assigned in some ``__init__`` of
   the chain.

All checks are AST-only so they run against mutated tree copies.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from .importgraph import CORE_DIR, list_modules
from .report import Finding

#: Hint attributes checked on every registry policy, with defaults from
#: the ``Policy`` base (kept in sync by the check itself: the base's
#: literal values are read from the AST, not hardcoded).
HINT_NAMES = ("uses_predictor", "unlimited_caps", "uniform_caps")

POLICY_BASE = "Policy"
REGISTRY_NAME = "POLICIES"
CORE_CLASS = "SchedulerCore"
PROTOCOL_CLASS = "Machine"
MACHINE_BASE = "MachineBase"
#: Concrete machines whose conformance is checked (module stem, class).
MACHINE_IMPLS = (("simulator", "Simulator"), ("executor", "LaneExecutor"))


def _parse(path: Path) -> ast.Module:
    return ast.parse(path.read_text(), filename=str(path))


def _classes(tree: ast.Module) -> Dict[str, ast.ClassDef]:
    return {n.name: n for n in tree.body if isinstance(n, ast.ClassDef)}


def _base_names(cls: ast.ClassDef) -> List[str]:
    out = []
    for b in cls.bases:
        if isinstance(b, ast.Name):
            out.append(b.id)
        elif isinstance(b, ast.Attribute):
            out.append(b.attr)
    return out


def _chain(name: str, classes: Dict[str, ast.ClassDef],
           stop: Optional[str] = None) -> List[ast.ClassDef]:
    """Linearized single-inheritance chain ``[cls, base, base's base, …]``
    restricted to classes defined in ``classes``; stops *before* ``stop``.
    """
    chain: List[ast.ClassDef] = []
    cur: Optional[str] = name
    seen = set()
    while cur is not None and cur in classes and cur not in seen:
        if cur == stop:
            break
        seen.add(cur)
        cls = classes[cur]
        chain.append(cls)
        bases = _base_names(cls)
        cur = bases[0] if bases else None
    return chain


def _methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _class_attr(chain: Sequence[ast.ClassDef], name: str):
    """Nearest literal class-level assignment of ``name`` in the chain.

    Returns (value, found); non-literal values count as found=True with
    value None (the checker then refuses to judge them).
    """
    for cls in chain:
        for node in cls.body:
            target = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                target = node.targets[0].id
            elif isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name) \
                    and node.value is not None:
                target = node.target.id
            if target == name:
                try:
                    return ast.literal_eval(node.value), True
                except ValueError:
                    return None, True
    return None, False


def _reads_attr(nodes: Sequence[ast.AST], attr: str) -> Optional[int]:
    """First line where any node's subtree reads ``.<attr>``, else None."""
    for root in nodes:
        for node in ast.walk(root):
            if isinstance(node, ast.Attribute) and node.attr == attr:
                return node.lineno
    return None


def _uses_name(fn: ast.FunctionDef, name: str) -> bool:
    return any(isinstance(n, ast.Name) and n.id == name
               for stmt in fn.body for n in ast.walk(stmt))


# --------------------------------------------------------------- pass 1
def check_policy_hints(core_dir: Optional[Path] = None) -> List[Finding]:
    core_dir = Path(core_dir) if core_dir is not None else CORE_DIR
    findings: List[Finding] = []
    path = (Path(core_dir) / "policies.py")
    tree = _parse(path)
    classes = _classes(tree)

    def finding(rule, context, line, message):
        findings.append(Finding("protocol", rule, "policies", context,
                                line, message))

    if POLICY_BASE not in classes:
        finding("policy-base-missing", "", 1,
                f"class {POLICY_BASE} not found in policies.py")
        return findings

    registry: List[str] = []
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == REGISTRY_NAME \
                and isinstance(node.value, ast.Dict):
            for v in node.value.values:
                if isinstance(v, ast.Name):
                    registry.append(v.id)
    if not registry:
        finding("registry-missing", "", 1,
                f"{REGISTRY_NAME} dict of policy classes not found")
        return findings

    for name in registry:
        if name not in classes:
            finding("registry-unknown-class", name, 1,
                    f"{REGISTRY_NAME} references {name} but no such class "
                    "is defined in policies.py")
            continue
        chain = _chain(name, classes)          # includes Policy base
        below_base = _chain(name, classes, stop=POLICY_BASE)
        hints = {}
        for hint in HINT_NAMES:
            value, found = _class_attr(chain, hint)
            if found and not isinstance(value, bool):
                finding("hint-not-literal", name, classes[name].lineno,
                        f"{name}.{hint} is not a literal bool; the "
                        "analyzer (and readers) cannot verify it")
                value = None
            if not found:
                finding("hint-unresolved", name, classes[name].lineno,
                        f"{name}.{hint} is not declared anywhere in its "
                        "class chain")
                value = None
            hints[hint] = value

        methods: List[ast.FunctionDef] = []
        for cls in chain:
            methods.extend(_methods(cls).values())

        # -- uses_predictor vs. predictor reads ---------------------------
        read_line = _reads_attr(methods, "predictor")
        if hints["uses_predictor"] is False and read_line is not None:
            finding("undeclared-predictor-use", name, read_line,
                    f"{name} declares uses_predictor=False but its class "
                    "chain reads .predictor — machines would skip the "
                    "Algorithm-1 bookkeeping this policy depends on")
        if hints["uses_predictor"] is True and read_line is None:
            finding("stale-predictor-hint", name, classes[name].lineno,
                    f"{name} declares uses_predictor=True but its class "
                    "chain never reads .predictor — per-block predictor "
                    "bookkeeping runs for nothing")

        # -- unlimited_caps vs. residency_cap overrides -------------------
        cap_defs = [m for cls in below_base
                    for m in [_methods(cls).get("residency_cap")]
                    if m is not None]
        if cap_defs and hints["unlimited_caps"] is True:
            finding("undeclared-cap-override", name, cap_defs[0].lineno,
                    f"{name} overrides residency_cap but declares "
                    "unlimited_caps=True — machines would skip the cap "
                    "query entirely and the override would never run")
        if not cap_defs and hints["unlimited_caps"] is False:
            finding("stale-cap-hint", name, classes[name].lineno,
                    f"{name} declares unlimited_caps=False but inherits "
                    "the uncapped base residency_cap")

        # -- uniform_caps vs. per-SM cap logic ----------------------------
        sm_using = [m for m in cap_defs if len(m.args.args) >= 3
                    and _uses_name(m, m.args.args[2].arg)]
        if sm_using and hints["uniform_caps"] is True:
            finding("undeclared-per-sm-caps", name, sm_using[0].lineno,
                    f"{name}.residency_cap uses its per-unit argument but "
                    "declares uniform_caps=True — cap syncs would fan one "
                    "unit's answer out to all units")
        if cap_defs and not sm_using and hints["uniform_caps"] is False:
            finding("stale-per-sm-hint", name, classes[name].lineno,
                    f"{name} declares uniform_caps=False but its "
                    "residency_cap ignores the per-unit argument")
    return findings


# --------------------------------------------------------------- pass 2
Call = Tuple[str, str, Tuple[str, ...]]   # (receiver, method, arg names)


def _arg_names(call: ast.Call) -> Tuple[str, ...]:
    names = []
    for a in call.args:
        if isinstance(a, ast.Name):
            names.append(a.id)
        elif isinstance(a, ast.Attribute):       # event.key -> key
            names.append(a.attr)
        else:
            names.append(ast.dump(a))
    return tuple(names)


def _dispatch_calls(stmts: Sequence[ast.stmt],
                    aliases: Dict[str, Tuple[str, str]],
                    skip_lost: bool = False) -> List[Call]:
    """(receiver, method, args) sequence of predictor/policy dispatches in
    ``stmts``, in source order.  ``skip_lost`` skips `if <...>.lost:`
    sub-branches (the fault path is typed-post-only by design)."""
    calls: List[Call] = []

    def walk(stmts: Sequence[ast.stmt]) -> None:
        for stmt in stmts:
            if skip_lost and isinstance(stmt, ast.If) \
                    and any(isinstance(n, ast.Attribute) and n.attr == "lost"
                            for n in ast.walk(stmt.test)):
                walk(stmt.orelse)
                continue
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                func = node.func
                if not isinstance(func, ast.Attribute):
                    continue
                if isinstance(func.value, ast.Attribute) \
                        and isinstance(func.value.value, ast.Name) \
                        and func.value.value.id == "self" \
                        and func.value.attr in ("predictor", "policy"):
                    calls.append((func.value.attr, func.attr,
                                  _arg_names(node)))
                elif isinstance(func.value, ast.Name) \
                        and func.value.id == "self" \
                        and func.attr in aliases:
                    recv, meth = aliases[func.attr]
                    calls.append((recv, meth, _arg_names(node)))

    walk(stmts)
    return calls


def check_fused_paths(core_dir: Optional[Path] = None) -> List[Finding]:
    core_dir = Path(core_dir) if core_dir is not None else CORE_DIR
    findings: List[Finding] = []
    tree = _parse(Path(core_dir) / "machine.py")
    classes = _classes(tree)

    def finding(rule, context, line, message):
        findings.append(Finding("protocol", rule, "machine", context,
                                line, message))

    core = classes.get(CORE_CLASS)
    if core is None:
        finding("core-missing", "", 1,
                f"class {CORE_CLASS} not found in machine.py")
        return findings
    methods = _methods(core)

    # Bound-method aliases installed by bind():
    # self._predictor_on_block_end = self.predictor.on_block_end
    aliases: Dict[str, Tuple[str, str]] = {}
    bind = methods.get("bind")
    if bind is not None:
        for stmt in ast.walk(bind):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                t, v = stmt.targets[0], stmt.value
                if isinstance(t, ast.Attribute) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id == "self" \
                        and isinstance(v, ast.Attribute) \
                        and isinstance(v.value, ast.Attribute) \
                        and isinstance(v.value.value, ast.Name) \
                        and v.value.value.id == "self" \
                        and v.value.attr in ("predictor", "policy"):
                    aliases[t.attr] = (v.value.attr, v.attr)

    post = methods.get("post")
    if post is None:
        finding("post-missing", CORE_CLASS, core.lineno,
                f"{CORE_CLASS}.post not found")
        return findings

    # Typed branches of post(): event class name -> branch body.
    branches: Dict[str, List[ast.stmt]] = {}
    for stmt in post.body:
        node = stmt
        while isinstance(node, ast.If):
            test = node.test
            if isinstance(test, ast.Call) \
                    and isinstance(test.func, ast.Name) \
                    and test.func.id == "isinstance" \
                    and len(test.args) == 2 \
                    and isinstance(test.args[1], ast.Name):
                branches[test.args[1].id] = node.body
            node = node.orelse[0] if len(node.orelse) == 1 \
                and isinstance(node.orelse[0], ast.If) else None

    pairs = (("post_block_start", "BlockStarted", False),
             ("post_block_end", "BlockEnded", True))
    for fused_name, event_cls, skip_lost in pairs:
        fused = methods.get(fused_name)
        if fused is None:
            finding("fused-path-missing", f"{CORE_CLASS}.{fused_name}",
                    core.lineno,
                    f"{CORE_CLASS}.{fused_name} not found")
            continue
        branch = branches.get(event_cls)
        if branch is None:
            finding("typed-branch-missing", f"{CORE_CLASS}.post",
                    post.lineno,
                    f"post() has no isinstance(event, {event_cls}) branch")
            continue
        fused_calls = _dispatch_calls(fused.body, aliases)
        typed_calls = _dispatch_calls(branch, aliases, skip_lost=skip_lost)
        if fused_calls != typed_calls:
            finding(
                "fused-path-drift", f"{CORE_CLASS}.{fused_name}",
                fused.lineno,
                f"fused {fused_name} dispatch {fused_calls} != typed "
                f"post()/{event_cls} dispatch {typed_calls}; the two "
                "paths must stay bit-identical (DESIGN.md Section 8)")
    return findings


# --------------------------------------------------------------- pass 3
def check_machine_signatures(core_dir: Optional[Path] = None
                             ) -> List[Finding]:
    core_dir = Path(core_dir) if core_dir is not None else CORE_DIR
    findings: List[Finding] = []
    modules = list_modules(core_dir)

    machine_tree = _parse(modules["machine"])
    machine_classes = _classes(machine_tree)

    def finding(rule, module, context, line, message):
        findings.append(Finding("protocol", rule, module, context, line,
                                message))

    proto = machine_classes.get(PROTOCOL_CLASS)
    if proto is None:
        finding("protocol-missing", "machine", "", 1,
                f"class {PROTOCOL_CLASS} not found in machine.py")
        return findings

    proto_methods = {
        name: [a.arg for a in fn.args.args[1:]]     # drop self
        for name, fn in _methods(proto).items()
    }
    proto_attrs = [n.target.id for n in proto.body
                   if isinstance(n, ast.AnnAssign)
                   and isinstance(n.target, ast.Name)]

    # Class map spanning machine.py and the implementation modules.
    all_classes = dict(machine_classes)
    impl_module: Dict[str, str] = {c: "machine" for c in machine_classes}
    for stem, cls_name in MACHINE_IMPLS:
        if stem not in modules:
            continue
        tree = _parse(modules[stem])
        for n, c in _classes(tree).items():
            all_classes.setdefault(n, c)
            impl_module.setdefault(n, stem)

    for stem, cls_name in MACHINE_IMPLS:
        if stem not in modules:
            continue
        if cls_name not in all_classes:
            finding("impl-missing", stem, cls_name, 1,
                    f"expected machine implementation {cls_name} not "
                    f"found in {stem}.py")
            continue
        chain = _chain(cls_name, all_classes)
        if not any(c.name == MACHINE_BASE for c in chain):
            finding("impl-base-drift", stem, cls_name,
                    all_classes[cls_name].lineno,
                    f"{cls_name} no longer derives from {MACHINE_BASE}; "
                    "the analyzer cannot resolve its protocol methods")
            continue

        for name, proto_args in sorted(proto_methods.items()):
            impl = None
            for cls in chain:
                impl = _methods(cls).get(name)
                if impl is not None:
                    break
            if impl is None:
                finding("method-missing", stem, f"{cls_name}.{name}",
                        all_classes[cls_name].lineno,
                        f"{cls_name} does not implement protocol method "
                        f"{name}() anywhere in its class chain")
                continue
            impl_args = [a.arg for a in impl.args.args[1:]]
            if impl_args != proto_args:
                finding(
                    "signature-drift", impl_module.get(cls.name, stem),
                    f"{cls.name}.{name}", impl.lineno,
                    f"{cls.name}.{name}({', '.join(impl_args)}) does not "
                    f"match protocol {PROTOCOL_CLASS}.{name}"
                    f"({', '.join(proto_args)}); positional names are "
                    "part of the contract (callers use keywords)")

        inits = [m for cls in chain
                 for m in [_methods(cls).get("__init__")] if m is not None]
        for attr in proto_attrs:
            assigned = False
            for init in inits:
                for node in ast.walk(init):
                    if isinstance(node, (ast.Assign, ast.AnnAssign)):
                        targets = node.targets \
                            if isinstance(node, ast.Assign) \
                            else [node.target]
                        for t in targets:
                            if isinstance(t, ast.Attribute) \
                                    and isinstance(t.value, ast.Name) \
                                    and t.value.id == "self" \
                                    and t.attr == attr:
                                assigned = True
            if not assigned:
                finding("attr-missing", stem, cls_name,
                        all_classes[cls_name].lineno,
                        f"{cls_name} never assigns protocol attribute "
                        f"self.{attr} in any __init__ of its chain")
    return findings


def check_protocols(core_dir: Optional[Path] = None) -> List[Finding]:
    """All three protocol-drift checks."""
    return (check_policy_hints(core_dir)
            + check_fused_paths(core_dir)
            + check_machine_signatures(core_dir))
