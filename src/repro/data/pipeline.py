"""Deterministic, seekable synthetic data pipeline.

Batches are pure functions of ``(seed, step)`` via counter-based PRNG
(threefry), so:

* any step's batch can be regenerated without replaying the stream —
  checkpoint/restart and elastic rescheduling need no data-state beyond the
  step counter (the paper's preemption model maps onto this directly);
* the same global batch is produced regardless of host count — each host can
  slice its shard of the globally-deterministic batch.

``batch_for_step`` is jit-safe (device-side generation: no host transfer),
``iterate`` is the host-side convenience wrapper with prefetch.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    # Synthetic distribution: Zipf-ish over the vocabulary, matching the
    # heavy-tailed rank-frequency shape of natural text.
    zipf_alpha: float = 1.1


def _tokens(key, shape, vocab: int, alpha: float) -> jnp.ndarray:
    """Zipf-distributed token ids via inverse-CDF on uniform draws."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    # rank ~ u^(-1/(alpha-1)) truncated to vocab (alpha>1)
    ranks = jnp.floor(u ** (-1.0 / (alpha - 1.0))) - 1.0
    return jnp.clip(ranks, 0, vocab - 1).astype(jnp.int32)


def batch_for_step(cfg: ArchConfig, shape: InputShape, step,
                   data_cfg: DataConfig = DataConfig()) -> Dict:
    """Global batch for ``step`` (jit-safe; step may be traced)."""
    key = jax.random.fold_in(jax.random.PRNGKey(data_cfg.seed), step)
    ks = jax.random.split(key, 3)
    n_text = shape.seq_len - (cfg.n_patches or 0)
    batch = {"tokens": _tokens(ks[0], (shape.global_batch, n_text),
                               cfg.vocab_size, data_cfg.zipf_alpha)}
    if cfg.n_patches:
        batch["patches"] = 0.02 * jax.random.normal(
            ks[1], (shape.global_batch, cfg.n_patches, cfg.d_model),
            jnp.bfloat16)
    if cfg.encoder is not None:
        batch["frames"] = 0.02 * jax.random.normal(
            ks[2], (shape.global_batch, cfg.encoder.n_frames, cfg.d_model),
            jnp.bfloat16)
    return batch


def batch_spec(cfg: ArchConfig, shape: InputShape) -> Dict:
    """ShapeDtypeStructs for one global batch (dry-run input specs)."""
    n_text = shape.seq_len - (cfg.n_patches or 0)
    spec = {"tokens": jax.ShapeDtypeStruct(
        (shape.global_batch, n_text), jnp.int32)}
    if cfg.n_patches:
        spec["patches"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.encoder is not None:
        spec["frames"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.encoder.n_frames, cfg.d_model),
            jnp.bfloat16)
    return spec


def iterate(cfg: ArchConfig, shape: InputShape, start_step: int = 0,
            data_cfg: DataConfig = DataConfig(),
            prefetch: int = 2) -> Iterator[Dict]:
    """Host-side iterator with background prefetch, resumable at any step."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            q.put(batch_for_step(cfg, shape, step, data_cfg))
            step += 1

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
