"""Attention blocks: GQA (full / sliding-window / non-causal) and cross
attention, with train/prefill/decode entry points.

Weights are stored head-major (``[d_model, n_heads, head_dim]``) so head or
head_dim axes can be sharded directly by the sharding rules.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .layers import DEFAULT_COMPUTE_DTYPE, apply_rope, cast


def gqa_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
             bias: bool = False) -> Dict:
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(n_heads * head_dim)
    p = {
        "wq": jax.random.normal(ks[0], (d_model, n_heads, head_dim)) * s_in,
        "wk": jax.random.normal(ks[1], (d_model, n_kv, head_dim)) * s_in,
        "wv": jax.random.normal(ks[2], (d_model, n_kv, head_dim)) * s_in,
        "wo": jax.random.normal(ks[3], (n_heads, head_dim, d_model)) * s_out,
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads, head_dim))
        p["bk"] = jnp.zeros((n_kv, head_dim))
        p["bv"] = jnp.zeros((n_kv, head_dim))
        p["bo"] = jnp.zeros((d_model,))
    return p


def _qkv(p: Dict, x: jnp.ndarray, dtype) -> Tuple:
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"], dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, cast(p["wk"], dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, cast(p["wv"], dtype))
    if "bq" in p:
        q = q + cast(p["bq"], dtype)
        k = k + cast(p["bk"], dtype)
        v = v + cast(p["bv"], dtype)
    return q, k, v


def _out(p: Dict, o: jnp.ndarray, dtype) -> jnp.ndarray:
    y = jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"], dtype))
    if "bo" in p:
        y = y + cast(p["bo"], dtype)
    return y


def gqa_apply(
    p: Dict,
    x: jnp.ndarray,                    # [B, S, D]
    *,
    rope_theta: Optional[float],
    mask_kind: str = "causal",         # causal|window|none
    window: int = 0,
    positions: Optional[jnp.ndarray] = None,
    backend: str = "xla",
    shard=None,
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence attention.  Returns (out [B,S,D], cache entries)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, dtype)
    if rope_theta is not None:
        pos = positions if positions is not None else jnp.arange(S)
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    if shard is not None:
        k = shard.replicate_seq(k)
        v = shard.replicate_seq(v)
    o = ops.flash_attention(q, k, v, mask_kind=mask_kind, window=window,
                            backend=backend)
    return _out(p, o, dtype), {"k": k, "v": v}


def gqa_decode(
    p: Dict,
    x: jnp.ndarray,                    # [B, D] one token
    cache: Dict,                       # {"k": [B,S,KV,hd], "v": ...}
    length: jnp.ndarray,               # [B] current cache fill
    *,
    rope_theta: Optional[float],
    window: int = 0,
    backend: str = "xla",
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> Tuple[jnp.ndarray, Dict]:
    """One decode step: append this token's K/V at ``length`` and attend."""
    B, D = x.shape
    q, k, v = _qkv(p, x[:, None, :], dtype)           # [B,1,H,hd]
    if rope_theta is not None:
        pos = length[:, None]                          # [B,1]
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    S = cache["k"].shape[1]
    if window and window < S:
        slot = (length % window)[:, None]
    else:
        slot = length[:, None]
    bidx = jnp.arange(B)[:, None]
    k_cache = cache["k"].at[bidx, slot].set(k.astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slot].set(v.astype(cache["v"].dtype))
    eff_len = jnp.minimum(length + 1,
                          window if window and window < S else S)
    o = ops.decode_attention(q[:, 0], k_cache, v_cache, eff_len,
                             backend=backend)
    y = _out(p, o[:, None, :, :], dtype)[:, 0]
    return y, {"k": k_cache, "v": v_cache}


# ----------------------------------------------------------------- cross
def cross_init(key, d_model: int, n_heads: int, n_kv: int,
               head_dim: int) -> Dict:
    return gqa_init(key, d_model, n_heads, n_kv, head_dim, bias=True)


def cross_apply(
    p: Dict,
    x: jnp.ndarray,                    # [B, Sq, D] decoder states
    enc_kv: Dict,                      # {"k": [B,Se,KV,hd], "v": ...}
    *,
    backend: str = "xla",
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> jnp.ndarray:
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"], dtype))
    if "bq" in p:
        q = q + cast(p["bq"], dtype)
    o = ops.flash_attention(q, enc_kv["k"], enc_kv["v"], mask_kind="none",
                            backend=backend)
    return _out(p, o, dtype)


def cross_kv(p: Dict, enc_out: jnp.ndarray, dtype=DEFAULT_COMPUTE_DTYPE) -> Dict:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, cast(p["wk"], dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, cast(p["wv"], dtype))
    if "bk" in p:
        k = k + cast(p["bk"], dtype)
        v = v + cast(p["bv"], dtype)
    return {"k": k, "v": v}
