"""The composable language model: plan construction, init, forward, loss,
prefill and decode for every architecture in the zoo.

A model is a sequence of *stages*; each stage scans a fixed *unit* (tuple of
LayerSpecs) over ``repeats`` stacked parameter sets, keeping the lowered HLO
compact regardless of depth.  Mixers: GQA / sliding-window GQA / MLA /
Mamba-2 SSD / RG-LRU.  FFNs: dense (SwiGLU/GeGLU/GELU) or MoE.  Optional
encoder (whisper) and patch-embedding stub (pixtral).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sharding.annotate import NULL_SHARDER

from . import attention as attn
from . import mla as mla_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import (
    DEFAULT_COMPUTE_DTYPE,
    apply_mlp,
    apply_norm,
    embed,
    embedding_init,
    mlp_init,
    norm_init,
    unembed,
)


@dataclass(frozen=True)
class LayerSpec:
    mixer: str                  # gqa|local|mla|ssd|rglru
    ffn: str                    # dense|moe|none
    cross: bool = False
    d_ff: Optional[int] = None  # per-layer FFN width override


@dataclass(frozen=True)
class Stage:
    unit: Tuple[LayerSpec, ...]
    repeats: int


def build_plan(cfg: ArchConfig) -> Tuple[Stage, ...]:
    if cfg.ssm is not None:
        return (Stage((LayerSpec("ssd", "none"),), cfg.n_layers),)
    if cfg.rglru is not None:
        pat = tuple("rglru" if p == "rec" else "local" for p in cfg.rglru.pattern)
        unit = tuple(LayerSpec(m, "dense") for m in pat)
        full, rem = divmod(cfg.n_layers, len(pat))
        stages = [Stage(unit, full)] if full else []
        if rem:
            stages.append(Stage(unit[:rem], 1))
        return tuple(stages)
    mixer = "mla" if cfg.attn_kind == "mla" else "gqa"
    if cfg.moe is not None:
        stages = []
        nd = cfg.moe.first_dense_layers
        if nd:
            stages.append(Stage(
                (LayerSpec(mixer, "dense", d_ff=cfg.moe.d_ff_dense),), nd))
        stages.append(Stage((LayerSpec(mixer, "moe"),), cfg.n_layers - nd))
        return tuple(stages)
    return (Stage((LayerSpec(mixer, "dense", cross=cfg.encoder is not None),),
                  cfg.n_layers),)


# ================================================================== init
def _layer_init(key, cfg: ArchConfig, spec: LayerSpec) -> Dict:
    ks = jax.random.split(key, 6)
    p: Dict = {"norm1": norm_init(cfg.d_model, cfg.norm)}
    if spec.mixer in ("gqa", "local"):
        p["mixer"] = attn.gqa_init(ks[0], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim_,
                                   bias=cfg.norm == "layer")
    elif spec.mixer == "mla":
        p["mixer"] = mla_mod.mla_init(ks[0], cfg.d_model, cfg.n_heads, cfg.mla)
    elif spec.mixer == "ssd":
        p["mixer"] = ssm_mod.mamba2_init(ks[0], cfg.d_model, cfg.ssm)
    elif spec.mixer == "rglru":
        p["mixer"] = rglru_mod.rglru_block_init(ks[0], cfg.d_model, cfg.rglru)
    else:
        raise ValueError(spec.mixer)
    if spec.cross:
        p["norm_cross"] = norm_init(cfg.d_model, cfg.norm)
        p["cross"] = attn.cross_init(ks[1], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim_)
    if spec.ffn == "dense":
        p["norm2"] = norm_init(cfg.d_model, cfg.norm)
        p["ffn"] = mlp_init(ks[2], cfg.d_model, spec.d_ff or cfg.d_ff, cfg.act)
    elif spec.ffn == "moe":
        p["norm2"] = norm_init(cfg.d_model, cfg.norm)
        p["ffn"] = moe_mod.moe_init(ks[2], cfg.d_model, cfg.d_ff, cfg.moe)
    return p


def _encoder_layer_init(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "norm1": norm_init(cfg.d_model, cfg.norm),
        "mixer": attn.gqa_init(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.head_dim_, bias=True),
        "norm2": norm_init(cfg.d_model, cfg.norm),
        "ffn": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act),
    }


def init(cfg: ArchConfig, key) -> Dict:
    plan = build_plan(cfg)
    keys = jax.random.split(key, len(plan) + 4)
    params: Dict = {
        "embed": embedding_init(keys[0], cfg.padded_vocab, cfg.d_model),
        "final_norm": norm_init(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": jax.random.normal(
                keys[1], (cfg.d_model, cfg.padded_vocab)) * 0.02}
    if cfg.encoder is not None:
        enc_keys = jax.random.split(keys[2], 2)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _encoder_layer_init(k, cfg))(
                jax.random.split(enc_keys[0], cfg.encoder.n_layers)),
            "final_norm": norm_init(cfg.d_model, cfg.norm),
        }
    for si, stage in enumerate(plan):
        stage_p = {}
        for ui, spec in enumerate(stage.unit):
            lk = jax.random.split(jax.random.fold_in(keys[3 + si], ui),
                                  stage.repeats)
            stage_p[f"u{ui}"] = jax.vmap(
                lambda k, s=spec: _layer_init(k, cfg, s))(lk)
        params[f"stage{si}"] = stage_p
    return params


# ================================================================ forward
def _sinusoid(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def _apply_layer(cfg: ArchConfig, spec: LayerSpec, p: Dict, x, *,
                 enc_out=None, positions=None, max_seq=None,
                 backend="xla", shard=NULL_SHARDER, dtype=DEFAULT_COMPUTE_DTYPE):
    """One layer, full sequence.  Returns (x, cache, aux)."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    cache = {}
    rope = cfg.rope_theta if cfg.attn_kind != "none" or cfg.rglru else None
    if spec.mixer in ("gqa", "local"):
        window = cfg.rglru.window if (spec.mixer == "local" and cfg.rglru) else 0
        mix, kv = attn.gqa_apply(
            p["mixer"], h, rope_theta=rope,
            mask_kind="window" if spec.mixer == "local" else "causal",
            window=window, positions=positions, backend=backend,
            shard=shard, dtype=dtype)
        cache = _ring_or_pad_kv(kv, spec, cfg, max_seq)
    elif spec.mixer == "mla":
        mix, kv = mla_mod.mla_apply(
            p["mixer"], h, cfg.mla, rope_theta=cfg.rope_theta,
            positions=positions, backend=backend, shard=shard, dtype=dtype)
        cache = _pad_mla(kv, max_seq)
    elif spec.mixer == "ssd":
        mix, cache = ssm_mod.mamba2_apply(
            p["mixer"], h, cfg.ssm, cfg.d_model, backend=backend,
            shard=shard, dtype=dtype)
    elif spec.mixer == "rglru":
        mix, cache = rglru_mod.rglru_block_apply(
            p["mixer"], h, cfg.rglru, backend=backend, shard=shard,
            dtype=dtype)
    x = x + mix
    if spec.cross and enc_out is not None:
        hc = apply_norm(p["norm_cross"], x, cfg.norm)
        ckv = attn.cross_kv(p["cross"], enc_out, dtype)
        x = x + attn.cross_apply(p["cross"], hc, ckv, backend=backend,
                                 dtype=dtype)
        cache["cross"] = ckv
    aux = jnp.zeros((), jnp.float32)

    def whook(w):
        return shard.weight_for_batch(w, x.shape[0])

    if spec.ffn == "dense":
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        # nested remat: the FFN's [*, d_ff] intermediates are the largest
        # per-layer activations; recompute them inside the layer's backward
        ffn_fn = jax.checkpoint(
            lambda q, v: apply_mlp(q, v, cfg.act, dtype, whook=whook))
        x = x + ffn_fn(p["ffn"], h2)
    elif spec.ffn == "moe":
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        y, aux = moe_mod.moe_apply(p["ffn"], h2, cfg.moe, shard=shard,
                                   dtype=dtype)
        x = x + y
    x = shard.activations(x)
    return x, cache, aux


def _ring_or_pad_kv(kv: Dict, spec: LayerSpec, cfg: ArchConfig,
                    max_seq: Optional[int]) -> Dict:
    if max_seq is None:
        return {}
    k, v = kv["k"], kv["v"]
    S = k.shape[1]
    if spec.mixer == "local" and cfg.rglru:
        W = cfg.rglru.window
        n = min(S, W)
        slots = (jnp.arange(S - n, S) % W)
        def ring(a):
            return jnp.zeros((a.shape[0], W) + a.shape[2:], a.dtype
                             ).at[:, slots].set(a[:, -n:])
        return {"k": ring(k), "v": ring(v)}
    pad = max_seq - S
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": k, "v": v}


def _pad_mla(kv: Dict, max_seq: Optional[int]) -> Dict:
    if max_seq is None:
        return {}
    pad = max_seq - kv["c_kv"].shape[1]
    if pad > 0:
        return {"c_kv": jnp.pad(kv["c_kv"], ((0, 0), (0, pad), (0, 0))),
                "k_rope": jnp.pad(kv["k_rope"], ((0, 0), (0, pad), (0, 0)))}
    return {"c_kv": kv["c_kv"], "k_rope": kv["k_rope"]}


def encoder_unit(cfg: ArchConfig, p: Dict, x, *, backend="xla",
                 shard=NULL_SHARDER, dtype=DEFAULT_COMPUTE_DTYPE):
    """One encoder layer (the encoder scan body)."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    mix, _ = attn.gqa_apply(p["mixer"], h, rope_theta=None,
                            mask_kind="none", backend=backend,
                            shard=shard, dtype=dtype)
    x = x + mix
    h2 = apply_norm(p["norm2"], x, cfg.norm)
    x = x + apply_mlp(p["ffn"], h2, cfg.act, dtype)
    return shard.activations(x)


def _encode(cfg: ArchConfig, params: Dict, frames: jnp.ndarray, *,
            backend="xla", shard=NULL_SHARDER,
            dtype=DEFAULT_COMPUTE_DTYPE) -> jnp.ndarray:
    """Whisper-style encoder over precomputed frame embeddings [B, F, D]."""
    x = frames.astype(dtype) + _sinusoid(frames.shape[1],
                                         cfg.d_model).astype(dtype)
    x = shard.activations(x)

    def body(x, p):
        return encoder_unit(cfg, p, x, backend=backend, shard=shard,
                            dtype=dtype), None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return apply_norm(params["encoder"]["final_norm"], x, cfg.norm)


def apply_unit(cfg: ArchConfig, stage: Stage, repeat_p: Dict, x, *,
               enc_out=None, positions=None, max_seq=None, backend="xla",
               shard=NULL_SHARDER, dtype=DEFAULT_COMPUTE_DTYPE):
    """One repeat of a stage's unit (the scan body).  Returns
    (x, cache entries, aux)."""
    entries = {}
    aux = jnp.zeros((), jnp.float32)
    for ui, spec in enumerate(stage.unit):
        x, cache, a = _apply_layer(
            cfg, spec, repeat_p[f"u{ui}"], x, enc_out=enc_out,
            positions=positions, max_seq=max_seq, backend=backend,
            shard=shard, dtype=dtype)
        entries[f"u{ui}"] = cache
        aux = aux + a
    return x, entries, aux


def forward(
    cfg: ArchConfig,
    params: Dict,
    tokens: jnp.ndarray,                 # [B, S_text]
    *,
    patches: Optional[jnp.ndarray] = None,      # [B, P, D] VLM stub embeds
    enc_frames: Optional[jnp.ndarray] = None,   # [B, F, D] audio stub embeds
    collect_cache: bool = False,
    max_seq: Optional[int] = None,
    backend: str = "xla",
    shard=NULL_SHARDER,
    remat: bool = False,
    return_hidden: bool = False,
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> Tuple:
    """Returns (logits [B,S,V] — or final hidden states if
    ``return_hidden``, for the vocab-chunked loss — , aux, caches|None)."""
    plan = build_plan(cfg)
    x = embed(params["embed"], tokens, dtype)
    if patches is not None:
        x = jnp.concatenate([patches.astype(dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    x = shard.activations(x)

    enc_out = None
    if cfg.encoder is not None and enc_frames is not None:
        enc_out = _encode(cfg, params, enc_frames, backend=backend,
                          shard=shard, dtype=dtype)

    cache_seq = max_seq if collect_cache else None
    caches: Dict = {}
    aux_total = jnp.zeros((), jnp.float32)
    for si, stage in enumerate(plan):
        stage_p = params[f"stage{si}"]

        def body(carry, repeat_p, stage=stage):
            x, aux = carry
            x, entries, a = apply_unit(
                cfg, stage, repeat_p, x, enc_out=enc_out,
                positions=positions, max_seq=cache_seq, backend=backend,
                shard=shard, dtype=dtype)
            return (x, aux + a), (entries if collect_cache else None)

        body_fn = jax.checkpoint(body) if remat else body
        (x, aux_total), ys = jax.lax.scan(body_fn, (x, aux_total), stage_p)
        if collect_cache:
            caches[f"stage{si}"] = ys

    x = apply_norm(params["final_norm"], x, cfg.norm)
    if return_hidden:
        return x, aux_total, (caches if collect_cache else None)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, dtype)
    else:
        logits = x @ params["lm_head"]["w"].astype(dtype)
    logits = shard.logits(logits)
    return logits, aux_total, (caches if collect_cache else None)


# =================================================================== loss
def _chunked_nll(x: jnp.ndarray, table: jnp.ndarray, transpose: bool,
                 targets: jnp.ndarray, vocab: int,
                 chunk: int = 8192, dtype=DEFAULT_COMPUTE_DTYPE):
    """Online-logsumexp cross entropy over vocabulary chunks.

    Materializing fp32 logits [B, S, V] costs gigabytes per device at the
    assigned vocab sizes (up to 256k); streaming the head matmul over vocab
    chunks with a checkpointed scan bounds the transient to [B, S, chunk]
    (EXPERIMENTS.md §Perf).  ``table`` is [V, D] if ``transpose`` (tied
    embeddings) else [D, V].  Returns (nll [B,S], lse [B,S]).
    """
    B, S, D = x.shape
    V = table.shape[0] if transpose else table.shape[1]
    chunk = min(chunk, V)
    n_chunks = -(-V // chunk)

    def body(carry, i):
        m, se, tl = carry
        start = i * chunk
        if transpose:
            wc = jax.lax.dynamic_slice_in_dim(table, start, chunk, 0)
            logits = (x @ wc.astype(dtype).T).astype(jnp.float32)
        else:
            wc = jax.lax.dynamic_slice_in_dim(table, start, chunk, 1)
            logits = (x @ wc.astype(dtype)).astype(jnp.float32)
        cols = start + jnp.arange(chunk)
        logits = jnp.where(cols[None, None, :] < vocab, logits, -1e30)
        new_m = jnp.maximum(m, logits.max(-1))
        se = se * jnp.exp(m - new_m) + jnp.exp(
            logits - new_m[..., None]).sum(-1)
        local = targets - start
        in_range = (local >= 0) & (local < chunk)
        lt = jnp.take_along_axis(
            logits, jnp.clip(local, 0, chunk - 1)[..., None], axis=-1)[..., 0]
        tl = jnp.where(in_range, lt, tl)
        return (new_m, se, tl), None

    init = (jnp.full((B, S), -1e30, jnp.float32),
            jnp.zeros((B, S), jnp.float32),
            jnp.zeros((B, S), jnp.float32))
    (m, se, tl), _ = jax.lax.scan(jax.checkpoint(body), init,
                                  jnp.arange(n_chunks))
    lse = jnp.log(jnp.maximum(se, 1e-30)) + m
    return lse - tl, lse


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict, *,
            backend: str = "xla", shard=NULL_SHARDER, remat: bool = False,
            aux_coef: float = 0.01, z_coef: float = 1e-4,
            dtype=DEFAULT_COMPUTE_DTYPE) -> Tuple[jnp.ndarray, Dict]:
    """Next-token cross entropy (+ MoE aux + z-loss), vocab-chunked."""
    hidden, aux, _ = forward(
        cfg, params, batch["tokens"], patches=batch.get("patches"),
        enc_frames=batch.get("frames"), backend=backend, shard=shard,
        remat=remat, dtype=dtype, return_hidden=True)
    n_prefix = 0 if batch.get("patches") is None else batch["patches"].shape[1]
    x = hidden[:, n_prefix:-1, :]
    targets = batch["tokens"][:, 1:]
    if cfg.tie_embeddings:
        nll, lse = _chunked_nll(x, params["embed"]["table"], True, targets,
                                cfg.padded_vocab, dtype=dtype)
    else:
        nll, lse = _chunked_nll(x, params["lm_head"]["w"], False, targets,
                                cfg.padded_vocab, dtype=dtype)
    nll = nll.mean()
    z_loss = z_coef * jnp.square(lse).mean()
    total = nll + z_loss + aux_coef * aux
    return total, {"nll": nll, "aux": aux, "z": z_loss}


# ================================================================ serving
def prefill(cfg: ArchConfig, params: Dict, tokens, *, max_seq: int,
            patches=None, enc_frames=None, backend="xla",
            shard=NULL_SHARDER, dtype=DEFAULT_COMPUTE_DTYPE):
    """Run the prompt, return (last-token logits [B,V], caches)."""
    total = tokens.shape[1] + (patches.shape[1] if patches is not None else 0)
    if max_seq < total:
        raise ValueError(
            f"max_seq={max_seq} smaller than prompt length {total} "
            "(includes patch prefix)")
    # head applied to the LAST position only: computing (and sharding-
    # constraining) full [B, S, V] logits forced XLA to materialize tens of
    # GiB at 32k x 256k vocab (EXPERIMENTS.md §Perf)
    hidden, _, caches = forward(
        cfg, params, tokens, patches=patches, enc_frames=enc_frames,
        collect_cache=True, max_seq=max_seq, backend=backend, shard=shard,
        return_hidden=True, dtype=dtype)
    last = hidden[:, -1, :]
    if cfg.tie_embeddings:
        logits = last @ params["embed"]["table"].astype(dtype).T
    else:
        logits = last @ params["lm_head"]["w"].astype(dtype)
    return logits, caches


def decode_unit(cfg: ArchConfig, stage: Stage, repeat_p: Dict,
                repeat_c: Dict, x, lengths, *, backend="xla",
                dtype=DEFAULT_COMPUTE_DTYPE):
    """One repeat of a stage's unit in decode mode (the decode scan body).
    Returns (x, updated cache entries)."""
    new_entries = {}
    for ui, spec in enumerate(stage.unit):
        p, c = repeat_p[f"u{ui}"], repeat_c[f"u{ui}"]
        h = apply_norm(p["norm1"], x[:, None, :], cfg.norm)[:, 0]
        if spec.mixer in ("gqa", "local"):
            window = (cfg.rglru.window
                      if (spec.mixer == "local" and cfg.rglru) else 0)
            mix, nc = attn.gqa_decode(
                p["mixer"], h, {"k": c["k"], "v": c["v"]}, lengths,
                rope_theta=cfg.rope_theta, window=window, backend=backend,
                dtype=dtype)
        elif spec.mixer == "mla":
            mix, nc = mla_mod.mla_decode(
                p["mixer"], h, {"c_kv": c["c_kv"], "k_rope": c["k_rope"]},
                lengths, cfg.mla, rope_theta=cfg.rope_theta, dtype=dtype)
        elif spec.mixer == "ssd":
            mix, nc = ssm_mod.mamba2_decode(
                p["mixer"], h, c, cfg.ssm, cfg.d_model, dtype=dtype)
        else:
            mix, nc = rglru_mod.rglru_block_decode(
                p["mixer"], h, c, cfg.rglru, dtype=dtype)
        x = x + mix
        if spec.cross and "cross" in c:
            hc = apply_norm(p["norm_cross"], x[:, None, :], cfg.norm)
            xc = attn.cross_apply(p["cross"], hc, c["cross"],
                                  backend=backend, dtype=dtype)
            x = x + xc[:, 0]
            nc["cross"] = c["cross"]
        if spec.ffn in ("dense", "moe"):
            h2 = apply_norm(p["norm2"], x[:, None, :], cfg.norm)
            if spec.ffn == "dense":
                x = x + apply_mlp(p["ffn"], h2, cfg.act, dtype)[:, 0]
            else:
                y, _ = moe_mod.moe_apply(p["ffn"], h2, cfg.moe, dtype=dtype)
                x = x + y[:, 0]
        new_entries[f"u{ui}"] = nc
    return x, new_entries


def decode_step(
    cfg: ArchConfig,
    params: Dict,
    token: jnp.ndarray,                  # [B] current token ids
    caches: Dict,
    lengths: jnp.ndarray,                # [B] positions already cached
    *,
    backend: str = "xla",
    shard=NULL_SHARDER,
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> Tuple[jnp.ndarray, Dict]:
    """One token for every sequence in the batch: (logits [B,V], caches)."""
    plan = build_plan(cfg)
    x = embed(params["embed"], token, dtype)                  # [B,D]
    x = shard.decode_activations(x)
    new_caches: Dict = {}
    for si, stage in enumerate(plan):
        stage_p = params[f"stage{si}"]
        stage_c = caches[f"stage{si}"]

        def body(x, inp, stage=stage):
            repeat_p, repeat_c = inp
            return decode_unit(cfg, stage, repeat_p, repeat_c, x, lengths,
                               backend=backend, dtype=dtype)

        x, new_stage_c = jax.lax.scan(body, x, (stage_p, stage_c))
        new_caches[f"stage{si}"] = new_stage_c

    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, dtype)
    else:
        logits = x @ params["lm_head"]["w"].astype(dtype)
    return logits, new_caches
