"""Model zoo: composable JAX modules for all assigned architectures."""
