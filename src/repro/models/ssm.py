"""Mamba-2 block: per-component projections -> causal conv1d -> SSD mixer ->
gated RMSNorm -> out-proj.  The SSD scan itself lives in repro.kernels
(chunked XLA / Pallas / sequential reference).

The x/B/C/dt/gate projections are SEPARATE weights (the reference
implementation fuses them into one in_proj): slicing a fused, model-sharded
projection output at non-shard-aligned offsets forces SPMD to replicate the
activations, which measured at ~80 GiB/device of extra temp on the
mamba2-2.7b train_4k cell (EXPERIMENTS.md §Perf).  Separate projections keep
the SSD head axis cleanly sharded end to end.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.kernels import ops

from .layers import DEFAULT_COMPUTE_DTYPE, apply_norm, cast, norm_init


def _heads(s: SSMConfig) -> int:
    return s.d_inner // s.head_dim


def mamba2_init(key, d_model: int, s: SSMConfig) -> Dict:
    heads = _heads(s)
    gn = s.n_groups * s.state_dim
    ks = jax.random.split(key, 8)
    sc = 1.0 / math.sqrt(d_model)
    return {
        "w_gate": jax.random.normal(ks[0], (d_model, s.d_inner)) * sc,
        "w_x": jax.random.normal(ks[1], (d_model, s.d_inner)) * sc,
        "w_b": jax.random.normal(ks[2], (d_model, gn)) * sc,
        "w_c": jax.random.normal(ks[3], (d_model, gn)) * sc,
        "w_dt": jax.random.normal(ks[4], (d_model, heads)) * sc,
        "conv_x_w": jax.random.normal(ks[5], (s.conv_width, s.d_inner)) * 0.2,
        "conv_x_b": jnp.zeros((s.d_inner,)),
        "conv_b_w": jax.random.normal(ks[6], (s.conv_width, gn)) * 0.2,
        "conv_b_b": jnp.zeros((gn,)),
        "conv_c_w": jax.random.normal(ks[7], (s.conv_width, gn)) * 0.2,
        "conv_c_b": jnp.zeros((gn,)),
        "dt_bias": jnp.zeros((heads,)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, heads)),
        "d_skip": jnp.ones((heads,)),
        "gate_norm": norm_init(s.d_inner),
        "out_proj": jax.random.normal(
            jax.random.fold_in(key, 99), (s.d_inner, d_model))
        / math.sqrt(s.d_inner),
    }


def _causal_conv(w, b, x, prev: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over [B, S, C]; ``prev`` is [B, W-1, C]."""
    W = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(W))
    return jax.nn.silu(out + b[None, None, :]), xp[:, -(W - 1):, :]


def mamba2_apply(
    p: Dict,
    x: jnp.ndarray,                     # [B, S, D]
    s: SSMConfig,
    d_model: int,
    *,
    backend: str = "xla",
    initial_state: Optional[Dict] = None,
    shard=None,
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence mamba2 mixer.  Returns (out, state dict)."""
    B, S, _ = x.shape
    heads = _heads(s)
    wcast = ((lambda w: shard.weight_for_batch(cast(w, dtype), B))
             if shard is not None else (lambda w: cast(w, dtype)))
    gate = x @ wcast(p["w_gate"])
    xs_r = x @ wcast(p["w_x"])
    if shard is not None:
        xs_r = shard.channels(xs_r)        # d_inner (=heads) over model
    b_r = x @ wcast(p["w_b"])
    c_r = x @ wcast(p["w_c"])
    dt_r = x @ wcast(p["w_dt"])

    prev = initial_state if initial_state else {}
    xs_c, conv_x = _causal_conv(wcast(p["conv_x_w"]), wcast(p["conv_x_b"]),
                                xs_r, prev.get("conv_x"))
    b_c, conv_b = _causal_conv(wcast(p["conv_b_w"]), wcast(p["conv_b_b"]),
                               b_r, prev.get("conv_b"))
    c_c, conv_c = _causal_conv(wcast(p["conv_c_w"]), wcast(p["conv_c_b"]),
                               c_r, prev.get("conv_c"))

    xs = xs_c.reshape(B, S, heads, s.head_dim)
    Bmat = b_c.reshape(B, S, s.n_groups, s.state_dim)
    Cmat = c_c.reshape(B, S, s.n_groups, s.state_dim)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    h0 = prev.get("ssm")
    y, hT = ops.ssd(xs, dt, A, Bmat, Cmat, chunk=s.chunk,
                    initial_state=h0, backend=backend)
    y = y + xs * cast(p["d_skip"], dtype)[None, None, :, None]
    y = y.reshape(B, S, s.d_inner)
    y = apply_norm(p["gate_norm"], y) * jax.nn.silu(gate)
    out = y @ wcast(p["out_proj"])
    return out, {"ssm": hT, "conv_x": conv_x, "conv_b": conv_b,
                 "conv_c": conv_c}


def mamba2_decode(
    p: Dict,
    x: jnp.ndarray,                     # [B, D]
    state: Dict,
    s: SSMConfig,
    d_model: int,
    *,
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> Tuple[jnp.ndarray, Dict]:
    B, _ = x.shape
    heads = _heads(s)
    gate = x @ cast(p["w_gate"], dtype)
    xs_r = (x @ cast(p["w_x"], dtype))[:, None, :]
    b_r = (x @ cast(p["w_b"], dtype))[:, None, :]
    c_r = (x @ cast(p["w_c"], dtype))[:, None, :]
    dt_r = x @ cast(p["w_dt"], dtype)

    def conv_step(wk, bk, u, hist):
        h = jnp.concatenate([hist, u], axis=1)                  # [B,W,C]
        out = jnp.einsum("bwc,wc->bc", h, cast(wk, dtype)) + cast(bk, dtype)
        return jax.nn.silu(out), h[:, 1:]

    xs_c, conv_x = conv_step(p["conv_x_w"], p["conv_x_b"], xs_r,
                             state["conv_x"])
    b_c, conv_b = conv_step(p["conv_b_w"], p["conv_b_b"], b_r,
                            state["conv_b"])
    c_c, conv_c = conv_step(p["conv_c_w"], p["conv_c_b"], c_r,
                            state["conv_c"])

    xs = xs_c.reshape(B, heads, s.head_dim)
    Bvec = b_c.reshape(B, s.n_groups, s.state_dim)
    Cvec = c_c.reshape(B, s.n_groups, s.state_dim)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    y, new_ssm = ops.ssd_decode_step(xs, dt, A, Bvec, Cvec, state["ssm"])
    y = y + xs * cast(p["d_skip"], dtype)[None, :, None]
    y = y.reshape(B, s.d_inner)
    y = apply_norm(p["gate_norm"], y) * jax.nn.silu(gate)
    out = y @ cast(p["out_proj"], dtype)
    return out, {"ssm": new_ssm, "conv_x": conv_x, "conv_b": conv_b,
                 "conv_c": conv_c}
