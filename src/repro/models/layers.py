"""Shared model building blocks (functional JAX, params as pytrees).

Conventions:

* Parameters are stored in fp32 and cast to ``compute_dtype`` (bf16 by
  default) at use; optimizer state stays fp32.
* Layer-stacked parameters carry a leading ``[n_layers, ...]`` axis and are
  consumed by ``jax.lax.scan`` so the lowered HLO stays compact for the
  512-device dry-run.
* Weight shapes put the contraction (input) dim first: ``w[d_in, d_out]``.
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

DEFAULT_COMPUTE_DTYPE = jnp.bfloat16


def cast(x: jnp.ndarray, dtype) -> jnp.ndarray:
    return x.astype(dtype) if x.dtype != dtype else x


# ------------------------------------------------------------------ linear
def linear_init(key, d_in: int, d_out: int, scale: Optional[float] = None,
                bias: bool = False) -> Dict:
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(p: Dict, x: jnp.ndarray, dtype=DEFAULT_COMPUTE_DTYPE) -> jnp.ndarray:
    y = x @ cast(p["w"], dtype)
    if "b" in p:
        y = y + cast(p["b"], dtype)
    return y


# ------------------------------------------------------------------- norms
def norm_init(d: int, kind: str = "rms") -> Dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layer":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Dict, x: jnp.ndarray, kind: str = "rms",
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rms":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = xf * p["scale"]
    if "bias" in p:
        out = out + p["bias"]
    return out.astype(x.dtype)


# -------------------------------------------------------------- embeddings
def embedding_init(key, vocab: int, d: int) -> Dict:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(p: Dict, tokens: jnp.ndarray,
          dtype=DEFAULT_COMPUTE_DTYPE) -> jnp.ndarray:
    return cast(p["table"], dtype)[tokens]


def unembed(p: Dict, x: jnp.ndarray,
            dtype=DEFAULT_COMPUTE_DTYPE) -> jnp.ndarray:
    return x @ cast(p["table"], dtype).T


# -------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: [..., S, H, D]; positions: [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # [D/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., :, None, :]              # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- mlp
def mlp_init(key, d_model: int, d_ff: int, act: str) -> Dict:
    ks = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {
            "gate": linear_init(ks[0], d_model, d_ff),
            "up": linear_init(ks[1], d_model, d_ff),
            "down": linear_init(ks[2], d_ff, d_model),
        }
    return {
        "up": linear_init(ks[0], d_model, d_ff, bias=True),
        "down": linear_init(ks[1], d_ff, d_model, bias=True),
    }


def apply_mlp(p: Dict, x: jnp.ndarray, act: str,
              dtype=DEFAULT_COMPUTE_DTYPE, whook=None) -> jnp.ndarray:
    """``whook`` optionally post-processes each cast weight (e.g. a sharding
    constraint forcing weight-side gathers under full-mesh batch plans)."""
    def lin(q, v):
        w = cast(q["w"], dtype)
        if whook is not None:
            w = whook(w)
        y = v @ w
        if "b" in q:
            y = y + cast(q["b"], dtype)
        return y

    if act == "swiglu":
        h = jax.nn.silu(lin(p["gate"], x)) * lin(p["up"], x)
    elif act == "geglu":
        h = jax.nn.gelu(lin(p["gate"], x)) * lin(p["up"], x)
    else:
        h = jax.nn.gelu(lin(p["up"], x))
    return lin(p["down"], h)


# ---------------------------------------------------------------- utility
def stack_layers(init_fn, key, n_layers: int) -> Dict:
    """Initialize ``n_layers`` identical layers stacked on a leading axis."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_fn)(keys)


def causal_mask(s_q: int, s_k: int, q_offset) -> jnp.ndarray:
    """[s_q, s_k] True where query may attend (supports KV-cache offsets)."""
    q_pos = q_offset + jnp.arange(s_q)[:, None]
    k_pos = jnp.arange(s_k)[None, :]
    return k_pos <= q_pos


def window_mask(s_q: int, s_k: int, q_offset, window: int) -> jnp.ndarray:
    q_pos = q_offset + jnp.arange(s_q)[:, None]
    k_pos = jnp.arange(s_k)[None, :]
    return (k_pos <= q_pos) & (k_pos > q_pos - window)
