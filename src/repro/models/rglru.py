"""RecurrentGemma/Griffin recurrent block: dual input projections, causal
conv1d, RG-LRU linear recurrence, gated output.

Gate projections are block-diagonal (as in Griffin); we use 16 blocks so the
block axis shards exactly over the 16-way ``model`` mesh axis (Griffin uses
8 — noted as a deviation in DESIGN.md).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.kernels import ops

from .layers import DEFAULT_COMPUTE_DTYPE, cast

N_GATE_BLOCKS = 16


def rglru_block_init(key, d_model: int, r: RGLRUConfig) -> Dict:
    ks = jax.random.split(key, 6)
    W = r.width
    blk = W // N_GATE_BLOCKS
    s_in = 1.0 / math.sqrt(d_model)
    s_blk = 1.0 / math.sqrt(blk)
    # a parameterized so that a = sigmoid(a_param) in ~(0.9, 0.999)
    a_param = jnp.log(jnp.expm1(  # softplus^-1
        -jnp.log(jnp.linspace(0.9, 0.999, W))))
    return {
        "wx": jax.random.normal(ks[0], (d_model, W)) * s_in,
        "wy": jax.random.normal(ks[1], (d_model, W)) * s_in,  # gate branch
        "conv_w": jax.random.normal(ks[2], (r.conv_width, W)) * 0.2,
        "conv_b": jnp.zeros((W,)),
        "gate_a": jax.random.normal(ks[3], (N_GATE_BLOCKS, blk, blk)) * s_blk,
        "gate_a_b": jnp.zeros((W,)),
        "gate_i": jax.random.normal(ks[4], (N_GATE_BLOCKS, blk, blk)) * s_blk,
        "gate_i_b": jnp.zeros((W,)),
        "a_param": a_param,
        "out": jax.random.normal(ks[5], (W, d_model)) / math.sqrt(W),
    }


def _block_linear(w, b, x, dtype):
    """x: [..., W] -> [..., W] with block-diagonal w [NB, blk, blk]."""
    nb, blk, _ = w.shape
    xb = x.reshape(x.shape[:-1] + (nb, blk))
    y = jnp.einsum("...nk,nkj->...nj", xb, cast(w, dtype))
    return y.reshape(x.shape) + cast(b, dtype)


def _log_a(p) -> jnp.ndarray:
    # log a = -softplus(a_param)  (guarantees a in (0,1))
    return -jax.nn.softplus(p["a_param"].astype(jnp.float32))


def rglru_block_apply(
    p: Dict,
    x: jnp.ndarray,                     # [B, S, D]
    r: RGLRUConfig,
    *,
    backend: str = "xla",
    initial_state: Optional[Dict] = None,
    shard=None,
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> Tuple[jnp.ndarray, Dict]:
    B, S, _ = x.shape
    wcast = ((lambda w: shard.weight_for_batch(cast(w, dtype), B))
             if shard is not None else (lambda w: cast(w, dtype)))
    u = x @ wcast(p["wx"])                                  # [B,S,W]
    if shard is not None:
        # keep the lru-width axis model-sharded through the recurrence: the
        # block-diagonal gates and channelwise scan are embarrassingly
        # parallel over channels
        u = shard.channels(u)
    gate_branch = jax.nn.gelu(x @ wcast(p["wy"]))
    W = r.conv_width
    prev = (initial_state["conv"] if initial_state
            else jnp.zeros((B, W - 1, u.shape[-1]), u.dtype))
    up = jnp.concatenate([prev, u], axis=1)
    conv = sum(up[:, i:i + S, :] * wcast(p["conv_w"])[i][None, None]
               for i in range(W)) + wcast(p["conv_b"])
    if shard is not None:
        conv = shard.channels(conv)
    ra = jax.nn.sigmoid(_block_linear(wcast(p["gate_a"]), wcast(p["gate_a_b"]),
                                      conv, dtype).astype(jnp.float32))
    ri = jax.nn.sigmoid(_block_linear(wcast(p["gate_i"]), wcast(p["gate_i_b"]),
                                      conv, dtype).astype(jnp.float32))
    h0 = initial_state["h"] if initial_state else None
    h, hT = ops.rglru(conv, ra, ri, _log_a(p), initial_state=h0,
                      backend=backend)
    if shard is not None:
        h = shard.channels(h)
    y = (h * gate_branch) @ wcast(p["out"])
    return y, {"h": hT, "conv": up[:, -(W - 1):, :]}


def rglru_block_decode(
    p: Dict,
    x: jnp.ndarray,                     # [B, D]
    state: Dict,                        # {"h": [B,W], "conv": [B,W-1,C]}
    r: RGLRUConfig,
    *,
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> Tuple[jnp.ndarray, Dict]:
    u = (x @ cast(p["wx"], dtype))[:, None, :]              # [B,1,W]
    gate_branch = jax.nn.gelu(x @ cast(p["wy"], dtype))
    hist = jnp.concatenate([state["conv"], u], axis=1)      # [B,Wc,C]
    conv = jnp.einsum("bwc,wc->bc", hist, cast(p["conv_w"], dtype)) \
        + cast(p["conv_b"], dtype)
    ra = jax.nn.sigmoid(_block_linear(p["gate_a"], p["gate_a_b"], conv, dtype)
                        .astype(jnp.float32))
    ri = jax.nn.sigmoid(_block_linear(p["gate_i"], p["gate_i_b"], conv, dtype)
                        .astype(jnp.float32))
    h, new_h = ops.rglru_decode_step(conv, ra, ri, _log_a(p), state["h"])
    y = (h * gate_branch) @ cast(p["out"], dtype)
    return y, {"h": new_h, "conv": hist[:, 1:]}
