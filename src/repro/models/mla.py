"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

Training/prefill use the naive expansion (decompress the latent KV per
position, then standard attention).  Decode uses the absorbed formulation:
queries are projected into the latent space so the cache stays compressed
(``kv_lora_rank + qk_rope_dim`` per token instead of
``n_heads * (qk_nope + v_dim)``) — this is MLA's serving advantage and
dramatically raises decode "residency" in the scheduler's sense.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.kernels import ops

from .layers import DEFAULT_COMPUTE_DTYPE, apply_norm, apply_rope, cast, norm_init


def mla_init(key, d_model: int, n_heads: int, m: MLAConfig) -> Dict:
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d_model)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    p: Dict = {}
    if m.q_lora_rank:
        p["wdq"] = jax.random.normal(ks[0], (d_model, m.q_lora_rank)) * s
        p["q_norm"] = norm_init(m.q_lora_rank)
        p["wuq"] = jax.random.normal(
            ks[1], (m.q_lora_rank, n_heads, qk_dim)) / math.sqrt(m.q_lora_rank)
    else:
        p["wq"] = jax.random.normal(ks[1], (d_model, n_heads, qk_dim)) * s
    p["wdkv"] = jax.random.normal(ks[2], (d_model, m.kv_lora_rank)) * s
    p["kv_norm"] = norm_init(m.kv_lora_rank)
    p["wkr"] = jax.random.normal(ks[3], (d_model, m.qk_rope_dim)) * s
    p["wuk"] = jax.random.normal(
        ks[4], (m.kv_lora_rank, n_heads, m.qk_nope_dim)) / math.sqrt(m.kv_lora_rank)
    p["wuv"] = jax.random.normal(
        ks[5], (m.kv_lora_rank, n_heads, m.v_head_dim)) / math.sqrt(m.kv_lora_rank)
    p["wo"] = jax.random.normal(
        ks[6], (n_heads, m.v_head_dim, d_model)) / math.sqrt(n_heads * m.v_head_dim)
    return p


def _queries(p: Dict, x, m: MLAConfig, rope_theta, positions, dtype):
    if "wdq" in p:
        cq = apply_norm(p["q_norm"], x @ cast(p["wdq"], dtype))
        q = jnp.einsum("bsr,rhk->bshk", cq, cast(p["wuq"], dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"], dtype))
    q_nope = q[..., : m.qk_nope_dim]
    q_rope = apply_rope(q[..., m.qk_nope_dim:], positions, rope_theta)
    return q_nope, q_rope


def mla_apply(
    p: Dict,
    x: jnp.ndarray,                        # [B, S, D]
    m: MLAConfig,
    *,
    rope_theta: float,
    positions: Optional[jnp.ndarray] = None,
    backend: str = "xla",
    shard=None,
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence MLA (naive expansion).  Returns (out, cache)."""
    B, S, _ = x.shape
    pos = positions if positions is not None else jnp.arange(S)
    q_nope, q_rope = _queries(p, x, m, rope_theta, pos, dtype)

    c_kv = apply_norm(p["kv_norm"], x @ cast(p["wdkv"], dtype))     # [B,S,R]
    k_rope = apply_rope((x @ cast(p["wkr"], dtype))[:, :, None, :],
                        pos, rope_theta)                            # [B,S,1,r]
    if shard is not None:
        c_kv = shard.replicate_seq(c_kv)
        k_rope = shard.replicate_seq(k_rope)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, cast(p["wuk"], dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, cast(p["wuv"], dtype))

    H = q_nope.shape[2]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_rope.shape[:2] + (H, m.qk_rope_dim))],
        axis=-1)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    o = ops.flash_attention(q, k, v, mask_kind="causal", scale=scale,
                            backend=backend)
    y = jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"], dtype))
    return y, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}


def mla_decode(
    p: Dict,
    x: jnp.ndarray,                        # [B, D]
    cache: Dict,                           # {"c_kv": [B,S,R], "k_rope": [B,S,r]}
    length: jnp.ndarray,                   # [B]
    m: MLAConfig,
    *,
    rope_theta: float,
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> Tuple[jnp.ndarray, Dict]:
    """Absorbed-matmul MLA decode on the compressed cache."""
    B, D = x.shape
    pos = length[:, None]
    q_nope, q_rope = _queries(p, x[:, None, :], m, rope_theta, pos, dtype)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]          # [B,H,*]

    c_t = apply_norm(p["kv_norm"], x @ cast(p["wdkv"], dtype))       # [B,R]
    kr_t = apply_rope((x @ cast(p["wkr"], dtype))[:, None, None, :],
                      pos, rope_theta)[:, 0, 0]                       # [B,r]
    bidx = jnp.arange(B)
    c_cache = cache["c_kv"].at[bidx, length].set(c_t.astype(cache["c_kv"].dtype))
    r_cache = cache["k_rope"].at[bidx, length].set(kr_t.astype(cache["k_rope"].dtype))

    # absorb W_uk into the query: q_lat [B,H,R]
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope, cast(p["wuk"], dtype))
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat, c_cache) +
              jnp.einsum("bhk,bsk->bhs", q_rope, r_cache)).astype(jnp.float32)
    logits = logits * scale
    S = c_cache.shape[1]
    valid = jnp.arange(S)[None] < (length + 1)[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(dtype)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, c_cache)     # [B,H,R]
    o = jnp.einsum("bhr,rhk->bhk", ctx, cast(p["wuv"], dtype))
    y = jnp.einsum("bhk,hkd->bd", o, cast(p["wo"], dtype))
    return y, {"c_kv": c_cache, "k_rope": r_cache}
