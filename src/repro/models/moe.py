"""Mixture-of-Experts FFN: top-k routing + capacity-based sort dispatch
(ops.moe_apply), optional shared experts (DeepSeek-style), auxiliary
load-balance loss.

Expert weights are stacked ``[E, ...]`` so the expert axis shards over the
``model`` mesh axis (expert parallelism).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.kernels import ops

from .layers import DEFAULT_COMPUTE_DTYPE, apply_mlp, cast, mlp_init


def moe_init(key, d_model: int, d_ff: int, cfg: MoEConfig) -> Dict:
    ks = jax.random.split(key, 5)
    E = cfg.n_experts
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {
        "router": jax.random.normal(ks[0], (d_model, E)) * s_in,
        "gate_w": jax.random.normal(ks[1], (E, d_model, d_ff)) * s_in,
        "up_w": jax.random.normal(ks[2], (E, d_model, d_ff)) * s_in,
        "down_w": jax.random.normal(ks[3], (E, d_ff, d_model)) * s_out,
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(ks[4], d_model, cfg.n_shared * d_ff, "swiglu")
    return p


def _moe_shard_map(p: Dict, x, idx, gate, cfg: MoEConfig, shard, dtype):
    """Expert-parallel MoE via shard_map: local routing + capacity dispatch,
    one all_to_all to the expert shards over ``model``, dense expert
    matmuls (weights FSDP-gathered over ``data``), one all_to_all back.

    This is the GShard/Switch pattern: collective volume per layer is
    ~2 * k * activations + expert-weight gather, deterministic and
    overlappable — the global-view sort/scatter formulation measured
    ~90 GiB/device/layer of SPMD-inserted all-reduce on dbrx
    (EXPERIMENTS.md §Perf).
    """
    mesh = shard.mesh
    model_axis = shard.model_axis
    batch_axes = shard.batch_axes
    n_model = mesh.shape[model_axis]
    E = cfg.n_experts
    assert E % n_model == 0, (E, n_model)
    B, S, D = x.shape
    b_ax = batch_axes if (batch_axes and
                          B % shard._axis_size(batch_axes) == 0) else None
    s_ax = model_axis if S % n_model == 0 else None
    data_axis = "data" if "data" in mesh.axis_names else None
    w_data = (data_axis if (data_axis and
                            D % mesh.shape[data_axis] == 0) else None)

    def local(x_l, idx_l, gate_l, gw, uw, dw):
        B_l, S_l, _ = x_l.shape
        T = B_l * S_l
        cap = max(1, int(cfg.capacity_factor * cfg.top_k * T // E))
        buf, meta = ops.moe_dispatch(
            x_l.reshape(T, D), idx_l.reshape(T, -1), gate_l.reshape(T, -1),
            E, cap)
        # tokens -> expert shards (split E, concat capacity)
        buf = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                 concat_axis=1, tiled=True)
        if w_data is not None:
            gw = jax.lax.all_gather(gw, w_data, axis=1, tiled=True)
            uw = jax.lax.all_gather(uw, w_data, axis=1, tiled=True)
            dw = jax.lax.all_gather(dw, w_data, axis=2, tiled=True)
        h = jnp.einsum("ecd,edf->ecf", buf, cast(gw, dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, cast(uw, dtype))
        h = jax.nn.silu(h.astype(jnp.float32)).astype(dtype) * u
        y = jnp.einsum("ecf,efd->ecd", h, cast(dw, dtype))
        # expert outputs -> back to token shards
        y = jax.lax.all_to_all(y, model_axis, split_axis=1, concat_axis=0,
                               tiled=True)
        out = ops.moe_combine(y, meta, T)
        return out.reshape(B_l, S_l, D)

    from jax.experimental.shard_map import shard_map
    act_spec = P(b_ax, s_ax, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(act_spec, act_spec, act_spec,
                  P(model_axis, w_data, None),
                  P(model_axis, w_data, None),
                  P(model_axis, None, w_data)),
        out_specs=act_spec,
        check_rep=False)
    return fn(x, idx, gate, p["gate_w"], p["up_w"], p["down_w"])


def moe_apply(
    p: Dict,
    x: jnp.ndarray,              # [B, S, D]
    cfg: MoEConfig,
    *,
    shard=None,
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out [B,S,D], aux load-balance loss scalar).

    Routing/dispatch is vmapped over the batch row: flattening B*S would
    merge the batch-sharded and sequence-sharded axes and force SPMD to
    replicate the activations (measured +100s/dev of all-gather and tens of
    GiB on dbrx/deepseek — see EXPERIMENTS.md §Perf).  Per-row dispatch
    keeps the batch axis data-parallel; capacity is per (row, expert).
    """
    B, S, D = x.shape
    logits = (x @ cast(p["router"], dtype)).astype(jnp.float32)   # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    if shard is not None and getattr(shard, "mesh", None) is not None \
            and S > 1:
        y = _moe_shard_map(p, x, idx.astype(jnp.int32),
                           gate.astype(dtype), cfg, shard, dtype)
    else:
        capacity = max(1, int(cfg.capacity_factor * cfg.top_k * S
                              // cfg.n_experts))
        y = jax.vmap(
            lambda xr, ir, gr: ops.moe_apply(
                xr, p["gate_w"], p["up_w"], p["down_w"], ir, gr, capacity,
                dtype=dtype)
        )(x, idx.astype(jnp.int32), gate.astype(dtype))
    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, "swiglu", dtype)

    # Switch-style aux loss: E * sum_e f_e * P_e
    E = cfg.n_experts
    me = probs.reshape(-1, E).mean(axis=0)                  # mean prob/expert
    one_hot = jax.nn.one_hot(idx[..., 0].reshape(-1), E, dtype=jnp.float32)
    ce = one_hot.mean(axis=0)
    aux = E * jnp.sum(me * ce)
    return y, aux
