"""Tests for the static determinism & cache-integrity analyzer.

Three layers (DESIGN.md Section 9):

* per-rule fixtures — each determinism lint fires exactly once on a
  known-bad snippet and stays silent on the blessed idioms;
* mutation tests — a scratch copy of ``repro/core`` is broken in the
  precise ways the analyzer exists to catch (fingerprint module dropped,
  shadow module smuggled in, hint flag contradicting the code, unseeded
  RNG added) and each mutation must turn the CLI red;
* bridge assertions — the checked-in ``_FINGERPRINT_SOURCES`` table
  equals the import-graph closure the analyzer computes, so the cache
  key provably covers every result-determining module.
"""

import shutil
from pathlib import Path

import pytest

from repro.analysis import (
    Baseline,
    Finding,
    apply_baseline,
    check_fingerprint_coverage,
    check_machine_signatures,
    check_policy_hints,
    check_protocols,
    expected_fingerprint_sources,
    load_fingerprint_table,
    main,
    scan_determinism,
    scan_source,
)
from repro.core.sweep import fingerprint_sources

CORE_DIR = Path(__file__).resolve().parents[1] / "src" / "repro" / "core"


# ------------------------------------------------------- per-rule fixtures
BAD_SNIPPETS = {
    "unseeded-random": "import random\n\ndef f():\n    return random.random()\n",
    "unseeded-random-numpy": (
        "import numpy as np\n\ndef f():\n    return np.random.rand()\n"),
    "set-iteration": (
        "def f():\n    out = []\n    for x in {1, 2, 3}:\n"
        "        out.append(x)\n    return out\n"),
    "set-iteration-keyed-sort": (
        "def f(xs):\n    return sorted(set(xs), key=lambda v: v % 3)\n"),
    "dict-popitem": "def f(d):\n    return d.popitem()\n",
    "id-in-key": "def f(xs):\n    return sorted(xs, key=lambda v: id(v))\n",
    "wallclock": "import time\n\ndef f():\n    return time.time()\n",
    "wallclock-datetime": (
        "import datetime\n\ndef f():\n    return datetime.datetime.now()\n"),
    "uuid": "import uuid\n\ndef f():\n    return str(uuid.uuid4())\n",
    "nan-json": "import json\n\ndef f(x):\n    return json.dumps(x)\n",
}
EXPECTED_RULE = {
    "unseeded-random-numpy": "unseeded-random",
    "set-iteration-keyed-sort": "set-iteration",
    "wallclock-datetime": "wallclock",
}


@pytest.mark.parametrize("name", sorted(BAD_SNIPPETS))
def test_each_determinism_lint_fires_exactly_once(name):
    findings = scan_source(BAD_SNIPPETS[name], module=name)
    rule = EXPECTED_RULE.get(name, name)
    assert [f.rule for f in findings] == [rule], (
        f"{name}: expected exactly one {rule!r} finding, got "
        f"{[f.format() for f in findings]}")
    (finding,) = findings
    assert finding.context == "f"
    assert finding.module == name


GOOD_SNIPPETS = {
    # Key-less sorted() over a set is a total order on distinct elements:
    # ties cannot fall back to the salted-hash iteration order.
    "total-sort": "def f(xs):\n    return sorted(set(xs))\n",
    # Seeded generators are the blessed randomness source.
    "seeded-rng": (
        "import random\n\ndef f(seed):\n"
        "    return random.Random(seed).random()\n"),
    "numpy-generator": (
        "import numpy as np\n\ndef f(seed):\n"
        "    return np.random.default_rng(seed).random()\n"),
    # Explicit allow_nan decision (either way) satisfies the JSON rule.
    "json-allow-nan": (
        "import json\n\ndef f(x):\n"
        "    return json.dumps(x, allow_nan=False)\n"),
    # Membership tests over sets are order-insensitive.
    "set-membership": "def f(x, xs):\n    return x in set(xs)\n",
}


@pytest.mark.parametrize("name", sorted(GOOD_SNIPPETS))
def test_blessed_idioms_stay_clean(name):
    assert scan_source(GOOD_SNIPPETS[name], module=name) == []


# --------------------------------------------------------- baseline logic
def _finding(rule="wallclock", module="m", context="c", line=1):
    return Finding("determinism", rule, module, context, line, "msg")


def test_baseline_suppresses_up_to_count_and_blocks_excess():
    base = Baseline(entries={"wallclock::m::c": (1, "justified")})
    report = apply_baseline([_finding(line=3), _finding(line=9)], base)
    assert len(report.suppressed) == 1
    assert len(report.blocking) == 1
    assert not report.ok


def test_baseline_with_empty_reason_blocks():
    base = Baseline(entries={"wallclock::m::c": (1, "  ")})
    report = apply_baseline([_finding()], base)
    assert report.empty_reasons and not report.ok


def test_stale_baseline_entry_is_reported_not_fatal():
    base = Baseline(entries={"wallclock::gone::x": (1, "was fixed")})
    report = apply_baseline([], base)
    assert report.stale_keys == ["wallclock::gone::x"]
    assert report.ok


def test_non_baselinable_pass_cannot_be_suppressed():
    fp = Finding("fingerprint", "under-coverage", "sweep", "des", 1, "msg")
    base = Baseline(entries={fp.key: (1, "nice try")})
    report = apply_baseline([fp], base)
    assert report.blocking == [fp]


# ------------------------------------------------------------ clean tree
def test_clean_tree_cli_exits_zero(capsys):
    assert main([]) == 0
    out = capsys.readouterr().out
    assert "0 blocking finding(s)" in out


def test_clean_tree_has_no_protocol_findings():
    assert check_protocols(CORE_DIR) == []


def test_clean_tree_has_no_fingerprint_findings():
    assert check_fingerprint_coverage(CORE_DIR) == []


# ------------------------------------------------------- bridge assertions
def test_fingerprint_table_equals_import_closure():
    """The satellite bridge: ``_FINGERPRINT_SOURCES`` == computed closure.

    If this fails, either a result-determining import was added (widen the
    table — the cache must invalidate) or one was removed (narrow it, or
    leave it as a safe over-approximation and update ENTRY_POINTS).
    """
    runtime = fingerprint_sources()
    expected = expected_fingerprint_sources(CORE_DIR)
    assert set(runtime) == set(expected)
    for machine in sorted(expected):
        assert set(runtime[machine]) == expected[machine], (
            f"{machine}: _FINGERPRINT_SOURCES drifted from the import "
            f"closure")


def test_static_table_parse_matches_runtime_table():
    static = load_fingerprint_table(CORE_DIR)
    assert static == fingerprint_sources()


def test_fingerprint_tuples_are_sorted_and_unique():
    for machine, mods in fingerprint_sources().items():
        assert sorted(set(mods)) == sorted(mods), machine


def test_distrib_joins_every_fingerprint_closure():
    """ISSUE 9 satellite: the distributed cell runner (distrib.py) holds
    the record schema and the cell execution path — every machine's
    fingerprint tuple must carry it, so an edit re-keys cached records on
    dispatcher and workers alike (the handshake then refuses mixed farms).
    """
    for machine, mods in fingerprint_sources().items():
        assert "distrib" in mods, machine


# --------------------------------------------------------- mutation tests
@pytest.fixture()
def scratch_core(tmp_path):
    core = tmp_path / "core"
    core.mkdir()
    for src in CORE_DIR.glob("*.py"):
        shutil.copy(src, core / src.name)
    return core


def _mutate(core, filename, old, new):
    path = core / filename
    text = path.read_text()
    assert old in text, f"mutation anchor missing in {filename}: {old!r}"
    path.write_text(text.replace(old, new, 1))


def test_mutation_dropped_fingerprint_module_fails(scratch_core):
    _mutate(scratch_core, "sweep.py", '"metrics"', '"metrics_gone"')
    findings = check_fingerprint_coverage(scratch_core)
    assert any(f.rule in ("under-coverage", "stale-entry") for f in findings)
    assert main(["--core-dir", str(scratch_core)]) == 1


def test_mutation_dropped_engine_module_fails(scratch_core):
    """ISSUE 7 satellite: the compiled-engine sources are fingerprinted for
    the DES machines — dropping one from the table must turn the CLI red
    (under-coverage: engine edits would serve stale cached schedules)."""
    _mutate(scratch_core, "sweep.py", '"fastsim_c"', '"fastsim_c_gone"')
    findings = check_fingerprint_coverage(scratch_core)
    assert any(f.rule == "under-coverage" and f.module == "fastsim_c"
               for f in findings)
    assert main(["--core-dir", str(scratch_core)]) == 1


def test_mutation_dropped_distrib_fails(scratch_core):
    """ISSUE 9 satellite: dropping distrib.py from a machine's fingerprint
    tuple must turn the CLI red — an under-covered cell runner would let
    record-schema edits serve stale cached records across the farm."""
    _mutate(scratch_core, "sweep.py",
            '"des": ("distrib"', '"des": ("distrib_gone"')
    findings = check_fingerprint_coverage(scratch_core)
    assert any(f.rule == "under-coverage" and f.module == "distrib"
               for f in findings)
    assert main(["--core-dir", str(scratch_core)]) == 1


def test_mutation_shadow_module_fails(scratch_core):
    (scratch_core / "shadow_helper.py").write_text(
        "from . import workload\n\n"
        "def tweak(spec):\n    return workload.scaled_spec(spec, 2.0)\n")
    findings = check_fingerprint_coverage(scratch_core)
    assert any(f.rule == "unclassified-module" for f in findings)
    assert main(["--core-dir", str(scratch_core)]) == 1


def test_mutation_undeclared_predictor_use_fails(scratch_core):
    _mutate(scratch_core, "policies.py",
            "class SRTF(Policy):\n",
            "class SRTF(Policy):\n    uses_predictor = False\n")
    findings = check_policy_hints(scratch_core)
    assert any(f.rule == "undeclared-predictor-use" for f in findings)
    assert main(["--core-dir", str(scratch_core)]) == 1


def test_mutation_unseeded_rng_fails(scratch_core):
    path = scratch_core / "simulator.py"
    path.write_text(path.read_text() +
                    "\n\ndef _jitter():\n"
                    "    import random\n"
                    "    return random.random()\n")
    findings = scan_determinism(scratch_core)
    assert any(f.rule == "unseeded-random" and f.module == "simulator"
               for f in findings)
    assert main(["--core-dir", str(scratch_core)]) == 1


def test_mutation_protocol_signature_drift_fails(scratch_core):
    _mutate(scratch_core, "executor.py",
            "def residency(self, key: str, sm: int)",
            "def residency(self, key: str, lane: int)")
    findings = check_machine_signatures(scratch_core)
    assert any(f.rule == "signature-drift" for f in findings)
    assert main(["--core-dir", str(scratch_core)]) == 1


def test_clean_scratch_copy_passes(scratch_core):
    """The scratch copy itself is clean — mutations, not copying, fail."""
    assert main(["--core-dir", str(scratch_core)]) == 0
