"""Tests for the async multi-tenant SchedulerService and the dynamic
(late-arrival) LaneExecutor surface it builds on.

Jobs here are cheap sleep/no-op blocks — no JAX — so the suite exercises
submission, SRTF ordering, late arrival, cancellation and per-tenant
metrics quickly and deterministically enough to assert on.
"""

import asyncio
import time

import pytest

from repro.core.executor import ExecutorJob, LaneExecutor
from repro.core.policies import make_policy
from repro.core.scheduler_service import (
    JobCancelled,
    JobHandle,
    SchedulerService,
)


def sleep_job(name, blocks, per_block=0.002, tenant=None):
    def mk(residency):
        def block():
            time.sleep(per_block)
        return block
    return ExecutorJob(name=name, num_blocks=blocks, max_residency=4,
                       make_block_fn=mk, tenant=tenant)


# ------------------------------------------------------- dynamic executor
def test_add_job_while_running():
    ex = LaneExecutor([sleep_job("first", 4)], make_policy("fifo"),
                      n_lanes=2)
    # drain a few events, then inject a late job mid-run
    assert ex.step()
    key = ex.add_job(sleep_job("late", 2))
    assert key == "late#1"
    assert ex.runs[key].arrival_time >= 0.0
    results = ex.run()
    assert set(results) == {"first#0", "late#1"}
    assert all(not r.cancelled for r in results.values())


def test_executor_cancel_at_boundary():
    ex = LaneExecutor([sleep_job("victim", 50), sleep_job("other", 3)],
                      make_policy("fifo"), n_lanes=2)
    for _ in range(6):
        ex.step()
    done_at_cancel = ex.runs["victim#0"].done
    assert ex.cancel("victim#0")
    assert not ex.cancel("victim#0")      # already finished
    results = ex.run()
    assert results["victim#0"].cancelled
    # no further blocks issued after the boundary (in-flight ones may land)
    assert ex.runs["victim#0"].done <= done_at_cancel + ex.n_lanes
    assert not results["other#1"].cancelled


def test_cancel_before_arrival_never_launches():
    # A job cancelled before its queued arrival event fires must not be
    # registered with the predictor (no state leak, no spurious reslice of
    # co-runners) nor scheduled.
    ex = LaneExecutor([sleep_job("live", 4)], make_policy("fifo"), n_lanes=2)
    doomed = ex.add_job(sleep_job("doomed", 8))
    assert ex.cancel(doomed)
    results = ex.run()
    assert results[doomed].cancelled and results[doomed].blocks == 0
    assert not ex.predictor.has_kernel(doomed)
    assert ex.runs[doomed].issued == 0
    assert not results["live#0"].cancelled


def test_duplicate_job_key_rejected():
    ex = LaneExecutor([], make_policy("fifo"), n_lanes=2)
    ex.add_job(sleep_job("a", 1), key="a#0")
    with pytest.raises(ValueError):
        ex.add_job(sleep_job("a", 1), key="a#0")


# ------------------------------------------------------------- the service
def test_async_staggered_submissions_complete_under_srtf():
    async def scenario():
        service = SchedulerService(n_lanes=4, policy="srtf")
        h_long = service.submit(sleep_job("long", 12, per_block=0.005),
                                tenant="team-a")
        await service.wait_until_busy()   # machine is provably running
        h_short = service.submit(sleep_job("short", 3), tenant="team-b")
        assert isinstance(h_long, JobHandle) and isinstance(h_short, JobHandle)
        r_long = await h_long.result()
        r_short = await h_short.result()
        service.close()
        return service, r_long, r_short

    service, r_long, r_short = asyncio.run(scenario())
    assert r_long.blocks == 12 and r_short.blocks == 3
    assert r_long.key == "long#0" and r_short.key == "short#1"
    # the short job arrived late: its arrival is after the machine started
    assert r_short.arrival > 0.0
    report = service.tenant_report()
    assert set(report) == {"team-a", "team-b"}
    for tenant in ("team-a", "team-b"):
        m = report[tenant]["metrics"]
        assert m is not None and m["stp"] > 0 and m["antt"] > 0


def test_solo_hint_vs_structural_estimate():
    async def scenario():
        service = SchedulerService(n_lanes=2, policy="fifo")
        h1 = service.submit(sleep_job("hinted", 4), tenant="hinted",
                            solo_runtime=0.004)
        h2 = service.submit(sleep_job("estimated", 4), tenant="estimated")
        await h1.result()
        await h2.result()
        service.close()
        return service

    service = asyncio.run(scenario())
    report = service.tenant_report()
    assert not report["hinted"]["solo_estimated"]
    assert report["estimated"]["solo_estimated"]
    assert report["estimated"]["metrics"]["antt"] > 0


def test_cancellation_raises_and_is_counted():
    async def scenario():
        service = SchedulerService(n_lanes=2, policy="fifo")
        h_doomed = service.submit(sleep_job("doomed", 500), tenant="t")
        h_ok = service.submit(sleep_job("ok", 2), tenant="t")
        await asyncio.sleep(0.02)
        h_doomed.cancel()
        ok = await h_ok.result()
        with pytest.raises(JobCancelled):
            await h_doomed.result()
        service.close()
        return service, ok

    service, ok = asyncio.run(scenario())
    assert not ok.cancelled
    report = service.tenant_report()
    assert report["t"]["cancelled"] == 1
    assert report["t"]["jobs"] == 1


def test_close_rejects_new_submissions_and_drain_collects():
    async def scenario():
        service = SchedulerService(n_lanes=2, policy="fifo")
        service.submit(sleep_job("a", 2), tenant="x")
        service.submit(sleep_job("b", 2), tenant="x")
        results = await service.drain()
        await service.aclose()
        with pytest.raises(RuntimeError):
            service.submit(sleep_job("c", 1))
        return results

    results = asyncio.run(scenario())
    assert {r.key for r in results} == {"a#0", "b#1"}


def test_close_with_cancel_pending_abandons_work():
    service = SchedulerService(n_lanes=2, policy="fifo")
    handle = service.submit(sleep_job("endless", 100000), tenant="t")
    time.sleep(0.02)
    service.close(cancel_pending=True)
    with pytest.raises(JobCancelled):
        handle.result_blocking(timeout=5)


def test_tenant_defaults_to_job_tenant_then_name():
    async def scenario():
        service = SchedulerService(n_lanes=2, policy="fifo")
        h1 = service.submit(sleep_job("named", 1, tenant="from-job"))
        h2 = service.submit(sleep_job("anon", 1))
        await h1.result()
        await h2.result()
        service.close()
        return service

    service = asyncio.run(scenario())
    assert set(service.tenant_report()) == {"from-job", "anon"}
