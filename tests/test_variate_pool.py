"""Variate-pool exhaustion coverage (DESIGN.md Section 13).

A lowered closed-loop source stages a bounded window of pre-drawn future
arrivals — the *variate pool* — so the engine can inject completions and
arrivals without crossing the Python boundary.  ``FastSimulator._stage_cap``
bounds the window per rebuild; when the engine drains it mid-run it exits
with code 7, the driver restages the next window and resumes.

The contract under test: an *undersized* pool must regrow and resume
(observed as exit-7 segments in ``segment_exits``) and the result must
stay byte-identical both to the reference loop and to a single-pool run
whose cap covers the whole offered process — across all three engine
backends.  Pool size is a performance knob, never a schedule input.
"""

import dataclasses

import pytest

from repro.core import fastsim_twin as tw
from repro.core.fastsim import FastSimulator, _native_advance
from repro.core.policies import make_policy
from repro.core.scenarios import MGkClosed, ThinkTime
from repro.core.simulator import Simulator

from test_fastpath import N_SM, ORACLE, SEED, TINY

BACKENDS = [
    pytest.param("interp", id="interp"),
    pytest.param("native", id="native",
                 marks=pytest.mark.skipif(
                     _native_advance() is None,
                     reason="no C toolchain / REPRO_NO_NATIVE=1")),
    pytest.param("numba", id="numba",
                 marks=pytest.mark.skipif(
                     not tw.NUMBA_AVAILABLE,
                     reason="numba not importable")),
]

#: (scenario factory, undersized cap) per lowered source mode.  Caps are
#: chosen well below the offered totals (10 mgk arrivals, 2x3 think-time
#: rounds) so every run needs several restage windows.
SCENARIOS = {
    "mgk": (lambda: MGkClosed(seed=SEED, names=sorted(TINY), specs=TINY,
                              n_total=10, mean_interarrival=1_500.0,
                              population=3), 2),
    "think": (lambda: ThinkTime(seed=SEED, names=sorted(TINY), specs=TINY,
                                n_tenants=2, mean_think=2_000.0,
                                n_rounds=3), 2),
}


def _run(cls, scn, policy, *, backend=None, stage_cap=None):
    kwargs = {} if cls is Simulator else {"backend": backend}
    sim = cls([], make_policy(policy), n_sm=N_SM, seed=SEED,
              record_trace=True, record_predictions=True,
              record_decisions=True, oracle_runtimes=dict(ORACLE),
              **kwargs)
    if stage_cap is not None:
        sim._stage_cap = stage_cap
    sim.attach_arrival_source(scn.make_process(scn.process_names()[0]))
    return sim, sim.run()


def _assert_identical(fast, ref):
    sim_f, res_f = fast
    sim_r, res_r = ref
    assert res_f.turnaround == res_r.turnaround
    assert res_f.finish == res_r.finish
    assert res_f.arrival == res_r.arrival
    assert res_f.unfinished == res_r.unfinished
    assert res_f.end_time == res_r.end_time
    assert res_f.makespan == res_r.makespan
    assert res_f.utilization == res_r.utilization
    assert sim_f.busy_time == sim_r.busy_time
    assert ([dataclasses.astuple(r) for r in sim_f.trace]
            == [dataclasses.astuple(r) for r in sim_r.trace])
    assert ([dataclasses.astuple(p) for p in sim_f.predictions]
            == [dataclasses.astuple(p) for p in sim_r.predictions])
    assert sim_f.decisions == sim_r.decisions


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", sorted(SCENARIOS))
@pytest.mark.parametrize("policy", ("fifo", "srtf-adaptive"))
def test_undersized_pool_regrows_and_matches_reference(
        mode, policy, backend):
    make_scn, cap = SCENARIOS[mode]
    small = _run(FastSimulator, make_scn(), policy,
                 backend=backend, stage_cap=cap)
    # The undersized pool really was exhausted and regrown mid-run...
    assert small[0].segment_exits.get(7, 0) >= 1
    # ...yet the observable surface matches the reference loop exactly.
    _assert_identical(small, _run(Simulator, make_scn(), policy))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", sorted(SCENARIOS))
def test_undersized_pool_matches_single_pool_run(mode, backend):
    make_scn, cap = SCENARIOS[mode]
    small = _run(FastSimulator, make_scn(), "srtf",
                 backend=backend, stage_cap=cap)
    whole = _run(FastSimulator, make_scn(), "srtf", backend=backend)
    # The default cap stages the whole offered process in one window —
    # no pool-exhaustion exits — so this pins that the restage windows
    # only split the pool, never reorder or redraw it.
    assert whole[0].segment_exits.get(7, 0) == 0
    assert small[0].segment_exits.get(7, 0) >= 1
    _assert_identical(small, whole)
